//! Golden `RunReport` snapshot (ROADMAP open item): per-policy outcome
//! constants at a fixed seed/config, pinned across commits.
//!
//! `tests/policy_parity.rs` compares the current build against itself, so
//! a change that perturbs both sides identically (e.g. an extra RNG draw
//! in the executor) passes parity silently.  This test closes that gap by
//! asserting against *recorded* constants in `tests/golden_report.txt`.
//!
//! Workflow:
//!   * regenerate (after an intentional behavior change):
//!     `TRIDENT_BLESS=1 cargo test --test golden_report` — inspect the
//!     diff of `tests/golden_report.txt` and commit it;
//!   * fresh checkout before the first bless: the fixture is absent, the
//!     test prints the bless instructions and passes (it cannot invent
//!     the constants; CI blesses then re-asserts to pin cross-process
//!     determinism until a blessed fixture is committed).
//!
//! The config mirrors `policy_parity::mk_det`: the mini 2-node instance
//! reaches `Status::Optimal` within the generous MILP budget, so every
//! run of this grid is deterministic.

use std::fmt::Write as _;

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::harness;
use trident::sim::ItemAttrs;
use trident::workload::pdf;

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_report.txt");

fn mk(variant: &Variant, seed: u64) -> Coordinator {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 10_000;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    Coordinator::new(
        pdf::pipeline(),
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0),
        Box::new(pdf::trace(50_000)),
        cfg,
        variant.clone(),
        ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 },
        seed,
    )
}

fn all_policies() -> Vec<(&'static str, Variant)> {
    vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("RayData", Variant::baseline(Policy::RayData)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("ContTune", Variant::baseline(Policy::ContTune)),
        ("SCOOT", harness::scoot_variant(
            &pdf::pipeline(),
            ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 },
        )),
        ("Trident", Variant::trident()),
    ]
}

#[test]
fn run_reports_match_golden_constants() {
    let mut lines = String::new();
    for (name, variant) in all_policies() {
        let r = mk(&variant, 5).run(300.0);
        writeln!(
            lines,
            "{name} throughput_bits={:016x} items={} ooms={} transitions={} milp_solves={} # {:.6} items/s",
            r.throughput.to_bits(),
            r.items_processed,
            r.oom_events,
            r.config_transitions,
            r.milp_ms.len(),
            r.throughput,
        )
        .unwrap();
    }
    if std::env::var("TRIDENT_BLESS").map(|v| v == "1").unwrap_or(false) {
        std::fs::write(GOLDEN, &lines).expect("write golden fixture");
        eprintln!("blessed {GOLDEN}:\n{lines}");
        return;
    }
    match std::fs::read_to_string(GOLDEN) {
        Ok(want) => assert_eq!(
            lines, want,
            "RunReport drifted from the golden snapshot; if the change is \
             intentional, re-bless with TRIDENT_BLESS=1 cargo test --test \
             golden_report and commit the fixture diff"
        ),
        Err(_) => eprintln!(
            "golden fixture missing ({GOLDEN}); record it with \
             TRIDENT_BLESS=1 cargo test --test golden_report and commit it.\n\
             current constants:\n{lines}"
        ),
    }
}
