//! Fork/join DAG integration: every scheduling policy drives the speech
//! pipeline (decode -> {ASR, caption} -> align-join -> filter) through the
//! full closed loop to completion, with conserved item counts across the
//! fork/join and no deadlock under bounded queues + join state.

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::harness;
use trident::workload::speech;

fn mk(variant: &Variant, seed: u64, clips: u64) -> Coordinator {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 800;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    Coordinator::new(
        speech::pipeline(),
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0),
        Box::new(speech::trace(clips)),
        cfg,
        variant.clone(),
        speech::src_attrs(),
        seed,
    )
}

fn all_policies() -> Vec<(&'static str, Variant)> {
    let scoot = harness::scoot_variant(&speech::pipeline(), speech::src_attrs());
    vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::baseline(Policy::RayData)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("ContTune", Variant::baseline(Policy::ContTune)),
        ("SCOOT", scoot),
        ("Trident", Variant::trident()),
    ]
}

/// Items out of the join == items into the fork, per policy, at drain:
/// the fork edge counts match (replication), the branch edges deliver
/// everything (branches are record-to-record), and the join processed one
/// merged record per forked segment.
#[test]
fn all_policies_complete_the_speech_dag_with_conservation() {
    for (name, variant) in all_policies() {
        let mut c = mk(&variant, 5, 250);
        let r = c.run_to_completion(4.0 * 3600.0);
        assert!(
            c.sim.drained(),
            "{name}: speech DAG must drain (no fork/join deadlock), \
             {} emitted",
            c.sim.items_emitted()
        );
        assert!(r.throughput > 0.0, "{name} must make progress");
        // Edge ids follow speech::pipeline(): 0 demux->decode,
        // 1 decode->asr, 2 decode->caption, 3 asr->join, 4 caption->join,
        // 5 join->filter.
        let e: Vec<u64> = (0..c.sim.spec.n_edges()).map(|i| c.sim.edge_emitted(i)).collect();
        assert_eq!(e[1], e[2], "{name}: fork replicates onto both branches");
        assert_eq!(e[1], e[3], "{name}: ASR branch conserves records");
        assert_eq!(e[2], e[4], "{name}: caption branch conserves records");
        assert_eq!(
            c.sim.processed_total(4),
            e[1],
            "{name}: join merges exactly one record per forked segment"
        );
        assert_eq!(
            e[5], e[1],
            "{name}: items out of the join == items into the fork"
        );
        // All join state consumed by the end.
        for mb in c.sim.join_state_mb() {
            assert!(mb.abs() < 1e-6, "{name}: leaked join memory: {mb} MB");
        }
    }
}

/// The MILP must route flow over all six DAG edges (one matrix per edge)
/// and both accelerator branches must actually get devices.
#[test]
fn trident_plans_cover_dag_edges_and_both_branches() {
    let mut c = mk(&Variant::trident(), 7, 300);
    let r = c.run(600.0);
    assert!(!r.milp_ms.is_empty(), "Trident re-solves the MILP");
    assert!(r.throughput > 0.0);
    assert_eq!(
        c.sim.n_routes_set(),
        c.sim.spec.n_edges(),
        "placement-aware plan must carry a routing matrix for every DAG edge"
    );
    let asr = c.sim.instances_of(2);
    let cap = c.sim.instances_of(3);
    assert!(!asr.is_empty(), "ASR branch placed");
    assert!(!cap.is_empty(), "caption branch placed");
}
