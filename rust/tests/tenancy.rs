//! Multi-tenant scheduling integration (the tentpole refactor's contract):
//!
//! * a single-tenant `Tenancy` is **bit-identical** to the classic
//!   single-pipeline constructor for every policy (the refactor is pure
//!   structure — `tests/policy_parity.rs` continues to pin the classic
//!   path against the harness);
//! * a two-tenant `pdf+speech` run shares one fixed-resource cluster with
//!   per-tenant conservation (each tenant's sink output matches what it
//!   admitted), drains both tenants, and reports per-tenant + aggregate
//!   throughput in `RunReport`.

use trident::config::{ClusterSpec, Tenancy, TenantSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, RunReport, Variant};
use trident::harness;
use trident::sim::ItemAttrs;
use trident::workload::{pdf, speech, Trace};

fn mini_cfg() -> TridentConfig {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    // Generous budget: the mini 2-node MILP reaches Optimal, so Trident
    // plans are deterministic under parallel test execution.
    cfg.milp_time_budget_ms = 10_000;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    cfg
}

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0)
}

fn pdf_src() -> ItemAttrs {
    ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 }
}

/// The classic single-pipeline constructor (pre-tenancy API).
fn classic(variant: &Variant, seed: u64) -> Coordinator {
    Coordinator::new(
        pdf::pipeline(),
        cluster(),
        Box::new(pdf::trace(50_000)),
        mini_cfg(),
        variant.clone(),
        pdf_src(),
        seed,
    )
}

/// The same deployment expressed as a one-tenant tenancy.
fn singleton(variant: &Variant, seed: u64) -> Coordinator {
    Coordinator::new_tenancy(
        Tenancy::single(pdf::pipeline()),
        cluster(),
        vec![Box::new(pdf::trace(50_000)) as Box<dyn Trace>],
        mini_cfg(),
        variant.clone(),
        vec![pdf_src()],
        seed,
    )
    .expect("single-tenant tenancy is valid")
}

fn two_tenant(variant: &Variant, seed: u64) -> Coordinator {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    Coordinator::new_tenancy(
        tenancy,
        cluster(),
        vec![
            Box::new(pdf::trace(300)) as Box<dyn Trace>,
            Box::new(speech::trace(120)) as Box<dyn Trace>,
        ],
        mini_cfg(),
        variant.clone(),
        vec![pdf_src(), speech::src_attrs()],
        seed,
    )
    .expect("two-tenant tenancy is valid")
}

fn all_policies() -> Vec<(&'static str, Variant)> {
    vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::baseline(Policy::RayData)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("ContTune", Variant::baseline(Policy::ContTune)),
        ("SCOOT", harness::scoot_variant(&pdf::pipeline(), pdf_src())),
        ("Trident", Variant::trident()),
    ]
}

/// Outcome key compared at the bit level (as in `policy_parity`).
fn key(r: &RunReport) -> (u64, u64, u32, u64, usize) {
    (
        r.throughput.to_bits(),
        r.items_processed,
        r.oom_events,
        r.config_transitions,
        r.milp_ms.len(),
    )
}

/// Acceptance criterion 1: `Tenancy::single` is bit-identical to the
/// classic build for all six policies.
#[test]
fn single_tenant_tenancy_is_bit_identical_for_all_policies() {
    for (name, variant) in all_policies() {
        let a = classic(&variant, 5).run(300.0);
        let b = singleton(&variant, 5).run(300.0);
        assert_eq!(key(&a), key(&b), "policy {name} diverged under Tenancy::single");
        assert!(a.throughput > 0.0, "{name} must make progress");
        // The singleton per-tenant section mirrors the aggregate exactly.
        assert_eq!(b.tenants.len(), 1);
        assert_eq!(b.tenants[0].id, "pdf");
        assert_eq!(
            b.tenants[0].throughput.to_bits(),
            b.throughput.to_bits(),
            "{name}: single-tenant aggregate == tenant throughput"
        );
    }
}

/// Acceptance criterion 2: a two-tenant pdf+speech run drains both
/// tenants on the shared cluster with per-tenant conservation and
/// per-tenant + aggregate reporting.
#[test]
fn two_tenant_run_conserves_per_tenant_and_reports() {
    for (name, variant) in [
        ("Static", Variant::baseline(Policy::Static)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("Trident", Variant::trident()),
    ] {
        let mut c = two_tenant(&variant, 5);
        let r = c.run_to_completion(4.0 * 3600.0);
        assert!(c.sim.drained(), "{name}: both tenants must drain");
        assert!(c.sim.tenant_drained(0) && c.sim.tenant_drained(1), "{name}");

        // Per-tenant admission recorded.
        assert_eq!(c.sim.items_emitted_t(0), 300, "{name}: pdf trace fully admitted");
        assert_eq!(c.sim.items_emitted_t(1), 120, "{name}: speech trace fully admitted");
        assert_eq!(
            c.sim.items_emitted(),
            (0..2).map(|t| c.sim.items_emitted_t(t)).sum::<u64>(),
            "{name}"
        );

        // Speech-tenant conservation is exact across its fork/join: edge
        // ids are offset by the pdf tenant's edge count in the merged DAG.
        let n_pdf_ops = pdf::pipeline().n_ops();
        let off = pdf::pipeline().n_edges();
        let e: Vec<u64> = (0..c.sim.spec.n_edges()).map(|i| c.sim.edge_emitted(i)).collect();
        assert_eq!(e[off + 1], e[off + 2], "{name}: fork replicates onto both branches");
        assert_eq!(e[off + 1], e[off + 3], "{name}: ASR branch conserves records");
        assert_eq!(e[off + 2], e[off + 4], "{name}: caption branch conserves records");
        assert_eq!(
            c.sim.processed_total(n_pdf_ops + 4),
            e[off + 1],
            "{name}: join merges one record per forked segment"
        );

        // Per-tenant sink conservation: everything each tenant admitted
        // comes out of its own sinks, scaled by its own D_o (fractional
        // fanout carries leave at most a few records per instance).
        for t in 0..2 {
            let d_o = c.sim.tenancy.d_o[t];
            let expect = c.sim.items_emitted_t(t) as f64 * d_o;
            let got = c.sim.out_records_t(t) as f64;
            assert!(
                (got - expect).abs() <= 0.05 * expect + 16.0,
                "{name}: tenant {t} sink output {got} vs admitted*D_o {expect}"
            );
        }
        assert_eq!(
            c.sim.out_records(),
            (0..2).map(|t| c.sim.out_records_t(t)).sum::<u64>(),
            "{name}: tenant outputs partition the total"
        );

        // RunReport: per-tenant + aggregate sections.
        assert_eq!(r.tenants.len(), 2, "{name}");
        assert_eq!(r.tenants[0].id, "pdf");
        assert_eq!(r.tenants[1].id, "speech");
        for t in &r.tenants {
            assert!(t.throughput > 0.0, "{name}: tenant {} made progress", t.id);
            assert!(t.items_processed > 0, "{name}");
        }
        let sum: f64 = r.tenants.iter().map(|t| t.throughput).sum();
        assert!(
            (sum - r.throughput).abs() < 1e-9,
            "{name}: aggregate is the per-tenant sum"
        );
    }
}

/// The shared cluster is respected: at every accel op placement, the
/// union of both tenants' instances fits the per-node device count.
#[test]
fn two_tenant_trident_respects_shared_capacity() {
    let mut c = two_tenant(&Variant::trident(), 7);
    let r = c.run(600.0);
    assert!(!r.milp_ms.is_empty(), "Trident re-solves the joint MILP");
    assert!(r.throughput > 0.0);
    let spec = &c.sim.spec;
    let x = c.sim.placement();
    for node in 0..2 {
        let acc: u32 = (0..spec.n_ops())
            .map(|i| x[i][node] * spec.operators[i].accels)
            .sum();
        assert!(acc <= 4, "node {node} over-packed across tenants: {acc}");
    }
    // Both tenants' accelerator branches are live on the shared pool.
    let n_pdf_ops = pdf::pipeline().n_ops();
    assert!(
        !c.sim.instances_of(9).is_empty() || !c.sim.instances_of(10).is_empty(),
        "pdf OCR ops placed"
    );
    assert!(
        !c.sim.instances_of(n_pdf_ops + 2).is_empty(),
        "speech ASR placed alongside pdf"
    );
}

/// Strictness: tenancy validation fails loudly on duplicate ids and bad
/// weights (the CLI surfaces these as exit-code-2 errors).
#[test]
fn tenancy_validation_is_strict() {
    let dup = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec { id: "pdf".into(), pipeline: speech::pipeline(), weight: 1.0, source_rate: 0.0 },
        ],
    };
    assert!(dup.validate().unwrap_err().contains("duplicate tenant id"));
    let coord = Coordinator::new_tenancy(
        dup,
        cluster(),
        vec![
            Box::new(pdf::trace(10)) as Box<dyn Trace>,
            Box::new(speech::trace(10)) as Box<dyn Trace>,
        ],
        mini_cfg(),
        Variant::baseline(Policy::Static),
        vec![pdf_src(), speech::src_attrs()],
        0,
    );
    assert!(coord.is_err(), "duplicate ids must be rejected at construction");
}

/// A paced tenant (finite `source_rate`) is admission-limited at its
/// offered load instead of running closed-loop.
#[test]
fn paced_source_rate_caps_admission() {
    let tenancy = Tenancy {
        tenants: vec![TenantSpec {
            id: "pdf".into(),
            pipeline: pdf::pipeline(),
            weight: 1.0,
            source_rate: 0.5, // one document every 2 s
        }],
    };
    let mut c = Coordinator::new_tenancy(
        tenancy,
        cluster(),
        vec![Box::new(pdf::trace(50_000)) as Box<dyn Trace>],
        mini_cfg(),
        Variant::baseline(Policy::Static),
        vec![pdf_src()],
        5,
    )
    .expect("valid");
    c.run(400.0);
    // 400 s at 0.5 items/s -> ~200 admissions (exact pacing modulo the
    // t=0 tick), far below what the unpaced closed loop admits.
    assert!(
        c.sim.items_emitted() <= 202,
        "paced source over-admitted: {}",
        c.sim.items_emitted()
    );
    assert!(
        c.sim.items_emitted() >= 150,
        "paced source under-admitted: {}",
        c.sim.items_emitted()
    );
}
