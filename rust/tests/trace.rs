//! End-to-end flight-recorder pins: a real closed-loop run produces a
//! parseable, internally consistent trace whose recomputed aggregates
//! match the `RunReport` the same run returned — the analyzer's
//! cross-check is the contract that the trace is a faithful record, not
//! a best-effort log.

use trident::config::{ClusterSpec, Json, Tenancy, TenantSpec, TridentConfig};
use trident::coordinator::{Coordinator, RunReport, Variant};
use trident::dynamics::DynamicsSpec;
use trident::sim::ItemAttrs;
use trident::trace::{summarize_jsonl, TraceFormat, TraceSink, TraceSummary, TRACE_SCHEMA};
use trident::workload::{pdf, speech, Trace};

fn mini_cfg() -> TridentConfig {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 10_000;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    cfg
}

fn pdf_src() -> ItemAttrs {
    ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 }
}

fn two_tenant(seed: u64) -> Coordinator {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    Coordinator::new_tenancy(
        tenancy,
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0),
        vec![
            Box::new(pdf::trace(300)) as Box<dyn Trace>,
            Box::new(speech::trace(120)) as Box<dyn Trace>,
        ],
        mini_cfg(),
        Variant::trident(),
        vec![pdf_src(), speech::src_attrs()],
        seed,
    )
    .expect("two-tenant tenancy is valid")
}

fn traced_run(seed: u64, dynamics: bool) -> (RunReport, Box<TraceSink>) {
    let mut coord = two_tenant(seed);
    if dynamics {
        let spec_json = r#"{"events": [
            {"at": 60, "kind": "node_fail", "node": 1},
            {"at": 120, "kind": "node_recover", "node": 1}
        ]}"#;
        let spec = DynamicsSpec::from_json(&Json::parse(spec_json).expect("valid json"))
            .expect("valid dynamics spec");
        coord.set_dynamics(spec).expect("valid dynamics spec");
    }
    coord.enable_trace();
    let report = coord.run(300.0);
    let sink = coord.take_trace().expect("trace sink present after run");
    (report, sink)
}

fn assert_matches_report(s: &TraceSummary, r: &RunReport) {
    let errs = s.check();
    assert!(errs.is_empty(), "trace/run_summary cross-check failed: {errs:?}");
    assert_eq!(s.schema, TRACE_SCHEMA);
    assert_eq!(s.windows, r.series.len(), "one window record per series point");
    assert_eq!(s.total_items(), r.items_processed, "window outs must sum to the run total");
    assert_eq!(s.solves, r.milp_ms.len(), "one solve record per MILP solve");
    assert_eq!(s.ooms, u64::from(r.oom_events), "one oom record per OOM kill");
    assert_eq!(s.transitions, r.config_transitions, "transition invalidations");
    assert_eq!(s.plans_committed, r.plans_committed, "committed plans");
    assert_eq!(s.dynamics_events, r.events.len(), "one dynamics record per event");
    assert_eq!(s.lost_records, r.lost_records, "loss ledger");
    assert_eq!(s.tenant_out.len(), r.tenants.len(), "per-tenant outs in every window");
    for (i, t) in r.tenants.iter().enumerate() {
        assert_eq!(s.tenant_out[i], t.items_processed, "tenant {}", t.id);
    }
    let replans = r.events.iter().filter(|e| e.replan_s.is_some()).count();
    let recovers = r.events.iter().filter(|e| e.recovered_s.is_some()).count();
    assert_eq!(s.replan_latencies.len(), replans, "replan milestones");
    assert_eq!(s.recover_latencies.len(), recovers, "recovery milestones");
}

/// The headline pin: run Trident end to end with the recorder on, feed
/// the JSONL back through the analyzer, and require every recomputed
/// aggregate to equal the `RunReport` the run itself returned.
#[test]
fn trace_aggregates_match_runreport() {
    let (report, sink) = traced_run(5, false);
    assert!(report.throughput > 0.0, "run must make progress");
    let s = summarize_jsonl(&sink.to_jsonl()).expect("trace parses");
    assert_matches_report(&s, &report);
    assert!(s.solves > 0, "Trident must have solved at least once");
    assert!(!s.ops.is_empty(), "op_window records must cover the pipeline");
    let rendered = s.render();
    assert!(rendered.contains("bottleneck:"), "attribution line present:\n{rendered}");
}

/// Same contract under scripted dynamics: the dynamics / replan /
/// recover / loss records reconcile with the event reports too.
#[test]
fn trace_aggregates_match_runreport_under_dynamics() {
    let (report, sink) = traced_run(9, true);
    assert!(!report.events.is_empty(), "dynamics timeline must fire");
    let s = summarize_jsonl(&sink.to_jsonl()).expect("trace parses");
    assert_matches_report(&s, &report);
    assert_eq!(s.dynamics_events, 2, "node_fail + node_recover");
}

/// The Chrome export is one valid JSON document with a traceEvents entry
/// per record, so Perfetto loads whatever the JSONL lane recorded.
#[test]
fn chrome_export_covers_every_record() {
    let (_, sink) = traced_run(5, false);
    let chrome = sink.to_chrome();
    let j = Json::parse(chrome.trim_end()).expect("chrome export is valid JSON");
    let evs = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert_eq!(evs.len(), sink.len(), "one trace event per record");
    assert!(evs.iter().any(|e| e.str_or("ph", "") == "X"), "duration events present");
}

/// `set_trace` writes the file at the end of `run` — the CLI contract —
/// and the on-disk bytes are what the in-memory sink would serialize.
#[test]
fn set_trace_writes_parseable_file() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("trident-trace-test-{}.jsonl", std::process::id()));
    let path_s = path.to_str().expect("utf-8 temp path").to_string();
    let mut coord = two_tenant(5);
    coord.set_trace(&path_s, TraceFormat::Jsonl);
    let report = coord.run(300.0);
    let text = std::fs::read_to_string(&path).expect("trace file written");
    std::fs::remove_file(&path).ok();
    let s = summarize_jsonl(&text).expect("on-disk trace parses");
    assert_matches_report(&s, &report);
}
