//! Solver-parity suite for the warm-started revised dual simplex: the
//! rewrite is a *pure speed change*, so every path must agree with the
//! dense two-phase reference —
//!
//! * dual-vs-primal LP parity: the revised solver (dual feasibility
//!   restore + primal finish) and the dense primal tableau agree on
//!   status, objective, and feasibility across random bounded LPs;
//! * MILP parity: warm-started and cold (dense-backend) branch & bound
//!   reach the same objective within the B&B pruning gap and feasible
//!   points on randomized bounded MILPs;
//! * scheduling parity: the two backends produce the same plan
//!   (parallelism and transition vectors) for a scheduling MILP.

use std::time::Duration;

use trident::config::ClusterSpec;
use trident::rngx::Rng;
use trident::scheduling::{solve_with_options, BasisCache, MilpInput, OpSched};
use trident::solver::{solve_lp, solve_milp_opts, Cmp, LpBackend, MilpOptions, Problem, Status};

/// B&B prunes at this relative gap (`solver/milp.rs`); objective parity
/// between backends holds to within twice that.
const REL_GAP_TOL: f64 = 1e-4;

fn random_lp(rng: &mut Rng, with_hard_rows: bool) -> Problem {
    let nv = 2 + rng.below(5);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..nv)
        .map(|i| p.cont(&format!("v{i}"), 0.0, rng.uniform(1.0, 9.0), rng.uniform(-2.0, 3.0)))
        .collect();
    let le: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.2, 2.0))).collect();
    p.constrain("le", le, Cmp::Le, rng.uniform(3.0, 18.0));
    if with_hard_rows {
        let ge: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.2, 1.0))).collect();
        p.constrain("ge", ge, Cmp::Ge, rng.uniform(0.3, 2.0));
        let eq = vec![(vars[0], 1.0), (vars[1], 1.0)];
        p.constrain("eq", eq, Cmp::Eq, rng.uniform(0.5, 3.0));
    }
    p
}

/// Revised (dual-restore + primal) vs dense (two-phase primal) on random
/// LPs: status, objective, and returned-point feasibility must match.
#[test]
fn lp_dual_vs_primal_parity_random() {
    let mut rng = Rng::new(20260801);
    for case in 0..80 {
        let p = random_lp(&mut rng, case % 2 == 0);
        let rev = solve_lp(&p);
        let dense = trident::solver::simplex::solve_lp(&p);
        assert_eq!(rev.status, dense.status, "case {case}: status parity");
        if dense.status == Status::Optimal {
            assert!(
                (rev.obj - dense.obj).abs() < 1e-6 * (1.0 + dense.obj.abs()),
                "case {case}: revised {} vs dense {}",
                rev.obj,
                dense.obj
            );
            assert!(p.is_feasible(&rev.x, 1e-6), "case {case}: revised point infeasible");
        }
    }
}

fn random_milp(rng: &mut Rng) -> Problem {
    let nv = 2 + rng.below(4);
    let nc = 1 + rng.below(3);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..nv)
        .map(|i| {
            if i % 2 == 0 {
                p.int(&format!("v{i}"), 0.0, 5.0, rng.uniform(-2.0, 4.0))
            } else {
                p.cont(&format!("v{i}"), 0.0, rng.uniform(2.0, 7.0), rng.uniform(-1.0, 3.0))
            }
        })
        .collect();
    for c in 0..nc {
        let coeffs: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(-0.5, 2.0))).collect();
        p.constrain(&format!("c{c}"), coeffs, Cmp::Le, rng.uniform(2.0, 14.0));
    }
    if nv >= 3 {
        let ge: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.1, 1.0))).collect();
        p.constrain("ge", ge, Cmp::Ge, rng.uniform(0.2, 1.0));
    }
    p
}

/// Property test (the ISSUE's solver-parity satellite): warm-started and
/// cold solves reach the same objective within the pruning gap and a
/// feasible point on randomized bounded MILPs.
#[test]
fn milp_warm_vs_cold_parity_random() {
    let budget = Duration::from_secs(10);
    let warm_opts = MilpOptions::default();
    let cold_opts =
        MilpOptions { backend: LpBackend::Dense, warm_basis: false, max_nodes: None };
    let mut rng = Rng::new(777);
    for case in 0..40 {
        let p = random_milp(&mut rng);
        let (sw, _, root) = solve_milp_opts(&p, budget, None, None, &warm_opts);
        let (sc, _, _) = solve_milp_opts(&p, budget, None, None, &cold_opts);
        assert_eq!(sw.status, sc.status, "case {case}: status parity");
        if sw.status == Status::Optimal {
            let tol = 1e-6 + 2.0 * REL_GAP_TOL * sc.obj.abs();
            assert!(
                (sw.obj - sc.obj).abs() <= tol,
                "case {case}: warm {} vs cold {}",
                sw.obj,
                sc.obj
            );
            assert!(p.is_feasible(&sw.x, 1e-5), "case {case}: warm point infeasible");
            // Re-solving from the cached root basis must not change the
            // answer either (the cross-round reuse level).
            if let Some(root) = root {
                let (sw2, stw2, _) = solve_milp_opts(&p, budget, None, Some(&root), &warm_opts);
                assert_eq!(sw2.status, Status::Optimal, "case {case}: re-solve status");
                assert!(
                    (sw2.obj - sw.obj).abs() <= tol,
                    "case {case}: re-solve {} vs {}",
                    sw2.obj,
                    sw.obj
                );
                assert!(
                    stw2.root_warm,
                    "case {case}: cached root basis must warm start ({stw2:?})"
                );
            }
        }
    }
}

fn sched_input(k: usize) -> MilpInput {
    let cluster = ClusterSpec::homogeneous(k, 64.0, 256.0, 4, 65536.0, 1250.0);
    let op = |name: &str, ut: f64, cpu: f64, accels: u32| OpSched {
        name: name.into(),
        ut_cur: ut,
        ut_cand: None,
        n_new: 0,
        n_old: 0,
        cpu,
        mem_gb: 2.0,
        accels,
        out_mb: 0.5,
        d_i: 1.0,
        h_start: 2.0,
        h_stop: 1.0,
        h_cold: 20.0,
        cur_x: vec![0; k],
    };
    MilpInput {
        ops: vec![
            op("parse", 10.0, 2.0, 0),
            op("llm", 2.0, 8.0, 1),
            op("filter", 20.0, 1.0, 0),
        ],
        edges: vec![(0, 1), (1, 2)],
        nodes: cluster.nodes,
        d_o: 1.0,
        tenants: Vec::new(),
        op_tenant: Vec::new(),
        t_sched: 30.0,
        lambda1: 1e-4,
        lambda2: 1e-6,
        b_max: 2,
        placement_aware: true,
        join_colocate: false,
        all_at_once: false,
    }
}

/// The scheduling MILP decoded through both backends: equal predicted
/// throughput (the "pure speed change" contract — exact vectors can
/// differ across backends on degenerate optima within the B&B pruning
/// gap) plus the structurally-forced part of the plan (the device-bound
/// accelerator op saturates all 8 devices either way).
#[test]
fn scheduling_objectives_match_across_backends() {
    let input = sched_input(2);
    let budget = Duration::from_secs(20);
    let warm = solve_with_options(
        &input,
        budget,
        &mut BasisCache::new(),
        &MilpOptions::default(),
    );
    let dense = solve_with_options(
        &input,
        budget,
        &mut BasisCache::new(),
        &MilpOptions { backend: LpBackend::Dense, warm_basis: false, max_nodes: None },
    );
    assert!(matches!(warm.status, Status::Optimal | Status::Limit));
    assert!(matches!(dense.status, Status::Optimal | Status::Limit));
    if warm.status == Status::Optimal && dense.status == Status::Optimal {
        assert!(
            (warm.t_pred - dense.t_pred).abs() <= 1e-3 * (1.0 + dense.t_pred.abs()),
            "warm {} vs dense {}",
            warm.t_pred,
            dense.t_pred
        );
        // 8 shared devices, one accel op: both backends must saturate.
        assert_eq!(warm.p[1], 8, "revised backend leaves devices idle: {:?}", warm.p);
        assert_eq!(dense.p[1], 8, "dense backend leaves devices idle: {:?}", dense.p);
    }
}
