//! Cluster-dynamics integration (the tentpole's contract):
//!
//! * same seed + same `DynamicsSpec` ⇒ bit-identical event timeline and
//!   `RunReport`;
//! * conservation under churn: a mid-run `NodeFail` on a join-holding
//!   node keeps per-tenant item accounting exact on the speech DAG, for
//!   both recovery policies;
//! * the event-driven re-plan fires within one `metrics_interval_s` of an
//!   injected `NodeFail`;
//! * the two-tenant pdf+speech churn scenario recovers >= 90% of
//!   pre-failure aggregate throughput strictly faster under Trident than
//!   under the never-re-planning Static baseline.

use trident::config::{ClusterSpec, Tenancy, TenantSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, RunReport, Variant};
use trident::dynamics::{ClusterEvent, DynamicsSpec, RecoveryPolicy, TimedEvent};
use trident::sim::PipelineSim;
use trident::workload::{pdf, speech, Trace};

fn mini_cfg() -> TridentConfig {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    // Generous budget: the mini 2-node MILP reaches Optimal, so Trident
    // plans are deterministic under parallel test execution.
    cfg.milp_time_budget_ms = 10_000;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    cfg
}

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0)
}

fn pdf_src() -> trident::sim::ItemAttrs {
    trident::sim::ItemAttrs {
        tokens_in: 36_000.0,
        tokens_out: 7_200.0,
        pixels_m: 12.0,
        frames: 12.0,
    }
}

/// Fail node 1 mid-run, recover it later (the headline churn scenario).
fn churn_spec(recovery: RecoveryPolicy) -> DynamicsSpec {
    DynamicsSpec {
        events: vec![
            TimedEvent { at_s: 150.0, event: ClusterEvent::NodeFail { node: 1 } },
            TimedEvent { at_s: 400.0, event: ClusterEvent::NodeRecover { node: 1 } },
        ],
        mtbf_s: 0.0,
        mttr_s: 0.0,
        recovery,
    }
}

/// Two-tenant pdf+speech coordinator with large traces (sources never
/// exhaust inside the run) and an optional dynamics spec.
fn two_tenant(variant: &Variant, seed: u64, dynamics: Option<DynamicsSpec>) -> Coordinator {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    let mut coord = Coordinator::new_tenancy(
        tenancy,
        cluster(),
        vec![
            Box::new(pdf::trace(50_000)) as Box<dyn Trace>,
            Box::new(speech::trace(20_000)) as Box<dyn Trace>,
        ],
        mini_cfg(),
        variant.clone(),
        vec![pdf_src(), speech::src_attrs()],
        seed,
    )
    .expect("two-tenant tenancy is valid");
    if let Some(spec) = dynamics {
        coord.set_dynamics(spec).expect("valid dynamics spec");
    }
    coord
}

fn key(r: &RunReport) -> (u64, u64, u32, u64, u64, u64) {
    (
        r.throughput.to_bits(),
        r.items_processed,
        r.oom_events,
        r.config_transitions,
        r.lost_records,
        r.tenants.iter().map(|t| t.items_lost).sum(),
    )
}

/// Same seed + same spec ⇒ bit-identical timeline and report, for a
/// scripted fail/recover under the Loss policy.
#[test]
fn dynamics_runs_are_deterministic() {
    let run = || {
        two_tenant(&Variant::trident(), 7, Some(churn_spec(RecoveryPolicy::Loss))).run(600.0)
    };
    let a = run();
    let b = run();
    assert_eq!(key(&a), key(&b), "same seed + spec must be bit-identical");
    assert_eq!(a.events.len(), b.events.len());
    for (x, y) in a.events.iter().zip(&b.events) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
        assert_eq!(x.lost_records, y.lost_records);
        assert_eq!(x.replan_s.map(f64::to_bits), y.replan_s.map(f64::to_bits));
        assert_eq!(x.recovered_s.map(f64::to_bits), y.recovered_s.map(f64::to_bits));
    }
    assert_eq!(a.events.len(), 2, "both scripted events fired");
}

/// Stochastic MTBF/MTTR churn is a pure function of the seed too.
#[test]
fn mtbf_runs_are_deterministic() {
    let spec = || DynamicsSpec {
        mtbf_s: 100.0,
        mttr_s: 25.0,
        recovery: RecoveryPolicy::Requeue,
        ..Default::default()
    };
    let run = || two_tenant(&Variant::baseline(Policy::Ds2), 11, Some(spec())).run(500.0);
    let a = run();
    let b = run();
    assert_eq!(key(&a), key(&b));
    assert_eq!(a.events.len(), b.events.len());
    assert!(!a.events.is_empty(), "an MTBF of 100s per node over 500s must churn");
}

/// Build the speech DAG at the sim level with explicit placement: every
/// operator on node 0 except the join (`align_merge`) on node 1, so a
/// node-1 failure hits exactly the join-holding instance.
fn speech_sim_with_join_on_node1(seed: u64) -> PipelineSim {
    let spec = speech::pipeline();
    let cluster = ClusterSpec::homogeneous(2, 64.0, 256.0, 4, 65536.0, 2500.0);
    let mut sim = PipelineSim::new(spec, cluster, Box::new(speech::trace(40)), seed);
    let asr_theta = sim.spec.operators[2].config_space.default_config();
    let cap_theta = sim.spec.operators[3].config_space.default_config();
    sim.add_instance(0, 0, vec![]).unwrap(); // demux
    sim.add_instance(1, 0, vec![]).unwrap(); // decode (fork)
    sim.add_instance(2, 0, asr_theta).unwrap(); // asr branch
    sim.add_instance(3, 0, cap_theta).unwrap(); // caption branch
    sim.add_instance(4, 1, vec![]).unwrap(); // align_merge (join) — node 1
    sim.add_instance(5, 0, vec![]).unwrap(); // quality_filter
    sim
}

/// Run until the join instance holds incomplete groups at a quiescent
/// point (empty queue/batch/pending), so a failure hits only buffered
/// join state; returns how many groups it held.
fn run_to_join_holding(sim: &mut PipelineSim, join_inst: usize) -> usize {
    let mut t = 10.0;
    sim.run_until(t);
    while t < 600.0 {
        let j = &sim.instances[join_inst];
        if !j.join_buf.is_empty()
            && j.queue.is_empty()
            && j.batch.is_empty()
            && j.pending_out.is_empty()
        {
            return j.join_buf.len();
        }
        t += 0.25;
        sim.run_until(t);
    }
    panic!("join never reached a quiescent holding state");
}

/// Conservation under churn, Loss policy: killing the join-holding node
/// drops exactly the buffered groups' lineages — every segment that
/// entered the branches is either merged by the join or in the loss
/// ledger, and the DAG still drains (tombstones keep orphaned siblings
/// from wedging it).
#[test]
fn node_fail_on_join_holder_keeps_accounting_exact_loss() {
    let mut sim = speech_sim_with_join_on_node1(21);
    let held = run_to_join_holding(&mut sim, 4);
    assert!(held > 0, "test setup: join must hold incomplete groups");
    let dropped = sim.fail_node(1, false);
    assert!(dropped > 0, "buffered partials must be ledgered");
    assert_eq!(
        sim.lost_items_t[0] as usize, held,
        "one killed lineage per buffered group"
    );
    // Replacement join instance on the surviving node.
    sim.add_instance(4, 0, vec![]).unwrap();
    for _ in 0..400 {
        sim.run_until(sim.now() + 10.0);
        if sim.drained() {
            break;
        }
    }
    assert!(sim.drained(), "tombstoned siblings must not wedge the join");
    // Fork replicates every segment onto both branches (edges 1 and 2).
    assert_eq!(sim.edge_emitted[1], sim.edge_emitted[2]);
    // Every segment is merged exactly once or lost exactly once.
    assert_eq!(
        sim.processed_total[4] + sim.lost_items_t[0],
        sim.edge_emitted[1],
        "segments in == merged + lost"
    );
    // Downstream of the join nothing else was lost.
    assert_eq!(sim.processed_total[5], sim.processed_total[4]);
    // Join memory fully released despite the crash.
    for mb in sim.join_state_mb() {
        assert!(mb.abs() < 1e-9, "join memory leaked: {mb} MB");
    }
}

/// Conservation under churn, Requeue policy: the same failure loses
/// nothing — buffered groups are parked/adopted and every segment is
/// merged exactly once.
#[test]
fn node_fail_on_join_holder_conserves_under_requeue() {
    let mut sim = speech_sim_with_join_on_node1(22);
    let held = run_to_join_holding(&mut sim, 4);
    assert!(held > 0);
    let dropped = sim.fail_node(1, true);
    assert_eq!(dropped, 0, "requeue loses nothing");
    sim.add_instance(4, 0, vec![]).unwrap();
    for _ in 0..400 {
        sim.run_until(sim.now() + 10.0);
        if sim.drained() {
            break;
        }
    }
    assert!(sim.drained(), "parked groups must be adopted, not wedged");
    assert_eq!(sim.lost_items_t[0], 0);
    assert_eq!(sim.lost_records_total(), 0);
    assert_eq!(
        sim.processed_total[4],
        sim.edge_emitted[1],
        "every segment merged exactly once"
    );
    assert_eq!(sim.processed_total[5], sim.processed_total[4]);
    for mb in sim.join_state_mb() {
        assert!(mb.abs() < 1e-9, "join memory leaked: {mb} MB");
    }
}

/// The acceptance bar: the event-driven re-plan fires within one
/// `metrics_interval_s` of the injected `NodeFail`, and Trident recovers
/// >= 90% of pre-failure aggregate throughput strictly faster than the
/// Static baseline (which never re-plans, so its dead instances stay
/// dead even after the node returns).
#[test]
fn churn_recovery_trident_beats_static() {
    let trident =
        two_tenant(&Variant::trident(), 5, Some(churn_spec(RecoveryPolicy::Requeue))).run(900.0);
    let statik = two_tenant(
        &Variant::baseline(Policy::Static),
        5,
        Some(churn_spec(RecoveryPolicy::Requeue)),
    )
    .run(900.0);

    let fail_ev = |r: &RunReport| {
        r.events
            .iter()
            .find(|e| e.label.starts_with("node_fail"))
            .expect("node_fail event recorded")
            .clone()
    };
    let t_fail = fail_ev(&trident);
    // Event-driven re-plan: within one metrics window of the failure.
    let interval = mini_cfg().metrics_interval_s;
    let replan = t_fail.replan_s.expect("trident re-plans after the failure");
    assert!(
        replan <= interval + 1e-9,
        "event-driven re-plan must fire within one metrics interval, took {replan}s"
    );
    // Trident recovers to >= 90% of its pre-failure throughput once the
    // node returns; Static (no re-planning: its dead instances are never
    // re-placed) must be strictly slower, if it ever recovers at all.
    let t_rec = t_fail
        .recovered_s
        .expect("trident must recover >= 90% of pre-failure throughput");
    let s_rec = fail_ev(&statik).recovered_s;
    match s_rec {
        None => {}
        Some(s) => assert!(
            t_rec < s,
            "trident must recover strictly faster: {t_rec}s vs {s}s"
        ),
    }
    assert!(
        trident.throughput > statik.throughput,
        "churn-aware re-planning must out-run the static allocation: {} vs {}",
        trident.throughput,
        statik.throughput
    );
}

/// Dynamic tenancy: the speech tenant arrives mid-run (dormant before),
/// the pdf tenant departs later — both splices re-plan and both tenants
/// make progress while active.
#[test]
fn tenants_splice_in_and_out_mid_run() {
    let spec = DynamicsSpec {
        events: vec![
            TimedEvent {
                at_s: 200.0,
                event: ClusterEvent::TenantArrive { tenant: "speech".into() },
            },
            TimedEvent {
                at_s: 500.0,
                event: ClusterEvent::TenantDepart { tenant: "pdf".into() },
            },
        ],
        ..Default::default()
    };
    let r = two_tenant(&Variant::trident(), 9, Some(spec)).run(700.0);
    assert_eq!(r.events.len(), 2);
    let speech = r.tenants.iter().find(|t| t.id == "speech").unwrap();
    let pdf = r.tenants.iter().find(|t| t.id == "pdf").unwrap();
    assert!(
        speech.items_admitted > 0 && speech.items_processed > 0,
        "arriving tenant must be spliced in and make progress: {speech:?}"
    );
    assert!(
        pdf.items_processed > 0,
        "departing tenant processed its admitted items: {pdf:?}"
    );
    for ev in &r.events {
        assert!(
            ev.replan_s.is_some(),
            "tenancy events must trigger re-plans: {ev:?}"
        );
    }
}
