//! Decomposed-solver parity suite: the Dantzig–Wolfe price-and-branch
//! path is a *speed* change for many-tenant rounds, never a semantic
//! one —
//!
//! * random multi-tenant instances: decomposed and monolithic reach
//!   objectives within tolerance, both give every tenant a feasible
//!   schedule, and the merged decomposed plan respects every shared
//!   node-capacity row (the coupling the master is responsible for);
//! * the single-tenant degenerate case is **bit-identical** to the
//!   classic MILP (the decomposed entry point routes straight to the
//!   monolithic solve below the tenant threshold);
//! * the pricing fan-out is deterministic: any thread count produces
//!   the identical plan.

use std::collections::HashMap;
use std::time::Duration;

use trident::config::ClusterSpec;
use trident::rngx::Rng;
use trident::scheduling::{
    solve_decomposed, solve_with_options, BasisCache, DecompOptions, MilpInput, MilpTenant,
    OpSched,
};
use trident::solver::MilpOptions;

/// Random instances tolerate 1% (column generation stops at the pruning
/// gap per subproblem and the master omits the 1e-6-scale migration
/// tiebreaker); the pinned scenarios below use the ISSUE's 0.5%.
const RANDOM_TOL: f64 = 1e-2;
const PINNED_TOL: f64 = 5e-3;

fn op(name: &str, ut: f64, cpu: f64, mem: f64, nodes: usize) -> OpSched {
    OpSched {
        name: name.into(),
        ut_cur: ut,
        ut_cand: None,
        n_new: 0,
        n_old: 0,
        cpu,
        mem_gb: mem,
        accels: 0,
        out_mb: 0.5,
        d_i: 1.0,
        h_start: 0.5,
        h_stop: 0.5,
        h_cold: 2.0,
        cur_x: vec![0; nodes],
    }
}

/// `nt` chain tenants with randomized rates/footprints/weights on a
/// shared cluster sized so capacity binds but stays feasible.
fn random_multi_tenant(rng: &mut Rng, nt: usize, placement_aware: bool) -> MilpInput {
    let nodes = 2 + rng.below(2);
    let cluster = ClusterSpec::homogeneous(nodes, 24.0, 96.0, 0, 0.0, 12_500.0);
    let mut ops = Vec::new();
    let mut edges = Vec::new();
    let mut op_tenant = Vec::new();
    let mut tenants = Vec::new();
    for t in 0..nt {
        let base = ops.len();
        let n_ops = 2 + rng.below(2);
        for i in 0..n_ops {
            ops.push(op(
                &format!("t{t}op{i}"),
                rng.uniform(8.0, 40.0),
                rng.uniform(0.5, 2.0),
                rng.uniform(0.5, 2.0),
                nodes,
            ));
            op_tenant.push(t);
            if i > 0 {
                edges.push((base + i - 1, base + i));
            }
        }
        tenants.push(MilpTenant {
            name: format!("tenant-{t}"),
            weight: rng.uniform(0.5, 2.0),
            d_o: 1.0,
        });
    }
    MilpInput {
        ops,
        edges,
        nodes: cluster.nodes,
        d_o: 1.0,
        tenants,
        op_tenant,
        t_sched: 30.0,
        lambda1: 1e-4,
        lambda2: 1e-6,
        b_max: 2,
        placement_aware,
        join_colocate: false,
        all_at_once: false,
    }
}

fn solve_both(input: &MilpInput, dopts: &DecompOptions) -> (f64, f64, Vec<f64>, Vec<f64>) {
    let budget = Duration::from_secs(20);
    let mono = solve_with_options(input, budget, &mut BasisCache::new(), &MilpOptions::default());
    let mut tenant_caches = HashMap::new();
    let dec = solve_decomposed(
        input,
        budget,
        &mut BasisCache::new(),
        &mut tenant_caches,
        &MilpOptions::default(),
        dopts,
    );
    // Shared capacity rows: the merged decomposed plan must respect the
    // coupling the master is responsible for.
    for (k, node) in input.nodes.iter().enumerate() {
        let (mut cpu, mut mem) = (0.0, 0.0);
        for (i, o) in input.ops.iter().enumerate() {
            let inst = dec.x[i][k] as f64;
            cpu += inst * o.cpu;
            mem += inst * o.mem_gb;
        }
        assert!(cpu <= node.cpu_cores + 1e-6, "node {k}: cpu {cpu} > {}", node.cpu_cores);
        assert!(mem <= node.mem_gb + 1e-6, "node {k}: mem {mem} > {}", node.mem_gb);
    }
    (mono.obj, dec.obj, mono.t_tenant.clone(), dec.t_tenant.clone())
}

/// Property test: random multi-tenant instances reach objectives within
/// tolerance with identical per-tenant feasibility (a tenant schedulable
/// under one path is schedulable under the other).
#[test]
fn decomposed_vs_monolithic_parity_random() {
    let mut rng = Rng::new(20260808);
    for case in 0..10 {
        let nt = 2 + rng.below(3);
        let input = random_multi_tenant(&mut rng, nt, case % 2 == 0);
        let (mono_obj, dec_obj, mono_t, dec_t) = solve_both(&input, &DecompOptions::default());
        assert!(
            dec_obj >= mono_obj - RANDOM_TOL * (1.0 + mono_obj.abs()),
            "case {case}: decomposed obj {dec_obj} vs monolithic {mono_obj}"
        );
        assert_eq!(mono_t.len(), dec_t.len(), "case {case}: tenant count");
        for (t, (m, d)) in mono_t.iter().zip(&dec_t).enumerate() {
            assert_eq!(
                *m > 1e-9,
                *d > 1e-9,
                "case {case}: tenant {t} feasibility disagrees (mono {m}, dec {d})"
            );
        }
    }
}

/// The pinned two-tenant scenario (the milp-bench shape at test scale):
/// decomposed objective within 0.5% of monolithic.
#[test]
fn decomposed_two_tenant_objective_pinned() {
    let mut rng = Rng::new(42);
    let input = random_multi_tenant(&mut rng, 2, true);
    let (mono_obj, dec_obj, _, _) = solve_both(&input, &DecompOptions::default());
    assert!(
        dec_obj >= mono_obj - PINNED_TOL * (1.0 + mono_obj.abs()),
        "decomposed obj {dec_obj} vs monolithic {mono_obj}"
    );
}

/// Single tenant under `--solver decomposed` degenerates to the classic
/// MILP **bit-identically** — every plan field, not just the objective.
#[test]
fn single_tenant_degenerates_bit_identically() {
    let nodes = 3;
    let cluster = ClusterSpec::homogeneous(nodes, 24.0, 96.0, 0, 0.0, 12_500.0);
    let input = MilpInput {
        ops: vec![
            op("parse", 10.0, 2.0, 2.0, nodes),
            op("embed", 4.0, 3.0, 4.0, nodes),
            op("sink", 25.0, 1.0, 1.0, nodes),
        ],
        edges: vec![(0, 1), (1, 2)],
        nodes: cluster.nodes,
        d_o: 1.0,
        tenants: Vec::new(),
        op_tenant: Vec::new(),
        t_sched: 30.0,
        lambda1: 1e-4,
        lambda2: 1e-6,
        b_max: 2,
        placement_aware: true,
        join_colocate: false,
        all_at_once: false,
    };
    let budget = Duration::from_secs(20);
    let mono = solve_with_options(&input, budget, &mut BasisCache::new(), &MilpOptions::default());
    let mut tenant_caches = HashMap::new();
    let dec = solve_decomposed(
        &input,
        budget,
        &mut BasisCache::new(),
        &mut tenant_caches,
        &MilpOptions::default(),
        &DecompOptions::default(),
    );
    assert_eq!(dec.p, mono.p);
    assert_eq!(dec.x, mono.x);
    assert_eq!(dec.b, mono.b);
    assert_eq!(dec.route, mono.route);
    assert_eq!(dec.edge_cons, mono.edge_cons);
    assert_eq!(dec.t_tenant, mono.t_tenant);
    assert_eq!(dec.t_pred, mono.t_pred);
    assert_eq!(dec.obj, mono.obj);
    assert_eq!(dec.status, mono.status);
    assert!(tenant_caches.is_empty(), "degenerate path must not touch tenant caches");
}

/// The tenant-count threshold routes below-threshold inputs through the
/// identical monolithic solve (same fallback as the single-tenant pin).
#[test]
fn below_threshold_falls_back_bit_identically() {
    let mut rng = Rng::new(7);
    let input = random_multi_tenant(&mut rng, 2, false);
    let budget = Duration::from_secs(20);
    let mono = solve_with_options(&input, budget, &mut BasisCache::new(), &MilpOptions::default());
    let mut tenant_caches = HashMap::new();
    let dec = solve_decomposed(
        &input,
        budget,
        &mut BasisCache::new(),
        &mut tenant_caches,
        &MilpOptions::default(),
        &DecompOptions { min_tenants: 3, ..DecompOptions::default() },
    );
    assert_eq!(dec.p, mono.p);
    assert_eq!(dec.x, mono.x);
    assert_eq!(dec.b, mono.b);
    assert_eq!(dec.t_tenant, mono.t_tenant);
    assert_eq!(dec.obj, mono.obj);
    assert_eq!(dec.status, mono.status);
}

/// Determinism contract: the pricing fan-out collects per-tenant results
/// in tenant order, so any thread count yields the identical plan.
#[test]
fn decomposed_is_deterministic_across_thread_counts() {
    let mut rng = Rng::new(99);
    let input = random_multi_tenant(&mut rng, 4, true);
    let budget = Duration::from_secs(20);
    let mut plans = Vec::new();
    for threads in [1usize, 4] {
        let mut tenant_caches = HashMap::new();
        let dec = solve_decomposed(
            &input,
            budget,
            &mut BasisCache::new(),
            &mut tenant_caches,
            &MilpOptions::default(),
            &DecompOptions { threads, ..DecompOptions::default() },
        );
        plans.push(dec);
    }
    let (a, b) = (&plans[0], &plans[1]);
    assert_eq!(a.p, b.p, "plans diverge across thread counts");
    assert_eq!(a.x, b.x);
    assert_eq!(a.b, b.b);
    assert_eq!(a.route, b.route);
    assert_eq!(a.t_tenant, b.t_tenant);
    assert_eq!(a.obj, b.obj);
    assert_eq!(a.status, b.status);
}
