//! Parity pins for the simulator raw-speed overhaul: batched link-FIFO
//! transfers and the slab pairing-heap event queue must change *speed*,
//! not results.
//!
//! * every policy produces a bit-identical `RunReport` with batched
//!   transfers vs the legacy seed event stream (`sim_seed_event_stream`),
//!   on single-tenant, two-tenant, and scripted-dynamics runs;
//! * conservation counters match exactly across modes when a node is
//!   killed with transfers mid-flight on the wire;
//! * the event queue keeps the earlier-time-then-FIFO-seq contract at
//!   equal timestamps;
//! * the tenant-sharded tick with its work-stealing worker pool is a
//!   partition of the serial run at every (K, W) — shard count and
//!   worker count decide wall-clock only, never a single bit of output,
//!   including oversubscribed K > W epochs where workers steal.

use trident::config::{
    ClusterSpec, ConfigSpace, CostW, FeatureExtractor, Json, OperatorKind, OperatorSpec,
    PipelineSpec, ServiceModel, Tenancy, TenantSpec, TridentConfig,
};
use trident::coordinator::{Coordinator, Policy, RunReport, Variant};
use trident::dynamics::{DynamicsSpec, RecoveryPolicy};
use trident::sim::{Engine, Ev, InstId, ItemAttrs, PipelineSim, ShardedSim, SimError};
use trident::workload::{pdf, speech, ItemDist, Phase, PhasedTrace, Trace};

fn mini_cfg(seed_stream: bool) -> TridentConfig {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    // Generous budget: the mini 2-node MILP reaches Optimal, so Trident
    // plans are deterministic under parallel test execution.
    cfg.milp_time_budget_ms = 10_000;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    cfg.sim_seed_event_stream = seed_stream;
    cfg
}

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0)
}

fn pdf_src() -> ItemAttrs {
    ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 }
}

fn single(variant: &Variant, seed: u64, seed_stream: bool) -> Coordinator {
    Coordinator::new(
        pdf::pipeline(),
        cluster(),
        Box::new(pdf::trace(50_000)),
        mini_cfg(seed_stream),
        variant.clone(),
        pdf_src(),
        seed,
    )
}

fn two_tenant(variant: &Variant, seed: u64, seed_stream: bool) -> Coordinator {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    Coordinator::new_tenancy(
        tenancy,
        cluster(),
        vec![
            Box::new(pdf::trace(300)) as Box<dyn Trace>,
            Box::new(speech::trace(120)) as Box<dyn Trace>,
        ],
        mini_cfg(seed_stream),
        variant.clone(),
        vec![pdf_src(), speech::src_attrs()],
        seed,
    )
    .expect("two-tenant tenancy is valid")
}

fn all_policies() -> Vec<(&'static str, Variant)> {
    vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::baseline(Policy::RayData)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("ContTune", Variant::baseline(Policy::ContTune)),
        ("SCOOT", trident::harness::scoot_variant(&pdf::pipeline(), pdf_src())),
        ("Trident", Variant::trident()),
    ]
}

/// Outcome key compared at the bit level: the transfer-path overhaul must
/// not perturb a single event.
fn key(r: &RunReport) -> (u64, u64, u32, u64, usize, u64) {
    (
        r.throughput.to_bits(),
        r.items_processed,
        r.oom_events,
        r.config_transitions,
        r.milp_ms.len(),
        r.lost_records,
    )
}

/// Every policy, single-tenant pdf: batched transfers reproduce the seed
/// event stream bit-for-bit.
#[test]
fn batched_transfers_bit_identical_all_policies() {
    for (name, variant) in all_policies() {
        let seed_stream = single(&variant, 5, true).run(300.0);
        let batched = single(&variant, 5, false).run(300.0);
        assert_eq!(
            key(&seed_stream),
            key(&batched),
            "policy {name} diverged between transfer modes"
        );
        assert!(batched.throughput > 0.0, "{name} must make progress");
    }
}

/// Two tenants sharing the cluster: per-tenant outcomes match across
/// modes too (cross-node forwarding of join partials included).
#[test]
fn batched_transfers_bit_identical_two_tenant() {
    for (name, variant) in
        [("Static", Variant::baseline(Policy::Static)), ("Trident", Variant::trident())]
    {
        let a = two_tenant(&variant, 7, true).run(400.0);
        let b = two_tenant(&variant, 7, false).run(400.0);
        assert_eq!(key(&a), key(&b), "policy {name} diverged between transfer modes");
        assert_eq!(a.tenants.len(), b.tenants.len());
        for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(ta.throughput.to_bits(), tb.throughput.to_bits(), "tenant {}", ta.id);
            assert_eq!(ta.items_processed, tb.items_processed, "tenant {}", ta.id);
        }
    }
}

/// Scripted cluster dynamics (node fail/recover + bandwidth dip): the
/// event timeline, replans, and loss ledger are mode-invariant.
#[test]
fn batched_transfers_bit_identical_under_dynamics() {
    let spec_json = r#"{"events": [
        {"at": 60, "kind": "node_fail", "node": 1},
        {"at": 90, "kind": "bandwidth_degrade", "node": 0, "factor": 0.5},
        {"at": 120, "kind": "node_recover", "node": 1},
        {"at": 150, "kind": "bandwidth_restore", "node": 0}
    ]}"#;
    let spec = || {
        DynamicsSpec::from_json(&Json::parse(spec_json).expect("valid json"))
            .expect("valid dynamics spec")
    };
    for (name, variant) in
        [("DS2", Variant::baseline(Policy::Ds2)), ("Trident", Variant::trident())]
    {
        let mut a = single(&variant, 9, true);
        a.set_dynamics(spec()).expect("valid dynamics spec");
        let mut b = single(&variant, 9, false);
        b.set_dynamics(spec()).expect("valid dynamics spec");
        let ra = a.run(300.0);
        let rb = b.run(300.0);
        assert_eq!(key(&ra), key(&rb), "policy {name} diverged under dynamics");
        assert_eq!(ra.events.len(), rb.events.len());
        for (ea, eb) in ra.events.iter().zip(&rb.events) {
            assert_eq!(ea.label, eb.label);
            assert_eq!(ea.lost_records, eb.lost_records);
        }
    }
}

// ---------------------------------------------------------------------
// Direct-executor conservation under NodeFail with transfers mid-flight
// ---------------------------------------------------------------------

fn chain_op(name: &str, base_rate: f64, out_mb: f64) -> OperatorSpec {
    OperatorSpec {
        name: name.into(),
        kind: OperatorKind::CpuSync,
        cpu: 1.0,
        mem_gb: 1.0,
        accels: 0,
        fanout: 1.0,
        out_mb,
        start_s: 0.5,
        stop_s: 0.5,
        cold_s: 2.0,
        tunable: false,
        config_space: ConfigSpace::default(),
        service: ServiceModel::Cpu {
            base_rate,
            ref_cost: 1.0,
            cost: CostW { konst: 1.0, ..Default::default() },
        },
        features: FeatureExtractor::Cost,
        child_scale: [1.0; 4],
        queue_cap: 32,
    }
}

fn slow_link_sim(seed_stream: bool) -> PipelineSim {
    // The middle op is the slowest stage (2/s vs the link's ~4/s), so its
    // queue holds a deep backlog by the kill time — a loss-mode NodeFail
    // deterministically catches records in every holding structure.
    let spec = PipelineSpec::chain(
        "wire",
        vec![chain_op("src", 50.0, 5.0), chain_op("mid", 2.0, 5.0), chain_op("sink", 40.0, 0.1)],
    );
    // 20 MB/s egress with 5 MB records: each hop costs 250 ms on the
    // wire, so a deep backlog serializes behind every link.
    let cluster = ClusterSpec::homogeneous(3, 64.0, 256.0, 2, 65536.0, 20.0);
    let dist = ItemDist {
        tokens_in: (4.0, 0.2),
        tokens_out: (3.0, 0.2),
        pixels_m: (0.0, 0.1),
        frames: (0.0, 0.0),
        size_mb: (1.0, 0.1),
    };
    let trace = PhasedTrace::new(vec![Phase { regime: 0, count: 400, sampler: dist }]);
    let mut sim = PipelineSim::new(spec, cluster, Box::new(trace), 17);
    sim.set_seed_event_stream(seed_stream);
    // One instance per op, each on its own node: every edge is a real
    // cross-node transfer.
    sim.add_instance(0, 0, vec![]).unwrap();
    sim.add_instance(1, 1, vec![]).unwrap();
    sim.add_instance(2, 2, vec![]).unwrap();
    sim
}

/// Kill the middle node while its ingress link has a batch mid-flight,
/// recover, run on: emitted/processed/output/lost ledgers are exactly
/// equal across transfer modes at every checkpoint.
#[test]
fn node_fail_mid_flight_conserves_identically() {
    for requeue in [true, false] {
        let mut counters = Vec::new();
        for seed_stream in [true, false] {
            let mut sim = slow_link_sim(seed_stream);
            sim.run_until(20.0);
            assert!(
                sim.instances_of(1).iter().any(|&i| sim.instances[i].reserved > 0),
                "scenario must have transfers mid-flight toward the victim"
            );
            let lost_now = sim.fail_node(1, requeue);
            sim.run_until(30.0);
            sim.set_node_up(1);
            let revived = sim.add_instance(1, 1, vec![]).unwrap();
            sim.run_until(120.0);
            counters.push((
                sim.items_emitted,
                sim.out_records,
                sim.processed_total.clone(),
                sim.lost_records.clone(),
                sim.engine.events_processed,
                sim.now().to_bits(),
                lost_now,
                revived,
            ));
        }
        assert_eq!(
            counters[0], counters[1],
            "NodeFail (requeue={requeue}) counters diverged between transfer modes"
        );
        // Ledger sanity: nothing is double-counted or silently dropped.
        let (emitted, out, _, ref lost, ..) = counters[0];
        let lost_total: u64 = lost.iter().sum();
        assert!(out + lost_total <= emitted * 2, "ledger blew past amplification bound");
        assert!(out > 0, "pipeline must keep flowing after recovery");
        if !requeue {
            assert!(lost_total > 0, "loss mode with a mid-flight kill must record losses");
        }
    }
}

/// Typed admission errors render the legacy strings (CLI strict-mode
/// output is part of the contract).
#[test]
fn sim_error_messages_unchanged() {
    let mut sim = slow_link_sim(false);
    sim.fail_node(2, false);
    let down = sim.add_instance(2, 2, vec![]).unwrap_err();
    assert_eq!(down, SimError::NodeDown { node: 2 });
    assert_eq!(down.to_string(), "node 2 is down");
    let oom = SimError::OutOfAccelerators {
        node: 1,
        op: "text_ocr".into(),
        booked: 7,
        want: 2,
        cap: 8,
    };
    assert_eq!(oom.to_string(), "node 1 out of accelerators for text_ocr (7+2 > 8)");
}

// ---------------------------------------------------------------------
// Event-queue determinism contract
// ---------------------------------------------------------------------

/// Equal-timestamp events drain in insertion order (FIFO seq tie-break),
/// interleaved across event kinds and with earlier events cutting in —
/// the exact contract the pairing-heap replacement must keep.
#[test]
fn event_queue_fifo_at_equal_timestamps() {
    let mut e = Engine::new();
    // Three waves at t=5.0 interleaved with one earlier and one later.
    for i in 0..10u32 {
        e.at(5.0, Ev::SourceEmit(i));
        e.at(5.0, Ev::InstanceReady(InstId(i)));
        e.at(5.0, Ev::BatchDone(InstId(i)));
    }
    e.at(1.0, Ev::SourceEmit(99));
    e.at(9.0, Ev::SourceEmit(100));
    let mut order = Vec::new();
    while let Some(ev) = e.next_before(f64::INFINITY) {
        order.push(ev);
    }
    let mut expected = vec![Ev::SourceEmit(99)];
    for i in 0..10u32 {
        expected.push(Ev::SourceEmit(i));
        expected.push(Ev::InstanceReady(InstId(i)));
        expected.push(Ev::BatchDone(InstId(i)));
    }
    expected.push(Ev::SourceEmit(100));
    assert_eq!(order, expected, "equal-time events must drain in insertion order");
}

// ---------------------------------------------------------------------
// Sharded parallel tick: tenant shards partition the serial run exactly
// ---------------------------------------------------------------------

fn shard_cfg(shards: usize, workers: usize) -> TridentConfig {
    let mut cfg = mini_cfg(false);
    cfg.sim_shards = shards;
    cfg.sim_workers = workers;
    cfg
}

/// The (K, W) grid every sharded parity pin sweeps: shard counts below,
/// at, and above the tenant count × worker counts below, at, and above
/// the shard count — clamps, the sequential W = 1 driver, and
/// oversubscribed stealing epochs all included.
const KW_GRID: &[(usize, usize)] = &[
    (1, 1), (1, 2), (1, 4),
    (3, 1), (3, 2), (3, 4),
    (8, 1), (8, 2), (8, 4),
];

fn single_sharded(variant: &Variant, seed: u64, shards: usize, workers: usize) -> Coordinator {
    Coordinator::new(
        pdf::pipeline(),
        cluster(),
        Box::new(pdf::trace(50_000)),
        shard_cfg(shards, workers),
        variant.clone(),
        pdf_src(),
        seed,
    )
}

fn two_tenant_sharded(variant: &Variant, seed: u64, shards: usize, workers: usize) -> Coordinator {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    Coordinator::new_tenancy(
        tenancy,
        cluster(),
        vec![
            Box::new(pdf::trace(300)) as Box<dyn Trace>,
            Box::new(speech::trace(120)) as Box<dyn Trace>,
        ],
        shard_cfg(shards, workers),
        variant.clone(),
        vec![pdf_src(), speech::src_attrs()],
        seed,
    )
    .expect("two-tenant tenancy is valid")
}

/// A single tenant clamps every requested K to one shard: the degenerate
/// path must reproduce (K=1, W=1) bit-for-bit for all six policies at
/// every (K, W) grid point.
#[test]
fn sharded_tick_bit_identical_single_tenant() {
    for (name, variant) in all_policies() {
        let base = single_sharded(&variant, 5, 1, 1).run(300.0);
        assert!(base.throughput > 0.0, "{name} must make progress");
        for &(k, w) in KW_GRID {
            if (k, w) == (1, 1) {
                continue;
            }
            let r = single_sharded(&variant, 5, k, w).run(300.0);
            assert_eq!(
                key(&base),
                key(&r),
                "policy {name} diverged at K={k} W={w} (single tenant)"
            );
        }
    }
}

/// Two tenants sharded across real threads: every policy's aggregate and
/// per-tenant outcomes land on the (K=1, W=1) run bit-for-bit at every
/// (K, W) grid point (K ∈ {3, 8} clamps to the 2 tenants and W clamps to
/// K — the clamps themselves are under test too).
#[test]
fn sharded_tick_bit_identical_two_tenant() {
    for (name, variant) in all_policies() {
        let base = two_tenant_sharded(&variant, 7, 1, 1).run(300.0);
        assert!(base.throughput > 0.0, "{name} must make progress");
        for &(k, w) in KW_GRID {
            if (k, w) == (1, 1) {
                continue;
            }
            let r = two_tenant_sharded(&variant, 7, k, w).run(300.0);
            assert_eq!(key(&base), key(&r), "policy {name} diverged at K={k} W={w} (two tenants)");
            assert_eq!(base.tenants.len(), r.tenants.len());
            for (ta, tb) in base.tenants.iter().zip(&r.tenants) {
                assert_eq!(
                    ta.throughput.to_bits(),
                    tb.throughput.to_bits(),
                    "{name} K={k} W={w}: tenant {}",
                    ta.id
                );
                assert_eq!(
                    ta.items_processed, tb.items_processed,
                    "{name} K={k} W={w}: tenant {}",
                    ta.id
                );
                assert_eq!(ta.items_lost, tb.items_lost, "{name} K={k} W={w}: tenant {}", ta.id);
            }
        }
    }
}

/// Scripted dynamics (node fail/recover + bandwidth dip) across shards:
/// every policy × both recovery policies × (K, W) ∈ {(2,1), (2,2), (4,4)}
/// replays the (1,1) event timeline and loss ledger bit-for-bit —
/// between-window mutations invalidate the shards' published buffers, so
/// these runs exercise the direct-gather fallback path too.
#[test]
fn sharded_tick_bit_identical_under_dynamics() {
    let spec_json = r#"{"events": [
        {"at": 60, "kind": "node_fail", "node": 1},
        {"at": 90, "kind": "bandwidth_degrade", "node": 0, "factor": 0.5},
        {"at": 120, "kind": "node_recover", "node": 1},
        {"at": 150, "kind": "bandwidth_restore", "node": 0}
    ]}"#;
    for (name, variant) in all_policies() {
        for recovery in [RecoveryPolicy::Requeue, RecoveryPolicy::Loss] {
            let mk = |k: usize, w: usize| {
                let mut c = two_tenant_sharded(&variant, 9, k, w);
                let mut d = DynamicsSpec::from_json(&Json::parse(spec_json).expect("valid json"))
                    .expect("valid dynamics spec");
                d.recovery = recovery;
                c.set_dynamics(d).expect("valid dynamics spec");
                c
            };
            let base = mk(1, 1).run(240.0);
            for (k, w) in [(2usize, 1usize), (2, 2), (4, 4)] {
                let r = mk(k, w).run(240.0);
                assert_eq!(
                    key(&base),
                    key(&r),
                    "policy {name} ({recovery:?}) diverged at K={k} W={w} under dynamics"
                );
                assert_eq!(base.events.len(), r.events.len(), "{name} ({recovery:?}) K={k} W={w}");
                for (ea, eb) in base.events.iter().zip(&r.events) {
                    assert_eq!(ea.label, eb.label, "{name} ({recovery:?}) K={k} W={w}");
                    assert_eq!(
                        ea.lost_records, eb.lost_records,
                        "{name} ({recovery:?}) K={k} W={w}: {}",
                        ea.label
                    );
                }
            }
        }
    }
}

/// Facade counters at the raw-sim level: the shards' ledgers partition
/// the serial `PipelineSim` run exactly (event totals included), and the
/// threaded tick matches the sequential shard loop.
#[test]
fn sharded_counters_partition_the_serial_run() {
    let scenario = || {
        let tenancy = Tenancy {
            tenants: vec![
                TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
                TenantSpec {
                    id: "speech".into(),
                    pipeline: speech::pipeline(),
                    weight: 1.0,
                    source_rate: 0.0,
                },
            ],
        };
        let (spec, view) = tenancy.merged().expect("valid tenancy");
        let traces: Vec<Box<dyn Trace>> = vec![
            Box::new(pdf::trace(200)) as Box<dyn Trace>,
            Box::new(speech::trace(100)) as Box<dyn Trace>,
        ];
        (spec, view, traces)
    };
    let place = |add: &mut dyn FnMut(usize, usize, Vec<f64>) -> Result<usize, SimError>,
                 spec: &PipelineSpec| {
        for (op, o) in spec.operators.iter().enumerate() {
            let theta = o.config_space.default_config();
            let placed = (0..2).any(|probe| add(op, (op + probe) % 2, theta.clone()).is_ok());
            assert!(placed, "placement failed for op {op}");
        }
    };

    let (spec, view, traces) = scenario();
    let serial_spec = spec.clone();
    let mut serial = PipelineSim::new_tenancy(spec, view, cluster(), traces, 13);
    place(&mut |op, node, theta| serial.add_instance(op, node, theta), &serial_spec);
    serial.run_until(150.0);

    for (k, threaded, workers) in
        [(2usize, true, 1usize), (2, true, 2), (2, false, 2), (4, true, 2), (4, true, 4)]
    {
        let (spec, view, traces) = scenario();
        let sh_spec = spec.clone();
        let mut sh = ShardedSim::new_tenancy(spec, view, cluster(), traces, 13, k);
        sh.set_threaded(threaded);
        sh.set_workers(workers);
        place(&mut |op, node, theta| sh.add_instance(op, node, theta), &sh_spec);
        sh.run_until(150.0);

        let tag = format!("K={k} threaded={threaded} W={workers}");
        assert_eq!(sh.events_processed(), serial.engine.events_processed, "{tag}: events");
        assert_eq!(sh.items_emitted(), serial.items_emitted, "{tag}: emitted");
        assert_eq!(sh.out_records(), serial.out_records, "{tag}: out records");
        assert_eq!(sh.now().to_bits(), serial.now().to_bits(), "{tag}: clock");
        for op in 0..serial.spec.n_ops() {
            assert_eq!(
                sh.processed_total(op),
                serial.processed_total[op],
                "{tag}: processed_total[{op}]"
            );
        }
        for edge in 0..serial.spec.n_edges() {
            assert_eq!(
                sh.edge_emitted(edge),
                serial.edge_emitted[edge],
                "{tag}: edge_emitted[{edge}]"
            );
        }
        for t in 0..2 {
            assert_eq!(sh.items_emitted_t(t), serial.items_emitted_t[t], "{tag}: tenant {t}");
            assert_eq!(sh.out_records_t(t), serial.out_records_t[t], "{tag}: tenant {t}");
            assert_eq!(
                sh.tenant_throughput(t).to_bits(),
                serial.tenant_throughput(t).to_bits(),
                "{tag}: tenant {t} throughput"
            );
        }
        assert_eq!(
            sh.avg_throughput().to_bits(),
            serial.avg_throughput().to_bits(),
            "{tag}: aggregate throughput"
        );
    }
}

// ---------------------------------------------------------------------
// Oversubscribed worker pool: K > W with stealing, dynamics included
// ---------------------------------------------------------------------

fn four_node_cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(4, 64.0, 256.0, 2, 65536.0, 200.0)
}

/// 8 mini chain tenants — more shards than the small W values in the
/// grid, so K > W epochs really queue several shard ticks per worker and
/// steal across deques.
fn eight_tenant_scenario(
) -> (PipelineSpec, trident::config::TenancyView, Vec<Box<dyn Trace>>) {
    let tenants = (0..8)
        .map(|t| TenantSpec {
            id: format!("mini-{t}"),
            pipeline: PipelineSpec::chain(
                "mini",
                vec![
                    chain_op("src", 40.0, 0.5),
                    chain_op("mid", 6.0, 0.5),
                    chain_op("sink", 30.0, 0.1),
                ],
            ),
            weight: 1.0,
            source_rate: 0.0,
        })
        .collect();
    let tenancy = Tenancy { tenants };
    let (spec, view) = tenancy.merged().expect("valid 8-tenant tenancy");
    let traces = (0..8)
        .map(|_| {
            let dist = ItemDist {
                tokens_in: (4.0, 0.2),
                tokens_out: (3.0, 0.2),
                pixels_m: (0.0, 0.1),
                frames: (0.0, 0.0),
                size_mb: (-1.0, 0.1),
            };
            Box::new(PhasedTrace::new(vec![Phase { regime: 0, count: 60, sampler: dist }]))
                as Box<dyn Trace>
        })
        .collect();
    (spec, view, traces)
}

fn place_mod4(
    add: &mut dyn FnMut(usize, usize, Vec<f64>) -> Result<usize, SimError>,
    spec: &PipelineSpec,
) {
    for (op, o) in spec.operators.iter().enumerate() {
        let theta = o.config_space.default_config();
        let placed = (0..4).any(|probe| add(op, (op + probe) % 4, theta.clone()).is_ok());
        assert!(placed, "placement failed for op {op}");
    }
}

/// The shared dynamics script for the oversubscription pins (a macro so
/// the serial `PipelineSim` and the `ShardedSim` facade — same method
/// names, no shared trait — run the identical call sequence): fail node 1
/// mid-run, dip node 0's bandwidth, recover both, re-place the dead ops,
/// then drive several more windows.  Every mutation lands between
/// windows, exercising the published-buffer invalidation fallback.
macro_rules! drive_dynamics {
    ($sim:expr, $requeue:expr, $spec:expr) => {{
        $sim.run_until(20.0);
        let lost = $sim.fail_node(1, $requeue);
        $sim.run_until(30.0);
        $sim.set_bandwidth_factor(0, 0.5);
        $sim.run_until(40.0);
        $sim.set_node_up(1);
        $sim.set_bandwidth_factor(0, 1.0);
        for (op, o) in $spec.operators.iter().enumerate() {
            if op % 4 == 1 {
                $sim.add_instance(op, 1, o.config_space.default_config())
                    .expect("node 1 is back up");
            }
        }
        for w in 1..=8 {
            $sim.run_until(40.0 + (w as f64) * 15.0);
        }
        lost
    }};
}

/// The regime the pool exists for — more shards than workers — with
/// scripted dynamics under both recovery policies: every (K, W) grid
/// point, oversubscribed K > W included, partitions the serial run's
/// ledgers exactly.
#[test]
fn sharded_oversubscribed_pool_partitions_serial_under_dynamics() {
    for requeue in [true, false] {
        let (spec, view, traces) = eight_tenant_scenario();
        let serial_spec = spec.clone();
        let mut serial = PipelineSim::new_tenancy(spec, view, four_node_cluster(), traces, 21);
        place_mod4(&mut |op, node, theta| serial.add_instance(op, node, theta), &serial_spec);
        let serial_lost = drive_dynamics!(serial, requeue, serial_spec);
        let tenant_rows = |emitted: &dyn Fn(usize) -> u64,
                           out: &dyn Fn(usize) -> u64,
                           lost: &dyn Fn(usize) -> u64,
                           thr: &dyn Fn(usize) -> u64| {
            (0..8).map(|t| (emitted(t), out(t), lost(t), thr(t))).collect::<Vec<_>>()
        };
        let serial_key = (
            serial.engine.events_processed,
            serial.items_emitted,
            serial.out_records,
            serial.processed_total.clone(),
            tenant_rows(
                &|t| serial.items_emitted_t[t],
                &|t| serial.out_records_t[t],
                &|t| serial.lost_items_t[t],
                &|t| serial.tenant_throughput(t).to_bits(),
            ),
            serial.now().to_bits(),
            serial_lost,
        );
        assert!(serial_key.2 > 0, "pipeline must keep flowing after recovery");
        for &(k, w) in KW_GRID {
            let (spec, view, traces) = eight_tenant_scenario();
            let sh_spec = spec.clone();
            let mut sh = ShardedSim::new_tenancy(spec, view, four_node_cluster(), traces, 21, k);
            sh.set_workers(w);
            place_mod4(&mut |op, node, theta| sh.add_instance(op, node, theta), &sh_spec);
            let lost = drive_dynamics!(sh, requeue, sh_spec);
            let sharded_key = (
                sh.events_processed(),
                sh.items_emitted(),
                sh.out_records(),
                (0..sh.spec.n_ops()).map(|op| sh.processed_total(op)).collect::<Vec<_>>(),
                tenant_rows(
                    &|t| sh.items_emitted_t(t),
                    &|t| sh.out_records_t(t),
                    &|t| sh.lost_items_t(t),
                    &|t| sh.tenant_throughput(t).to_bits(),
                ),
                sh.now().to_bits(),
                lost,
            );
            assert_eq!(
                serial_key, sharded_key,
                "K={k} W={w} requeue={requeue} diverged from serial"
            );
        }
    }
}

/// The two clamps the bench artifact records as `k_effective` /
/// `workers_effective`: K clamps to the tenant count, and W clamps to
/// [1, K] (including W requested above K, and W = 0 meaning auto).
#[test]
fn shard_and_worker_clamps() {
    // 8 tenants, K = 3: W clamps against K, not the tenant count.
    let (spec, view, traces) = eight_tenant_scenario();
    let mut sh = ShardedSim::new_tenancy(spec, view, four_node_cluster(), traces, 3, 3);
    assert_eq!(sh.shard_count(), 3);
    sh.set_workers(16);
    assert_eq!(sh.workers_effective(), 3, "W > K must clamp to K");
    sh.set_workers(1);
    assert_eq!(sh.workers_effective(), 1);
    sh.set_workers(0);
    let auto = sh.workers_effective();
    assert!((1..=3).contains(&auto), "auto W must stay within [1, K], got {auto}");

    // 2 tenants, K = 8: K clamps first, then W clamps to the clamped K.
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    let (spec, view) = tenancy.merged().expect("valid tenancy");
    let traces: Vec<Box<dyn Trace>> =
        vec![Box::new(pdf::trace(10)), Box::new(speech::trace(10))];
    let mut sh2 = ShardedSim::new_tenancy(spec, view, cluster(), traces, 1, 8);
    assert_eq!(sh2.shard_count(), 2, "K = 8 must clamp to the 2 tenants");
    sh2.set_workers(4);
    assert_eq!(sh2.workers_effective(), 2, "W = 4 must clamp to the clamped K = 2");
}

// ---------------------------------------------------------------------
// Flight recorder: tracing must never perturb the run
// ---------------------------------------------------------------------

/// Tracing on vs off is bit-identical for every policy across the
/// (K, W) grid: the recorder only *observes* the run (no RNG draws, no
/// event-order perturbation), so enabling it cannot move a single bit of
/// the RunReport — aggregate, per-tenant, or windowed series.
#[test]
fn tracing_off_bit_identity_all_policies() {
    for (name, variant) in all_policies() {
        for (ki, &k) in [1usize, 3, 8].iter().enumerate() {
            // Alternate W to cover both the sequential driver and the pool
            // without squaring the grid.
            let w = if ki % 2 == 0 { 1 } else { 4 };
            let plain = two_tenant_sharded(&variant, 7, k, w).run(300.0);
            let mut traced_coord = two_tenant_sharded(&variant, 7, k, w);
            traced_coord.enable_trace();
            let traced = traced_coord.run(300.0);
            assert_eq!(
                key(&plain),
                key(&traced),
                "policy {name} K={k} W={w}: tracing perturbed the run"
            );
            assert_eq!(plain.series.len(), traced.series.len(), "{name} K={k} W={w}");
            for ((ta, va), (tb, vb)) in plain.series.iter().zip(&traced.series) {
                assert_eq!(ta.to_bits(), tb.to_bits(), "{name} K={k} W={w}: series time");
                assert_eq!(va.to_bits(), vb.to_bits(), "{name} K={k} W={w}: series value");
            }
            for (pa, pb) in plain.tenants.iter().zip(&traced.tenants) {
                assert_eq!(
                    pa.throughput.to_bits(),
                    pb.throughput.to_bits(),
                    "{name} K={k} W={w}: tenant {}",
                    pa.id
                );
                assert_eq!(
                    pa.items_processed, pb.items_processed,
                    "{name} K={k} W={w}: tenant {}",
                    pa.id
                );
            }
            let sink = traced_coord.take_trace().expect("trace sink present after run");
            assert!(!sink.is_empty(), "{name} K={k} W={w}: trace must record events");
        }
    }
}

/// Same seed ⇒ byte-identical JSONL on the sim lane.  Wall-lane records
/// (solver/pool wall clocks) are host-dependent by design, so they are
/// the only lines allowed to differ between two identical runs.
#[test]
fn trace_jsonl_deterministic_modulo_wall_lane() {
    let sim_lines = |k: usize, w: usize| {
        let mut coord = two_tenant_sharded(&Variant::trident(), 11, k, w);
        coord.enable_trace();
        coord.run(300.0);
        let sink = coord.take_trace().expect("trace sink present after run");
        sink.to_jsonl()
            .lines()
            .filter(|l| !l.contains("\"lane\":\"wall\""))
            .map(|l| l.to_string())
            .collect::<Vec<String>>()
    };
    let a = sim_lines(3, 4);
    let b = sim_lines(3, 4);
    assert!(!a.is_empty(), "trace must have sim-lane records");
    assert_eq!(a, b, "same-seed sim-lane JSONL must be byte-identical");
    // And the sim lane is (K, W)-invariant too: sharding is a wall-clock
    // optimization, never a semantic one.  Only the header may differ —
    // it records the run's shard/worker configuration by design.
    let c = sim_lines(1, 1);
    assert_eq!(a.len(), c.len(), "sim-lane record count must not depend on (K, W)");
    assert_ne!(a[0], c[0], "header must record the actual (K, W)");
    assert_eq!(a[1..], c[1..], "sim-lane JSONL beyond the header must not depend on (K, W)");
}
