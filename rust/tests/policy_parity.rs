//! Policy-parity regression tests for the trait-based policy refactor:
//! the `SchedulingPolicy` dispatch must change structure, not results.
//!
//! * every policy driven through the parallel harness produces a
//!   bit-identical `RunReport` to the reference serial path;
//! * the per-policy semantics of the old inline dispatch are preserved
//!   (Static/SCOOT never re-plan, only Trident touches the MILP, every
//!   baseline keeps making progress);
//! * harness aggregates are invariant to the worker count (`--jobs`).

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, RunReport, Variant};
use trident::harness::{self, Job};
use trident::sim::ItemAttrs;
use trident::workload::pdf;

fn mini_cfg() -> TridentConfig {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 800;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    cfg
}

fn mk_with_cfg(variant: &Variant, seed: u64, cfg: TridentConfig) -> Coordinator {
    Coordinator::new(
        pdf::pipeline(),
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0),
        Box::new(pdf::trace(50_000)),
        cfg,
        variant.clone(),
        ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 },
        seed,
    )
}

fn mk(variant: &Variant, seed: u64) -> Coordinator {
    mk_with_cfg(variant, seed, mini_cfg())
}

/// Like [`mk`] but with a generous MILP wall-clock budget: the mini
/// 2-node instance always reaches `Status::Optimal`, so Trident plans are
/// deterministic even when sibling worker threads oversubscribe the host
/// (the anytime-solver caveat in the harness docs).
fn mk_det(variant: &Variant, seed: u64) -> Coordinator {
    let mut cfg = mini_cfg();
    cfg.milp_time_budget_ms = 10_000;
    mk_with_cfg(variant, seed, cfg)
}

fn all_policies() -> Vec<(&'static str, Variant)> {
    vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::baseline(Policy::RayData)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("ContTune", Variant::baseline(Policy::ContTune)),
        ("SCOOT", Variant::baseline(Policy::Scoot)),
        ("Trident", Variant::trident()),
    ]
}

/// The fields that pin a run's outcome exactly (throughput compared at the
/// bit level — the refactor must not perturb a single event).
fn key(r: &RunReport) -> (u64, u64, u32, u64, usize) {
    (
        r.throughput.to_bits(),
        r.items_processed,
        r.oom_events,
        r.config_transitions,
        r.milp_ms.len(),
    )
}

/// Each of the six policies, run through the harness, must reproduce the
/// reference serial run bit-for-bit.
#[test]
fn trait_dispatch_matches_serial_reference() {
    for (name, variant) in all_policies() {
        let serial = mk_det(&variant, 5).run(300.0);
        let jobs = vec![Job::timed(name, variant.clone(), 5, 300.0)];
        let harnessed = harness::run_grid(&jobs, 1, |_, job| mk_det(&job.variant, job.seed));
        assert_eq!(key(&serial), key(&harnessed[0]), "policy {name} diverged");
        assert!(serial.throughput > 0.0, "{name} must make progress");
    }
}

/// Semantics of the pre-refactor inline dispatch, now enforced per trait
/// impl: Static/SCOOT never transition or re-solve; only Trident records
/// MILP solves; reactive baselines keep flowing.
#[test]
fn policy_semantics_preserved() {
    let s = mk(&Variant::baseline(Policy::Static), 3).run(300.0);
    assert_eq!(s.config_transitions, 0, "Static never transitions");
    assert!(s.milp_ms.is_empty(), "Static never re-solves the MILP");

    let sc = mk(&Variant::baseline(Policy::Scoot), 3).run(300.0);
    assert_eq!(sc.config_transitions, 0, "SCOOT never transitions at runtime");
    assert!(sc.milp_ms.is_empty(), "SCOOT never re-solves the MILP");

    let t = mk(&Variant::trident(), 3).run(300.0);
    assert!(!t.milp_ms.is_empty(), "Trident re-solves the MILP");

    for p in [Policy::RayData, Policy::Ds2, Policy::ContTune] {
        let r = mk(&Variant::baseline(p), 3).run(300.0);
        assert!(r.throughput > 0.0, "{p:?} must make progress");
        assert!(r.milp_ms.is_empty(), "{p:?} never touches the MILP");
    }
}

/// The dynamics subsystem at rest: attaching an EMPTY `DynamicsSpec`
/// (no scripted events, no MTBF churn) engages the timeline machinery
/// but must not perturb a single event — bit-identical to the classic
/// closed loop for every policy.  This is the no-dynamics compatibility
/// contract of the cluster-dynamics PR.
#[test]
fn empty_dynamics_is_bit_identical() {
    for (name, variant) in all_policies() {
        let base = mk_det(&variant, 5).run(300.0);
        let mut coord = mk_det(&variant, 5);
        coord
            .set_dynamics(trident::dynamics::DynamicsSpec::default())
            .expect("empty dynamics spec is valid");
        let with = coord.run(300.0);
        assert_eq!(key(&base), key(&with), "policy {name} perturbed by empty dynamics");
        assert!(with.events.is_empty());
        assert_eq!(with.lost_records, 0);
    }
}

/// The batched-transfer overhaul at rest: flipping the simulator back to
/// the legacy seed event stream (`sim_seed_event_stream`) must not
/// perturb a single event — the two transfer representations share one
/// `(time, seq)` key space, so this is bit-identical, not approximate.
/// The full six-policy sweep lives in `tests/sim_perf_parity.rs`; this
/// pin keeps the contract visible next to the other parity invariants.
#[test]
fn seed_event_stream_is_bit_identical() {
    for (name, variant) in
        [("Static", Variant::baseline(Policy::Static)), ("Trident", Variant::trident())]
    {
        let batched = mk_det(&variant, 5).run(300.0);
        let mut cfg = mini_cfg();
        cfg.milp_time_budget_ms = 10_000;
        cfg.sim_seed_event_stream = true;
        let seeded = mk_with_cfg(&variant, 5, cfg).run(300.0);
        assert_eq!(key(&batched), key(&seeded), "policy {name} diverged across transfer modes");
    }
}

/// Same grid, different `--jobs`: reports and aggregates are identical.
#[test]
fn harness_invariant_to_worker_count() {
    let grid: Vec<Job> = [
        ("Static", Variant::baseline(Policy::Static)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("Trident", Variant::trident()),
    ]
    .into_iter()
    .flat_map(|(name, v)| {
        (0..2u64).map(move |s| Job::timed(name, v.clone(), 5 + s, 250.0))
    })
    .collect();

    let serial = harness::run_grid(&grid, 1, |_, job| mk_det(&job.variant, job.seed));
    let parallel = harness::run_grid(&grid, 4, |_, job| mk_det(&job.variant, job.seed));
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(key(a), key(b), "cell {i} depends on worker count");
    }

    let s1 = harness::summarize(&grid, &serial);
    let s4 = harness::summarize(&grid, &parallel);
    assert_eq!(s1.len(), 3);
    for (a, b) in s1.iter().zip(&s4) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.n, 2, "two seeds per label");
        assert_eq!(
            a.throughput.mean.to_bits(),
            b.throughput.mean.to_bits(),
            "aggregate for {} depends on worker count",
            a.label
        );
        assert_eq!(a.throughput.std.to_bits(), b.throughput.std.to_bits());
    }
}
