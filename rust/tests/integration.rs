//! Integration tests: closed loop, PJRT round-trip vs native oracle, plan
//! feasibility invariants, failure injection.

use std::time::Duration;

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::rngx::Rng;
#[cfg(feature = "pjrt")]
use trident::runtime::{fit_hyper, GpBackend};
use trident::scheduling::{solve, MilpInput, OpSched};
use trident::sim::ItemAttrs;
use trident::workload::pdf;

fn mini() -> Coordinator {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 8;
    cfg.bo_init = 3;
    cfg.milp_time_budget_ms = 800;
    Coordinator::new(
        pdf::pipeline(),
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0),
        Box::new(pdf::trace(50_000)),
        cfg,
        Variant::trident(),
        ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 },
        5,
    )
}

#[test]
fn closed_loop_survives_regime_shifts_and_makes_progress() {
    let mut c = mini();
    let r = c.run(900.0);
    assert!(r.throughput > 0.1, "{r:?}");
    assert!(r.items_processed > 50);
    // the control loop actually ran
    assert!(!r.milp_ms.is_empty());
    assert!(r.obs_overhead_ms >= 0.0);
}

/// The PJRT artifact and the native oracle must agree numerically.
/// (Compiled only with the `pjrt` feature; the offline default build has
/// no PJRT backend at all.)
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_matches_native_gp() {
    let Ok(arts) = trident::runtime::Artifacts::load(&trident::runtime::Artifacts::default_dir())
    else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let pjrt = GpBackend::Pjrt(arts);
    let native = GpBackend::Native;
    let mut rng = Rng::new(0);
    for case in 0..5 {
        let n = 5 + rng.below(40);
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..4).map(|_| rng.uniform(0.0, 2.0)).collect())
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + x[0] - 0.5 * x[1] + rng.normal(0.0, 0.05)).collect();
        let qs: Vec<Vec<f64>> = (0..8)
            .map(|_| (0..4).map(|_| rng.uniform(0.0, 2.0)).collect())
            .collect();
        let hyper = fit_hyper(&xs, &ys);
        let a = pjrt.gp_predict(&xs, &ys, &qs, hyper).unwrap();
        let b = native.gp_predict(&xs, &ys, &qs, hyper).unwrap();
        for (i, ((ma, va), (mb, vb))) in a.iter().zip(&b).enumerate() {
            assert!(
                (ma - mb).abs() < 2e-2 * (1.0 + mb.abs()),
                "case {case} q{i}: mean {ma} vs {mb}"
            );
            assert!((va - vb).abs() < 5e-2 * (1.0 + vb.abs()), "case {case} q{i}: var {va} vs {vb}");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_acquisition_matches_native() {
    let Ok(arts) = trident::runtime::Artifacts::load(&trident::runtime::Artifacts::default_dir())
    else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pjrt = GpBackend::Pjrt(arts);
    let native = GpBackend::Native;
    let mut rng = Rng::new(1);
    let n = 12;
    let thetas: Vec<Vec<f64>> = (0..n).map(|_| (0..6).map(|_| rng.f64()).collect()).collect();
    let uts: Vec<f64> = thetas.iter().map(|t| 5.0 + 4.0 * t[0]).collect();
    let mems: Vec<f64> = thetas.iter().map(|t| 30.0 + 30.0 * t[0] * t[0]).collect();
    let cands: Vec<Vec<f64>> = (0..20).map(|_| (0..6).map(|_| rng.f64()).collect()).collect();
    let hu = fit_hyper(&thetas, &uts);
    let hm = fit_hyper(&thetas, &mems);
    let a = pjrt.acquisition(&thetas, &uts, &mems, &cands, hu, hm, 8.0, 55.0).unwrap();
    let b = native.acquisition(&thetas, &uts, &mems, &cands, hu, hm, 8.0, 55.0).unwrap();
    for (i, (pa, pb)) in a.iter().zip(&b).enumerate() {
        assert!((pa.pof - pb.pof).abs() < 0.05, "cand {i}: pof {} vs {}", pa.pof, pb.pof);
        assert!(
            (pa.mu_ut - pb.mu_ut).abs() < 0.1 * (1.0 + pb.mu_ut.abs()),
            "cand {i}: mu {} vs {}",
            pa.mu_ut,
            pb.mu_ut
        );
    }
}

/// Property: MILP plans are feasible under random scheduler states.
#[test]
fn milp_plans_always_feasible() {
    let mut rng = Rng::new(7);
    for case in 0..15 {
        let k = 2 + rng.below(3);
        let n = 3 + rng.below(5);
        let nodes = ClusterSpec::homogeneous(k, 64.0, 256.0, 4, 65536.0, 1250.0).nodes;
        let ops: Vec<OpSched> = (0..n)
            .map(|i| {
                let accel = rng.bool(0.3);
                OpSched {
                    name: format!("op{i}"),
                    ut_cur: rng.uniform(0.5, 30.0),
                    ut_cand: rng.bool(0.3).then(|| rng.uniform(1.0, 40.0)),
                    n_new: 0,
                    n_old: rng.below(6) as u32 + 1,
                    cpu: if accel { 8.0 } else { rng.uniform(0.5, 4.0) },
                    mem_gb: rng.uniform(1.0, 8.0),
                    accels: accel as u32,
                    out_mb: rng.uniform(0.05, 20.0),
                    d_i: rng.uniform(0.5, 20.0),
                    h_start: 2.0,
                    h_stop: 1.0,
                    h_cold: rng.uniform(5.0, 40.0),
                    cur_x: (0..k).map(|_| rng.below(3) as u32).collect(),
                }
            })
            .collect();
        let input = MilpInput {
            ops,
            edges: (1..n).map(|i| (i - 1, i)).collect(),
            nodes,
            d_o: rng.uniform(0.5, 5.0),
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            t_sched: 90.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 4,
            placement_aware: rng.bool(0.7),
            join_colocate: rng.bool(0.3),
            all_at_once: rng.bool(0.3),
        };
        let plan = solve(&input, Duration::from_secs(3));
        // Plan invariants
        for (i, o) in input.ops.iter().enumerate() {
            assert_eq!(
                plan.x[i].iter().sum::<u32>(),
                plan.p[i],
                "case {case}: placement consistency"
            );
            assert!(plan.p[i] >= 1, "case {case}: p>=1");
            assert!(
                plan.b[i] <= o.n_old.max(plan.p[i]),
                "case {case}: rolling batch bound"
            );
        }
        for kk in 0..k {
            let acc: u32 = (0..n).map(|i| plan.x[i][kk] * input.ops[i].accels).sum();
            assert!(acc <= 4, "case {case}: accel capacity");
            let cpu: f64 = (0..n).map(|i| plan.x[i][kk] as f64 * input.ops[i].cpu).sum();
            assert!(cpu <= 64.0 + 1e-6, "case {case}: cpu capacity");
        }
    }
}

/// Failure injection: an OOM-prone deployed configuration must not wedge
/// the pipeline — the safety fallback reverts to defaults.
#[test]
fn oom_storm_recovers() {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    let mut variant = Variant::baseline(Policy::Static);
    // Deploy an OOM-prone config on every tunable op from t=0.
    let pl = pdf::pipeline();
    variant.initial_configs = Some(
        pl.operators
            .iter()
            .map(|o| o.tunable.then(|| vec![128.0, 16384.0, 32.0, 0.0, 0.0, 0.0]))
            .collect(),
    );
    let mut c = Coordinator::new(
        pl,
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0),
        Box::new(pdf::trace(50_000)),
        cfg,
        variant,
        ItemAttrs { tokens_in: 96_000.0, tokens_out: 19_200.0, pixels_m: 30.0, frames: 30.0 },
        9,
    );
    let r = c.run(600.0);
    assert!(r.oom_events > 0, "injection must trigger OOMs");
    assert!(r.throughput > 0.01, "pipeline must keep making progress: {r:?}");
}

#[test]
fn deterministic_runs_same_seed() {
    let r1 = mini().run(300.0);
    let r2 = mini().run(300.0);
    assert_eq!(r1.items_processed, r2.items_processed);
    assert!((r1.throughput - r2.throughput).abs() < 1e-12);
}
