//! Table 2 (RQ2): scheduling-layer comparison under identical
//! observation + adaptation inputs (baselines get Trident's estimates and
//! recommendations, applied all-at-once).
//! Paper: Trident 2.01x/1.88x > Trident(all-at-once) 1.92x/1.79x >
//! ContTune 1.42x/1.36x > DS2 1.38x/1.25x > RayData 1.22x/1.30x.

#[path = "common.rs"]
mod common;

use trident::coordinator::{Policy, Variant};
use trident::report::Table;

fn main() {
    let mut table = Table::new(
        "Table 2: scheduling under shared Observation+Adaptation (vs Static)",
        &["Method", "PDF", "Video"],
    );
    let methods: Vec<(&str, Variant)> = vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::controlled(Policy::RayData)),
        ("DS2", Variant::controlled(Policy::Ds2)),
        ("ContTune", Variant::controlled(Policy::ContTune)),
        ("Trident (all-at-once)", {
            let mut v = Variant::trident();
            v.rolling = false;
            v
        }),
        ("Trident", Variant::trident()),
    ];
    let mut base = [1.0, 1.0];
    let mut rows = Vec::new();
    for (name, variant) in methods {
        let mut speed = Vec::new();
        for (j, wname) in ["PDF", "Video"].iter().enumerate() {
            let w = common::workload(wname);
            let r = common::run(w, variant.clone(), 11);
            eprintln!("  {name} / {wname}: {:.3} items/s", r.throughput);
            if name == "Static" {
                base[j] = r.throughput.max(1e-12);
            }
            speed.push(r.throughput / base[j]);
        }
        rows.push((name.to_string(), speed));
    }
    for (name, speed) in rows {
        table.row(vec![name, format!("{:.2}x", speed[0]), format!("{:.2}x", speed[1])]);
    }
    table.emit("table2_scheduling");
}
