//! Table 2 (RQ2): scheduling-layer comparison under identical
//! observation + adaptation inputs (baselines get Trident's estimates and
//! recommendations, applied all-at-once).
//! Paper: Trident 2.01x/1.88x > Trident(all-at-once) 1.92x/1.79x >
//! ContTune 1.42x/1.36x > DS2 1.38x/1.25x > RayData 1.22x/1.30x.
//!
//! The 24 (method, workload) cells fan out across cores (Speech is this
//! repo's fork/join DAG extension and PDF+Speech its two-tenant
//! shared-cluster scenario; the paper reports PDF and Video only).

#[path = "common.rs"]
mod common;

use trident::coordinator::{Policy, Variant};
use trident::report::Table;

const WORKLOADS: [&str; 4] = ["PDF", "Video", "Speech", "PDF+Speech"];

fn main() {
    let methods: Vec<(&str, Variant)> = vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::controlled(Policy::RayData)),
        ("DS2", Variant::controlled(Policy::Ds2)),
        ("ContTune", Variant::controlled(Policy::ContTune)),
        ("Trident (all-at-once)", {
            let mut v = Variant::trident();
            v.rolling = false;
            v
        }),
        ("Trident", Variant::trident()),
    ];
    let mut cells = Vec::new();
    for (name, variant) in &methods {
        for wname in WORKLOADS {
            cells.push(common::Cell::new(format!("{name}/{wname}"), wname, variant.clone(), 11));
        }
    }
    let reports = common::run_cells(&cells);

    let mut table = Table::new(
        "Table 2: scheduling under shared Observation+Adaptation (vs Static)",
        &["Method", "PDF", "Video", "Speech", "PDF+Speech"],
    );
    let mut base = vec![1.0; WORKLOADS.len()];
    let mut rows = Vec::new();
    for (mi, (name, _)) in methods.iter().enumerate() {
        let mut speed = Vec::new();
        for j in 0..WORKLOADS.len() {
            let r = &reports[mi * WORKLOADS.len() + j];
            eprintln!("  {name} / {}: {:.3} items/s", WORKLOADS[j], r.throughput);
            if *name == "Static" {
                base[j] = r.throughput.max(1e-12);
            }
            speed.push(r.throughput / base[j]);
        }
        rows.push((name.to_string(), speed));
    }
    for (name, speed) in rows {
        let mut row = vec![name];
        row.extend(speed.iter().map(|s| format!("{s:.2}x")));
        table.row(row);
    }
    table.emit("table2_scheduling");
}
