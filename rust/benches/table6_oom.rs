//! Table 6 (RQ4c): OOM events and throughput impact during end-to-end
//! execution — Constrained vs Unconstrained BO in the full closed loop,
//! plus an (approximate) OOM-free oracle.
//! Paper: constrained cuts OOM events ~80% and downtime 462→102 s /
//! 352→68 s, ending up faster despite conservative configs.

#[path = "common.rs"]
mod common;

use trident::adaptation::Strategy;
use trident::coordinator::Variant;
use trident::report::Table;

fn main() {
    let mut table = Table::new(
        "Table 6: OOM events and throughput impact (end-to-end)",
        &["Metric", "PDF Unconstr.", "PDF Constr.", "Video Unconstr.", "Video Constr."],
    );
    let mut events = Vec::new();
    let mut downtime = Vec::new();
    let mut loss = Vec::new();
    for wname in ["PDF", "Video"] {
        // approximate OOM-free oracle: constrained BO with a wide margin
        let oracle = {
            let w = common::workload(wname);
            let mut v = Variant::trident();
            v.strategy = Strategy::ConstrainedBo;
            let mut cfg_run = common::run(w, v, 21);
            cfg_run.throughput += 0.0;
            cfg_run
        };
        for strategy in [Strategy::UnconstrainedBo, Strategy::ConstrainedBo] {
            let w = common::workload(wname);
            let mut v = Variant::trident();
            v.strategy = strategy;
            let r = common::run(w, v, 13);
            eprintln!(
                "  {wname} {strategy:?}: {} OOMs, {:.0}s downtime, {:.3} items/s",
                r.oom_events, r.oom_downtime_s, r.throughput
            );
            events.push(r.oom_events);
            downtime.push(r.oom_downtime_s);
            let oracle_thr = oracle.throughput.max(r.throughput);
            loss.push(100.0 * (1.0 - r.throughput / oracle_thr));
        }
    }
    table.row(vec![
        "OOM events".into(),
        events[0].to_string(),
        events[1].to_string(),
        events[2].to_string(),
        events[3].to_string(),
    ]);
    table.row(vec![
        "Cumulative downtime (s)".into(),
        format!("{:.0}", downtime[0]),
        format!("{:.0}", downtime[1]),
        format!("{:.0}", downtime[2]),
        format!("{:.0}", downtime[3]),
    ]);
    table.row(vec![
        "Throughput loss vs oracle".into(),
        format!("{:.1}%", loss[0]),
        format!("{:.1}%", loss[1]),
        format!("{:.1}%", loss[2]),
        format!("{:.1}%", loss[3]),
    ]);
    table.emit("table6_oom");
}
