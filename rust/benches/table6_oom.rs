//! Table 6 (RQ4c): OOM events and throughput impact during end-to-end
//! execution — Constrained vs Unconstrained BO in the full closed loop,
//! plus an (approximate) OOM-free oracle.
//! Paper: constrained cuts OOM events ~80% and downtime 462→102 s /
//! 352→68 s, ending up faster despite conservative configs.
//!
//! The 6 (workload, strategy) cells — oracle + two strategies per
//! workload — fan out across cores.

#[path = "common.rs"]
mod common;

use trident::adaptation::Strategy;
use trident::coordinator::Variant;
use trident::report::Table;

fn main() {
    // Per workload: [oracle (constrained, wide margin), Unconstrained,
    // Constrained] — 3 cells each, in that order.
    let mut cells = Vec::new();
    for wname in ["PDF", "Video"] {
        let mut oracle = Variant::trident();
        oracle.strategy = Strategy::ConstrainedBo;
        cells.push(common::Cell::new(format!("oracle/{wname}"), wname, oracle, 21));
        for strategy in [Strategy::UnconstrainedBo, Strategy::ConstrainedBo] {
            let mut v = Variant::trident();
            v.strategy = strategy;
            cells.push(common::Cell::new(format!("{strategy:?}/{wname}"), wname, v, 13));
        }
    }
    let reports = common::run_cells(&cells);

    let mut events = Vec::new();
    let mut downtime = Vec::new();
    let mut loss = Vec::new();
    for (wi, wname) in ["PDF", "Video"].into_iter().enumerate() {
        let oracle = &reports[wi * 3];
        for (si, strategy) in [Strategy::UnconstrainedBo, Strategy::ConstrainedBo]
            .into_iter()
            .enumerate()
        {
            let r = &reports[wi * 3 + 1 + si];
            eprintln!(
                "  {wname} {strategy:?}: {} OOMs, {:.0}s downtime, {:.3} items/s",
                r.oom_events, r.oom_downtime_s, r.throughput
            );
            events.push(r.oom_events);
            downtime.push(r.oom_downtime_s);
            let oracle_thr = oracle.throughput.max(r.throughput);
            loss.push(100.0 * (1.0 - r.throughput / oracle_thr));
        }
    }

    let mut table = Table::new(
        "Table 6: OOM events and throughput impact (end-to-end)",
        &["Metric", "PDF Unconstr.", "PDF Constr.", "Video Unconstr.", "Video Constr."],
    );
    table.row(vec![
        "OOM events".into(),
        events[0].to_string(),
        events[1].to_string(),
        events[2].to_string(),
        events[3].to_string(),
    ]);
    table.row(vec![
        "Cumulative downtime (s)".into(),
        format!("{:.0}", downtime[0]),
        format!("{:.0}", downtime[1]),
        format!("{:.0}", downtime[2]),
        format!("{:.0}", downtime[3]),
    ]);
    table.row(vec![
        "Throughput loss vs oracle".into(),
        format!("{:.1}%", loss[0]),
        format!("{:.1}%", loss[1]),
        format!("{:.1}%", loss[2]),
        format!("{:.1}%", loss[3]),
    ]);
    table.emit("table6_oom");
}
