//! Figure 2 (RQ1): end-to-end throughput of every scheduler on both
//! pipelines, reported as speedup over Static.
//! Paper: Trident 2.01x/1.88x > SCOOT 1.21x/1.17x > RayData 1.12x/1.18x >
//! ContTune 1.04x/0.96x > DS2 0.87x/0.79x.

#[path = "common.rs"]
mod common;

use trident::coordinator::{Policy, Variant};
use trident::report::{f2, Table};

fn main() {
    let mut table = Table::new(
        "Figure 2: end-to-end throughput (speedup vs Static)",
        &["Method", "PDF items/s", "PDF speedup", "Video items/s", "Video speedup"],
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let methods: Vec<(&str, Box<dyn Fn(&common::Workload) -> Variant>)> = vec![
        ("Static", Box::new(|_| Variant::baseline(Policy::Static))),
        ("Ray Data", Box::new(|_| Variant::baseline(Policy::RayData))),
        ("DS2", Box::new(|_| Variant::baseline(Policy::Ds2))),
        ("ContTune", Box::new(|_| Variant::baseline(Policy::ContTune))),
        ("SCOOT", Box::new(|w| common::scoot_variant(&w.pipeline, w.src))),
        ("Trident", Box::new(|_| Variant::trident())),
    ];
    for (name, mk) in &methods {
        let mut thr = Vec::new();
        for wname in ["PDF", "Video"] {
            let w = common::workload(wname);
            let variant = mk(&w);
            let r = common::run(w, variant, 7);
            eprintln!("  {name} / {wname}: {:.3} items/s ({:.0}s)", r.throughput, r.duration_s);
            thr.push(r.throughput);
        }
        rows.push((name.to_string(), thr));
    }
    let base = rows[0].1.clone();
    for (name, thr) in &rows {
        table.row(vec![
            name.clone(),
            f2(thr[0]),
            format!("{:.2}x", thr[0] / base[0]),
            f2(thr[1]),
            format!("{:.2}x", thr[1] / base[1]),
        ]);
    }
    table.emit("fig2_end_to_end");
}
