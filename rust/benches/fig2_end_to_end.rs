//! Figure 2 (RQ1): end-to-end throughput of every scheduler on both
//! pipelines, reported as speedup over Static.
//! Paper: Trident 2.01x/1.88x > SCOOT 1.21x/1.17x > RayData 1.12x/1.18x >
//! ContTune 1.04x/0.96x > DS2 0.87x/0.79x.
//!
//! The 24 (method, workload) cells are independent runs; they fan out
//! across cores through the experiment harness.  (Speech is this repo's
//! fork/join DAG extension, and PDF+Speech its two-tenant shared-cluster
//! scenario; the paper reports single-tenant PDF and Video only.)

#[path = "common.rs"]
mod common;

use trident::coordinator::{Policy, Variant};
use trident::report::{f2, Table};

const WORKLOADS: [&str; 4] = ["PDF", "Video", "Speech", "PDF+Speech"];

fn main() {
    let methods: Vec<(&str, Box<dyn Fn(&str) -> Variant>)> = vec![
        ("Static", Box::new(|_| Variant::baseline(Policy::Static))),
        ("Ray Data", Box::new(|_| Variant::baseline(Policy::RayData))),
        ("DS2", Box::new(|_| Variant::baseline(Policy::Ds2))),
        ("ContTune", Box::new(|_| Variant::baseline(Policy::ContTune))),
        ("SCOOT", Box::new(common::scoot_variant_for)),
        ("Trident", Box::new(|_| Variant::trident())),
    ];
    let mut cells = Vec::new();
    for (name, mk) in &methods {
        for wname in WORKLOADS {
            cells.push(common::Cell::new(format!("{name}/{wname}"), wname, mk(wname), 7));
        }
    }
    let reports = common::run_cells(&cells);

    let mut table = Table::new(
        "Figure 2: end-to-end throughput (speedup vs Static)",
        &[
            "Method",
            "PDF items/s",
            "PDF speedup",
            "Video items/s",
            "Video speedup",
            "Speech items/s",
            "Speech speedup",
            "PDF+Speech items/s",
            "PDF+Speech speedup",
        ],
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (mi, (name, _)) in methods.iter().enumerate() {
        let thr: Vec<f64> = (0..WORKLOADS.len())
            .map(|j| {
                let r = &reports[mi * WORKLOADS.len() + j];
                eprintln!(
                    "  {name} / {}: {:.3} items/s ({:.0}s)",
                    WORKLOADS[j], r.throughput, r.duration_s
                );
                r.throughput
            })
            .collect();
        rows.push((name.to_string(), thr));
    }
    let base = rows[0].1.clone();
    for (name, thr) in &rows {
        let mut row = vec![name.clone()];
        for j in 0..WORKLOADS.len() {
            row.push(f2(thr[j]));
            row.push(format!("{:.2}x", thr[j] / base[j]));
        }
        table.row(row);
    }
    table.emit("fig2_end_to_end");
}
