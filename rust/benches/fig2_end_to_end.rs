//! Figure 2 (RQ1): end-to-end throughput of every scheduler on both
//! pipelines, reported as speedup over Static.
//! Paper: Trident 2.01x/1.88x > SCOOT 1.21x/1.17x > RayData 1.12x/1.18x >
//! ContTune 1.04x/0.96x > DS2 0.87x/0.79x.
//!
//! The 12 (method, workload) cells are independent runs; they fan out
//! across cores through the experiment harness.

#[path = "common.rs"]
mod common;

use trident::coordinator::{Policy, Variant};
use trident::report::{f2, Table};

const WORKLOADS: [&str; 2] = ["PDF", "Video"];

fn main() {
    let methods: Vec<(&str, Box<dyn Fn(&common::Workload) -> Variant>)> = vec![
        ("Static", Box::new(|_| Variant::baseline(Policy::Static))),
        ("Ray Data", Box::new(|_| Variant::baseline(Policy::RayData))),
        ("DS2", Box::new(|_| Variant::baseline(Policy::Ds2))),
        ("ContTune", Box::new(|_| Variant::baseline(Policy::ContTune))),
        ("SCOOT", Box::new(|w| common::scoot_variant(&w.pipeline, w.src))),
        ("Trident", Box::new(|_| Variant::trident())),
    ];
    let mut cells = Vec::new();
    for (name, mk) in &methods {
        for wname in WORKLOADS {
            let w = common::workload(wname);
            cells.push(common::Cell::new(format!("{name}/{wname}"), wname, mk(&w), 7));
        }
    }
    let reports = common::run_cells(&cells);

    let mut table = Table::new(
        "Figure 2: end-to-end throughput (speedup vs Static)",
        &["Method", "PDF items/s", "PDF speedup", "Video items/s", "Video speedup"],
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (mi, (name, _)) in methods.iter().enumerate() {
        let thr: Vec<f64> = (0..WORKLOADS.len())
            .map(|j| {
                let r = &reports[mi * WORKLOADS.len() + j];
                eprintln!(
                    "  {name} / {}: {:.3} items/s ({:.0}s)",
                    WORKLOADS[j], r.throughput, r.duration_s
                );
                r.throughput
            })
            .collect();
        rows.push((name.to_string(), thr));
    }
    let base = rows[0].1.clone();
    for (name, thr) in &rows {
        table.row(vec![
            name.clone(),
            f2(thr[0]),
            format!("{:.2}x", thr[0] / base[0]),
            f2(thr[1]),
            format!("{:.2}x", thr[1] / base[1]),
        ]);
    }
    table.emit("fig2_end_to_end");
}
