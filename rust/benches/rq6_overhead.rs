//! RQ6: system overhead — per-invocation observation/adaptation cost and
//! MILP solve time at 8 and 16 nodes.
//! Paper: obs 2 ms, adapt 4 ms; MILP 206/62 ms (8 nodes) -> 1521/259 ms
//! (16 nodes), all off the critical path.

#[path = "common.rs"]
mod common;

use std::time::{Duration, Instant};
use trident::config::TenancyView;
use trident::coordinator::{nominal_attrs_rooted, Variant};
use trident::report::Table;
use trident::scheduling::{solve, MilpInput, MilpTenant, OpSched};
use trident::sim::ItemAttrs;

/// MILP instance for a bench workload; `A+B` names build the joint
/// multi-tenant problem (union of operators, weighted max-min objective).
fn milp_input(wname: &str, nodes: usize) -> MilpInput {
    let (spec, view, srcs) = if wname.contains('+') {
        let (tenancy, _, srcs) = common::tenancy_for(wname);
        let (spec, view) = tenancy.merged().expect("bench tenancy is valid");
        (spec, view, srcs)
    } else {
        let w = common::workload(wname);
        let view = TenancyView::single_for(&w.pipeline);
        (w.pipeline, view, vec![w.src])
    };
    let roots: Vec<(usize, ItemAttrs)> = view.sources.iter().copied().zip(srcs).collect();
    let nominal = nominal_attrs_rooted(&spec, &roots);
    let (d_i, d_o) = spec.amplification();
    MilpInput {
        ops: spec
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| OpSched {
                name: o.name.clone(),
                ut_cur: trident::sim::service::true_unit_rate(
                    &o.service,
                    &o.config_space.default_config(),
                    &nominal[i],
                ),
                ut_cand: if o.tunable { Some(1.5) } else { None },
                n_new: 0,
                n_old: 4,
                cpu: o.cpu,
                mem_gb: o.mem_gb,
                accels: o.accels,
                out_mb: o.out_mb,
                d_i: d_i[i],
                h_start: o.start_s,
                h_stop: o.stop_s,
                h_cold: o.cold_s,
                cur_x: vec![0; nodes],
            })
            .collect(),
        edges: spec.edges.clone(),
        nodes: common::cluster(nodes).nodes,
        d_o,
        tenants: MilpTenant::from_view(&view),
        op_tenant: view.op_tenant.clone(),
        t_sched: 90.0,
        lambda1: 1e-4,
        lambda2: 1e-6,
        b_max: 8,
        placement_aware: true,
        join_colocate: false,
        all_at_once: false,
    }
}

fn main() {
    // Layer overheads from a short live run.
    let w = common::workload("PDF");
    let mut cfg = trident::config::TridentConfig::default();
    cfg.native_gp = false;
    let mut coord = trident::coordinator::Coordinator::new(
        w.pipeline,
        common::cluster(8),
        w.trace,
        cfg,
        Variant::trident(),
        w.src,
        1,
    );
    let r = coord.run(600.0);

    let mut table = Table::new("RQ6: system overhead", &["Metric", "Measured"]);
    table.row(vec!["Observation layer / invocation".into(), format!("{:.2} ms", r.obs_overhead_ms)]);
    table.row(vec!["Adaptation layer / invocation".into(), format!("{:.2} ms", r.adapt_overhead_ms)]);

    for nodes in [8usize, 16] {
        // Speech exercises the DAG (fork/join) edge-list formulation;
        // PDF+Speech the joint multi-tenant (weighted max-min) problem.
        for wname in ["PDF", "Video", "Speech", "PDF+Speech"] {
            let input = milp_input(wname, nodes);
            // median of 3 solves
            // The scheduler consumes the incumbent at its solve budget
            // (2 s); report wall at budget plus the remaining B&B gap,
            // the total simplex pivots, and the in-tree warm-start hit
            // rate (children inheriting their parent's basis).
            let mut times: Vec<(f64, f64, usize, f64, f64, f64, f64)> = (0..3)
                .map(|_| {
                    let t0 = Instant::now();
                    let plan = solve(&input, Duration::from_secs(2));
                    assert!(plan.t_pred > 0.0);
                    (
                        t0.elapsed().as_secs_f64() * 1e3,
                        plan.stats.gap * 100.0,
                        plan.stats.pivots,
                        plan.stats.warm_hit_rate() * 100.0,
                        plan.stats.build_ms,
                        plan.stats.root_lp_ms,
                        plan.stats.bnb_ms,
                    )
                })
                .collect();
            times.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            table.row(vec![
                format!("MILP solve, {wname} pipeline, {nodes} nodes (median)"),
                format!(
                    "{:.0} ms (build {:.1} / root LP {:.1} / B&B {:.1} ms; gap {:.1}%, \
                     {} pivots, warm-start hit rate {:.1}%)",
                    times[1].0, times[1].4, times[1].5, times[1].6, times[1].1, times[1].2,
                    times[1].3
                ),
            ]);
            // Cross-round warm start on the multi-tenant instance: round
            // 2 of the same-shape problem with drifted rates through the
            // basis cache — the online re-optimization cost RQ6 cares
            // about.
            if wname.contains('+') {
                let mut cache = trident::scheduling::BasisCache::new();
                let r1 =
                    trident::scheduling::solve_cached(&input, Duration::from_secs(2), &mut cache);
                let mut input2 = input.clone();
                for o in &mut input2.ops {
                    o.ut_cur *= 1.03;
                }
                let t0 = Instant::now();
                let r2 =
                    trident::scheduling::solve_cached(&input2, Duration::from_secs(2), &mut cache);
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                assert!(r1.t_pred > 0.0 && r2.t_pred > 0.0);
                table.row(vec![
                    format!("MILP re-solve (cached basis), {wname}, {nodes} nodes"),
                    format!(
                        "{:.0} ms ({} pivots, root warm: {}, warm-start hit rate {:.1}%)",
                        ms,
                        r2.stats.pivots,
                        r2.stats.root_warm,
                        r2.stats.warm_hit_rate() * 100.0
                    ),
                ]);
                // The decomposed backend on the same joint instance:
                // per-phase wall including the pricing rounds.
                let mut tenant_caches = std::collections::HashMap::new();
                let t0 = Instant::now();
                let dec = trident::scheduling::solve_decomposed(
                    &input,
                    Duration::from_secs(2),
                    &mut trident::scheduling::BasisCache::new(),
                    &mut tenant_caches,
                    &trident::solver::MilpOptions::default(),
                    &trident::scheduling::DecompOptions::default(),
                );
                let dms = t0.elapsed().as_secs_f64() * 1e3;
                assert!(dec.t_pred > 0.0);
                table.row(vec![
                    format!("MILP solve (decomposed), {wname}, {nodes} nodes"),
                    format!(
                        "{:.0} ms (build {:.1} / root LP {:.1} / B&B {:.1} / pricing {:.1} ms; \
                         {} pricing rounds, {} columns)",
                        dms,
                        dec.stats.build_ms,
                        dec.stats.root_lp_ms,
                        dec.stats.bnb_ms,
                        dec.stats.pricing_ms,
                        dec.stats.pricing_rounds,
                        dec.stats.columns
                    ),
                ]);
            }
        }
    }
    table.emit("rq6_overhead");
}
