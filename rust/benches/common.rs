//! Shared mini-harness for the paper-reproduction benches (criterion is
//! unavailable in the offline crate set; each bench is a `harness = false`
//! binary that prints the paper-style rows and persists results/).

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, RunReport, Variant};
use trident::sim::ItemAttrs;
use trident::workload::{pdf, video, Trace};

pub const MAX_SIM_S: f64 = 4.0 * 3600.0;

pub fn cluster(nodes: usize) -> ClusterSpec {
    ClusterSpec::homogeneous(nodes, 256.0, 1024.0, 8, 65536.0, 12_500.0)
}

pub struct Workload {
    pub name: &'static str,
    pub pipeline: trident::config::PipelineSpec,
    pub trace: Box<dyn Trace>,
    pub src: ItemAttrs,
}

pub fn pdf_workload(docs: u64) -> Workload {
    Workload {
        name: "PDF",
        pipeline: pdf::pipeline(),
        trace: Box::new(pdf::trace(docs)),
        src: ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 },
    }
}

pub fn video_workload(vids: u64) -> Workload {
    Workload {
        name: "Video",
        pipeline: video::pipeline(),
        trace: Box::new(video::trace(vids)),
        src: ItemAttrs { tokens_in: 5_400.0, tokens_out: 480.0, pixels_m: 0.9, frames: 600.0 },
    }
}

pub fn items_for(name: &str) -> u64 {
    if name == "Video" { 2000 } else { 900 }
}

pub fn workload(name: &str) -> Workload {
    if name == "Video" { video_workload(items_for(name)) } else { pdf_workload(items_for(name)) }
}

/// Run one (workload, variant) pair to completion on the 8-node cluster.
pub fn run(w: Workload, variant: Variant, seed: u64) -> RunReport {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = std::env::var("TRIDENT_NATIVE_GP").map(|v| v == "1").unwrap_or(false);
    let mut coord = Coordinator::new(w.pipeline, cluster(8), w.trace, cfg, variant, w.src, seed);
    coord.run_to_completion(MAX_SIM_S)
}

/// SCOOT's offline per-operator tuning phase: BO against a sustained
/// isolated-operator evaluation at the *first* regime (the paper tunes
/// offline before the run), then deploy statically.
pub fn scoot_variant(pipeline: &trident::config::PipelineSpec, src: ItemAttrs) -> Variant {
    use trident::adaptation::{ConfigTuner, Strategy, TunerConfig};
    use trident::runtime::GpBackend;
    let backend = GpBackend::from_env();
    let nominal = trident::coordinator::nominal_attrs(pipeline, src);
    let mut rng = trident::rngx::Rng::new(99);
    let configs: Vec<Option<Vec<f64>>> = pipeline
        .operators
        .iter()
        .enumerate()
        .map(|(i, o)| {
            if !o.tunable {
                return None;
            }
            let mut tuner = ConfigTuner::new(
                o.config_space.clone(),
                TunerConfig {
                    strategy: Strategy::ConstrainedBo,
                    budget: 30,
                    n_init: 5,
                    eta: 0.6,
                    mem_limit_mb: 65_536.0 - 2048.0,
                    seed: i as u64,
                },
            );
            while !tuner.done() {
                let theta = tuner.next_candidate(&backend);
                let ut = trident::sim::service::true_unit_rate(&o.service, &theta, &nominal[i])
                    * rng.lognormal(0.0, 0.05);
                let mem = trident::sim::service::expected_mem(&o.service, &theta, &nominal[i])
                    * rng.lognormal(0.02, 0.03);
                let oom = mem > 65_536.0;
                tuner.record(theta, ut, mem, oom);
            }
            tuner.best().map(|e| e.theta.clone())
        })
        .collect();
    let mut v = Variant::baseline(Policy::Scoot);
    v.initial_configs = Some(configs);
    v
}
