//! Shared mini-harness for the paper-reproduction benches (criterion is
//! unavailable in the offline crate set; each bench is a `harness = false`
//! binary that prints the paper-style rows and persists results/).
//!
//! Simulation-bound benches fan their (workload, variant, seed) grids out
//! across cores through `trident::harness` ([`run_cells`]); cells are
//! seeded deterministically and share no state, so results match the old
//! serial loops whenever every Trident MILP solve completes within its
//! wall-clock budget (see the harness module docs for the anytime-solver
//! caveat).  Wall-clock-measuring benches (rq6) stay serial so timings
//! are not perturbed by sibling cells.

#![allow(dead_code)] // each bench includes this module and uses a subset

use trident::config::{ClusterSpec, Tenancy, TenantSpec, TridentConfig};
use trident::coordinator::{Coordinator, RunReport, Variant};
use trident::harness::{self, Job};
use trident::sim::ItemAttrs;
use trident::workload::{pdf, speech, video, Trace};

pub const MAX_SIM_S: f64 = harness::MAX_SIM_S;

pub fn cluster(nodes: usize) -> ClusterSpec {
    ClusterSpec::homogeneous(nodes, 256.0, 1024.0, 8, 65536.0, 12_500.0)
}

pub struct Workload {
    pub name: &'static str,
    pub pipeline: trident::config::PipelineSpec,
    pub trace: Box<dyn Trace>,
    pub src: ItemAttrs,
}

pub fn pdf_workload(docs: u64) -> Workload {
    Workload {
        name: "PDF",
        pipeline: pdf::pipeline(),
        trace: Box::new(pdf::trace(docs)),
        src: pdf::src_attrs(),
    }
}

pub fn video_workload(vids: u64) -> Workload {
    Workload {
        name: "Video",
        pipeline: video::pipeline(),
        trace: Box::new(video::trace(vids)),
        src: video::src_attrs(),
    }
}

/// The branching (fork/join) speech curation DAG — every policy in the
/// end-to-end benches is also evaluated on a non-chain topology.
pub fn speech_workload(clips: u64) -> Workload {
    Workload {
        name: "Speech",
        pipeline: speech::pipeline(),
        trace: Box::new(speech::trace(clips)),
        src: speech::src_attrs(),
    }
}

pub fn items_for(name: &str) -> u64 {
    match name {
        "PDF" => 900,
        "Video" => 2000,
        "Speech" => 1500,
        other => panic!("unknown bench workload '{other}' (expected PDF|Video|Speech)"),
    }
}

/// Strict lookup: a typo'd workload name must not silently bench the PDF
/// chain under another column's label (same contract as the CLI's
/// `pipeline_of`).
pub fn workload(name: &str) -> Workload {
    match name {
        "PDF" => pdf_workload(items_for(name)),
        "Video" => video_workload(items_for(name)),
        "Speech" => speech_workload(items_for(name)),
        other => panic!("unknown bench workload '{other}' (expected PDF|Video|Speech)"),
    }
}

/// Multi-tenant bench workloads are named `A+B` (e.g. "PDF+Speech"): each
/// part runs as one tenant on the shared 8-node cluster, at half its
/// single-tenant item count (the cluster is shared).
pub fn tenancy_for(wname: &str) -> (Tenancy, Vec<Box<dyn Trace>>, Vec<ItemAttrs>) {
    let mut tenants = Vec::new();
    let mut traces: Vec<Box<dyn Trace>> = Vec::new();
    let mut srcs = Vec::new();
    for part in wname.split('+') {
        let w = match part {
            "PDF" => pdf_workload(items_for(part) / 2),
            "Video" => video_workload(items_for(part) / 2),
            "Speech" => speech_workload(items_for(part) / 2),
            other => panic!("unknown bench workload '{other}' (expected PDF|Video|Speech)"),
        };
        tenants.push(TenantSpec {
            id: w.pipeline.name.clone(),
            pipeline: w.pipeline,
            weight: 1.0,
            source_rate: 0.0,
        });
        traces.push(w.trace);
        srcs.push(w.src);
    }
    (Tenancy { tenants }, traces, srcs)
}

/// SCOOT variant for a bench workload name, tenant-aware for `A+B` names.
pub fn scoot_variant_for(wname: &str) -> Variant {
    if wname.contains('+') {
        let (tenancy, _, srcs) = tenancy_for(wname);
        let (spec, view) = tenancy.merged().expect("bench tenancy is valid");
        harness::scoot_variant_merged(&spec, &view, &srcs)
    } else {
        let w = workload(wname);
        harness::scoot_variant(&w.pipeline, w.src)
    }
}

fn coordinator_for(wname: &str, variant: Variant, seed: u64, collect_mape: bool) -> Coordinator {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = std::env::var("TRIDENT_NATIVE_GP").map(|v| v == "1").unwrap_or(false);
    let mut coord = if wname.contains('+') {
        let (tenancy, traces, srcs) = tenancy_for(wname);
        Coordinator::new_tenancy(tenancy, cluster(8), traces, cfg, variant, srcs, seed)
            .expect("bench tenancy is valid")
    } else {
        let w = workload(wname);
        Coordinator::new(w.pipeline, cluster(8), w.trace, cfg, variant, w.src, seed)
    };
    coord.collect_mape = collect_mape;
    coord
}

/// One grid cell for [`run_cells`]: a (workload, variant, seed) triple run
/// to completion on the 8-node cluster.
pub struct Cell {
    pub label: String,
    pub workload: &'static str,
    pub variant: Variant,
    pub seed: u64,
    pub collect_mape: bool,
}

impl Cell {
    pub fn new(label: impl Into<String>, workload: &'static str, variant: Variant, seed: u64) -> Cell {
        Cell { label: label.into(), workload, variant, seed, collect_mape: false }
    }
}

/// Worker count for [`run_cells`]: `TRIDENT_BENCH_JOBS` overrides the
/// one-per-core default.  Cap it below the core count (or set it to 1)
/// when strict Trident reproducibility matters on a loaded host — see the
/// anytime-MILP caveat in the harness module docs.
fn bench_workers() -> usize {
    std::env::var("TRIDENT_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(harness::default_workers)
}

/// Fan the cells out across cores; reports come back in cell order.
pub fn run_cells(cells: &[Cell]) -> Vec<RunReport> {
    let jobs: Vec<Job> = cells
        .iter()
        .map(|c| Job::new(c.label.clone(), c.variant.clone(), c.seed))
        .collect();
    harness::run_grid(&jobs, bench_workers(), |i, job| {
        coordinator_for(cells[i].workload, job.variant.clone(), job.seed, cells[i].collect_mape)
    })
}

/// SCOOT's offline tuning phase (now in the library so the CLI sweep can
/// use it too).
pub fn scoot_variant(pipeline: &trident::config::PipelineSpec, src: ItemAttrs) -> Variant {
    harness::scoot_variant(pipeline, src)
}
