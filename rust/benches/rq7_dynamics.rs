//! RQ7 (repo extension): cluster dynamics — throughput dip depth and
//! recovery time under node churn, per scheduling policy.
//!
//! The headline two-tenant pdf+speech deployment takes a scripted
//! `NodeFail` mid-run and a `NodeRecover` later.  For each policy we
//! report the pre-failure baseline, the dip floor while the node is down,
//! time-to-replan after the failure (Trident's event-driven path fires
//! within one metrics window; Static never re-plans), and the
//! time-to-90%-of-baseline recovery once the node returns.  The static
//! baseline's instances die with the node and are never re-placed, so its
//! recovery column is the contrast the tentpole is about.

#[path = "common.rs"]
mod common;

use trident::config::{Tenancy, TenantSpec};
use trident::coordinator::{Coordinator, Policy, RunReport, Variant};
use trident::dynamics::{ClusterEvent, DynamicsSpec, RecoveryPolicy, TimedEvent};
use trident::harness::{self, Job};
use trident::report::{f2, Table};
use trident::workload::{pdf, speech, Trace};

const FAIL_AT: f64 = 400.0;
const RECOVER_AT: f64 = 900.0;
const DURATION: f64 = 1800.0;
const SEED: u64 = 11;

/// Fail three of the eight nodes at once (a rack-level outage — deep
/// enough that no policy can sit out the dip), recover them together.
fn churn_spec() -> DynamicsSpec {
    let mut events = Vec::new();
    for node in [1usize, 2, 3] {
        events.push(TimedEvent { at_s: FAIL_AT, event: ClusterEvent::NodeFail { node } });
        events.push(TimedEvent { at_s: RECOVER_AT, event: ClusterEvent::NodeRecover { node } });
    }
    DynamicsSpec { events, mtbf_s: 0.0, mttr_s: 0.0, recovery: RecoveryPolicy::Requeue }
}

fn coordinator(variant: &Variant, seed: u64) -> Coordinator {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    let mut cfg = trident::config::TridentConfig::default();
    cfg.native_gp = std::env::var("TRIDENT_NATIVE_GP").map(|v| v == "1").unwrap_or(false);
    let mut coord = Coordinator::new_tenancy(
        tenancy,
        common::cluster(8),
        vec![
            Box::new(pdf::trace(500_000)) as Box<dyn Trace>,
            Box::new(speech::trace(200_000)) as Box<dyn Trace>,
        ],
        cfg,
        variant.clone(),
        vec![pdf::src_attrs(), speech::src_attrs()],
        seed,
    )
    .expect("two-tenant tenancy is valid");
    coord.set_dynamics(churn_spec()).expect("valid churn spec");
    coord
}

/// Min windowed throughput while the node is down, relative to the
/// event's pre-failure baseline.
fn dip_floor(r: &RunReport) -> f64 {
    let base = r
        .events
        .iter()
        .find(|e| e.label.starts_with("node_fail"))
        .map(|e| e.baseline_thr)
        .unwrap_or(0.0)
        .max(1e-12);
    r.series
        .iter()
        .filter(|&&(t, _)| t > FAIL_AT + 30.0 && t <= RECOVER_AT)
        .map(|&(_, v)| v / base)
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    let methods: Vec<(&str, Variant)> = vec![
        ("Static", Variant::baseline(Policy::Static)),
        ("Ray Data", Variant::baseline(Policy::RayData)),
        ("DS2", Variant::baseline(Policy::Ds2)),
        ("ContTune", Variant::baseline(Policy::ContTune)),
        ("Trident", Variant::trident()),
    ];
    let jobs: Vec<Job> = methods
        .iter()
        .map(|(name, v)| Job::timed(*name, v.clone(), SEED, DURATION))
        .collect();
    let workers = std::env::var("TRIDENT_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(harness::default_workers);
    let reports = harness::run_grid(&jobs, workers, |_, job| coordinator(&job.variant, job.seed));

    let mut table = Table::new(
        &format!(
            "RQ7: two-tenant pdf+speech churn (fail nodes 1-3 @{FAIL_AT}s, recover @{RECOVER_AT}s)"
        ),
        &["Method", "base items/s", "dip floor", "replan s", "recover(90%) s", "lost", "items/s"],
    );
    for ((name, _), r) in methods.iter().zip(&reports) {
        let ev = r.events.iter().find(|e| e.label.starts_with("node_fail"));
        let fmt_opt = |v: Option<f64>| match v {
            Some(s) => format!("{s:.0}"),
            None => "-".to_string(),
        };
        table.row(vec![
            name.to_string(),
            f2(ev.map(|e| e.baseline_thr).unwrap_or(0.0)),
            format!("{:.2}", dip_floor(r)),
            fmt_opt(ev.and_then(|e| e.replan_s)),
            fmt_opt(ev.and_then(|e| e.recovered_s)),
            format!("{}", r.lost_records),
            f2(r.throughput),
        ]);
        eprintln!("done: {name}");
    }
    table.emit("rq7_dynamics");

    // The acceptance bar, asserted here too so `cargo bench rq7_dynamics`
    // fails loudly if the event-driven path regresses.
    let trident = &reports[methods.len() - 1];
    let statik = &reports[0];
    let t_ev = trident
        .events
        .iter()
        .find(|e| e.label.starts_with("node_fail"))
        .expect("trident records the failure");
    let replan = t_ev.replan_s.expect("trident re-plans after the failure");
    assert!(
        replan <= trident::config::TridentConfig::default().metrics_interval_s + 1e-9,
        "event-driven re-plan took {replan}s (> one metrics interval)"
    );
    let t_rec = t_ev.recovered_s.expect("trident recovers to >= 90% of baseline");
    let s_rec = statik
        .events
        .iter()
        .find(|e| e.label.starts_with("node_fail"))
        .and_then(|e| e.recovered_s);
    if let Some(s) = s_rec {
        assert!(t_rec < s, "trident must recover strictly faster: {t_rec} vs {s}");
    }
    println!(
        "rq7 acceptance: trident replan {replan:.1}s, recover {t_rec:.0}s; static recover {}",
        s_rec.map(|s| format!("{s:.0}s")).unwrap_or_else(|| "never".into())
    );
}
