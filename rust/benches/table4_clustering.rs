//! Table 4 (RQ4a): workload clustering accuracy — Trident's online
//! algorithm vs offline K-means / DBSCAN with the complete dataset.
//! Paper: all find the true cluster count; online purity/ARI only
//! marginally below offline.

#[path = "common.rs"]
mod common;

use trident::adaptation::cluster_metrics::{ari, purity};
use trident::adaptation::offline_cluster::{dbscan, dbscan_n_clusters, kmeans};
use trident::adaptation::{ClusterConfig, OnlineClustering};
use trident::config::FeatureExtractor;
use trident::report::{f2, Table};
use trident::rngx::Rng;
use trident::workload::{pdf, video, Trace};

fn samples(wname: &str, n: usize) -> (Vec<Vec<f64>>, Vec<u8>, usize) {
    // Per-request features as seen by the adaptation layer at the tunable
    // operator (token/pixel loads after the split stages).
    let mut rng = Rng::new(5);
    let (mut trace, ex, scale): (Box<dyn Trace>, _, [f64; 4]) = if wname == "Video" {
        (Box::new(video::trace(n as u64)), FeatureExtractor::LlmTokens, [1.0 / 6.0, 1.0, 1.0, 1.0 / 6.0])
    } else {
        (Box::new(pdf::trace(n as u64)), FeatureExtractor::LlmTokens, [1.0 / 120.0, 1.0 / 120.0, 0.01, 1.0])
    };
    let mut xs = Vec::new();
    let mut truth = Vec::new();
    let mut regimes = 0usize;
    while let Some(item) = trace.next_item(&mut rng) {
        let a = trident::sim::ItemAttrs {
            tokens_in: item.attrs.tokens_in * scale[0],
            tokens_out: item.attrs.tokens_out * scale[1],
            pixels_m: item.attrs.pixels_m * scale[2],
            frames: item.attrs.frames * scale[3],
        };
        xs.push(a.cluster_features(ex).to_vec());
        truth.push(item.regime);
        regimes = regimes.max(item.regime as usize + 1);
    }
    (xs, truth, regimes)
}

fn main() {
    let mut table = Table::new(
        "Table 4: workload clustering accuracy",
        &["Method", "Pipeline", "Clusters", "Purity", "ARI"],
    );
    for wname in ["PDF", "Video"] {
        let (xs, truth, k_true) = samples(wname, 3000);
        // offline K-means (given the true k, as in the paper)
        let (km, _) = kmeans(&xs, k_true, 4, 1);
        table.row(vec![
            "K-means (offline)".into(),
            wname.into(),
            k_true.to_string(),
            f2(purity(&km, &truth)),
            f2(ari(&km, &truth)),
        ]);
        // offline DBSCAN
        let db = dbscan(&xs, 0.12, 8);
        table.row(vec![
            "DBSCAN (offline)".into(),
            wname.into(),
            dbscan_n_clusters(&db).to_string(),
            f2(purity(&db, &truth)),
            f2(ari(&db, &truth)),
        ]);
        // Trident online
        let mut oc = OnlineClustering::new(ClusterConfig::default());
        let assigns: Vec<usize> = xs.iter().map(|x| oc.assign(x) as usize).collect();
        table.row(vec![
            "Trident (online)".into(),
            wname.into(),
            oc.n_clusters().to_string(),
            f2(purity(&assigns, &truth)),
            f2(ari(&assigns, &truth)),
        ]);
    }
    table.emit("table4_clustering");
}
