//! Table 5 (RQ4b): configuration-optimization comparison on the two
//! representative tunable operators (TextOCR on PDF, Captioning on video),
//! 30 evaluations each under sustained full load.
//! Paper: Unconstrained BO nominally best but † (OOM-picked);
//! Constrained BO within 1–2% of it; both >> grid > random > default.

#[path = "common.rs"]
mod common;

use trident::adaptation::{ConfigTuner, Strategy, TunerConfig};
use trident::coordinator::nominal_attrs;
use trident::report::Table;
use trident::rngx::Rng;
use trident::runtime::GpBackend;
use trident::sim::service;

const CAP_MB: f64 = 65_536.0;

fn main() {
    let backend = GpBackend::from_env();
    let mut table = Table::new(
        "Table 5: configuration optimization (throughput vs default; † = OOM-prone best)",
        &["Method", "TextOCR (PDF)", "Captioning (Video)"],
    );
    let mut cells: Vec<Vec<String>> = vec![Vec::new(); 5];
    for wname in ["PDF", "Video"] {
        let w = common::workload(wname);
        let target = if wname == "PDF" { "text_ocr" } else { "caption" };
        let idx = w.pipeline.interner().op(target).idx();
        let attrs = nominal_attrs(&w.pipeline, w.src)[idx];
        let op = &w.pipeline.operators[idx];
        let default_ut =
            service::true_unit_rate(&op.service, &op.config_space.default_config(), &attrs);
        cells[0].push("1.00x".to_string());
        for (row, strategy) in [
            (1, Strategy::RandomSearch),
            (2, Strategy::GridSearch),
            (3, Strategy::UnconstrainedBo),
            (4, Strategy::ConstrainedBo),
        ] {
            // average over a few seeds for stability
            let mut speed = 0.0;
            let mut oom_best = false;
            for seed in 0..3u64 {
                let mut rng = Rng::new(seed * 77 + 1);
                let mut tuner = ConfigTuner::new(
                    op.config_space.clone(),
                    TunerConfig {
                        strategy,
                        budget: 30,
                        n_init: 5,
                        eta: 0.6,
                        mem_limit_mb: CAP_MB - 2048.0,
                        seed,
                    },
                );
                while !tuner.done() {
                    let theta = tuner.next_candidate(&backend);
                    let ut = service::true_unit_rate(&op.service, &theta, &attrs)
                        * rng.lognormal(0.0, 0.05);
                    let mem = service::expected_mem(&op.service, &theta, &attrs)
                        * rng.lognormal(0.02, 0.03);
                    tuner.record(theta, ut, mem, mem > CAP_MB);
                }
                if let Some(best) = tuner.best() {
                    speed += best.ut / default_ut / 3.0;
                    // sustained execution check: would the nominal best OOM
                    // under the allocator-noise upper tail?
                    let sustained =
                        service::expected_mem(&op.service, &best.theta, &attrs) * (1.06f64);
                    oom_best |= sustained > CAP_MB || best.mem_mb > CAP_MB - 1024.0;
                }
            }
            let dag = if oom_best && strategy == Strategy::UnconstrainedBo { "†" } else { "" };
            cells[row].push(format!("{speed:.2}x{dag}"));
        }
    }
    for (i, label) in [
        "Default Config",
        "Random Search",
        "Grid Search",
        "Unconstrained BO",
        "Constrained BO (Trident)",
    ]
    .iter()
    .enumerate()
    {
        table.row(vec![label.to_string(), cells[i][0].clone(), cells[i][1].clone()]);
    }
    table.emit("table5_config_opt");
}
