//! Table 3 (RQ3): capacity-estimation accuracy (MAPE %) against the
//! isolated-profiling oracle, for the estimator lattice.
//! Paper: true-rate 62.7/54.3 >> EMA 28.3/25.7 > GP 24.3/21.8 >>
//! GP+signal 8.4/7.1 > GP+two-stage 5.6/4.8.

#[path = "common.rs"]
mod common;

use trident::config::TridentConfig;
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::report::{pct, Table};

fn main() {
    let mut table = Table::new(
        "Table 3: processing-capacity estimation accuracy (MAPE %)",
        &["Method", "PDF", "Video"],
    );
    let mut cols: Vec<std::collections::HashMap<&'static str, f64>> = Vec::new();
    for wname in ["PDF", "Video"] {
        let w = common::workload(wname);
        let cfg = TridentConfig::default();
        let mut coord = Coordinator::new(
            w.pipeline,
            common::cluster(8),
            w.trace,
            cfg,
            Variant::baseline(Policy::Static),
            w.src,
            3,
        );
        coord.collect_mape = true;
        let r = coord.run_to_completion(common::MAX_SIM_S);
        eprintln!("  {wname}: {:?}", r.estimator_mape);
        cols.push(r.estimator_mape);
    }
    for (label, key) in [
        ("True Processing Rate", "true_rate"),
        ("EMA", "ema"),
        ("GP w/o filtering", "gp_raw"),
        ("GP + signal filtering", "gp_signal"),
        ("GP + two-stage filtering (Trident)", "gp_two_stage"),
    ] {
        table.row(vec![
            label.into(),
            pct(cols[0].get(key).copied().unwrap_or(f64::NAN)),
            pct(cols[1].get(key).copied().unwrap_or(f64::NAN)),
        ]);
    }
    table.emit("table3_observation");
}
