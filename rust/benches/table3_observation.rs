//! Table 3 (RQ3): capacity-estimation accuracy (MAPE %) against the
//! isolated-profiling oracle, for the estimator lattice.
//! Paper: true-rate 62.7/54.3 >> EMA 28.3/25.7 > GP 24.3/21.8 >>
//! GP+signal 8.4/7.1 > GP+two-stage 5.6/4.8.
//!
//! The two workload runs fan out across cores (MAPE collection is per-run
//! state, so the cells stay independent).

#[path = "common.rs"]
mod common;

use trident::coordinator::{Policy, Variant};
use trident::report::{pct, Table};

fn main() {
    let cells: Vec<common::Cell> = ["PDF", "Video"]
        .into_iter()
        .map(|wname| {
            let mut c =
                common::Cell::new(wname, wname, Variant::baseline(Policy::Static), 3);
            c.collect_mape = true;
            c
        })
        .collect();
    let reports = common::run_cells(&cells);
    let cols: Vec<std::collections::HashMap<&'static str, f64>> = reports
        .iter()
        .map(|r| {
            eprintln!("  {}: {:?}", r.pipeline, r.estimator_mape);
            r.estimator_mape.clone()
        })
        .collect();

    let mut table = Table::new(
        "Table 3: processing-capacity estimation accuracy (MAPE %)",
        &["Method", "PDF", "Video"],
    );
    for (label, key) in [
        ("True Processing Rate", "true_rate"),
        ("EMA", "ema"),
        ("GP w/o filtering", "gp_raw"),
        ("GP + signal filtering", "gp_signal"),
        ("GP + two-stage filtering (Trident)", "gp_two_stage"),
    ] {
        table.row(vec![
            label.into(),
            pct(cols[0].get(key).copied().unwrap_or(f64::NAN)),
            pct(cols[1].get(key).copied().unwrap_or(f64::NAN)),
        ]);
    }
    table.emit("table3_observation");
}
