//! Figure 3 (RQ5): component ablation, throughput normalized to full
//! Trident (100%).
//! Paper: w/o observation 66.5/60.9 < w/o adaptation 79.6/78.1 <
//! w/o placement 90.5/84.0 < w/o rolling 95.5/95.2.
//!
//! The 10 (variant, workload) cells fan out across cores.

#[path = "common.rs"]
mod common;

use trident::coordinator::Variant;
use trident::report::Table;

const WORKLOADS: [&str; 2] = ["PDF", "Video"];

fn main() {
    let variants: Vec<(&str, Box<dyn Fn() -> Variant>)> = vec![
        ("Trident (full)", Box::new(Variant::trident)),
        ("w/o Observation Layer", Box::new(|| {
            let mut v = Variant::trident();
            v.use_observation = false; // true-processing-rate estimates
            v
        })),
        ("w/o Adaptation Layer", Box::new(|| {
            let mut v = Variant::trident();
            v.use_adaptation = false; // fixed initial configs
            v
        })),
        ("w/o Placement-Aware Scheduling", Box::new(|| {
            let mut v = Variant::trident();
            v.placement_aware = false;
            v
        })),
        ("w/o Rolling Update", Box::new(|| {
            let mut v = Variant::trident();
            v.rolling = false; // all-at-once restarts
            v
        })),
    ];
    let mut cells = Vec::new();
    for (name, mk) in &variants {
        for wname in WORKLOADS {
            cells.push(common::Cell::new(format!("{name}/{wname}"), wname, mk(), 17));
        }
    }
    let reports = common::run_cells(&cells);

    let mut table = Table::new(
        "Figure 3: ablation (throughput normalized to full Trident = 100%)",
        &["Variant", "PDF", "Video"],
    );
    let mut base = [1.0, 1.0];
    let mut rows = Vec::new();
    for (vi, (name, _)) in variants.iter().enumerate() {
        let mut vals = Vec::new();
        for j in 0..WORKLOADS.len() {
            let r = &reports[vi * WORKLOADS.len() + j];
            eprintln!("  {name} / {}: {:.3}", WORKLOADS[j], r.throughput);
            if *name == "Trident (full)" {
                base[j] = r.throughput.max(1e-12);
            }
            vals.push(100.0 * r.throughput / base[j]);
        }
        rows.push((name.to_string(), vals));
    }
    for (name, vals) in rows {
        table.row(vec![name, format!("{:.1}%", vals[0]), format!("{:.1}%", vals[1])]);
    }
    table.emit("fig3_ablation");
}
