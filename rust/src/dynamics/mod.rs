//! Cluster dynamics: a deterministic, seed-driven timeline of node churn,
//! tenant arrivals/departures, and bandwidth shifts injected into the sim
//! clock — the fourth subsystem alongside observation / adaptation /
//! scheduling.
//!
//! The paper's premise is that multimodal pipelines are *non-stationary*;
//! this module makes the cluster and the tenancy non-stationary too.  A
//! [`DynamicsSpec`] combines a scripted JSON event list with optional
//! stochastic MTBF/MTTR node-churn processes (sampled through `rngx`, so
//! the same seed + spec always yields the bit-identical timeline), and the
//! coordinator applies the resulting [`TimedEvent`]s at their exact sim
//! timestamps between metrics windows:
//!
//! * **NodeFail** — every instance on the node dies *immediately* (no
//!   drain).  What happens to its in-flight records is governed by the
//!   [`RecoveryPolicy`]: `Requeue` re-injects them at the operator they
//!   were lost at (the lineage-re-execution shortcut — conservation stays
//!   exact), `Loss` drops them and counts them in the per-op/per-tenant
//!   loss ledgers (join groups are tombstoned so orphaned sibling
//!   partials cannot wedge the DAG).
//! * **NodeRecover / NodeJoin** — the node's capacity returns (join names
//!   a node of the cluster spec that starts *offline* and comes up at the
//!   event time).
//! * **TenantArrive / TenantDepart** — the tenant's source is spliced
//!   in/out mid-run; an arriving tenant starts dormant (no instances, no
//!   load) and a departing tenant drains what it already admitted.
//! * **BandwidthDegrade / BandwidthRestore** — the node's egress link
//!   rate is scaled by a factor in (0, 1], then restored.
//!
//! Every event marks the coordinator's *event-driven re-plan* path: the
//! next metrics window triggers an immediate scheduling round (instead of
//! waiting for the periodic `t_sched_s` timer), observation samples of
//! the affected operators are invalidated (the paper's path-⑨ rule
//! extended to topology changes), and the MILP is rebuilt over the
//! surviving node/tenant set, warm-started through the restricted basis
//! repair in `scheduling::BasisCache`.

use crate::config::Json;

/// One cluster/tenancy event.  Nodes are named by cluster index, tenants
/// by tenant id (resolved against the tenancy at `validate` time).
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    /// The node crashes: instances die instantly, in-flight work is
    /// requeued or lost per the [`RecoveryPolicy`].
    NodeFail { node: usize },
    /// A previously failed node comes back (empty — instances must be
    /// re-placed by the scheduler).
    NodeRecover { node: usize },
    /// A node that started offline joins the cluster.  The node must be
    /// declared in the cluster spec; it is held down from t = 0 until
    /// this event fires.
    NodeJoin { node: usize },
    /// The tenant's source starts offering load.  A tenant with an
    /// arrival event starts dormant (no instances, no load).
    TenantArrive { tenant: String },
    /// The tenant stops offering load; already-admitted items drain and
    /// the next re-plan reclaims its instances.
    TenantDepart { tenant: String },
    /// Scale the node's egress link rate by `factor` in (0, 1].
    BandwidthDegrade { node: usize, factor: f64 },
    /// Restore the node's egress link to its spec rate.
    BandwidthRestore { node: usize },
}

impl ClusterEvent {
    /// Short stable kind tag (reports, tests, JSON round-trips).
    pub fn kind(&self) -> &'static str {
        match self {
            ClusterEvent::NodeFail { .. } => "node_fail",
            ClusterEvent::NodeRecover { .. } => "node_recover",
            ClusterEvent::NodeJoin { .. } => "node_join",
            ClusterEvent::TenantArrive { .. } => "tenant_arrive",
            ClusterEvent::TenantDepart { .. } => "tenant_depart",
            ClusterEvent::BandwidthDegrade { .. } => "bandwidth_degrade",
            ClusterEvent::BandwidthRestore { .. } => "bandwidth_restore",
        }
    }

    /// The node the event touches, if any.
    pub fn node(&self) -> Option<usize> {
        match *self {
            ClusterEvent::NodeFail { node }
            | ClusterEvent::NodeRecover { node }
            | ClusterEvent::NodeJoin { node }
            | ClusterEvent::BandwidthDegrade { node, .. }
            | ClusterEvent::BandwidthRestore { node } => Some(node),
            _ => None,
        }
    }

    /// The tenant id the event touches, if any.
    pub fn tenant(&self) -> Option<&str> {
        match self {
            ClusterEvent::TenantArrive { tenant } | ClusterEvent::TenantDepart { tenant } => {
                Some(tenant)
            }
            _ => None,
        }
    }
}

/// An event pinned to an absolute sim timestamp.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedEvent {
    pub at_s: f64,
    pub event: ClusterEvent,
}

/// What happens to a failed node's in-flight records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Re-inject surviving records at the operator they were lost at
    /// (lineage re-execution shortcut): per-tenant conservation stays
    /// exact and nothing is counted lost.
    #[default]
    Requeue,
    /// Drop them: records are counted in the per-op loss ledger, killed
    /// lineages once per tenant, and join groups are tombstoned so
    /// orphaned sibling partials are dropped on arrival instead of
    /// wedging the join.
    Loss,
}

impl RecoveryPolicy {
    pub fn parse(s: &str) -> Result<RecoveryPolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "requeue" => Ok(RecoveryPolicy::Requeue),
            "loss" => Ok(RecoveryPolicy::Loss),
            other => Err(format!("unknown recovery policy '{other}' (expected requeue|loss)")),
        }
    }
}

/// The full dynamics specification: scripted events plus optional
/// stochastic node churn.
#[derive(Debug, Clone, Default)]
pub struct DynamicsSpec {
    /// Scripted events (need not be sorted; [`DynamicsSpec::timeline`]
    /// orders them deterministically).
    pub events: Vec<TimedEvent>,
    /// Mean time between failures per node, seconds (0 = no stochastic
    /// churn).  Each node's fail/recover process is sampled independently
    /// from exponential inter-event times.
    pub mtbf_s: f64,
    /// Mean time to recovery, seconds (used only when `mtbf_s > 0`).
    pub mttr_s: f64,
    pub recovery: RecoveryPolicy,
}

impl DynamicsSpec {
    /// True when no events can ever fire.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.mtbf_s <= 0.0
    }

    /// Strict JSON parse: unknown event kinds, missing/invalid timestamps,
    /// and out-of-range factors are errors, never silently skipped.
    ///
    /// ```json
    /// {"recovery": "requeue", "mtbf_s": 0, "mttr_s": 0,
    ///  "events": [
    ///    {"at": 300, "kind": "node_fail", "node": 1},
    ///    {"at": 600, "kind": "node_recover", "node": 1},
    ///    {"at": 900, "kind": "tenant_arrive", "tenant": "speech"},
    ///    {"at": 420, "kind": "bandwidth_degrade", "node": 0, "factor": 0.25}
    ///  ]}
    /// ```
    pub fn from_json(j: &Json) -> Result<DynamicsSpec, String> {
        let recovery = match j.get("recovery").map(|r| r.as_str()) {
            None => RecoveryPolicy::default(),
            Some(Some(s)) => RecoveryPolicy::parse(s)?,
            Some(None) => return Err("dynamics: 'recovery' must be a string".into()),
        };
        let num = |key: &str| -> Result<f64, String> {
            match j.get(key) {
                None => Ok(0.0),
                Some(v) => v
                    .as_f64()
                    .filter(|x| x.is_finite() && *x >= 0.0)
                    .ok_or_else(|| format!("dynamics: '{key}' must be a non-negative number")),
            }
        };
        let mtbf_s = num("mtbf_s")?;
        let mttr_s = num("mttr_s")?;
        if mtbf_s > 0.0 && mttr_s <= 0.0 {
            return Err("dynamics: mtbf_s > 0 requires mttr_s > 0".into());
        }
        let mut events = Vec::new();
        if let Some(arr) = j.get("events") {
            let arr = arr.as_arr().ok_or("dynamics: 'events' must be an array")?;
            for (i, ej) in arr.iter().enumerate() {
                events.push(Self::event_from_json(ej).map_err(|e| format!("event {i}: {e}"))?);
            }
        }
        Ok(DynamicsSpec { events, mtbf_s, mttr_s, recovery })
    }

    fn event_from_json(ej: &Json) -> Result<TimedEvent, String> {
        let at_s = ej
            .get("at")
            .and_then(Json::as_f64)
            .filter(|t| t.is_finite() && *t >= 0.0)
            .ok_or("missing or invalid 'at' timestamp (must be a finite number >= 0)")?;
        let kind = ej
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("missing 'kind'")?;
        let node = || -> Result<usize, String> {
            // Strict: Json::as_usize would saturate -1 to 0 and truncate
            // 1.9 to 1 — silently failing a different node than scripted.
            ej.get("node")
                .and_then(Json::as_f64)
                .filter(|f| f.is_finite() && *f >= 0.0 && f.fract() == 0.0)
                .map(|f| f as usize)
                .ok_or_else(|| format!("'{kind}' needs a non-negative integer 'node'"))
        };
        let tenant = || -> Result<String, String> {
            ej.get("tenant")
                .and_then(Json::as_str)
                .map(str::to_string)
                .filter(|t| !t.is_empty())
                .ok_or_else(|| format!("'{kind}' needs a non-empty 'tenant' id"))
        };
        let event = match kind {
            "node_fail" => ClusterEvent::NodeFail { node: node()? },
            "node_recover" => ClusterEvent::NodeRecover { node: node()? },
            "node_join" => ClusterEvent::NodeJoin { node: node()? },
            "tenant_arrive" => ClusterEvent::TenantArrive { tenant: tenant()? },
            "tenant_depart" => ClusterEvent::TenantDepart { tenant: tenant()? },
            "bandwidth_degrade" => {
                let factor = ej
                    .get("factor")
                    .and_then(Json::as_f64)
                    .filter(|f| *f > 0.0 && *f <= 1.0)
                    .ok_or("'bandwidth_degrade' needs a 'factor' in (0, 1]")?;
                ClusterEvent::BandwidthDegrade { node: node()?, factor }
            }
            "bandwidth_restore" => ClusterEvent::BandwidthRestore { node: node()? },
            other => {
                return Err(format!(
                    "unknown event kind '{other}' (expected node_fail|node_recover|node_join|\
                     tenant_arrive|tenant_depart|bandwidth_degrade|bandwidth_restore)"
                ))
            }
        };
        Ok(TimedEvent { at_s, event })
    }

    /// Validate the scripted events against a concrete deployment: node
    /// indices in range, tenant ids known, and a joining node not touched
    /// before its join.
    pub fn validate(&self, n_nodes: usize, tenant_ids: &[String]) -> Result<(), String> {
        for (i, te) in self.events.iter().enumerate() {
            if let Some(node) = te.event.node() {
                if node >= n_nodes {
                    return Err(format!(
                        "event {i} ({}): node {node} out of range for {n_nodes} nodes",
                        te.event.kind()
                    ));
                }
            }
            if let Some(t) = te.event.tenant() {
                if !tenant_ids.iter().any(|id| id == t) {
                    return Err(format!(
                        "event {i} ({}): unknown tenant '{t}' (known: {})",
                        te.event.kind(),
                        tenant_ids.join(", ")
                    ));
                }
            }
        }
        // A node with a NodeJoin starts offline; no earlier event may
        // reference it (the script would be ambiguous about its state).
        for node in self.joining_nodes() {
            let join_t = self
                .events
                .iter()
                .filter(|te| te.event == ClusterEvent::NodeJoin { node })
                .map(|te| te.at_s)
                .fold(f64::INFINITY, f64::min);
            for te in &self.events {
                if te.event.node() == Some(node)
                    && te.event != (ClusterEvent::NodeJoin { node })
                    && te.at_s < join_t
                {
                    return Err(format!(
                        "node {node} is referenced at t={} before its node_join at t={join_t}",
                        te.at_s
                    ));
                }
            }
        }
        Ok(())
    }

    /// Nodes that start offline (they have a `node_join` event).
    pub fn joining_nodes(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for te in &self.events {
            if let ClusterEvent::NodeJoin { node } = te.event {
                if !out.contains(&node) {
                    out.push(node);
                }
            }
        }
        out
    }

    /// Tenants that start dormant (they have a `tenant_arrive` event).
    pub fn arriving_tenants(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for te in &self.events {
            if let ClusterEvent::TenantArrive { tenant } = &te.event {
                if !out.iter().any(|t| t == tenant) {
                    out.push(tenant.clone());
                }
            }
        }
        out
    }

    /// The full event timeline over `[0, horizon_s)`: scripted events
    /// merged with the sampled MTBF/MTTR churn processes, sorted by
    /// timestamp with stable script order on ties.  Purely a function of
    /// `(self, n_nodes, horizon_s, seed)` — same inputs, bit-identical
    /// timeline.
    pub fn timeline(&self, n_nodes: usize, horizon_s: f64, seed: u64) -> Vec<TimedEvent> {
        let mut all: Vec<TimedEvent> = self.events.clone();
        if self.mtbf_s > 0.0 && self.mttr_s > 0.0 {
            let joining = self.joining_nodes();
            for node in 0..n_nodes {
                if joining.contains(&node) {
                    // Churn starts only once the node has joined; keep the
                    // sampled process off joining nodes for simplicity.
                    continue;
                }
                let mut rng = crate::rngx::Rng::new(
                    seed ^ 0x6479_6e61_6d69_6373 ^ ((node as u64) << 32),
                );
                let mut t = rng.exponential(1.0 / self.mtbf_s);
                while t < horizon_s {
                    all.push(TimedEvent { at_s: t, event: ClusterEvent::NodeFail { node } });
                    t += rng.exponential(1.0 / self.mttr_s);
                    if t >= horizon_s {
                        break;
                    }
                    all.push(TimedEvent { at_s: t, event: ClusterEvent::NodeRecover { node } });
                    t += rng.exponential(1.0 / self.mtbf_s);
                }
            }
        }
        // Stable: ties keep insertion order (scripted before sampled,
        // lower node first), so the timeline is reproducible.
        all.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        all.retain(|te| te.at_s < horizon_s);
        all
    }
}

/// Per-event recovery metrics reported in `RunReport::events`.
#[derive(Debug, Clone)]
pub struct EventReport {
    pub at_s: f64,
    /// Stable kind tag plus the node/tenant it touched, e.g.
    /// `node_fail(node 1)`.
    pub label: String,
    /// Mean windowed throughput over the windows preceding the event
    /// (the recovery reference level).
    pub baseline_thr: f64,
    /// Seconds from the event to the next committed scheduling round
    /// (event-driven re-plans make this at most one metrics interval).
    pub replan_s: Option<f64>,
    /// Seconds from the event until windowed throughput first sustains
    /// >= 90% of `baseline_thr` for two consecutive windows.
    pub recovered_s: Option<f64>,
    /// Records dropped by this event (0 under `RecoveryPolicy::Requeue`).
    pub lost_records: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<DynamicsSpec, String> {
        DynamicsSpec::from_json(&Json::parse(s).expect("valid json"))
    }

    #[test]
    fn parses_scripted_timeline() {
        let spec = parse(
            r#"{"recovery": "loss", "events": [
                {"at": 300, "kind": "node_fail", "node": 1},
                {"at": 600, "kind": "node_recover", "node": 1},
                {"at": 100, "kind": "tenant_arrive", "tenant": "speech"},
                {"at": 400, "kind": "bandwidth_degrade", "node": 0, "factor": 0.25},
                {"at": 500, "kind": "bandwidth_restore", "node": 0},
                {"at": 900, "kind": "tenant_depart", "tenant": "speech"},
                {"at": 200, "kind": "node_join", "node": 2}
            ]}"#,
        )
        .unwrap();
        assert_eq!(spec.events.len(), 7);
        assert_eq!(spec.recovery, RecoveryPolicy::Loss);
        assert_eq!(spec.joining_nodes(), vec![2]);
        assert_eq!(spec.arriving_tenants(), vec!["speech".to_string()]);
        let tl = spec.timeline(3, 1000.0, 7);
        assert_eq!(tl.len(), 7);
        assert!(tl.windows(2).all(|w| w[0].at_s <= w[1].at_s), "sorted");
        assert_eq!(tl[0].event, ClusterEvent::TenantArrive { tenant: "speech".into() });
    }

    #[test]
    fn rejects_unknown_kinds_and_bad_timestamps() {
        let bad_kind = parse(r#"{"events": [{"at": 1, "kind": "node_explode", "node": 0}]}"#);
        assert!(bad_kind.unwrap_err().contains("unknown event kind"));
        let no_at = parse(r#"{"events": [{"kind": "node_fail", "node": 0}]}"#);
        assert!(no_at.unwrap_err().contains("'at'"));
        let neg_at = parse(r#"{"events": [{"at": -5, "kind": "node_fail", "node": 0}]}"#);
        assert!(neg_at.unwrap_err().contains("'at'"));
        let bad_factor =
            parse(r#"{"events": [{"at": 1, "kind": "bandwidth_degrade", "node": 0, "factor": 1.5}]}"#);
        assert!(bad_factor.unwrap_err().contains("factor"));
        let no_tenant = parse(r#"{"events": [{"at": 1, "kind": "tenant_arrive"}]}"#);
        assert!(no_tenant.unwrap_err().contains("tenant"));
        let neg_node = parse(r#"{"events": [{"at": 1, "kind": "node_fail", "node": -1}]}"#);
        assert!(neg_node.unwrap_err().contains("'node'"));
        let frac_node = parse(r#"{"events": [{"at": 1, "kind": "node_fail", "node": 1.5}]}"#);
        assert!(frac_node.unwrap_err().contains("'node'"));
        let bad_recovery = parse(r#"{"recovery": "yolo", "events": []}"#);
        assert!(bad_recovery.unwrap_err().contains("recovery"));
        let bad_mtbf = parse(r#"{"mtbf_s": 100}"#);
        assert!(bad_mtbf.unwrap_err().contains("mttr_s"));
    }

    #[test]
    fn validates_against_deployment() {
        let spec = parse(r#"{"events": [{"at": 1, "kind": "node_fail", "node": 9}]}"#).unwrap();
        assert!(spec.validate(2, &["pdf".into()]).unwrap_err().contains("out of range"));
        let spec =
            parse(r#"{"events": [{"at": 1, "kind": "tenant_depart", "tenant": "ghost"}]}"#)
                .unwrap();
        assert!(spec.validate(2, &["pdf".into()]).unwrap_err().contains("unknown tenant"));
        let spec = parse(
            r#"{"events": [
                {"at": 50, "kind": "node_fail", "node": 1},
                {"at": 100, "kind": "node_join", "node": 1}
            ]}"#,
        )
        .unwrap();
        assert!(spec.validate(2, &["pdf".into()]).unwrap_err().contains("before its node_join"));
    }

    #[test]
    fn mtbf_timeline_is_deterministic_and_alternates() {
        let spec = DynamicsSpec { mtbf_s: 400.0, mttr_s: 60.0, ..Default::default() };
        let a = spec.timeline(4, 3600.0, 42);
        let b = spec.timeline(4, 3600.0, 42);
        assert_eq!(a, b, "same seed, bit-identical timeline");
        let c = spec.timeline(4, 3600.0, 43);
        assert_ne!(a, c, "seed perturbs the sampled churn");
        assert!(!a.is_empty(), "an hour at 400s MTBF over 4 nodes churns");
        // Per node: fail and recover strictly alternate, fail first.
        for node in 0..4 {
            let evs: Vec<&ClusterEvent> = a
                .iter()
                .filter(|te| te.event.node() == Some(node))
                .map(|te| &te.event)
                .collect();
            for (i, ev) in evs.iter().enumerate() {
                let want = if i % 2 == 0 { "node_fail" } else { "node_recover" };
                assert_eq!(ev.kind(), want, "node {node} event {i}");
            }
        }
        assert!(a.iter().all(|te| te.at_s < 3600.0));
    }

    #[test]
    fn empty_spec_is_empty() {
        assert!(DynamicsSpec::default().is_empty());
        assert!(DynamicsSpec::default().timeline(8, 1e4, 0).is_empty());
    }
}
