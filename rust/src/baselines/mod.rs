//! Baseline schedulers from the paper's evaluation (§8.2–8.3):
//!
//! * **Static** — a manually-tuned fixed allocation (we realize "manual
//!   tuning" as a one-shot MILP solve against nominal first-regime rates,
//!   never re-planned);
//! * **Ray Data** — threshold-based reactive autoscaling per operator
//!   (queue pressure / utilization), placement-unaware;
//! * **DS2** — useful-time processing rates + topology-derived parallelism
//!   (assumes synchronous operators; systematically misestimates async
//!   capacity);
//! * **ContTune** — DS2's observation plus conservative Bayesian steps on
//!   the bottleneck operator's parallelism;
//! * **SCOOT** — offline per-operator configuration tuning; deploys the
//!   tuned configs on the Static allocation, no runtime adaptation.
//!
//! All of them produce a placement matrix `x[op][node]`; each implements
//! the coordinator's [`SchedulingPolicy`] trait, and the coordinator
//! applies every plan to the executor identically, so RQ1/RQ2 comparisons
//! differ only in policy.  (Static and SCOOT never re-plan; their policy
//! impl lives in `coordinator::policy` next to Trident's.)

use crate::config::{ClusterSpec, PipelineSpec, TenancyView};
use crate::coordinator::policy::{Plan, PolicyCtx, SchedulingPolicy, TransitionCmd};
use crate::sim::OpMetrics;

/// A placement decision: instances per (op, node).
pub type Placement = Vec<Vec<u32>>;

/// A copy of `cluster` with down nodes' capacity zeroed: the greedy
/// packers then skip them naturally, so every baseline "survives" node
/// churn by re-planning cold over the surviving set.
pub fn masked_cluster(cluster: &ClusterSpec, node_up: &[bool]) -> ClusterSpec {
    let mut c = cluster.clone();
    for (nd, &up) in c.nodes.iter_mut().zip(node_up) {
        if !up {
            nd.cpu_cores = 0.0;
            nd.mem_gb = 0.0;
            nd.accels = 0;
        }
    }
    c
}

/// Greedy capacity-respecting packer shared by the baselines: place
/// `p[i]` instances of each op, accel ops first, round-robin across nodes.
/// Returns the achieved placement (may be short if resources run out).
pub fn pack(pipeline: &PipelineSpec, cluster: &ClusterSpec, p: &[u32]) -> Placement {
    let k = cluster.nodes.len();
    let n = pipeline.n_ops();
    let mut cpu: Vec<f64> = cluster.nodes.iter().map(|nd| nd.cpu_cores).collect();
    let mut mem: Vec<f64> = cluster.nodes.iter().map(|nd| nd.mem_gb).collect();
    let mut acc: Vec<f64> = cluster.nodes.iter().map(|nd| nd.accels as f64).collect();
    let mut x = vec![vec![0u32; k]; n];
    // Accel ops first (scarce), then CPU ops; round-robin for spread.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(pipeline.operators[i].accels));
    for &i in &order {
        let o = &pipeline.operators[i];
        let mut next = 0usize;
        for _ in 0..p[i] {
            let mut placed = false;
            for probe in 0..k {
                let kk = (next + probe) % k;
                let fits = cpu[kk] >= o.cpu
                    && mem[kk] >= o.mem_gb
                    && (o.accels == 0 || acc[kk] >= o.accels as f64);
                if fits {
                    cpu[kk] -= o.cpu;
                    mem[kk] -= o.mem_gb;
                    acc[kk] -= o.accels as f64;
                    x[i][kk] += 1;
                    next = kk + 1;
                    placed = true;
                    break;
                }
            }
            if !placed {
                break;
            }
        }
    }
    x
}

/// Like [`pack`], but round-robin at instance granularity (accel-first
/// op order): every op receives its first instance before any op gets
/// its second.  Under multi-tenant accelerator scarcity the classic
/// greedy order can hand all devices to the first tenant's operators and
/// zero out a later tenant's — and a zero-instance operator wedges its
/// whole DAG.  Single-tenant plans keep the classic [`pack`] (bit-for-bit
/// pre-tenancy behavior); the baselines switch to this packer whenever
/// the tenancy has more than one tenant.
pub fn pack_fair(pipeline: &PipelineSpec, cluster: &ClusterSpec, p: &[u32]) -> Placement {
    let k = cluster.nodes.len();
    let n = pipeline.n_ops();
    let mut cpu: Vec<f64> = cluster.nodes.iter().map(|nd| nd.cpu_cores).collect();
    let mut mem: Vec<f64> = cluster.nodes.iter().map(|nd| nd.mem_gb).collect();
    let mut acc: Vec<f64> = cluster.nodes.iter().map(|nd| nd.accels as f64).collect();
    let mut x = vec![vec![0u32; k]; n];
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(pipeline.operators[i].accels));
    let mut next = vec![0usize; n];
    let mut remaining: Vec<u32> = p.to_vec();
    loop {
        let mut placed_any = false;
        for &i in &order {
            if remaining[i] == 0 {
                continue;
            }
            let o = &pipeline.operators[i];
            let mut placed = false;
            for probe in 0..k {
                let kk = (next[i] + probe) % k;
                let fits = cpu[kk] >= o.cpu
                    && mem[kk] >= o.mem_gb
                    && (o.accels == 0 || acc[kk] >= o.accels as f64);
                if fits {
                    cpu[kk] -= o.cpu;
                    mem[kk] -= o.mem_gb;
                    acc[kk] -= o.accels as f64;
                    x[i][kk] += 1;
                    next[i] = kk + 1;
                    remaining[i] -= 1;
                    placed = true;
                    placed_any = true;
                    break;
                }
            }
            if !placed {
                remaining[i] = 0; // out of room for this op: stop asking
            }
        }
        if !placed_any {
            break;
        }
    }
    x
}

/// Waterfall parallelism: given per-instance rates, the max throughput the
/// cluster supports and the per-op instance counts to sustain it.
/// This is the core of DS2's "three steps" adapted to the offline setting
/// (the source rate is a decision, so target = best achievable).
pub fn waterfall(
    pipeline: &PipelineSpec,
    cluster: &ClusterSpec,
    rates: &[f64],
    headroom: f64,
) -> Vec<u32> {
    waterfall_t(pipeline, &TenancyView::single_for(pipeline), cluster, rates, headroom)
}

/// Tenant-aware [`waterfall`]: the bottleneck throughput is computed per
/// tenant over the merged operator list (each tenant's own D_o / D_i),
/// so one tenant's amplification never distorts another's sizing.  The
/// single-tenant view reduces exactly to the classic DS2 form.
pub fn waterfall_t(
    pipeline: &PipelineSpec,
    tenancy: &TenancyView,
    cluster: &ClusterSpec,
    rates: &[f64],
    headroom: f64,
) -> Vec<u32> {
    let n = pipeline.n_ops();
    let (d_i, _) = pipeline.amplification();
    // Max instances per op if it had the whole cluster (resource caps).
    let cap = |i: usize| -> f64 {
        let o = &pipeline.operators[i];
        if o.accels > 0 {
            // accel ops share devices: assume equal split among accel ops
            let n_accel_ops = pipeline.operators.iter().filter(|q| q.accels > 0).count() as f64;
            (cluster.total_accels() as f64 / o.accels as f64 / n_accel_ops).floor().max(1.0)
        } else {
            (cluster.total_cpus() / o.cpu / (n as f64 / 2.0)).floor().max(1.0)
        }
    };
    let mut t_star = vec![f64::INFINITY; tenancy.n_tenants()];
    for i in 0..n {
        let t = tenancy.op_tenant[i];
        t_star[t] = t_star[t].min(tenancy.d_o[t] / d_i[i] * cap(i) * rates[i].max(1e-9));
    }
    (0..n)
        .map(|i| {
            let t = tenancy.op_tenant[i];
            let need = t_star[t] * d_i[i] / (tenancy.d_o[t] * rates[i].max(1e-9)) * headroom;
            (need.ceil() as u32).max(1)
        })
        .collect()
}

/// DS2 as a pluggable policy: useful-time rates + waterfall parallelism
/// with a small headroom, greedily re-packed every scheduling round.
pub struct Ds2 {
    pub headroom: f64,
}

impl Default for Ds2 {
    fn default() -> Self {
        Ds2 { headroom: 1.05 }
    }
}

/// Classic greedy pack for one tenant, fair round-robin pack for many
/// (see [`pack_fair`]).  Under cluster dynamics, down nodes are masked
/// out and inactive (dormant/departed) tenants' ops get zero instances —
/// the identity transformation on a fully live deployment.
fn pack_for(ctx: &PolicyCtx<'_>, p: &[u32]) -> Placement {
    let mut p = p.to_vec();
    for (i, pi) in p.iter_mut().enumerate() {
        if !ctx.op_active(i) {
            *pi = 0;
        } else if *pi == 0 {
            // An op wiped out by a node failure: the reactive baselines
            // size relative to the current count, so re-seed one instance
            // or the op (and its whole DAG) would stay dead forever.
            // Unreachable absent dynamics (counts never hit 0).
            *pi = 1;
        }
    }
    let masked;
    let cluster = if ctx.node_up.iter().all(|&u| u) {
        ctx.cluster
    } else {
        masked = masked_cluster(ctx.cluster, ctx.node_up);
        &masked
    };
    if ctx.tenancy.n_tenants() > 1 {
        pack_fair(ctx.spec, cluster, &p)
    } else {
        pack(ctx.spec, cluster, &p)
    }
}

impl SchedulingPolicy for Ds2 {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> Plan {
        let p = waterfall_t(ctx.spec, ctx.tenancy, ctx.cluster, ctx.rates, self.headroom);
        let x = pack_for(ctx, &p);
        Plan {
            placement: Some(x),
            routes: None,
            transitions: TransitionCmd::AllAtOnce,
            milp_ms: None,
            stats: None,
        }
    }
}

/// Ray Data's default reactive autoscaler: per-operator thresholds on
/// queue backlog and utilization, one step at a time, no global view.
pub struct RayDataAutoscaler {
    /// Scale up when avg queue exceeds this fraction of capacity.
    pub q_high: f64,
    /// Scale down when utilization is below this and queue near-empty.
    pub u_low: f64,
    pub u_high: f64,
}

impl Default for RayDataAutoscaler {
    fn default() -> Self {
        RayDataAutoscaler { q_high: 0.5, u_low: 0.3, u_high: 0.85 }
    }
}

impl RayDataAutoscaler {
    /// One reactive step: returns the new target parallelism per op.
    pub fn step(
        &self,
        pipeline: &PipelineSpec,
        metrics: &[OpMetrics],
        cur_p: &[u32],
    ) -> Vec<u32> {
        let mut p = cur_p.to_vec();
        for (i, m) in metrics.iter().enumerate() {
            let cap = pipeline.operators[i].queue_cap as f64;
            let backlog = m.queue_avg / (cap * cur_p[i].max(1) as f64);
            if backlog > self.q_high || m.utilization > self.u_high {
                p[i] = cur_p[i] + 1;
            } else if m.utilization < self.u_low && m.queue_end < 4 && cur_p[i] > 1 {
                p[i] = cur_p[i] - 1;
            }
        }
        p
    }
}

impl SchedulingPolicy for RayDataAutoscaler {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> Plan {
        let p = self.step(ctx.spec, ctx.metrics, ctx.cur_p);
        let x = pack_for(ctx, &p);
        Plan {
            placement: Some(x),
            routes: None,
            transitions: TransitionCmd::AllAtOnce,
            milp_ms: None,
            stats: None,
        }
    }
}

/// ContTune-style conservative Bayesian step on top of DS2 parallelism:
/// nudge the bottleneck operator up while the observed throughput keeps
/// improving; back off when it stops helping (big-spring-small-step,
/// reduced to its conservative-exploration core).
pub struct ContTune {
    last_throughput: f64,
    last_bumped: Option<usize>,
}

impl Default for ContTune {
    fn default() -> Self {
        ContTune { last_throughput: 0.0, last_bumped: None }
    }
}

impl ContTune {
    pub fn step(
        &mut self,
        pipeline: &PipelineSpec,
        tenancy: &TenancyView,
        rates: &[f64],
        metrics: &[OpMetrics],
        cur_p: &[u32],
        throughput: f64,
    ) -> Vec<u32> {
        let (d_i, _) = pipeline.amplification();
        // Per-op pipeline-rate conversion using the op's own tenant D_o.
        let g = |i: usize| tenancy.d_o[tenancy.op_tenant[i]] / d_i[i];
        let mut p = cur_p.to_vec();
        // Undo the previous bump if it did not help (conservative).
        if let Some(i) = self.last_bumped {
            if throughput < self.last_throughput * 1.01 && p[i] > 1 {
                p[i] -= 1;
                self.last_bumped = None;
                self.last_throughput = throughput;
                return p;
            }
        }
        // Bottleneck = smallest estimated capacity margin.
        let bottleneck = (0..pipeline.n_ops())
            .filter(|&i| metrics[i].records_out > 0)
            .min_by(|&a, &b| {
                let ca = g(a) * cur_p[a] as f64 * rates[a].max(1e-9);
                let cb = g(b) * cur_p[b] as f64 * rates[b].max(1e-9);
                ca.partial_cmp(&cb).unwrap()
            });
        if let Some(i) = bottleneck {
            p[i] = cur_p[i] + 1;
            self.last_bumped = Some(i);
        }
        self.last_throughput = throughput;
        p
    }
}

impl SchedulingPolicy for ContTune {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> Plan {
        let p = self.step(
            ctx.spec,
            ctx.tenancy,
            ctx.rates,
            ctx.metrics,
            ctx.cur_p,
            ctx.last_throughput,
        );
        let x = pack_for(ctx, &p);
        Plan {
            placement: Some(x),
            routes: None,
            transitions: TransitionCmd::AllAtOnce,
            milp_ms: None,
            stats: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::sim::metrics::InstanceMetrics;
    use crate::workload::pdf;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(8, 256.0, 1024.0, 8, 65536.0, 12500.0)
    }

    fn mk_metrics(util: f64, qavg: f64) -> OpMetrics {
        OpMetrics {
            op: 0,
            window_s: 5.0,
            records_in: 10,
            records_out: 10,
            rate_per_inst: 1.0,
            utilization: util,
            queue_begin: qavg as usize,
            queue_end: qavg as usize,
            queue_avg: qavg,
            feat_mean: [0.0; 4],
            feat_std: [0.0; 4],
            peak_mem_mb: 0.0,
            oom_events: 0,
            n_active: 1,
            cluster_samples: vec![],
            per_instance: Vec::<InstanceMetrics>::new(),
        }
    }

    #[test]
    fn pack_respects_resources() {
        let pl = pdf::pipeline();
        let p: Vec<u32> = vec![4; pl.n_ops()];
        let x = pack(&pl, &cluster(), &p);
        for kk in 0..8 {
            let acc: u32 = (0..pl.n_ops())
                .map(|i| x[i][kk] * pl.operators[i].accels)
                .sum();
            assert!(acc <= 8);
            let cpu: f64 = (0..pl.n_ops())
                .map(|i| x[i][kk] as f64 * pl.operators[i].cpu)
                .sum();
            assert!(cpu <= 256.0);
        }
        // accel ops fully placed (scarce first)
        for i in 0..pl.n_ops() {
            if pl.operators[i].accels > 0 {
                assert_eq!(x[i].iter().sum::<u32>(), 4, "op {i}");
            }
        }
    }

    #[test]
    fn waterfall_balances_amplification() {
        let pl = pdf::pipeline();
        let rates: Vec<f64> = pl.operators.iter().map(|_| 10.0).collect();
        let p = waterfall(&pl, &cluster(), &rates, 1.1);
        let (d_i, _) = pl.amplification();
        // ops with higher amplification need proportionally more instances
        let hi = d_i
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(p[hi] >= p[0], "amplified op gets more instances: {p:?}");
        assert!(p.iter().all(|&v| v >= 1));
    }

    #[test]
    fn raydata_scales_on_pressure() {
        let pl = pdf::pipeline();
        let rd = RayDataAutoscaler::default();
        let metrics: Vec<OpMetrics> = (0..pl.n_ops())
            .map(|i| match i {
                0 => mk_metrics(0.95, 200.0), // overloaded
                1 => mk_metrics(0.1, 0.0),    // idle
                _ => mk_metrics(0.5, 10.0),   // fine
            })
            .collect();
        let cur = vec![2u32; pl.n_ops()];
        let p = rd.step(&pl, &metrics, &cur);
        assert_eq!(p[0], 3, "overloaded scales up");
        assert_eq!(p[1], 1, "idle scales down");
        assert_eq!(p[2], 2, "healthy unchanged");
    }

    #[test]
    fn conttune_reverts_unhelpful_bump() {
        let pl = pdf::pipeline();
        let view = TenancyView::single_for(&pl);
        let rates: Vec<f64> = pl.operators.iter().map(|_| 10.0).collect();
        let metrics: Vec<OpMetrics> = (0..pl.n_ops()).map(|_| mk_metrics(0.5, 0.0)).collect();
        let mut ct = ContTune::default();
        let p0 = vec![2u32; pl.n_ops()];
        let p1 = ct.step(&pl, &view, &rates, &metrics, &p0, 1.0);
        let bumped = (0..p1.len()).find(|&i| p1[i] > p0[i]).expect("bumps one op");
        // throughput did not improve -> revert
        let p2 = ct.step(&pl, &view, &rates, &metrics, &p1, 1.0);
        assert_eq!(p2[bumped], p0[bumped], "unhelpful bump reverted");
    }

    /// Under multi-tenant device scarcity, the fair packer must give
    /// every accel op its first instance before any op gets seconds —
    /// the classic greedy pack would zero out the last tenant's ops.
    #[test]
    fn pack_fair_never_zeroes_a_feasible_op() {
        use crate::config::{Tenancy, TenantSpec};
        use crate::workload::speech;
        let tenancy = Tenancy {
            tenants: vec![
                TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
                TenantSpec { id: "speech".into(), pipeline: speech::pipeline(), weight: 1.0, source_rate: 0.0 },
            ],
        };
        let (spec, _) = tenancy.merged().unwrap();
        // Small cluster: 8 devices for 5 accel ops wanting 2 each (=10).
        let cluster = ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0);
        let p: Vec<u32> = spec
            .operators
            .iter()
            .map(|o| if o.accels > 0 { 2 } else { 1 })
            .collect();
        let x = pack_fair(&spec, &cluster, &p);
        for (i, o) in spec.operators.iter().enumerate() {
            assert!(
                x[i].iter().sum::<u32>() >= 1,
                "op {i} ({}) zeroed out by the fair packer",
                o.name
            );
        }
        // Still capacity-respecting.
        for kk in 0..2 {
            let acc: u32 = (0..spec.n_ops()).map(|i| x[i][kk] * spec.operators[i].accels).sum();
            assert!(acc <= 4);
        }
    }

    /// The merged two-tenant waterfall sizes each tenant against its own
    /// bottleneck: a heavy-amplification tenant must not inflate the
    /// instance counts of its neighbour.
    #[test]
    fn waterfall_t_isolates_tenant_amplification() {
        use crate::config::{Tenancy, TenantSpec};
        use crate::workload::speech;
        let tenancy = Tenancy {
            tenants: vec![
                TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
                TenantSpec { id: "speech".into(), pipeline: speech::pipeline(), weight: 1.0, source_rate: 0.0 },
            ],
        };
        let (spec, view) = tenancy.merged().unwrap();
        let rates: Vec<f64> = spec.operators.iter().map(|_| 10.0).collect();
        let p = waterfall_t(&spec, &view, &cluster(), &rates, 1.1);
        assert_eq!(p.len(), spec.n_ops());
        assert!(p.iter().all(|&v| v >= 1));
        // Single-tenant slice equivalence: the pdf ops sized by the merged
        // call match a pdf-only waterfall with the same uniform rates
        // (cap() sees more ops in the merged union, so compare against a
        // run over the same merged spec restricted to tenant 0's rows).
        let n_pdf = pdf::pipeline().n_ops();
        for i in 0..n_pdf {
            assert_eq!(view.op_tenant[i], 0);
        }
        for i in n_pdf..spec.n_ops() {
            assert_eq!(view.op_tenant[i], 1);
        }
    }
}
