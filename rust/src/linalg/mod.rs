//! Minimal dense linear algebra: row-major matrices, Cholesky, triangular
//! solves.  Backs the native (non-PJRT) Gaussian-Process path used as a
//! numerical oracle in tests and as a fallback when AOT artifacts are
//! absent (`TRIDENT_NATIVE_GP=1`).

/// Dense row-major `rows x cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// Matrix–matrix product.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
/// Returns `None` if the matrix is not (numerically) PD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `L x = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve `L^T x = b` for lower-triangular `L` (backward substitution).
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve `A x = b` for SPD `A` via Cholesky.
pub fn cho_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        // A = B B^T + n*I is SPD.
        let mut b = Mat::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal(0.0, 1.0);
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(0);
        for n in [1usize, 2, 5, 17, 40] {
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).expect("SPD");
            let rec = l.matmul(&l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-8, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn triangular_solves_invert() {
        let mut rng = Rng::new(1);
        for n in [1usize, 3, 8, 25] {
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            let x_true: Vec<f64> = (0..n).map(|i| (i as f64) - 2.0).collect();
            let b = l.matvec(&x_true);
            let x = solve_lower(&l, &b);
            for (xa, xb) in x.iter().zip(&x_true) {
                assert!((xa - xb).abs() < 1e-9);
            }
            let bt = l.transpose().matvec(&x_true);
            let xt = solve_lower_t(&l, &bt);
            for (xa, xb) in xt.iter().zip(&x_true) {
                assert!((xa - xb).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cho_solve_property_random_systems() {
        // property-style: 50 random SPD systems, residual must vanish.
        let mut rng = Rng::new(2);
        for case in 0..50 {
            let n = 1 + rng.below(20);
            let a = random_spd(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 3.0)).collect();
            let b = a.matvec(&x_true);
            let x = cho_solve(&a, &b).unwrap();
            for (xa, xb) in x.iter().zip(&x_true) {
                assert!((xa - xb).abs() < 1e-6, "case={case} n={n}");
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(3);
        let a = random_spd(&mut rng, 6);
        let i = Mat::eye(6);
        assert_eq!(a.matmul(&i).data.len(), a.data.len());
        for (x, y) in a.matmul(&i).data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }
}
