//! # Trident
//!
//! A reproduction of *Trident: Adaptive Scheduling for Heterogeneous
//! Multimodal Data Pipelines* (CS.DC 2026) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the streaming coordinator: discrete-event
//!   cluster/pipeline runtime, metrics collection, the observation /
//!   adaptation / scheduling closed loop, the MILP scheduler, and all
//!   baseline schedulers from the paper's evaluation.  Schedulers are
//!   pluggable [`coordinator::SchedulingPolicy`] implementations over one
//!   shared substrate, and the [`harness`] module fans variant × seed
//!   evaluation grids out across cores.
//! * **Layer 2 (`python/compile/model.py`)** — the GP posterior and the
//!   memory-constrained BO acquisition as JAX graphs, AOT-lowered to HLO
//!   text artifacts.
//! * **Layer 1 (`python/compile/kernels/matern.py`)** — the Matérn-5/2
//!   cross-covariance Pallas kernel the Layer-2 graphs call.
//!
//! At runtime Python is never on the path: `runtime/` loads the artifacts
//! through the PJRT CPU client (`xla` crate, behind the off-by-default
//! `pjrt` cargo feature) and the coordinator calls the compiled
//! executables directly.  The default build uses the pure-Rust native GP
//! oracle and has no third-party dependencies at all.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod adaptation;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod dynamics;
pub mod harness;
pub mod linalg;
pub mod observation;
pub mod report;
pub mod rngx;
pub mod runtime;
pub mod scheduling;
pub mod sim;
pub mod solver;
pub mod testutil;
pub mod trace;
pub mod workload;
