//! Scheduling layer (paper §6): the joint parallelism / placement /
//! configuration-transition MILP and the rolling-update state machine.

pub mod milp_model;
pub mod rolling;

pub use milp_model::{
    solve, solve_cached, solve_with_options, BasisCache, MilpInput, MilpTenant, OpSched,
    SchedulePlan,
};
pub use rolling::RollingState;
