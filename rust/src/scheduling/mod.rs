//! Scheduling layer (paper §6): the joint parallelism / placement /
//! configuration-transition MILP and the rolling-update state machine.

pub mod decomposed;
pub mod milp_model;
pub mod rolling;

pub use decomposed::{solve_decomposed, DecompOptions, SolverBackend};
pub use milp_model::{
    solve, solve_cached, solve_with_options, tenant_block, BasisCache, MilpInput, MilpTenant,
    OpSched, SchedulePlan,
};
pub use rolling::RollingState;
