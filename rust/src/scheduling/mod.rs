//! Scheduling layer (paper §6): the joint parallelism / placement /
//! configuration-transition MILP and the rolling-update state machine.

pub mod milp_model;
pub mod rolling;

pub use milp_model::{solve, MilpInput, MilpTenant, OpSched, SchedulePlan};
pub use rolling::RollingState;
