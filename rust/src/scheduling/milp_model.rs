//! The Trident scheduling MILP (paper §6, Eqs. 10–26): joint parallelism,
//! placement, flow routing, and rolling configuration transitions under
//! heterogeneous per-node CPU / memory / accelerator capacity and network
//! egress, with migration-cost regularization.
//!
//! **Formulation note (documented deviation).**  The paper's flow
//! constraints (Eqs. 18–19) put `w` in "instance units" on *both* sides of
//! an edge, which forces `p_i = p_{i+1}` when read literally.  We model the
//! same co-location objective with *rate-based* flow variables:
//! per pipeline edge `(u, v)` and node k we track `l_{e,k}` (rate produced
//! AND consumed on k), `e_{e,k}` (exported) and `m_{e,k}` (imported), with
//! (i) total flow pinned to the throughput the edge must carry
//! (`T · D_v / D_o`), (ii) per-node source/destination capacity bounds
//! linear in `x`, and (iii) the egress expression (Eq. 20) minimized
//! through `E_max`.  This is linear, O(|E|k) instead of O(|E|k²), and
//! strictly more faithful to what the executor routes (rates, not
//! instance-units).
//!
//! **DAG topology.**  Flow conservation runs over the pipeline's explicit
//! edge list, not over chain positions: a fork's outgoing edges each carry
//! the full replicated volume `D_u · fanout_u`, and a join consumes one
//! merged record per aligned group, so each of its incoming edges carries
//! `D_v` — which is exactly `d_i[v]` from `PipelineSpec::amplification`,
//! making the per-edge demand `T · D_v / D_o` uniform across topologies.
//! A chain is the path-shaped special case and builds the identical
//! problem (same variables, names, and coefficients) as the pre-DAG
//! formulation.
//!
//! **Known join approximation.**  By default the relaxation treats a
//! join's incoming edges independently, so a plan may land sibling
//! partials of one group on different nodes; the executor then forwards
//! the late partial to the group's holding instance over the egress link
//! — traffic the `E_max` budget never saw.  The gap is second-order
//! (holder affinity follows the same routing fractions, so most groups
//! co-locate), but on link-bound plans realized throughput can fall
//! below `t_pred`.  The fix is the **co-located-join-inflow constraint**
//! (`MilpInput::join_colocate`, wired to
//! `TridentConfig::milp_join_colocation` / CLI `--join-colocate`): tie
//! the per-node consumption of a join's in-edges together, so siblings
//! are consumed where the holder runs and their forwarding shows up in
//! the egress rows.  Always feasible (a join's in-edges carry equal
//! demand by construction) and only tightens the relaxation.
//!
//! **Multi-tenancy.**  With N > 1 `tenants` rows the problem carries one
//! throughput variable `T_t` per tenant and maximizes the weighted
//! max-min epigraph `T_min` (`w_t · T_min <= T_t`) plus an infinitesimal
//! per-tenant bonus; per-op/per-edge rows bind their own tenant's `T_t`
//! through `D_o^t`, while node capacity and egress rows span the union
//! of all tenants' operators.  An empty `tenants` list builds the
//! classic single-tenant problem unchanged.

use std::time::Duration;

use crate::config::NodeSpec;
use crate::solver::{BasisSnapshot, Cmp, MilpOptions, MilpStats, Problem, Status, Var};

/// Infinitesimal per-tenant throughput bonus in the multi-tenant
/// objective (so non-bottleneck tenants still take Pareto-dominant
/// throughput).  The Dantzig–Wolfe master charges columns the same
/// coefficient, keeping the decomposed objective comparable to the
/// monolithic one term for term.
pub(crate) const TENANT_BONUS: f64 = 1e-6;
/// Symmetry-breaking preference for low-index nodes on placement vars.
pub(crate) const EPS_NODE: f64 = 1e-9;

/// Per-operator scheduler inputs for one round.
#[derive(Debug, Clone)]
pub struct OpSched {
    pub name: String,
    /// Current-config per-instance rate UT_i^cur (records/s).
    pub ut_cur: f64,
    /// Candidate-config rate UT_i^cand (None when s_i != Tuned).
    pub ut_cand: Option<f64>,
    /// Rolling state: instances already on the candidate config.
    pub n_new: u32,
    /// Instances still on the current config.
    pub n_old: u32,
    /// Resources per instance.
    pub cpu: f64,
    pub mem_gb: f64,
    pub accels: u32,
    /// Output record size, MB.
    pub out_mb: f64,
    /// Amplification D_i (input volume relative to pipeline input).
    pub d_i: f64,
    /// Lifecycle costs, seconds.
    pub h_start: f64,
    pub h_stop: f64,
    pub h_cold: f64,
    /// Current placement x̄_{i,k}.
    pub cur_x: Vec<u32>,
}

/// One tenant row of a multi-tenant MILP: its weight in the weighted
/// max-min objective and its own output amplification D_o^t.
#[derive(Debug, Clone)]
pub struct MilpTenant {
    pub name: String,
    pub weight: f64,
    pub d_o: f64,
}

impl MilpTenant {
    /// MILP tenant rows from a merged tenancy view.  Empty for a single
    /// tenant: the solver then builds the classic scalar-`d_o` problem
    /// (identical variables, names, and coefficients to the pre-tenancy
    /// formulation).
    pub fn from_view(view: &crate::config::TenancyView) -> Vec<MilpTenant> {
        if view.n_tenants() <= 1 {
            return Vec::new();
        }
        view.ids
            .iter()
            .zip(&view.weights)
            .zip(&view.d_o)
            .map(|((id, &w), &d)| MilpTenant { name: id.clone(), weight: w, d_o: d })
            .collect()
    }
}

/// Scheduler MILP inputs.
#[derive(Debug, Clone)]
pub struct MilpInput {
    pub ops: Vec<OpSched>,
    /// Pipeline dataflow edges `(from_op, to_op)`; flow/egress variables
    /// are created per edge (`PipelineSpec::edges` order).
    pub edges: Vec<(usize, usize)>,
    pub nodes: Vec<NodeSpec>,
    pub d_o: f64,
    /// Multi-tenant structure: one row per tenant.  Empty = the classic
    /// single-tenant formulation on the scalar `d_o`; with N > 1 rows the
    /// solver builds per-tenant throughput variables T_t and a weighted
    /// max-min epigraph objective over shared node-capacity/egress rows.
    pub tenants: Vec<MilpTenant>,
    /// Tenant index per op (parallel to `ops`; may be empty when
    /// `tenants` is empty).
    pub op_tenant: Vec<usize>,
    /// Scheduling window T_sched (cold-start discount, Eq. 11).
    pub t_sched: f64,
    pub lambda1: f64,
    pub lambda2: f64,
    /// Rolling batch cap B_max.
    pub b_max: u32,
    /// Disable network/egress modelling (w/o-placement ablation).
    pub placement_aware: bool,
    /// Tie each join's in-edge consumption together per node, so sibling
    /// partials of one group are consumed where the holder runs and the
    /// egress rows see the forwarding traffic (the "known join
    /// approximation" fix; off by default).
    pub join_colocate: bool,
    /// Force all-at-once transitions (w/o-rolling ablation): b_i is fixed
    /// to n_old whenever a candidate exists.
    pub all_at_once: bool,
}

impl MilpInput {
    /// Tenant of op `i` (0 when single-tenant).
    pub(crate) fn tenant_of(&self, i: usize) -> usize {
        if self.tenants.len() > 1 {
            self.op_tenant[i]
        } else {
            0
        }
    }

    /// Output amplification governing op `i`'s pipeline-rate conversion.
    fn d_o_of(&self, i: usize) -> f64 {
        if self.tenants.len() > 1 {
            self.tenants[self.op_tenant[i]].d_o
        } else {
            self.d_o
        }
    }

    pub(crate) fn n_tenants(&self) -> usize {
        self.tenants.len().max(1)
    }
}

/// Solved plan, decoded back into scheduler terms.
#[derive(Debug, Clone)]
pub struct SchedulePlan {
    /// Parallelism p_i.
    pub p: Vec<u32>,
    /// Placement x_{i,k}.
    pub x: Vec<Vec<u32>>,
    /// Rolling batch b_i (instances to switch this round).
    pub b: Vec<u32>,
    /// Flow fractions per pipeline edge: route[e][k][l] (row-normalized,
    /// indexed by `MilpInput::edges` order).
    pub route: Vec<Vec<Vec<f64>>>,
    /// Predicted aggregate throughput (input records/s; the sum of
    /// `t_tenant` — identical to T for a single tenant).
    pub t_pred: f64,
    /// Predicted per-tenant throughput (singleton for single-tenant).
    pub t_tenant: Vec<f64>,
    /// Consumption rate (l + m) per edge per node, in `edges` order —
    /// empty when placement-unaware.  Diagnostics/tests: the join
    /// co-location constraint makes sibling in-edge rows equal.
    pub edge_cons: Vec<Vec<f64>>,
    /// Solver objective of the returned plan (`NEG_INFINITY` when no
    /// incumbent was found) — what the decomposed-vs-monolithic parity
    /// gates compare.
    pub obj: f64,
    pub status: Status,
    pub stats: MilpStats,
}

/// Cross-round warm-start cache for the scheduling MILP.
///
/// Round r+1's constraint matrix differs from round r's only in drifted
/// rate/memory coefficients (same operators, nodes, edges → same
/// variables and rows), so round r's optimal root basis is
/// primal-feasible-or-near for round r+1 and the revised simplex
/// converges in a few pivots instead of a full two-phase solve.
/// Invalidation rule: **same shape ⇒ reuse, changed shape ⇒ repair** —
/// the cache is keyed by a structural hash of the problem (variable
/// count, integrality, per-row comparison operators and coefficient
/// sparsity pattern; coefficient *values* excluded, since tolerating
/// their drift is the point).  A key match replays the basis verbatim.
/// A mismatch — a topology event removed or restored a node, or spliced
/// a tenant in/out — takes the *restricted-warm* path instead of going
/// fully cold: variables and rows are named by stable op/node/tenant
/// identity, so [`BasisSnapshot::remap_to`] can price out the removed
/// node's columns (rows whose basic column vanished seat their logical)
/// and keep everything that survived.  A repair that turns out singular
/// is rejected by the LP layer and falls back to cold, so the path can
/// only ever save pivots.
#[derive(Debug, Default)]
pub struct BasisCache {
    key: Option<u64>,
    basis: Option<BasisSnapshot>,
    /// Variable / row names of the cached problem, for the name-based
    /// repair across shape changes.
    var_names: Vec<String>,
    row_names: Vec<String>,
    /// Shape-mismatch lookups salvaged by the restricted-warm repair
    /// (diagnostics; asserted by the dynamics tests).
    pub restricted_repairs: u64,
}

impl BasisCache {
    pub fn new() -> BasisCache {
        BasisCache::default()
    }
}

/// Structural (shape-only) FNV-1a hash of a problem: anything that would
/// change variable/row indexing perturbs the key; coefficient values do
/// not.
fn shape_key(p: &Problem) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(FNV_PRIME);
    };
    mix(p.n_vars() as u64);
    for (j, &int) in p.integer.iter().enumerate() {
        if int {
            mix(j as u64 | (1u64 << 63));
        }
    }
    mix(p.rows.len() as u64);
    for row in &p.rows {
        mix(match row.cmp {
            Cmp::Le => 1,
            Cmp::Ge => 2,
            Cmp::Eq => 3,
        });
        mix(row.coeffs.len() as u64);
        for &(j, _) in &row.coeffs {
            mix(j as u64);
        }
    }
    h
}

/// Build + solve the round's MILP (one-shot: no cross-round cache).
pub fn solve(input: &MilpInput, budget: Duration) -> SchedulePlan {
    solve_cached(input, budget, &mut BasisCache::new())
}

/// Build + solve the round's MILP, warm-starting the root LP from
/// `cache` when the problem shape matches the previous round, and
/// re-caching the new root basis for the next one.
pub fn solve_cached(input: &MilpInput, budget: Duration, cache: &mut BasisCache) -> SchedulePlan {
    solve_with_options(input, budget, cache, &MilpOptions::default())
}

/// [`solve_cached`] with explicit branch-and-bound options — how
/// `milp-bench` runs the identical scheduling MILP through the dense
/// baseline and the warm-started revised backend at a deterministic node
/// cap, so pivot counts are comparable across machines.
pub fn solve_with_options(
    input: &MilpInput,
    budget: Duration,
    cache: &mut BasisCache,
    opts: &MilpOptions,
) -> SchedulePlan {
    let build_t = std::time::Instant::now();
    let model = build_model(input);
    let built_ms = build_t.elapsed().as_secs_f64() * 1e3;
    let (sol, mut stats) = solve_model(input, &model, budget, cache, opts);
    stats.build_ms += built_ms;
    decode(input, sol, stats, &model.t_v, &model.p_v, &model.x_v, &model.b_v, &model.flow_v)
}

/// The constructed scheduling MILP plus every variable handle the solve,
/// decode, and Dantzig–Wolfe pricing paths need.  Building once and
/// mutating `prob.obj` in place is what lets the decomposed path re-price
/// a tenant's subproblem every round without re-assembling the rows (the
/// shape — and therefore the [`BasisCache`] key — never changes).
pub(crate) struct Model {
    pub(crate) prob: Problem,
    pub(crate) t_v: Vec<Var>,
    t_min: Option<Var>,
    e_max: Var,
    j_mig: Var,
    pub(crate) p_v: Vec<Var>,
    pub(crate) x_v: Vec<Vec<Var>>,
    pub(crate) b_v: Vec<Var>,
    z_v: Vec<(Var, usize)>,
    pub(crate) flow_v: Vec<Vec<(Var, Var, Var)>>,
}

/// Build the round's MILP (variables, rows — no solve).
pub(crate) fn build_model(input: &MilpInput) -> Model {
    let n = input.ops.len();
    let k = input.nodes.len();
    let mut prob = Problem::new();

    // Conservative per-op instance cap from total cluster resources.
    let cap_i: Vec<f64> = input
        .ops
        .iter()
        .map(|o| {
            let by_cpu: f64 = input.nodes.iter().map(|nd| (nd.cpu_cores / o.cpu.max(1e-9)).floor()).sum();
            let by_acc: f64 = if o.accels > 0 {
                input.nodes.iter().map(|nd| (nd.accels / o.accels) as f64).sum()
            } else {
                f64::INFINITY
            };
            by_cpu.min(by_acc).max(1.0)
        })
        .collect();

    // Throughput variables and E_max, J_mig.  Single-tenant: one T with
    // objective weight 1 (the classic formulation, unchanged).  Multi-
    // tenant: per-tenant T_t plus the weighted max-min epigraph variable
    // T_min (objective 1), with an infinitesimal per-tenant bonus so
    // non-bottleneck tenants still take Pareto-dominant throughput.
    let multi = input.tenants.len() > 1;
    let nt = input.n_tenants();
    let t_ub_t: Vec<f64> = (0..nt)
        .map(|t| {
            input
                .ops
                .iter()
                .enumerate()
                .zip(&cap_i)
                .filter(|((i, _), _)| input.tenant_of(*i) == t)
                .map(|((i, o), c)| {
                    input.d_o_of(i) / o.d_i * c * o.ut_cur.max(o.ut_cand.unwrap_or(0.0)).max(1e-6)
                })
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    // Variables and rows are named by stable op/node/tenant IDENTITY
    // (names, not positional indices): a topology event that removes a
    // node or splices a tenant out shifts every position, and the
    // restricted-warm basis repair (`BasisCache`) aligns the surviving
    // columns/rows across rounds by these names.
    let (t_min, t_v): (Option<Var>, Vec<Var>) = if multi {
        let z = prob.cont("T_min", 0.0, f64::INFINITY, 1.0);
        let ts = (0..nt)
            .map(|t| {
                prob.cont(
                    &format!("T_{}", input.tenants[t].name),
                    0.0,
                    t_ub_t[t].max(1.0) * 2.0,
                    TENANT_BONUS,
                )
            })
            .collect();
        (Some(z), ts)
    } else {
        (None, vec![prob.cont("T", 0.0, t_ub_t[0].max(1.0) * 2.0, 1.0)])
    };
    let e_max = prob.cont("E_max", 0.0, f64::INFINITY, -input.lambda1);
    let j_mig = prob.cont("J_mig", 0.0, f64::INFINITY, -input.lambda2);
    if let Some(z) = t_min {
        for (t, tv) in t_v.iter().enumerate() {
            // T_min <= T_t / w_t  <=>  w_t * T_min - T_t <= 0.
            prob.constrain(
                &format!("maxmin_{}", input.tenants[t].name),
                vec![(z, input.tenants[t].weight), (*tv, -1.0)],
                Cmp::Le,
                0.0,
            );
        }
    }

    // Symmetry breaking: infinitesimal preference for low-index nodes.
    let eps_node = EPS_NODE;

    // p_i, x_{i,k}, b_i
    let mut p_v = Vec::with_capacity(n);
    let mut x_v = vec![Vec::with_capacity(k); n];
    let mut b_v = Vec::with_capacity(n);
    // (z var, op index) pairs so the warm start never re-resolves ops by name.
    let mut z_v: Vec<(Var, usize)> = Vec::new();
    for (i, o) in input.ops.iter().enumerate() {
        let p = prob.int(&format!("p_{}", o.name), (o.n_new.max(1)) as f64, cap_i[i], 0.0);
        p_v.push(p);
        for kk in 0..k {
            let xmax = per_node_cap(o, &input.nodes[kk]);
            let x = prob.int(
                &format!("x_{}_{}", o.name, input.nodes[kk].name),
                0.0,
                xmax,
                -eps_node * kk as f64,
            );
            x_v[i].push(x);
        }
        let has_cand = o.ut_cand.is_some() && o.n_old > 0;
        let b_hi = if has_cand {
            if input.all_at_once {
                o.n_old as f64 // forced below to equal n_old
            } else {
                o.n_old.min(input.b_max) as f64
            }
        } else {
            0.0
        };
        let b = prob.int(&format!("b_{}", o.name), 0.0, b_hi, 0.0);
        if has_cand && input.all_at_once {
            // all-at-once ablation: switch everything or nothing; model as
            // b == n_old when the transition is profitable is nonlinear, so
            // we let the MILP choose via a binary-scaled variable: b in
            // {0, n_old} via auxiliary binary.
            let z = prob.int(&format!("z_{}", o.name), 0.0, 1.0, 0.0);
            z_v.push((z, i));
            prob.constrain(
                &format!("allatonce_{}", o.name),
                vec![(b, 1.0), (z, -(o.n_old as f64))],
                Cmp::Eq,
                0.0,
            );
        }
        b_v.push(b);
    }

    // Throughput constraints (Eq. 13), with the cold-start-discounted rate
    // \hat{UT}_i (Eq. 11) precomputed.  Each op bounds its own tenant's T.
    for (i, o) in input.ops.iter().enumerate() {
        let ut_cand = o.ut_cand.unwrap_or(0.0);
        let ut_hat = ut_cand * (1.0 - o.h_cold / input.t_sched).max(0.0);
        let g = input.d_o_of(i) / o.d_i; // converts per-op rate to pipeline rate
        // T <= g*[ (p - n_new - b) UTcur + n_new UTcand + b UThat ]
        //    = g*UTcur*p + g*(UThat - UTcur)*b + g*n_new*(UTcand - UTcur)
        let rhs = g * o.n_new as f64 * (ut_cand - o.ut_cur);
        prob.constrain(
            &format!("thr_{}", o.name),
            vec![
                (t_v[input.tenant_of(i)], 1.0),
                (p_v[i], -g * o.ut_cur),
                (b_v[i], -g * (ut_hat - o.ut_cur)),
            ],
            Cmp::Le,
            rhs,
        );
        // p_stay >= 0 (Eq. 26): p - b >= n_new
        prob.constrain(
            &format!("stay_{}", o.name),
            vec![(p_v[i], 1.0), (b_v[i], -1.0)],
            Cmp::Ge,
            o.n_new as f64,
        );
    }

    // Placement consistency (Eq. 14).
    for i in 0..n {
        let mut c: Vec<(Var, f64)> = x_v[i].iter().map(|&x| (x, 1.0)).collect();
        c.push((p_v[i], -1.0));
        prob.constrain(&format!("place_{}", input.ops[i].name), c, Cmp::Eq, 0.0);
    }

    // Node resource capacity (Eqs. 15–17).
    for (kk, node) in input.nodes.iter().enumerate() {
        let cpu: Vec<(Var, f64)> = (0..n).map(|i| (x_v[i][kk], input.ops[i].cpu)).collect();
        prob.constrain(&format!("cpu_{}", node.name), cpu, Cmp::Le, node.cpu_cores);
        let mem: Vec<(Var, f64)> = (0..n).map(|i| (x_v[i][kk], input.ops[i].mem_gb)).collect();
        prob.constrain(&format!("mem_{}", node.name), mem, Cmp::Le, node.mem_gb);
        let acc: Vec<(Var, f64)> = (0..n)
            .filter(|&i| input.ops[i].accels > 0)
            .map(|i| (x_v[i][kk], input.ops[i].accels as f64))
            .collect();
        if !acc.is_empty() {
            prob.constrain(&format!("acc_{}", node.name), acc, Cmp::Le, node.accels as f64);
        }
    }

    // Migration accounting (Eqs. 21–22).  **Deviation:** the explicit
    // δ+/δ− variables double the tableau for a 1e-6-weight tiebreaker, so
    // the deployment-stability preference is enforced structurally instead:
    // the warm-start incumbent reuses the current placement wherever
    // feasible, and the relative-gap pruning in branch & bound keeps that
    // incumbent unless a strictly better (beyond-gap) plan exists.  J_mig
    // stays in the objective at 0 for API compatibility.
    let _ = j_mig;

    // Rate-based flow + egress (replaces Eqs. 18–20; see module docs).
    // Per pipeline edge (u, v) and node k: l = locally-consumed rate,
    // e = exported, m = imported.  production_k = l+e, consumption_k = l+m.
    let mut flow_v: Vec<Vec<(Var, Var, Var)>> = Vec::new();
    if input.placement_aware && !input.edges.is_empty() {
        // Edges are named by their endpoint ops ("u>v"), nodes by name.
        let ename = |ei: usize| -> String {
            let (u, v) = input.edges[ei];
            format!("{}>{}", input.ops[u].name, input.ops[v].name)
        };
        for (ei, &(u, v)) in input.edges.iter().enumerate() {
            // D_v is the per-edge volume for forks (replication) and joins
            // (aligned-group consumption) alike; see module docs.
            let d_next = input.ops[v].d_i;
            let fan = d_next / input.ops[u].d_i;
            // Capacity rates include the candidate config (a mid-rollout
            // operator can run faster than ut_cur).
            let rate_of = |o: &OpSched| o.ut_cur.max(o.ut_cand.unwrap_or(0.0)).max(1e-6);
            let src_rate = rate_of(&input.ops[u]) * fan;
            let dst_rate = rate_of(&input.ops[v]);
            let mut per_edge = Vec::with_capacity(k);
            for kk in 0..k {
                let nn = &input.nodes[kk].name;
                let l = prob.cont(&format!("l_{}_{nn}", ename(ei)), 0.0, f64::INFINITY, 0.0);
                let e = prob.cont(&format!("e_{}_{nn}", ename(ei)), 0.0, f64::INFINITY, 0.0);
                let m = prob.cont(&format!("m_{}_{nn}", ename(ei)), 0.0, f64::INFINITY, 0.0);
                // production <= source capacity on k
                prob.constrain(
                    &format!("fsrc_{}_{nn}", ename(ei)),
                    vec![(l, 1.0), (e, 1.0), (x_v[u][kk], -src_rate)],
                    Cmp::Le,
                    0.0,
                );
                // consumption <= destination capacity on k
                prob.constrain(
                    &format!("fdst_{}_{nn}", ename(ei)),
                    vec![(l, 1.0), (m, 1.0), (x_v[v][kk], -dst_rate)],
                    Cmp::Le,
                    0.0,
                );
                per_edge.push((l, e, m));
            }
            // Exported == imported across the cluster.
            let mut bal: Vec<(Var, f64)> = Vec::with_capacity(2 * k);
            for &(_, e, m) in &per_edge {
                bal.push((e, 1.0));
                bal.push((m, -1.0));
            }
            prob.constrain(&format!("fbal_{}", ename(ei)), bal, Cmp::Eq, 0.0);
            // Total consumption equals the rate this edge must carry:
            // sum_k (l+m) = T_t * D_v / D_o^t (the owning tenant's T).
            let mut tot: Vec<(Var, f64)> = Vec::with_capacity(2 * k + 1);
            for &(l, _, m) in &per_edge {
                tot.push((l, 1.0));
                tot.push((m, 1.0));
            }
            tot.push((t_v[input.tenant_of(v)], -d_next / input.d_o_of(v)));
            prob.constrain(&format!("ftot_{}", ename(ei)), tot, Cmp::Eq, 0.0);
            flow_v.push(per_edge);
        }
        // Egress (Eq. 20): per node, exported bytes <= E_max.
        for kk in 0..k {
            let mut c: Vec<(Var, f64)> = Vec::new();
            for (ei, per_edge) in flow_v.iter().enumerate() {
                let (u, _) = input.edges[ei];
                c.push((per_edge[kk].1, input.ops[u].out_mb));
            }
            c.push((e_max, -1.0));
            prob.constrain(&format!("egress_{}", input.nodes[kk].name), c, Cmp::Le, 0.0);
        }
        // Join co-location (flag): tie a join's in-edge consumption
        // together per node, so sibling partials of a group are consumed
        // on the holder's node and their cross-node forwarding shows up
        // in the egress rows above (see "Known join approximation").
        // All in-edges of a join carry equal demand by construction
        // (PipelineSpec::validate), so the equality is always feasible.
        if input.join_colocate {
            for v in 0..n {
                let ine: Vec<usize> =
                    (0..input.edges.len()).filter(|&e| input.edges[e].1 == v).collect();
                if ine.len() <= 1 {
                    continue;
                }
                let e0 = ine[0];
                for &e in &ine[1..] {
                    for kk in 0..k {
                        let (l0, _, m0) = flow_v[e0][kk];
                        let (l1, _, m1) = flow_v[e][kk];
                        prob.constrain(
                            &format!("jco_{}_{}", ename(e), input.nodes[kk].name),
                            vec![(l0, 1.0), (m0, 1.0), (l1, -1.0), (m1, -1.0)],
                            Cmp::Eq,
                            0.0,
                        );
                    }
                }
            }
        }
    }

    Model { prob, t_v, t_min, e_max, j_mig, p_v, x_v, b_v, z_v, flow_v }
}

/// Solve a built model under the cross-round cache protocol, returning
/// the raw solver outcome (the decomposed path re-solves the same model
/// with mutated objectives and decodes columns itself).
pub(crate) fn solve_model(
    input: &MilpInput,
    model: &Model,
    budget: Duration,
    cache: &mut BasisCache,
    opts: &MilpOptions,
) -> (crate::solver::Solution, MilpStats) {
    let prob = &model.prob;
    // Greedy warm start: a feasible plan so branch & bound prunes from the
    // first node and Limit statuses still carry a usable incumbent.  The
    // point is feasibility-only, so it stays valid when the pricing path
    // has swapped the objective.
    let warm = warm_start(
        input,
        prob,
        model.p_v.len(),
        &model.p_v,
        &model.x_v,
        &model.b_v,
        &model.z_v,
        &model.flow_v,
        &model.t_v,
        model.t_min,
        model.e_max,
        model.j_mig,
    );

    let key = shape_key(prob);
    let hit = cache.key == Some(key);
    let mut repaired: Option<BasisSnapshot> = None;
    if !hit {
        if let Some(cached) = &cache.basis {
            // Shape change (topology event): restricted-warm repair by
            // stable variable/row names instead of a cold start.
            repaired = cached.remap_to(&cache.var_names, &cache.row_names, prob);
            if repaired.is_some() {
                cache.restricted_repairs += 1;
            }
        }
    }
    // Same shape ⇒ replay the cached basis by reference (no clone on the
    // steady-state path); changed shape ⇒ use the repair, if any.
    let warm_basis = if hit { cache.basis.as_ref() } else { repaired.as_ref() };
    let (sol, stats, root_basis) =
        crate::solver::solve_milp_opts(prob, budget, warm, warm_basis, opts);
    // Re-cache for the next round (a failed root solve drops the entry
    // so a bad basis is never replayed).  Names only change with the
    // shape, so the steady-state round skips the string clones too.
    if !hit {
        cache.var_names = prob.names.clone();
        cache.row_names = prob.rows.iter().map(|r| r.name.clone()).collect();
    }
    cache.key = Some(key);
    cache.basis = root_basis;
    (sol, stats)
}

/// Extract tenant `t`'s block from a multi-tenant input: its ops and
/// intra-tenant edges on the full cluster, as the classic single-tenant
/// formulation (identical variables, names, and coefficients to solving
/// that tenant alone — the Dantzig–Wolfe pricing subproblem).  Returns
/// the block plus the union-index maps for its ops and edges, used to
/// scatter a chosen column back into the union plan.
pub fn tenant_block(input: &MilpInput, t: usize) -> (MilpInput, Vec<usize>, Vec<usize>) {
    if input.tenants.len() <= 1 {
        assert_eq!(t, 0, "single-tenant input has only block 0");
        let ops = (0..input.ops.len()).collect();
        let edges = (0..input.edges.len()).collect();
        let mut block = input.clone();
        block.tenants = Vec::new();
        block.op_tenant = Vec::new();
        return (block, ops, edges);
    }
    let op_map: Vec<usize> =
        (0..input.ops.len()).filter(|&i| input.tenant_of(i) == t).collect();
    let mut back = vec![usize::MAX; input.ops.len()];
    for (bi, &ui) in op_map.iter().enumerate() {
        back[ui] = bi;
    }
    let mut edges = Vec::new();
    let mut edge_map = Vec::new();
    for (ei, &(u, v)) in input.edges.iter().enumerate() {
        if back[u] != usize::MAX && back[v] != usize::MAX {
            edges.push((back[u], back[v]));
            edge_map.push(ei);
        } else {
            debug_assert!(
                back[u] == usize::MAX && back[v] == usize::MAX,
                "pipeline edges never span tenants"
            );
        }
    }
    let block = MilpInput {
        ops: op_map.iter().map(|&i| input.ops[i].clone()).collect(),
        edges,
        nodes: input.nodes.clone(),
        d_o: input.tenants[t].d_o,
        tenants: Vec::new(),
        op_tenant: Vec::new(),
        t_sched: input.t_sched,
        lambda1: input.lambda1,
        lambda2: input.lambda2,
        b_max: input.b_max,
        placement_aware: input.placement_aware,
        join_colocate: input.join_colocate,
        all_at_once: input.all_at_once,
    };
    (block, op_map, edge_map)
}

/// Dual prices charged to one tenant's pricing subproblem, already
/// sliced out of the master's row duals (see `decomposed.rs` for the row
/// layout).  `y_acc`/`y_eg` are `None` when the master has no such rows.
pub(crate) struct PricingDuals<'a> {
    pub y_maxmin: f64,
    pub y_cpu: &'a [f64],
    pub y_mem: &'a [f64],
    pub y_acc: Option<&'a [f64]>,
    pub y_eg: Option<&'a [f64]>,
}

/// Rewrite a block model's objective to the Dantzig–Wolfe reduced-cost
/// form: the column's master objective contribution minus the dual price
/// of its coupling-row usage, expressed on the block's own variables.
/// The constraint matrix (and therefore the `BasisCache` shape key) is
/// untouched, so per-tenant warm starts survive every pricing round.
///
/// Master contribution: `TENANT_BONUS·T − Σ EPS_NODE·k·x_{i,k}`; the
/// maxmin row carries `−T`, capacity rows carry resource·x, egress rows
/// carry out_mb·e.  The subproblem's own `E_max` is priced at 0: egress
/// is charged through the master duals, not double-counted.
pub(crate) fn set_pricing_objective(model: &mut Model, input: &MilpInput, d: &PricingDuals) {
    let obj = &mut model.prob.obj;
    obj.iter_mut().for_each(|c| *c = 0.0);
    obj[model.t_v[0].0] = TENANT_BONUS + d.y_maxmin;
    for (i, o) in input.ops.iter().enumerate() {
        for (kk, &x) in model.x_v[i].iter().enumerate() {
            let mut c = -EPS_NODE * kk as f64 - d.y_cpu[kk] * o.cpu - d.y_mem[kk] * o.mem_gb;
            if o.accels > 0 {
                if let Some(ya) = d.y_acc {
                    c -= ya[kk] * o.accels as f64;
                }
            }
            obj[x.0] = c;
        }
    }
    if let Some(ye) = d.y_eg {
        for (ei, per_edge) in model.flow_v.iter().enumerate() {
            let (u, _) = input.edges[ei];
            for (kk, &(_, e, _)) in per_edge.iter().enumerate() {
                obj[e.0] = -ye[kk] * input.ops[u].out_mb;
            }
        }
    }
}

/// A block solution projected onto the master's coupling rows: tenant
/// throughput, master-objective contribution, and per-node resource /
/// egress usage.
pub(crate) struct BlockColumn {
    pub t_c: f64,
    pub obj: f64,
    pub cpu: Vec<f64>,
    pub mem: Vec<f64>,
    pub acc: Vec<f64>,
    pub egress: Vec<f64>,
}

pub(crate) fn block_column(
    model: &Model,
    input: &MilpInput,
    sol: &crate::solver::Solution,
) -> BlockColumn {
    let k = input.nodes.len();
    let t_c = sol.value(model.t_v[0]).max(0.0);
    let mut obj = TENANT_BONUS * t_c;
    let mut cpu = vec![0.0; k];
    let mut mem = vec![0.0; k];
    let mut acc = vec![0.0; k];
    for (i, o) in input.ops.iter().enumerate() {
        for (kk, &xv) in model.x_v[i].iter().enumerate() {
            let x = sol.int_value(xv).max(0) as f64;
            if x == 0.0 {
                continue;
            }
            obj -= EPS_NODE * kk as f64 * x;
            cpu[kk] += o.cpu * x;
            mem[kk] += o.mem_gb * x;
            acc[kk] += o.accels as f64 * x;
        }
    }
    let mut egress = vec![0.0; k];
    for (ei, per_edge) in model.flow_v.iter().enumerate() {
        let (u, _) = input.edges[ei];
        for (kk, &(_, e, _)) in per_edge.iter().enumerate() {
            egress[kk] += sol.value(e).max(0.0) * input.ops[u].out_mb;
        }
    }
    BlockColumn { t_c, obj, cpu, mem, acc, egress }
}

fn per_node_cap(o: &OpSched, node: &NodeSpec) -> f64 {
    let mut cap = (node.cpu_cores / o.cpu.max(1e-9)).floor();
    cap = cap.min((node.mem_gb / o.mem_gb.max(1e-9)).floor());
    if o.accels > 0 {
        cap = cap.min((node.accels / o.accels) as f64);
    }
    cap.max(0.0)
}

pub(crate) fn decode(
    input: &MilpInput,
    sol: crate::solver::Solution,
    stats: MilpStats,
    t_v: &[Var],
    p_v: &[Var],
    x_v: &[Vec<Var>],
    b_v: &[Var],
    flow_v: &[Vec<(Var, Var, Var)>],
) -> SchedulePlan {
    let n = input.ops.len();
    let k = input.nodes.len();
    if sol.x.is_empty() {
        // Infeasible/limit without incumbent: keep current deployment.
        return SchedulePlan {
            p: input.ops.iter().map(|o| o.cur_x.iter().sum::<u32>().max(1)).collect(),
            x: input.ops.iter().map(|o| o.cur_x.clone()).collect(),
            b: vec![0; n],
            route: Vec::new(),
            t_pred: 0.0,
            t_tenant: vec![0.0; t_v.len()],
            edge_cons: Vec::new(),
            obj: f64::NEG_INFINITY,
            status: sol.status,
            stats,
        };
    }
    let p = p_v.iter().map(|&v| sol.int_value(v).max(1) as u32).collect();
    let x: Vec<Vec<u32>> = x_v
        .iter()
        .map(|row| row.iter().map(|&v| sol.int_value(v).max(0) as u32).collect())
        .collect();
    let b = b_v.iter().map(|&v| sol.int_value(v).max(0) as u32).collect();
    // Reconstruct the k x k routing fractions from (l, e, m): local flow
    // stays, exports are spread over importers proportionally to m_l.
    let mut route = Vec::new();
    let mut edge_cons = Vec::new();
    for per_edge in flow_v {
        let l: Vec<f64> = per_edge.iter().map(|&(l, _, _)| sol.value(l).max(0.0)).collect();
        let e: Vec<f64> = per_edge.iter().map(|&(_, e, _)| sol.value(e).max(0.0)).collect();
        let m: Vec<f64> = per_edge.iter().map(|&(_, _, m)| sol.value(m).max(0.0)).collect();
        let m_total: f64 = m.iter().sum();
        edge_cons.push((0..k).map(|kk| l[kk] + m[kk]).collect());
        let mut mat = vec![vec![0.0; k]; k];
        for kk in 0..k {
            let prod = l[kk] + e[kk];
            if prod <= 1e-9 {
                mat[kk][kk] = 1.0;
                continue;
            }
            mat[kk][kk] = l[kk] / prod;
            if m_total > 1e-9 {
                for ll in 0..k {
                    if ll != kk {
                        mat[kk][ll] = (e[kk] / prod) * (m[ll] / m_total);
                    }
                }
            }
        }
        route.push(mat);
    }
    let t_tenant: Vec<f64> = t_v.iter().map(|&v| sol.value(v)).collect();
    SchedulePlan {
        p,
        x,
        b,
        route,
        t_pred: t_tenant.iter().sum(),
        t_tenant,
        edge_cons,
        obj: sol.obj,
        status: sol.status,
        stats,
    }
}

/// Greedy feasible plan used as the branch-and-bound incumbent:
/// accelerator-bound ops get every device (spread round-robin), CPU ops get
/// just enough instances to match the resulting bottleneck throughput,
/// packed first-fit; flows route locally first, spillover spread
/// proportionally to importer capacity.
#[allow(clippy::too_many_arguments)]
fn warm_start(
    input: &MilpInput,
    prob: &Problem,
    n: usize,
    p_v: &[Var],
    x_v: &[Vec<Var>],
    b_v: &[Var],
    z_v: &[(Var, usize)],
    flow_v: &[Vec<(Var, Var, Var)>],
    t_v: &[Var],
    t_min: Option<Var>,
    e_max: Var,
    j_mig: Var,
) -> Option<Vec<f64>> {
    let k = input.nodes.len();
    let nt = input.n_tenants();
    let mut cpu_free: Vec<f64> = input.nodes.iter().map(|nd| nd.cpu_cores).collect();
    let mut mem_free: Vec<f64> = input.nodes.iter().map(|nd| nd.mem_gb).collect();
    let mut acc_free: Vec<f64> = input.nodes.iter().map(|nd| nd.accels as f64).collect();
    let mut x = vec![vec![0u32; k]; n];

    // Pass 1: accelerator ops — fill every device, spread evenly among
    // accel ops (they are the scarce resource).
    let accel_ops: Vec<usize> = (0..n).filter(|&i| input.ops[i].accels > 0).collect();
    if !accel_ops.is_empty() {
        let mut turn = 0usize;
        'fill: loop {
            let mut placed_any = false;
            for _ in 0..accel_ops.len() {
                let i = accel_ops[turn % accel_ops.len()];
                turn += 1;
                let o = &input.ops[i];
                // find node with room
                if let Some(kk) = (0..k).find(|&kk| {
                    acc_free[kk] >= o.accels as f64
                        && cpu_free[kk] >= o.cpu
                        && mem_free[kk] >= o.mem_gb
                }) {
                    acc_free[kk] -= o.accels as f64;
                    cpu_free[kk] -= o.cpu;
                    mem_free[kk] -= o.mem_gb;
                    x[i][kk] += 1;
                    placed_any = true;
                }
            }
            if !placed_any {
                break 'fill;
            }
        }
    }
    // Throughput implied by accel allocation, per tenant.
    let mut t_vals = vec![f64::INFINITY; nt];
    for &i in &accel_ops {
        let p: u32 = x[i].iter().sum();
        if p == 0 {
            return None;
        }
        let g = input.d_o_of(i) / input.ops[i].d_i;
        let tv = &mut t_vals[input.tenant_of(i)];
        *tv = tv.min(g * p as f64 * input.ops[i].ut_cur.max(1e-9));
    }
    for tv in &mut t_vals {
        if !tv.is_finite() {
            *tv = 1.0; // all-CPU tenant: aim low, still feasible
        }
    }

    // Pass 2: CPU ops — enough instances for the tenant's t_val, first-fit
    // (prefer nodes where the op already runs, then co-location with
    // neighbours).
    for i in 0..n {
        if input.ops[i].accels > 0 {
            continue;
        }
        let o = &input.ops[i];
        let g = input.d_o_of(i) / o.d_i;
        let mut need = ((t_vals[input.tenant_of(i)] / (g * o.ut_cur.max(1e-9))).ceil() as u32).max(1);
        // 10% headroom so the CPU stage is not the binding constraint.
        need = need + (need / 8) + 1;
        let mut placed = 0u32;
        while placed < need {
            // Prefer nodes where the op already runs (the warm start then
            // realizes the migration-penalty preference for the status
            // quo), then the emptiest node.
            let kk_opt = (0..k)
                .filter(|&kk| cpu_free[kk] >= o.cpu && mem_free[kk] >= o.mem_gb)
                .max_by(|&a, &b| {
                    let pa = (input.ops[i].cur_x.get(a).copied().unwrap_or(0) > x[i][a]) as u32;
                    let pb = (input.ops[i].cur_x.get(b).copied().unwrap_or(0) > x[i][b]) as u32;
                    pa.cmp(&pb).then(cpu_free[a].partial_cmp(&cpu_free[b]).unwrap())
                });
            let Some(kk) = kk_opt else { break };
            cpu_free[kk] -= o.cpu;
            mem_free[kk] -= o.mem_gb;
            x[i][kk] += 1;
            placed += 1;
        }
        if placed == 0 {
            return None; // cannot place even one instance
        }
        if placed < need {
            // CPU-bound: lower the tenant's throughput target accordingly.
            let tv = &mut t_vals[input.tenant_of(i)];
            *tv = tv.min(g * placed as f64 * o.ut_cur.max(1e-9));
        }
    }
    // Re-check every op supports its tenant's t_val.
    for i in 0..n {
        let g = input.d_o_of(i) / input.ops[i].d_i;
        let p: u32 = x[i].iter().sum();
        let tv = &mut t_vals[input.tenant_of(i)];
        *tv = tv.min(g * p as f64 * input.ops[i].ut_cur.max(1e-9));
    }
    for tv in &mut t_vals {
        *tv = tv.max(0.0);
    }

    // Profitable rolling transitions: take b_i = min(n_old, B_max) whenever
    // the cold-start-discounted candidate rate beats the current one
    // (Eq. 11 test), then recompute the throughput with the mixed rates of
    // Eq. 13.  This puts transitions into the incumbent even when the
    // branch-and-bound budget expires at the root.
    let mut b_pick = vec![0u32; n];
    let mut t_mixed = vec![f64::INFINITY; nt];
    for i in 0..n {
        let o = &input.ops[i];
        let p: u32 = x[i].iter().sum();
        let g = input.d_o_of(i) / o.d_i;
        let ut_cand = o.ut_cand.unwrap_or(0.0);
        let ut_hat = ut_cand * (1.0 - o.h_cold / input.t_sched).max(0.0);
        if o.ut_cand.is_some() && o.n_old > 0 && ut_hat > o.ut_cur {
            let limit = if input.all_at_once { o.n_old } else { o.n_old.min(input.b_max) };
            b_pick[i] = limit.min(p.saturating_sub(o.n_new));
        }
        let stay = p.saturating_sub(o.n_new + b_pick[i]) as f64;
        let cap = g
            * (stay * o.ut_cur
                + o.n_new as f64 * ut_cand
                + b_pick[i] as f64 * ut_hat.max(0.0));
        let tm = &mut t_mixed[input.tenant_of(i)];
        *tm = tm.min(cap.max(0.0));
    }
    for t in 0..nt {
        if t_mixed[t].is_finite() {
            // b is only taken when it raises the op's capacity, so the
            // mixed throughput dominates the plain one.
            t_vals[t] = t_mixed[t].max(0.0);
        }
    }

    // Assemble the full variable vector.
    let mut sol = vec![0.0; prob.n_vars()];
    for (t, &tv) in t_v.iter().enumerate() {
        sol[tv.0] = t_vals[t];
    }
    if let Some(z) = t_min {
        let zval = (0..nt)
            .map(|t| t_vals[t] / input.tenants[t].weight)
            .fold(f64::INFINITY, f64::min);
        sol[z.0] = zval.max(0.0);
    }
    for i in 0..n {
        let p: u32 = x[i].iter().sum();
        sol[p_v[i].0] = p as f64;
        sol[b_v[i].0] = b_pick[i] as f64;
        for kk in 0..k {
            sol[x_v[i][kk].0] = x[i][kk] as f64;
        }
    }
    // all-at-once auxiliary binaries (z_<op>): b is 0 or n_old by
    // construction; (var, op) pairs were recorded at creation, so no
    // name scan.
    for &(zv, i) in z_v {
        sol[zv.0] = if b_pick[i] > 0 { 1.0 } else { 0.0 };
    }
    sol[j_mig.0] = 0.0;

    // Flows: local first, spillover spread by importer capacity.
    let mut e_val: f64 = 0.0;
    let mut egress_mb = vec![0.0; k];
    for (ei, per_edge) in flow_v.iter().enumerate() {
        let (u, v) = input.edges[ei];
        let d_next = input.ops[v].d_i;
        let fan = d_next / input.ops[u].d_i;
        let rate_of = |o: &OpSched| o.ut_cur.max(o.ut_cand.unwrap_or(0.0)).max(1e-6);
        let src_rate = rate_of(&input.ops[u]) * fan;
        let dst_rate = rate_of(&input.ops[v]);
        let demand = t_vals[input.tenant_of(v)] * d_next / input.d_o_of(v);
        let scap: Vec<f64> = (0..k).map(|kk| x[u][kk] as f64 * src_rate).collect();
        let dcap: Vec<f64> = (0..k).map(|kk| x[v][kk] as f64 * dst_rate).collect();
        let s_tot: f64 = scap.iter().sum();
        let d_tot: f64 = dcap.iter().sum();
        if demand > s_tot + 1e-9 || demand > d_tot + 1e-9 {
            return None; // shouldn't happen: t_val respects capacities
        }
        // production/consumption proportional to capacity, local first
        for kk in 0..k {
            let prod = if s_tot > 0.0 { demand * scap[kk] / s_tot } else { 0.0 };
            let cons = if d_tot > 0.0 { demand * dcap[kk] / d_tot } else { 0.0 };
            let l = prod.min(cons);
            let e = prod - l;
            let m = cons - l;
            let (lv, ev, mv) = per_edge[kk];
            sol[lv.0] = l;
            sol[ev.0] = e;
            sol[mv.0] = m;
            egress_mb[kk] += e * input.ops[u].out_mb;
        }
    }
    for kk in 0..k {
        e_val = e_val.max(egress_mb[kk]);
    }
    sol[e_max.0] = e_val;
    Some(sol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;

    fn nodes(k: usize) -> Vec<NodeSpec> {
        ClusterSpec::homogeneous(k, 64.0, 256.0, 4, 65536.0, 1250.0).nodes
    }

    fn op(name: &str, ut: f64, cpu: f64, accels: u32, d_i: f64, out_mb: f64, k: usize) -> OpSched {
        OpSched {
            name: name.into(),
            ut_cur: ut,
            ut_cand: None,
            n_new: 0,
            n_old: 0,
            cpu,
            mem_gb: 2.0,
            accels,
            out_mb,
            d_i,
            h_start: 2.0,
            h_stop: 1.0,
            h_cold: 20.0,
            cur_x: vec![0; k],
        }
    }

    fn chain_edges(n: usize) -> Vec<(usize, usize)> {
        (1..n).map(|i| (i - 1, i)).collect()
    }

    fn base_input(k: usize) -> MilpInput {
        MilpInput {
            ops: vec![
                op("cpu_a", 10.0, 2.0, 0, 1.0, 0.5, k),
                op("llm", 2.0, 8.0, 1, 1.0, 0.1, k),
                op("cpu_b", 20.0, 1.0, 0, 1.0, 0.1, k),
            ],
            edges: chain_edges(3),
            nodes: nodes(k),
            d_o: 1.0,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        }
    }

    fn solve10(i: &MilpInput) -> SchedulePlan {
        solve(i, Duration::from_secs(10))
    }

    #[test]
    fn bottleneck_gets_the_accelerators() {
        let input = base_input(2);
        let plan = solve10(&input);
        assert!(matches!(plan.status, Status::Optimal | Status::Limit));
        // 8 NPUs total -> p_llm = 8, T = 16
        assert_eq!(plan.p[1], 8, "all accelerators used: {:?}", plan.p);
        assert!((plan.t_pred - 16.0).abs() < 0.5, "T {}", plan.t_pred);
        // supporting ops sized to match
        assert!(plan.p[0] as f64 * 10.0 >= plan.t_pred - 0.5);
        assert!(plan.p[2] as f64 * 20.0 >= plan.t_pred - 0.5);
    }

    #[test]
    fn respects_node_capacity() {
        let input = base_input(2);
        let plan = solve10(&input);
        for kk in 0..2 {
            let acc: u32 = (0..3).map(|i| plan.x[i][kk] * input.ops[i].accels).sum();
            assert!(acc <= 4);
            let cpu: f64 = (0..3).map(|i| plan.x[i][kk] as f64 * input.ops[i].cpu).sum();
            assert!(cpu <= 64.0 + 1e-6);
        }
    }

    #[test]
    fn amplification_scales_requirements() {
        // Middle op sees 10x the records: needs 10x more capacity.
        let mut input = base_input(2);
        input.ops[1].d_i = 10.0;
        input.ops[1].accels = 0;
        input.ops[1].cpu = 1.0;
        input.ops[1].ut_cur = 10.0;
        input.ops[2].d_i = 10.0;
        input.ops[2].ut_cur = 100.0;
        let plan = solve10(&input);
        // T limited by op1: T <= (1/10) * p1 * 10 = p1 -> wants p1 large
        assert!(plan.p[1] > plan.p[0], "amplified op needs more instances: {:?}", plan.p);
    }

    #[test]
    fn rolling_update_when_candidate_much_better() {
        let mut input = base_input(2);
        input.ops[1].ut_cand = Some(4.0); // 2x the current rate
        input.ops[1].n_old = 8;
        input.ops[1].cur_x = vec![4, 4];
        input.ops[1].h_cold = 5.0; // cheap restart vs 30s window
        let plan = solve10(&input);
        assert!(plan.b[1] > 0, "profitable transition must start: {:?}", plan.b);
        assert!(plan.b[1] <= 2, "bounded by B_max");
    }

    #[test]
    fn transition_deferred_when_cold_start_dominates() {
        let mut input = base_input(2);
        input.ops[1].ut_cand = Some(2.1); // marginal gain
        input.ops[1].n_old = 8;
        input.ops[1].cur_x = vec![4, 4];
        input.ops[1].h_cold = 29.0; // eats ~97% of the window
        let plan = solve10(&input);
        assert_eq!(plan.b[1], 0, "marginal + expensive transition deferred");
    }

    #[test]
    fn rolling_continues_mixed_state() {
        // Mid-transition: n_new already faster; T must use the mix.
        let mut input = base_input(2);
        input.ops[1].ut_cand = Some(4.0);
        input.ops[1].n_new = 2;
        input.ops[1].n_old = 6;
        input.ops[1].h_cold = 5.0;
        input.ops[1].cur_x = vec![4, 4];
        let plan = solve10(&input);
        assert!(plan.p[1] >= 2, "p >= n_new (no rollback)");
        assert!(plan.b[1] >= 1, "continues the rollout");
    }

    #[test]
    fn colocation_reduces_egress() {
        // Two chained CPU ops with heavy intermediate data must co-locate.
        let k = 2;
        let mut input = MilpInput {
            ops: vec![
                op("producer", 10.0, 4.0, 0, 1.0, 50.0, k), // 50 MB/record!
                op("consumer", 10.0, 4.0, 0, 1.0, 0.1, k),
            ],
            edges: chain_edges(2),
            nodes: nodes(k),
            d_o: 1.0,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        };
        input.ops[0].d_i = 1.0;
        input.ops[1].d_i = 1.0;
        let plan = solve10(&input);
        // With symmetric capacity the solver can route all flow locally:
        // route matrices should be (near-)diagonal.
        for m in &plan.route {
            for kk in 0..k {
                assert!(
                    m[kk][kk] > 0.95,
                    "local routing expected, got {:?}",
                    plan.route
                );
            }
        }
    }

    #[test]
    fn migration_penalty_prefers_status_quo() {
        // Two equivalent placements; current deployment must win ties.
        let mut input = base_input(2);
        input.ops[0].cur_x = vec![2, 0];
        input.ops[1].cur_x = vec![4, 4];
        input.ops[2].cur_x = vec![1, 0];
        let plan = solve10(&input);
        // LLM placement is forced (4+4); CPU ops should stay put if able.
        assert!(
            plan.x[0][0] >= plan.x[0][1],
            "prefer existing node for op0: {:?}",
            plan.x
        );
    }

    #[test]
    fn all_at_once_switches_everything_or_nothing() {
        let mut input = base_input(2);
        input.all_at_once = true;
        input.ops[1].ut_cand = Some(4.0);
        input.ops[1].n_old = 8;
        input.ops[1].cur_x = vec![4, 4];
        input.ops[1].h_cold = 5.0;
        let plan = solve10(&input);
        assert!(plan.b[1] == 0 || plan.b[1] == 8, "all-at-once: {:?}", plan.b);
    }

    #[test]
    fn sixteen_node_instance_solves_within_budget() {
        let k = 16;
        let mut ops = Vec::new();
        for i in 0..9 {
            let accel = i == 2 || i == 5 || i == 7;
            let mut o = op(
                &format!("op{i}"),
                if accel { 2.0 } else { 15.0 },
                if accel { 8.0 } else { 2.0 },
                accel as u32,
                [1.0, 1.0, 6.0, 6.0, 4.2, 4.2, 3.6, 3.6, 3.6][i],
                1.0,
                k,
            );
            o.cur_x = vec![0; k];
            ops.push(o);
        }
        let input = MilpInput {
            ops,
            edges: chain_edges(9),
            nodes: nodes(k),
            d_o: 3.6,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        };
        let start = std::time::Instant::now();
        let plan = solve(&input, Duration::from_secs(20));
        let wall = start.elapsed();
        assert!(plan.t_pred > 0.0, "{:?}", plan.status);
        assert!(wall < Duration::from_secs(21));
        // feasibility of the decoded integer plan
        for kk in 0..k {
            let acc: u32 = (0..9).map(|i| plan.x[i][kk] * input.ops[i].accels).sum();
            assert!(acc <= 4);
        }
    }

    #[test]
    fn dag_flow_covers_every_edge() {
        // Diamond: 0 -> {1 (accel), 2 (accel)} -> 3; both branches carry
        // the full replicated volume, so the accel branch capacity binds T.
        let k = 2;
        let mut ops = vec![
            op("decode", 10.0, 2.0, 0, 1.0, 1.0, k),
            op("asr", 2.0, 8.0, 1, 1.0, 0.1, k),
            op("caption", 2.0, 8.0, 1, 1.0, 0.1, k),
            op("join", 40.0, 1.0, 0, 1.0, 0.1, k),
        ];
        for o in &mut ops {
            o.cur_x = vec![0; k];
        }
        let input = MilpInput {
            ops,
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
            nodes: nodes(k),
            d_o: 1.0,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        };
        let plan = solve(&input, Duration::from_secs(10));
        assert!(matches!(plan.status, Status::Optimal | Status::Limit));
        assert_eq!(plan.route.len(), 4, "one routing matrix per DAG edge");
        // 8 devices split across the two accel branches: 4 + 4, T = 8.
        assert_eq!(plan.p[1] + plan.p[2], 8, "both branches saturate the devices: {:?}", plan.p);
        assert!((plan.t_pred - 8.0).abs() < 0.6, "T {}", plan.t_pred);
        // Each branch must sustain the full replicated volume.
        assert!(plan.p[1] as f64 * 2.0 >= plan.t_pred - 0.5);
        assert!(plan.p[2] as f64 * 2.0 >= plan.t_pred - 0.5);
        // Routing rows are normalized distributions.
        for m in &plan.route {
            for row in m {
                let s: f64 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-6, "row sums to {s}");
            }
        }
    }

    /// Two tenants, one accelerator op each, contending for 8 shared
    /// devices: the weighted max-min objective must give the weight-3
    /// tenant ~3x the weight-1 tenant's throughput (device split ~6/2),
    /// and the shared node-capacity rows must hold over the union.
    #[test]
    fn weighted_max_min_splits_shared_devices() {
        let k = 2;
        let mut ops = vec![
            op("a:llm", 2.0, 8.0, 1, 1.0, 0.1, k),
            op("b:llm", 2.0, 8.0, 1, 1.0, 0.1, k),
        ];
        for o in &mut ops {
            o.cur_x = vec![0; k];
        }
        let input = MilpInput {
            ops,
            edges: vec![], // two single-op tenants: no dataflow edges
            nodes: nodes(k),
            d_o: 1.0,
            tenants: vec![
                MilpTenant { name: "a".into(), weight: 1.0, d_o: 1.0 },
                MilpTenant { name: "b".into(), weight: 3.0, d_o: 1.0 },
            ],
            op_tenant: vec![0, 1],
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        };
        let plan = solve(&input, Duration::from_secs(10));
        assert!(matches!(plan.status, Status::Optimal | Status::Limit));
        assert_eq!(plan.t_tenant.len(), 2);
        assert!(plan.t_tenant.iter().all(|&t| t > 0.0), "{:?}", plan.t_tenant);
        // Shared accelerator capacity over the union of tenants' ops.
        for kk in 0..k {
            let acc: u32 = (0..2).map(|i| plan.x[i][kk] * input.ops[i].accels).sum();
            assert!(acc <= 4, "node {kk} over-packed: {:?}", plan.x);
        }
        // Aggregate prediction is the per-tenant sum.
        assert!((plan.t_pred - (plan.t_tenant[0] + plan.t_tenant[1])).abs() < 1e-9);
        // The optimality-dependent properties hold whenever the tiny
        // instance is solved to optimality (the overwhelmingly common
        // case in 10 s; a Limit incumbent on a heavily loaded host is
        // feasible but may not have exploited every device yet).
        if plan.status == Status::Optimal {
            let ratio = plan.t_tenant[1] / plan.t_tenant[0];
            assert!(
                (2.0..=4.0).contains(&ratio),
                "weight-3 tenant gets ~3x: T={:?} p={:?}",
                plan.t_tenant,
                plan.p
            );
            assert_eq!(plan.p[0] + plan.p[1], 8, "all shared devices used: {:?}", plan.p);
        }
    }

    /// The co-located-join-inflow flag ties a join's per-node in-edge
    /// consumption together, so on a link-bound diamond the egress budget
    /// sees the sibling-partial forwarding and t_pred can only tighten.
    fn link_bound_diamond(join_colocate: bool) -> SchedulePlan {
        let k = 2;
        // Tiny egress links + heavy branch records: the link binds the plan.
        let mut nds = nodes(k);
        for nd in &mut nds {
            nd.egress_mbps = 20.0;
        }
        let mut ops = vec![
            op("decode", 20.0, 2.0, 0, 1.0, 2.0, k),
            op("asr", 2.0, 8.0, 1, 1.0, 10.0, k), // 10 MB partials
            op("caption", 2.0, 8.0, 1, 1.0, 10.0, k),
            op("join", 40.0, 1.0, 0, 1.0, 0.1, k),
        ];
        for o in &mut ops {
            o.cur_x = vec![0; k];
        }
        let input = MilpInput {
            ops,
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
            nodes: nds,
            d_o: 1.0,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            join_colocate,
            all_at_once: false,
        };
        solve(&input, Duration::from_secs(10))
    }

    /// Cross-round warm start: a second solve of the same-shape problem
    /// with drifted coefficients must take the cached-basis path and
    /// reach the same plan a cold solve does.
    #[test]
    fn cross_round_cache_warm_starts_and_preserves_plan() {
        let input = base_input(2);
        let mut cache = BasisCache::new();
        let p1 = solve_cached(&input, Duration::from_secs(10), &mut cache);
        assert!(p1.t_pred > 0.0);
        // Drift the rates the way a new metrics window would.
        let mut input2 = input.clone();
        for o in &mut input2.ops {
            o.ut_cur *= 1.03;
        }
        let p2 = solve_cached(&input2, Duration::from_secs(10), &mut cache);
        assert!(
            p2.stats.root_warm,
            "round 2 must warm start from the cached basis: {:?}",
            p2.stats
        );
        // Objective-level equality is the warm-start contract (exact
        // plan equality can differ across exploration orders on
        // degenerate optima within the B&B pruning gap).
        let cold = solve(&input2, Duration::from_secs(10));
        if p2.status == Status::Optimal && cold.status == Status::Optimal {
            assert!(
                (p2.t_pred - cold.t_pred).abs() <= 1e-3 * (1.0 + cold.t_pred.abs()),
                "warm {} vs cold {}",
                p2.t_pred,
                cold.t_pred
            );
        }
    }

    /// Shape change ⇒ repair, not replay: a different topology must not
    /// reuse the cached basis verbatim — it goes through the name-based
    /// restricted-warm repair (and never panics or replays stale
    /// indices).  Results must match a cold solve either way.
    #[test]
    fn cache_repairs_on_shape_change() {
        let mut cache = BasisCache::new();
        let p1 = solve_cached(&base_input(2), Duration::from_secs(10), &mut cache);
        assert!(p1.t_pred > 0.0);
        // 3 nodes instead of 2: different variables and rows.
        let mut input2 = base_input(3);
        input2.ops[0].ut_cur *= 1.01;
        let p2 = solve_cached(&input2, Duration::from_secs(10), &mut cache);
        assert!(p2.t_pred > 0.0, "{:?}", p2.status);
        assert_eq!(cache.restricted_repairs, 1, "shape change takes the repair path");
        let cold = solve(&input2, Duration::from_secs(10));
        if p2.status == Status::Optimal && cold.status == Status::Optimal {
            assert!(
                (p2.t_pred - cold.t_pred).abs() <= 1e-3 * (1.0 + cold.t_pred.abs()),
                "repaired {} vs cold {}",
                p2.t_pred,
                cold.t_pred
            );
        }
    }

    /// The headline restricted-warm case: a node FAILS between rounds, so
    /// round 2's MILP covers one node fewer.  The cached basis is
    /// repaired by pricing out the dead node's columns (stable names
    /// align the survivors) and the plan must match a cold solve of the
    /// restricted problem.
    #[test]
    fn cache_restricted_warm_survives_node_removal() {
        let mut cache = BasisCache::new();
        let p1 = solve_cached(&base_input(3), Duration::from_secs(10), &mut cache);
        assert!(p1.t_pred > 0.0);
        // Node 1 fails: the surviving problem keeps nodes {0, 2} with
        // their original names, and drifted rates.
        let mut input2 = base_input(3);
        input2.nodes.remove(1);
        for o in &mut input2.ops {
            o.cur_x = vec![0; 2];
            o.ut_cur *= 1.02;
        }
        let p2 = solve_cached(&input2, Duration::from_secs(10), &mut cache);
        assert!(p2.t_pred > 0.0, "{:?}", p2.status);
        assert_eq!(cache.restricted_repairs, 1, "node removal takes the repair path");
        assert_eq!(p2.x[0].len(), 2, "plan covers the surviving node set");
        let cold = solve(&input2, Duration::from_secs(10));
        if p2.status == Status::Optimal && cold.status == Status::Optimal {
            assert!(
                (p2.t_pred - cold.t_pred).abs() <= 1e-3 * (1.0 + cold.t_pred.abs()),
                "restricted-warm {} vs cold {}",
                p2.t_pred,
                cold.t_pred
            );
        }
        // Round 3: same (restricted) shape again — the plain cached-basis
        // path resumes.
        let mut input3 = input2.clone();
        for o in &mut input3.ops {
            o.ut_cur *= 1.01;
        }
        let p3 = solve_cached(&input3, Duration::from_secs(10), &mut cache);
        assert!(p3.t_pred > 0.0);
        assert!(p3.stats.root_warm, "same-shape round must warm start: {:?}", p3.stats);
        assert_eq!(cache.restricted_repairs, 1, "no further repair needed");
    }

    #[test]
    fn join_colocation_ties_sibling_inflows() {
        let plan = link_bound_diamond(true);
        assert!(matches!(plan.status, Status::Optimal | Status::Limit));
        assert!(plan.t_pred > 0.0);
        // Edges 2 and 3 enter the join: per-node consumption must match.
        let (a, b) = (&plan.edge_cons[2], &plan.edge_cons[3]);
        for kk in 0..a.len() {
            assert!(
                (a[kk] - b[kk]).abs() < 1e-6 * (1.0 + a[kk].abs()),
                "sibling in-edges consumed on different nodes: {a:?} vs {b:?}"
            );
        }
        // The constraint only tightens the relaxation: t_pred must not
        // exceed the unconstrained plan's.  Comparable only when both
        // solves reached a true optimum (a Limit incumbent on a loaded
        // host can undershoot on either side).
        let plain = link_bound_diamond(false);
        if plan.status == Status::Optimal && plain.status == Status::Optimal {
            assert!(
                plan.t_pred <= plain.t_pred + 1e-6,
                "co-location must not loosen the bound: {} vs {}",
                plan.t_pred,
                plain.t_pred
            );
        }
    }

    /// The same co-location flag on the real speech DAG (the workload the
    /// ROADMAP item names): sibling in-edge consumption ties per node on a
    /// link-bound instance.
    #[test]
    fn join_colocation_on_speech_dag() {
        let pl = crate::workload::speech::pipeline();
        let k = 2;
        let mut nds = nodes(k);
        for nd in &mut nds {
            nd.egress_mbps = 30.0;
        }
        let (d_i, d_o) = pl.amplification();
        let ops: Vec<OpSched> = pl
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let mut s = op(&o.name, if o.accels > 0 { 2.0 } else { 20.0 }, o.cpu, o.accels, d_i[i], 5.0, k);
                s.mem_gb = o.mem_gb;
                s
            })
            .collect();
        let input = MilpInput {
            ops,
            edges: pl.edges.clone(),
            nodes: nds,
            d_o,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            join_colocate: true,
            all_at_once: false,
        };
        let plan = solve(&input, Duration::from_secs(10));
        assert!(plan.t_pred > 0.0, "{:?}", plan.status);
        // speech edges: 3 = asr->align, 4 = caption->align (the join).
        let (a, b) = (&plan.edge_cons[3], &plan.edge_cons[4]);
        for kk in 0..k {
            assert!(
                (a[kk] - b[kk]).abs() < 1e-6 * (1.0 + a[kk].abs()),
                "speech join in-edges must co-locate: {a:?} vs {b:?}"
            );
        }
    }
}
