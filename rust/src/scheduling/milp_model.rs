//! The Trident scheduling MILP (paper §6, Eqs. 10–26): joint parallelism,
//! placement, flow routing, and rolling configuration transitions under
//! heterogeneous per-node CPU / memory / accelerator capacity and network
//! egress, with migration-cost regularization.
//!
//! **Formulation note (documented deviation).**  The paper's flow
//! constraints (Eqs. 18–19) put `w` in "instance units" on *both* sides of
//! an edge, which forces `p_i = p_{i+1}` when read literally.  We model the
//! same co-location objective with *rate-based* flow variables:
//! per pipeline edge `(u, v)` and node k we track `l_{e,k}` (rate produced
//! AND consumed on k), `e_{e,k}` (exported) and `m_{e,k}` (imported), with
//! (i) total flow pinned to the throughput the edge must carry
//! (`T · D_v / D_o`), (ii) per-node source/destination capacity bounds
//! linear in `x`, and (iii) the egress expression (Eq. 20) minimized
//! through `E_max`.  This is linear, O(|E|k) instead of O(|E|k²), and
//! strictly more faithful to what the executor routes (rates, not
//! instance-units).
//!
//! **DAG topology.**  Flow conservation runs over the pipeline's explicit
//! edge list, not over chain positions: a fork's outgoing edges each carry
//! the full replicated volume `D_u · fanout_u`, and a join consumes one
//! merged record per aligned group, so each of its incoming edges carries
//! `D_v` — which is exactly `d_i[v]` from `PipelineSpec::amplification`,
//! making the per-edge demand `T · D_v / D_o` uniform across topologies.
//! A chain is the path-shaped special case and builds the identical
//! problem (same variables, names, and coefficients) as the pre-DAG
//! formulation.
//!
//! **Known join approximation.**  The relaxation treats a join's incoming
//! edges independently, so a plan may land sibling partials of one group
//! on different nodes; the executor then forwards the late partial to the
//! group's holding instance over the egress link — traffic the `E_max`
//! budget never saw.  The gap is second-order (holder affinity follows
//! the same routing fractions, so most groups co-locate), but on
//! link-bound plans realized throughput can fall below `t_pred`; a
//! co-located-join-inflow constraint (tie the per-node consumption shares
//! of a join's in-edges together) is the known fix if it ever dominates.

use std::time::Duration;

use crate::config::NodeSpec;
use crate::solver::{Cmp, MilpStats, Problem, Status, Var};

/// Per-operator scheduler inputs for one round.
#[derive(Debug, Clone)]
pub struct OpSched {
    pub name: String,
    /// Current-config per-instance rate UT_i^cur (records/s).
    pub ut_cur: f64,
    /// Candidate-config rate UT_i^cand (None when s_i != Tuned).
    pub ut_cand: Option<f64>,
    /// Rolling state: instances already on the candidate config.
    pub n_new: u32,
    /// Instances still on the current config.
    pub n_old: u32,
    /// Resources per instance.
    pub cpu: f64,
    pub mem_gb: f64,
    pub accels: u32,
    /// Output record size, MB.
    pub out_mb: f64,
    /// Amplification D_i (input volume relative to pipeline input).
    pub d_i: f64,
    /// Lifecycle costs, seconds.
    pub h_start: f64,
    pub h_stop: f64,
    pub h_cold: f64,
    /// Current placement x̄_{i,k}.
    pub cur_x: Vec<u32>,
}

/// Scheduler MILP inputs.
#[derive(Debug, Clone)]
pub struct MilpInput {
    pub ops: Vec<OpSched>,
    /// Pipeline dataflow edges `(from_op, to_op)`; flow/egress variables
    /// are created per edge (`PipelineSpec::edges` order).
    pub edges: Vec<(usize, usize)>,
    pub nodes: Vec<NodeSpec>,
    pub d_o: f64,
    /// Scheduling window T_sched (cold-start discount, Eq. 11).
    pub t_sched: f64,
    pub lambda1: f64,
    pub lambda2: f64,
    /// Rolling batch cap B_max.
    pub b_max: u32,
    /// Disable network/egress modelling (w/o-placement ablation).
    pub placement_aware: bool,
    /// Force all-at-once transitions (w/o-rolling ablation): b_i is fixed
    /// to n_old whenever a candidate exists.
    pub all_at_once: bool,
}

/// Solved plan, decoded back into scheduler terms.
#[derive(Debug, Clone)]
pub struct SchedulePlan {
    /// Parallelism p_i.
    pub p: Vec<u32>,
    /// Placement x_{i,k}.
    pub x: Vec<Vec<u32>>,
    /// Rolling batch b_i (instances to switch this round).
    pub b: Vec<u32>,
    /// Flow fractions per pipeline edge: route[e][k][l] (row-normalized,
    /// indexed by `MilpInput::edges` order).
    pub route: Vec<Vec<Vec<f64>>>,
    /// Predicted pipeline throughput (input records/s).
    pub t_pred: f64,
    pub status: Status,
    pub stats: MilpStats,
}

/// Build + solve the round's MILP.
pub fn solve(input: &MilpInput, budget: Duration) -> SchedulePlan {
    let n = input.ops.len();
    let k = input.nodes.len();
    let mut prob = Problem::new();

    // Conservative per-op instance cap from total cluster resources.
    let cap_i: Vec<f64> = input
        .ops
        .iter()
        .map(|o| {
            let by_cpu: f64 = input.nodes.iter().map(|nd| (nd.cpu_cores / o.cpu.max(1e-9)).floor()).sum();
            let by_acc: f64 = if o.accels > 0 {
                input.nodes.iter().map(|nd| (nd.accels / o.accels) as f64).sum()
            } else {
                f64::INFINITY
            };
            by_cpu.min(by_acc).max(1.0)
        })
        .collect();

    // T and E_max, J_mig.
    let t_ub: f64 = input
        .ops
        .iter()
        .zip(&cap_i)
        .map(|(o, c)| input.d_o / o.d_i * c * o.ut_cur.max(o.ut_cand.unwrap_or(0.0)).max(1e-6))
        .fold(f64::INFINITY, f64::min);
    let t = prob.cont("T", 0.0, t_ub.max(1.0) * 2.0, 1.0);
    let e_max = prob.cont("E_max", 0.0, f64::INFINITY, -input.lambda1);
    let j_mig = prob.cont("J_mig", 0.0, f64::INFINITY, -input.lambda2);

    // Symmetry breaking: infinitesimal preference for low-index nodes.
    let eps_node = 1e-9;

    // p_i, x_{i,k}, b_i
    let mut p_v = Vec::with_capacity(n);
    let mut x_v = vec![Vec::with_capacity(k); n];
    let mut b_v = Vec::with_capacity(n);
    for (i, o) in input.ops.iter().enumerate() {
        let p = prob.int(&format!("p_{i}"), (o.n_new.max(1)) as f64, cap_i[i], 0.0);
        p_v.push(p);
        for kk in 0..k {
            let xmax = per_node_cap(o, &input.nodes[kk]);
            let x = prob.int(
                &format!("x_{i}_{kk}"),
                0.0,
                xmax,
                -eps_node * kk as f64,
            );
            x_v[i].push(x);
        }
        let has_cand = o.ut_cand.is_some() && o.n_old > 0;
        let b_hi = if has_cand {
            if input.all_at_once {
                o.n_old as f64 // forced below to equal n_old
            } else {
                o.n_old.min(input.b_max) as f64
            }
        } else {
            0.0
        };
        let b = prob.int(&format!("b_{i}"), 0.0, b_hi, 0.0);
        if has_cand && input.all_at_once {
            // all-at-once ablation: switch everything or nothing; model as
            // b == n_old when the transition is profitable is nonlinear, so
            // we let the MILP choose via a binary-scaled variable: b in
            // {0, n_old} via auxiliary binary.
            let z = prob.int(&format!("z_{i}"), 0.0, 1.0, 0.0);
            prob.constrain(
                &format!("allatonce_{i}"),
                vec![(b, 1.0), (z, -(o.n_old as f64))],
                Cmp::Eq,
                0.0,
            );
        }
        b_v.push(b);
    }

    // Throughput constraints (Eq. 13), with the cold-start-discounted rate
    // \hat{UT}_i (Eq. 11) precomputed.
    for (i, o) in input.ops.iter().enumerate() {
        let ut_cand = o.ut_cand.unwrap_or(0.0);
        let ut_hat = ut_cand * (1.0 - o.h_cold / input.t_sched).max(0.0);
        let g = input.d_o / o.d_i; // converts per-op rate to pipeline rate
        // T <= g*[ (p - n_new - b) UTcur + n_new UTcand + b UThat ]
        //    = g*UTcur*p + g*(UThat - UTcur)*b + g*n_new*(UTcand - UTcur)
        let rhs = g * o.n_new as f64 * (ut_cand - o.ut_cur);
        prob.constrain(
            &format!("thr_{i}"),
            vec![
                (t, 1.0),
                (p_v[i], -g * o.ut_cur),
                (b_v[i], -g * (ut_hat - o.ut_cur)),
            ],
            Cmp::Le,
            rhs,
        );
        // p_stay >= 0 (Eq. 26): p - b >= n_new
        prob.constrain(
            &format!("stay_{i}"),
            vec![(p_v[i], 1.0), (b_v[i], -1.0)],
            Cmp::Ge,
            o.n_new as f64,
        );
    }

    // Placement consistency (Eq. 14).
    for i in 0..n {
        let mut c: Vec<(Var, f64)> = x_v[i].iter().map(|&x| (x, 1.0)).collect();
        c.push((p_v[i], -1.0));
        prob.constrain(&format!("place_{i}"), c, Cmp::Eq, 0.0);
    }

    // Node resource capacity (Eqs. 15–17).
    for (kk, node) in input.nodes.iter().enumerate() {
        let cpu: Vec<(Var, f64)> = (0..n).map(|i| (x_v[i][kk], input.ops[i].cpu)).collect();
        prob.constrain(&format!("cpu_{kk}"), cpu, Cmp::Le, node.cpu_cores);
        let mem: Vec<(Var, f64)> = (0..n).map(|i| (x_v[i][kk], input.ops[i].mem_gb)).collect();
        prob.constrain(&format!("mem_{kk}"), mem, Cmp::Le, node.mem_gb);
        let acc: Vec<(Var, f64)> = (0..n)
            .filter(|&i| input.ops[i].accels > 0)
            .map(|i| (x_v[i][kk], input.ops[i].accels as f64))
            .collect();
        if !acc.is_empty() {
            prob.constrain(&format!("acc_{kk}"), acc, Cmp::Le, node.accels as f64);
        }
    }

    // Migration accounting (Eqs. 21–22).  **Deviation:** the explicit
    // δ+/δ− variables double the tableau for a 1e-6-weight tiebreaker, so
    // the deployment-stability preference is enforced structurally instead:
    // the warm-start incumbent reuses the current placement wherever
    // feasible, and the relative-gap pruning in branch & bound keeps that
    // incumbent unless a strictly better (beyond-gap) plan exists.  J_mig
    // stays in the objective at 0 for API compatibility.
    let _ = j_mig;

    // Rate-based flow + egress (replaces Eqs. 18–20; see module docs).
    // Per pipeline edge (u, v) and node k: l = locally-consumed rate,
    // e = exported, m = imported.  production_k = l+e, consumption_k = l+m.
    let mut flow_v: Vec<Vec<(Var, Var, Var)>> = Vec::new();
    if input.placement_aware && !input.edges.is_empty() {
        for (ei, &(u, v)) in input.edges.iter().enumerate() {
            // D_v is the per-edge volume for forks (replication) and joins
            // (aligned-group consumption) alike; see module docs.
            let d_next = input.ops[v].d_i;
            let fan = d_next / input.ops[u].d_i;
            // Capacity rates include the candidate config (a mid-rollout
            // operator can run faster than ut_cur).
            let rate_of = |o: &OpSched| o.ut_cur.max(o.ut_cand.unwrap_or(0.0)).max(1e-6);
            let src_rate = rate_of(&input.ops[u]) * fan;
            let dst_rate = rate_of(&input.ops[v]);
            let mut per_edge = Vec::with_capacity(k);
            for kk in 0..k {
                let l = prob.cont(&format!("l_{ei}_{kk}"), 0.0, f64::INFINITY, 0.0);
                let e = prob.cont(&format!("e_{ei}_{kk}"), 0.0, f64::INFINITY, 0.0);
                let m = prob.cont(&format!("m_{ei}_{kk}"), 0.0, f64::INFINITY, 0.0);
                // production <= source capacity on k
                prob.constrain(
                    &format!("fsrc_{ei}_{kk}"),
                    vec![(l, 1.0), (e, 1.0), (x_v[u][kk], -src_rate)],
                    Cmp::Le,
                    0.0,
                );
                // consumption <= destination capacity on k
                prob.constrain(
                    &format!("fdst_{ei}_{kk}"),
                    vec![(l, 1.0), (m, 1.0), (x_v[v][kk], -dst_rate)],
                    Cmp::Le,
                    0.0,
                );
                per_edge.push((l, e, m));
            }
            // Exported == imported across the cluster.
            let mut bal: Vec<(Var, f64)> = Vec::with_capacity(2 * k);
            for &(_, e, m) in &per_edge {
                bal.push((e, 1.0));
                bal.push((m, -1.0));
            }
            prob.constrain(&format!("fbal_{ei}"), bal, Cmp::Eq, 0.0);
            // Total consumption equals the rate this edge must carry:
            // sum_k (l+m) = T * D_v / D_o.
            let mut tot: Vec<(Var, f64)> = Vec::with_capacity(2 * k + 1);
            for &(l, _, m) in &per_edge {
                tot.push((l, 1.0));
                tot.push((m, 1.0));
            }
            tot.push((t, -d_next / input.d_o));
            prob.constrain(&format!("ftot_{ei}"), tot, Cmp::Eq, 0.0);
            flow_v.push(per_edge);
        }
        // Egress (Eq. 20): per node, exported bytes <= E_max.
        for kk in 0..k {
            let mut c: Vec<(Var, f64)> = Vec::new();
            for (ei, per_edge) in flow_v.iter().enumerate() {
                let (u, _) = input.edges[ei];
                c.push((per_edge[kk].1, input.ops[u].out_mb));
            }
            c.push((e_max, -1.0));
            prob.constrain(&format!("egress_{kk}"), c, Cmp::Le, 0.0);
        }
    }

    // Greedy warm start: a feasible plan so branch & bound prunes from the
    // first node and Limit statuses still carry a usable incumbent.
    let warm = warm_start(input, &prob, p_v.len(), &p_v, &x_v, &b_v, &flow_v, t, e_max, j_mig);

    let (sol, stats) = crate::solver::solve_milp_from(&prob, budget, warm);
    decode(input, sol, stats, &p_v, &x_v, &b_v, &flow_v)
}

fn per_node_cap(o: &OpSched, node: &NodeSpec) -> f64 {
    let mut cap = (node.cpu_cores / o.cpu.max(1e-9)).floor();
    cap = cap.min((node.mem_gb / o.mem_gb.max(1e-9)).floor());
    if o.accels > 0 {
        cap = cap.min((node.accels / o.accels) as f64);
    }
    cap.max(0.0)
}

fn decode(
    input: &MilpInput,
    sol: crate::solver::Solution,
    stats: MilpStats,
    p_v: &[Var],
    x_v: &[Vec<Var>],
    b_v: &[Var],
    flow_v: &[Vec<(Var, Var, Var)>],
) -> SchedulePlan {
    let n = input.ops.len();
    let k = input.nodes.len();
    if sol.x.is_empty() {
        // Infeasible/limit without incumbent: keep current deployment.
        return SchedulePlan {
            p: input.ops.iter().map(|o| o.cur_x.iter().sum::<u32>().max(1)).collect(),
            x: input.ops.iter().map(|o| o.cur_x.clone()).collect(),
            b: vec![0; n],
            route: Vec::new(),
            t_pred: 0.0,
            status: sol.status,
            stats,
        };
    }
    let p = p_v.iter().map(|&v| sol.int_value(v).max(1) as u32).collect();
    let x: Vec<Vec<u32>> = x_v
        .iter()
        .map(|row| row.iter().map(|&v| sol.int_value(v).max(0) as u32).collect())
        .collect();
    let b = b_v.iter().map(|&v| sol.int_value(v).max(0) as u32).collect();
    // Reconstruct the k x k routing fractions from (l, e, m): local flow
    // stays, exports are spread over importers proportionally to m_l.
    let mut route = Vec::new();
    for per_edge in flow_v {
        let l: Vec<f64> = per_edge.iter().map(|&(l, _, _)| sol.value(l).max(0.0)).collect();
        let e: Vec<f64> = per_edge.iter().map(|&(_, e, _)| sol.value(e).max(0.0)).collect();
        let m: Vec<f64> = per_edge.iter().map(|&(_, _, m)| sol.value(m).max(0.0)).collect();
        let m_total: f64 = m.iter().sum();
        let mut mat = vec![vec![0.0; k]; k];
        for kk in 0..k {
            let prod = l[kk] + e[kk];
            if prod <= 1e-9 {
                mat[kk][kk] = 1.0;
                continue;
            }
            mat[kk][kk] = l[kk] / prod;
            if m_total > 1e-9 {
                for ll in 0..k {
                    if ll != kk {
                        mat[kk][ll] = (e[kk] / prod) * (m[ll] / m_total);
                    }
                }
            }
        }
        route.push(mat);
    }
    SchedulePlan {
        p,
        x,
        b,
        route,
        t_pred: sol.value(Var(0)),
        status: sol.status,
        stats,
    }
}

/// Greedy feasible plan used as the branch-and-bound incumbent:
/// accelerator-bound ops get every device (spread round-robin), CPU ops get
/// just enough instances to match the resulting bottleneck throughput,
/// packed first-fit; flows route locally first, spillover spread
/// proportionally to importer capacity.
#[allow(clippy::too_many_arguments)]
fn warm_start(
    input: &MilpInput,
    prob: &Problem,
    n: usize,
    p_v: &[Var],
    x_v: &[Vec<Var>],
    b_v: &[Var],
    flow_v: &[Vec<(Var, Var, Var)>],
    t: Var,
    e_max: Var,
    j_mig: Var,
) -> Option<Vec<f64>> {
    let k = input.nodes.len();
    let mut cpu_free: Vec<f64> = input.nodes.iter().map(|nd| nd.cpu_cores).collect();
    let mut mem_free: Vec<f64> = input.nodes.iter().map(|nd| nd.mem_gb).collect();
    let mut acc_free: Vec<f64> = input.nodes.iter().map(|nd| nd.accels as f64).collect();
    let mut x = vec![vec![0u32; k]; n];

    // Pass 1: accelerator ops — fill every device, spread evenly among
    // accel ops (they are the scarce resource).
    let accel_ops: Vec<usize> = (0..n).filter(|&i| input.ops[i].accels > 0).collect();
    if !accel_ops.is_empty() {
        let mut turn = 0usize;
        'fill: loop {
            let mut placed_any = false;
            for _ in 0..accel_ops.len() {
                let i = accel_ops[turn % accel_ops.len()];
                turn += 1;
                let o = &input.ops[i];
                // find node with room
                if let Some(kk) = (0..k).find(|&kk| {
                    acc_free[kk] >= o.accels as f64
                        && cpu_free[kk] >= o.cpu
                        && mem_free[kk] >= o.mem_gb
                }) {
                    acc_free[kk] -= o.accels as f64;
                    cpu_free[kk] -= o.cpu;
                    mem_free[kk] -= o.mem_gb;
                    x[i][kk] += 1;
                    placed_any = true;
                }
            }
            if !placed_any {
                break 'fill;
            }
        }
    }
    // Throughput implied by accel allocation.
    let mut t_val = f64::INFINITY;
    for &i in &accel_ops {
        let p: u32 = x[i].iter().sum();
        if p == 0 {
            return None;
        }
        let g = input.d_o / input.ops[i].d_i;
        t_val = t_val.min(g * p as f64 * input.ops[i].ut_cur.max(1e-9));
    }
    if !t_val.is_finite() {
        t_val = 1.0; // all-CPU pipeline: aim low, still feasible
    }

    // Pass 2: CPU ops — enough instances for t_val, first-fit (prefer
    // nodes where the op already runs, then co-location with neighbours).
    for i in 0..n {
        if input.ops[i].accels > 0 {
            continue;
        }
        let o = &input.ops[i];
        let g = input.d_o / o.d_i;
        let mut need = ((t_val / (g * o.ut_cur.max(1e-9))).ceil() as u32).max(1);
        // 10% headroom so the CPU stage is not the binding constraint.
        need = need + (need / 8) + 1;
        let mut placed = 0u32;
        while placed < need {
            // Prefer nodes where the op already runs (the warm start then
            // realizes the migration-penalty preference for the status
            // quo), then the emptiest node.
            let kk_opt = (0..k)
                .filter(|&kk| cpu_free[kk] >= o.cpu && mem_free[kk] >= o.mem_gb)
                .max_by(|&a, &b| {
                    let pa = (input.ops[i].cur_x.get(a).copied().unwrap_or(0) > x[i][a]) as u32;
                    let pb = (input.ops[i].cur_x.get(b).copied().unwrap_or(0) > x[i][b]) as u32;
                    pa.cmp(&pb).then(cpu_free[a].partial_cmp(&cpu_free[b]).unwrap())
                });
            let Some(kk) = kk_opt else { break };
            cpu_free[kk] -= o.cpu;
            mem_free[kk] -= o.mem_gb;
            x[i][kk] += 1;
            placed += 1;
        }
        if placed == 0 {
            return None; // cannot place even one instance
        }
        if placed < need {
            // CPU-bound: lower the throughput target accordingly.
            t_val = t_val.min(g * placed as f64 * o.ut_cur.max(1e-9));
        }
    }
    // Re-check every op supports t_val.
    for i in 0..n {
        let g = input.d_o / input.ops[i].d_i;
        let p: u32 = x[i].iter().sum();
        t_val = t_val.min(g * p as f64 * input.ops[i].ut_cur.max(1e-9));
    }
    t_val = t_val.max(0.0);

    // Profitable rolling transitions: take b_i = min(n_old, B_max) whenever
    // the cold-start-discounted candidate rate beats the current one
    // (Eq. 11 test), then recompute the throughput with the mixed rates of
    // Eq. 13.  This puts transitions into the incumbent even when the
    // branch-and-bound budget expires at the root.
    let mut b_pick = vec![0u32; n];
    let mut t_mixed = f64::INFINITY;
    for i in 0..n {
        let o = &input.ops[i];
        let p: u32 = x[i].iter().sum();
        let g = input.d_o / o.d_i;
        let ut_cand = o.ut_cand.unwrap_or(0.0);
        let ut_hat = ut_cand * (1.0 - o.h_cold / input.t_sched).max(0.0);
        if o.ut_cand.is_some() && o.n_old > 0 && ut_hat > o.ut_cur {
            let limit = if input.all_at_once { o.n_old } else { o.n_old.min(input.b_max) };
            b_pick[i] = limit.min(p.saturating_sub(o.n_new));
        }
        let stay = p.saturating_sub(o.n_new + b_pick[i]) as f64;
        let cap = g
            * (stay * o.ut_cur
                + o.n_new as f64 * ut_cand
                + b_pick[i] as f64 * ut_hat.max(0.0));
        t_mixed = t_mixed.min(cap.max(0.0));
    }
    if t_mixed.is_finite() {
        // b is only taken when it raises the op's capacity, so the mixed
        // throughput dominates the plain one.
        t_val = t_mixed.max(0.0);
    }

    // Assemble the full variable vector.
    let mut sol = vec![0.0; prob.n_vars()];
    sol[t.0] = t_val;
    for i in 0..n {
        let p: u32 = x[i].iter().sum();
        sol[p_v[i].0] = p as f64;
        sol[b_v[i].0] = b_pick[i] as f64;
        for kk in 0..k {
            sol[x_v[i][kk].0] = x[i][kk] as f64;
        }
    }
    // all-at-once auxiliary binaries (z_i): b is 0 or n_old by construction.
    for (idx, name) in prob.names.iter().enumerate() {
        if let Some(rest) = name.strip_prefix("z_") {
            let i: usize = rest.parse().ok()?;
            sol[idx] = if b_pick[i] > 0 { 1.0 } else { 0.0 };
        }
    }
    sol[j_mig.0] = 0.0;

    // Flows: local first, spillover spread by importer capacity.
    let mut e_val: f64 = 0.0;
    let mut egress_mb = vec![0.0; k];
    for (ei, per_edge) in flow_v.iter().enumerate() {
        let (u, v) = input.edges[ei];
        let d_next = input.ops[v].d_i;
        let fan = d_next / input.ops[u].d_i;
        let rate_of = |o: &OpSched| o.ut_cur.max(o.ut_cand.unwrap_or(0.0)).max(1e-6);
        let src_rate = rate_of(&input.ops[u]) * fan;
        let dst_rate = rate_of(&input.ops[v]);
        let demand = t_val * d_next / input.d_o;
        let scap: Vec<f64> = (0..k).map(|kk| x[u][kk] as f64 * src_rate).collect();
        let dcap: Vec<f64> = (0..k).map(|kk| x[v][kk] as f64 * dst_rate).collect();
        let s_tot: f64 = scap.iter().sum();
        let d_tot: f64 = dcap.iter().sum();
        if demand > s_tot + 1e-9 || demand > d_tot + 1e-9 {
            return None; // shouldn't happen: t_val respects capacities
        }
        // production/consumption proportional to capacity, local first
        for kk in 0..k {
            let prod = if s_tot > 0.0 { demand * scap[kk] / s_tot } else { 0.0 };
            let cons = if d_tot > 0.0 { demand * dcap[kk] / d_tot } else { 0.0 };
            let l = prod.min(cons);
            let e = prod - l;
            let m = cons - l;
            let (lv, ev, mv) = per_edge[kk];
            sol[lv.0] = l;
            sol[ev.0] = e;
            sol[mv.0] = m;
            egress_mb[kk] += e * input.ops[u].out_mb;
        }
    }
    for kk in 0..k {
        e_val = e_val.max(egress_mb[kk]);
    }
    sol[e_max.0] = e_val;
    Some(sol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;

    fn nodes(k: usize) -> Vec<NodeSpec> {
        ClusterSpec::homogeneous(k, 64.0, 256.0, 4, 65536.0, 1250.0).nodes
    }

    fn op(name: &str, ut: f64, cpu: f64, accels: u32, d_i: f64, out_mb: f64, k: usize) -> OpSched {
        OpSched {
            name: name.into(),
            ut_cur: ut,
            ut_cand: None,
            n_new: 0,
            n_old: 0,
            cpu,
            mem_gb: 2.0,
            accels,
            out_mb,
            d_i,
            h_start: 2.0,
            h_stop: 1.0,
            h_cold: 20.0,
            cur_x: vec![0; k],
        }
    }

    fn chain_edges(n: usize) -> Vec<(usize, usize)> {
        (1..n).map(|i| (i - 1, i)).collect()
    }

    fn base_input(k: usize) -> MilpInput {
        MilpInput {
            ops: vec![
                op("cpu_a", 10.0, 2.0, 0, 1.0, 0.5, k),
                op("llm", 2.0, 8.0, 1, 1.0, 0.1, k),
                op("cpu_b", 20.0, 1.0, 0, 1.0, 0.1, k),
            ],
            edges: chain_edges(3),
            nodes: nodes(k),
            d_o: 1.0,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            all_at_once: false,
        }
    }

    fn solve10(i: &MilpInput) -> SchedulePlan {
        solve(i, Duration::from_secs(10))
    }

    #[test]
    fn bottleneck_gets_the_accelerators() {
        let input = base_input(2);
        let plan = solve10(&input);
        assert!(matches!(plan.status, Status::Optimal | Status::Limit));
        // 8 NPUs total -> p_llm = 8, T = 16
        assert_eq!(plan.p[1], 8, "all accelerators used: {:?}", plan.p);
        assert!((plan.t_pred - 16.0).abs() < 0.5, "T {}", plan.t_pred);
        // supporting ops sized to match
        assert!(plan.p[0] as f64 * 10.0 >= plan.t_pred - 0.5);
        assert!(plan.p[2] as f64 * 20.0 >= plan.t_pred - 0.5);
    }

    #[test]
    fn respects_node_capacity() {
        let input = base_input(2);
        let plan = solve10(&input);
        for kk in 0..2 {
            let acc: u32 = (0..3).map(|i| plan.x[i][kk] * input.ops[i].accels).sum();
            assert!(acc <= 4);
            let cpu: f64 = (0..3).map(|i| plan.x[i][kk] as f64 * input.ops[i].cpu).sum();
            assert!(cpu <= 64.0 + 1e-6);
        }
    }

    #[test]
    fn amplification_scales_requirements() {
        // Middle op sees 10x the records: needs 10x more capacity.
        let mut input = base_input(2);
        input.ops[1].d_i = 10.0;
        input.ops[1].accels = 0;
        input.ops[1].cpu = 1.0;
        input.ops[1].ut_cur = 10.0;
        input.ops[2].d_i = 10.0;
        input.ops[2].ut_cur = 100.0;
        let plan = solve10(&input);
        // T limited by op1: T <= (1/10) * p1 * 10 = p1 -> wants p1 large
        assert!(plan.p[1] > plan.p[0], "amplified op needs more instances: {:?}", plan.p);
    }

    #[test]
    fn rolling_update_when_candidate_much_better() {
        let mut input = base_input(2);
        input.ops[1].ut_cand = Some(4.0); // 2x the current rate
        input.ops[1].n_old = 8;
        input.ops[1].cur_x = vec![4, 4];
        input.ops[1].h_cold = 5.0; // cheap restart vs 30s window
        let plan = solve10(&input);
        assert!(plan.b[1] > 0, "profitable transition must start: {:?}", plan.b);
        assert!(plan.b[1] <= 2, "bounded by B_max");
    }

    #[test]
    fn transition_deferred_when_cold_start_dominates() {
        let mut input = base_input(2);
        input.ops[1].ut_cand = Some(2.1); // marginal gain
        input.ops[1].n_old = 8;
        input.ops[1].cur_x = vec![4, 4];
        input.ops[1].h_cold = 29.0; // eats ~97% of the window
        let plan = solve10(&input);
        assert_eq!(plan.b[1], 0, "marginal + expensive transition deferred");
    }

    #[test]
    fn rolling_continues_mixed_state() {
        // Mid-transition: n_new already faster; T must use the mix.
        let mut input = base_input(2);
        input.ops[1].ut_cand = Some(4.0);
        input.ops[1].n_new = 2;
        input.ops[1].n_old = 6;
        input.ops[1].h_cold = 5.0;
        input.ops[1].cur_x = vec![4, 4];
        let plan = solve10(&input);
        assert!(plan.p[1] >= 2, "p >= n_new (no rollback)");
        assert!(plan.b[1] >= 1, "continues the rollout");
    }

    #[test]
    fn colocation_reduces_egress() {
        // Two chained CPU ops with heavy intermediate data must co-locate.
        let k = 2;
        let mut input = MilpInput {
            ops: vec![
                op("producer", 10.0, 4.0, 0, 1.0, 50.0, k), // 50 MB/record!
                op("consumer", 10.0, 4.0, 0, 1.0, 0.1, k),
            ],
            edges: chain_edges(2),
            nodes: nodes(k),
            d_o: 1.0,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            all_at_once: false,
        };
        input.ops[0].d_i = 1.0;
        input.ops[1].d_i = 1.0;
        let plan = solve10(&input);
        // With symmetric capacity the solver can route all flow locally:
        // route matrices should be (near-)diagonal.
        for m in &plan.route {
            for kk in 0..k {
                assert!(
                    m[kk][kk] > 0.95,
                    "local routing expected, got {:?}",
                    plan.route
                );
            }
        }
    }

    #[test]
    fn migration_penalty_prefers_status_quo() {
        // Two equivalent placements; current deployment must win ties.
        let mut input = base_input(2);
        input.ops[0].cur_x = vec![2, 0];
        input.ops[1].cur_x = vec![4, 4];
        input.ops[2].cur_x = vec![1, 0];
        let plan = solve10(&input);
        // LLM placement is forced (4+4); CPU ops should stay put if able.
        assert!(
            plan.x[0][0] >= plan.x[0][1],
            "prefer existing node for op0: {:?}",
            plan.x
        );
    }

    #[test]
    fn all_at_once_switches_everything_or_nothing() {
        let mut input = base_input(2);
        input.all_at_once = true;
        input.ops[1].ut_cand = Some(4.0);
        input.ops[1].n_old = 8;
        input.ops[1].cur_x = vec![4, 4];
        input.ops[1].h_cold = 5.0;
        let plan = solve10(&input);
        assert!(plan.b[1] == 0 || plan.b[1] == 8, "all-at-once: {:?}", plan.b);
    }

    #[test]
    fn sixteen_node_instance_solves_within_budget() {
        let k = 16;
        let mut ops = Vec::new();
        for i in 0..9 {
            let accel = i == 2 || i == 5 || i == 7;
            let mut o = op(
                &format!("op{i}"),
                if accel { 2.0 } else { 15.0 },
                if accel { 8.0 } else { 2.0 },
                accel as u32,
                [1.0, 1.0, 6.0, 6.0, 4.2, 4.2, 3.6, 3.6, 3.6][i],
                1.0,
                k,
            );
            o.cur_x = vec![0; k];
            ops.push(o);
        }
        let input = MilpInput {
            ops,
            edges: chain_edges(9),
            nodes: nodes(k),
            d_o: 3.6,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            all_at_once: false,
        };
        let start = std::time::Instant::now();
        let plan = solve(&input, Duration::from_secs(20));
        let wall = start.elapsed();
        assert!(plan.t_pred > 0.0, "{:?}", plan.status);
        assert!(wall < Duration::from_secs(21));
        // feasibility of the decoded integer plan
        for kk in 0..k {
            let acc: u32 = (0..9).map(|i| plan.x[i][kk] * input.ops[i].accels).sum();
            assert!(acc <= 4);
        }
    }

    #[test]
    fn dag_flow_covers_every_edge() {
        // Diamond: 0 -> {1 (accel), 2 (accel)} -> 3; both branches carry
        // the full replicated volume, so the accel branch capacity binds T.
        let k = 2;
        let mut ops = vec![
            op("decode", 10.0, 2.0, 0, 1.0, 1.0, k),
            op("asr", 2.0, 8.0, 1, 1.0, 0.1, k),
            op("caption", 2.0, 8.0, 1, 1.0, 0.1, k),
            op("join", 40.0, 1.0, 0, 1.0, 0.1, k),
        ];
        for o in &mut ops {
            o.cur_x = vec![0; k];
        }
        let input = MilpInput {
            ops,
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
            nodes: nodes(k),
            d_o: 1.0,
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            all_at_once: false,
        };
        let plan = solve(&input, Duration::from_secs(10));
        assert!(matches!(plan.status, Status::Optimal | Status::Limit));
        assert_eq!(plan.route.len(), 4, "one routing matrix per DAG edge");
        // 8 devices split across the two accel branches: 4 + 4, T = 8.
        assert_eq!(plan.p[1] + plan.p[2], 8, "both branches saturate the devices: {:?}", plan.p);
        assert!((plan.t_pred - 8.0).abs() < 0.6, "T {}", plan.t_pred);
        // Each branch must sustain the full replicated volume.
        assert!(plan.p[1] as f64 * 2.0 >= plan.t_pred - 0.5);
        assert!(plan.p[2] as f64 * 2.0 >= plan.t_pred - 0.5);
        // Routing rows are normalized distributions.
        for m in &plan.route {
            for row in m {
                let s: f64 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-6, "row sums to {s}");
            }
        }
    }
}
