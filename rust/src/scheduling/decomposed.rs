//! Decomposed solve path for the multi-tenant scheduling MILP:
//! Dantzig–Wolfe price-and-branch over per-tenant blocks.
//!
//! Tenants couple only through shared node-capacity and egress rows, so
//! the union MILP splits into a small restricted master LP (one λ per
//! generated per-tenant schedule, the shared capacity/egress rows, the
//! weighted max-min epigraph) and independent per-tenant pricing
//! subproblems — each the classic single-tenant MILP this crate already
//! builds bit-identically ([`tenant_block`]), re-solved warm against the
//! master's dual prices via the per-tenant [`BasisCache`] (the pricing
//! rounds only mutate objective coefficients, so the cache's shape key
//! never changes and every round after the first replays the previous
//! basis).  Subproblems fan out across tenants with `std::thread::scope`
//! and are collected in tenant order, so the result is bit-identical at
//! any thread count.
//!
//! Fallback contract: any abort in the engine (master LP failure,
//! infeasible integrality repair, artificial slack in the repaired
//! solution) and every input below the tenant-count threshold routes to
//! the monolithic [`solve_with_options`] — in particular a single-tenant
//! input under the decomposed backend degenerates to the classic MILP
//! **bit-identically**.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::solver::{
    solve_dw, DwColumn, DwDuals, DwOptions, DwRow, DwStatic, MilpOptions, MilpStats,
    PricedColumn, Status,
};

use super::milp_model::{
    block_column, build_model, decode, set_pricing_objective, solve_model, solve_with_options,
    tenant_block, BasisCache, MilpInput, Model, PricingDuals, SchedulePlan,
};

/// Which solve path backs the scheduling round.
pub use crate::config::SolverBackend;

/// Decomposition knobs (scheduling-level; engine knobs in
/// [`DwOptions`]).
#[derive(Debug, Clone)]
pub struct DecompOptions {
    /// Below this many tenants the monolithic MILP is used directly
    /// (the master/pricing machinery cannot pay for itself, and the
    /// single-tenant case must stay bit-identical).
    pub min_tenants: usize,
    /// Pricing fan-out threads (0 = available parallelism).
    pub threads: usize,
    /// Hard cap on pricing rounds.
    pub max_rounds: usize,
}

impl Default for DecompOptions {
    fn default() -> Self {
        DecompOptions { min_tenants: 2, threads: 0, max_rounds: 25 }
    }
}

/// Per-tenant state threaded through the engine's fan-out: the extracted
/// block, its built model (objective mutated in place between rounds),
/// the tenant's own warm-start cache, and every block solution generated
/// so far (the column payloads; `DwColumn::tag` indexes this).
struct TenantState {
    name: String,
    block: MilpInput,
    model: Model,
    cache: BasisCache,
    op_map: Vec<usize>,
    edge_map: Vec<usize>,
    payloads: Vec<crate::solver::Solution>,
}

/// Solve the round's MILP through the decomposed path, falling back to
/// the monolithic solve when decomposition does not apply or aborts.
///
/// `tenant_caches` is keyed by tenant name so caches survive tenant
/// arrival/departure (dynamic tenancy reshuffles indices, not names);
/// `mono_cache` serves the fallback path exactly as in the monolithic
/// backend.
pub fn solve_decomposed(
    input: &MilpInput,
    budget: Duration,
    mono_cache: &mut BasisCache,
    tenant_caches: &mut HashMap<String, BasisCache>,
    opts: &MilpOptions,
    dopts: &DecompOptions,
) -> SchedulePlan {
    let nt = input.tenants.len();
    if nt <= 1 || nt < dopts.min_tenants.max(2) {
        // Degenerate: the classic MILP, bit-identical (same build, same
        // cache protocol, same solver options).
        return solve_with_options(input, budget, mono_cache, opts);
    }
    let start = Instant::now();
    let k = input.nodes.len();
    let any_acc = input.ops.iter().any(|o| o.accels > 0);
    let has_flows = input.placement_aware && !input.edges.is_empty();

    // ---- per-tenant blocks -------------------------------------------
    let mut states: Vec<TenantState> = Vec::with_capacity(nt);
    for t in 0..nt {
        let (block, op_map, edge_map) = tenant_block(input, t);
        if block.ops.is_empty() {
            return solve_with_options(input, budget, mono_cache, opts);
        }
        let name = input.tenants[t].name.clone();
        let cache = tenant_caches.remove(&name).unwrap_or_default();
        let model = build_model(&block);
        states.push(TenantState {
            name,
            block,
            model,
            cache,
            op_map,
            edge_map,
            payloads: Vec::new(),
        });
    }

    // ---- master coupling rows ----------------------------------------
    // Row layout (the dual-slicing contract with the pricing closure):
    //   [0, nt)                      maxmin_t   w_t·T_min − Σ T_c·λ ≤ 0
    //   [nt, nt+k)                   cpu_k      Σ cpu-usage·λ ≤ cap_k
    //   [nt+k, nt+2k)                mem_k
    //   [nt+2k, nt+3k)               acc_k      (only when any op has accels)
    //   [.., ..+k)                   egress_k   Σ egress-MB·λ − E_max ≤ 0
    let mut rows: Vec<DwRow> = Vec::new();
    for t in 0..nt {
        rows.push(DwRow {
            name: format!("maxmin_{}", input.tenants[t].name),
            cmp: crate::solver::Cmp::Le,
            rhs: 0.0,
        });
    }
    for node in &input.nodes {
        rows.push(DwRow {
            name: format!("cpu_{}", node.name),
            cmp: crate::solver::Cmp::Le,
            rhs: node.cpu_cores,
        });
    }
    for node in &input.nodes {
        rows.push(DwRow {
            name: format!("mem_{}", node.name),
            cmp: crate::solver::Cmp::Le,
            rhs: node.mem_gb,
        });
    }
    let acc_base = if any_acc {
        for node in &input.nodes {
            rows.push(DwRow {
                name: format!("acc_{}", node.name),
                cmp: crate::solver::Cmp::Le,
                rhs: node.accels as f64,
            });
        }
        Some(nt + 2 * k)
    } else {
        None
    };
    let eg_base = if has_flows {
        let base = rows.len();
        for node in &input.nodes {
            rows.push(DwRow {
                name: format!("egress_{}", node.name),
                cmp: crate::solver::Cmp::Le,
                rhs: 0.0,
            });
        }
        Some(base)
    } else {
        None
    };

    let mut statics = vec![DwStatic {
        name: "T_min".into(),
        obj: 1.0,
        lo: 0.0,
        up: f64::INFINITY,
        coeffs: (0..nt).map(|t| (t, input.tenants[t].weight)).collect(),
    }];
    if let Some(base) = eg_base {
        statics.push(DwStatic {
            name: "E_max".into(),
            obj: -input.lambda1,
            lo: 0.0,
            up: f64::INFINITY,
            coeffs: (0..k).map(|kk| (base + kk, -1.0)).collect(),
        });
    }

    // ---- seed / pricing oracles --------------------------------------
    let make_column = |st: &mut TenantState, sol: crate::solver::Solution, t: usize| -> DwColumn {
        let bc = block_column(&st.model, &st.block, &sol);
        let mut coeffs: Vec<(usize, f64)> = Vec::with_capacity(2 + 4 * k);
        if bc.t_c != 0.0 {
            coeffs.push((t, -bc.t_c));
        }
        for kk in 0..k {
            if bc.cpu[kk] != 0.0 {
                coeffs.push((nt + kk, bc.cpu[kk]));
            }
        }
        for kk in 0..k {
            if bc.mem[kk] != 0.0 {
                coeffs.push((nt + k + kk, bc.mem[kk]));
            }
        }
        if let Some(base) = acc_base {
            for kk in 0..k {
                if bc.acc[kk] != 0.0 {
                    coeffs.push((base + kk, bc.acc[kk]));
                }
            }
        }
        if let Some(base) = eg_base {
            for kk in 0..k {
                if bc.egress[kk] != 0.0 {
                    coeffs.push((base + kk, bc.egress[kk]));
                }
            }
        }
        let tag = st.payloads.len();
        st.payloads.push(sol);
        DwColumn { obj: bc.obj, coeffs, tag }
    };

    let seed = |t: usize, st: &mut TenantState| -> Option<Vec<PricedColumn>> {
        // Standalone optimum under the block's natural objective: the
        // classic single-tenant solve, warm from the tenant's own cache.
        let (sol, stats) = solve_model(&st.block, &st.model, budget, &mut st.cache, opts);
        if sol.x.is_empty() {
            return None;
        }
        let col = make_column(st, sol, t);
        Some(vec![PricedColumn { col, stats }])
    };

    let price = |t: usize, st: &mut TenantState, duals: &DwDuals| -> Option<PricedColumn> {
        let pd = PricingDuals {
            y_maxmin: duals.coupling[t],
            y_cpu: &duals.coupling[nt..nt + k],
            y_mem: &duals.coupling[nt + k..nt + 2 * k],
            y_acc: acc_base.map(|b| &duals.coupling[b..b + k]),
            y_eg: eg_base.map(|b| &duals.coupling[b..b + k]),
        };
        set_pricing_objective(&mut st.model, &st.block, &pd);
        let (sol, stats) = solve_model(&st.block, &st.model, budget, &mut st.cache, opts);
        if sol.x.is_empty() {
            return None;
        }
        let col = make_column(st, sol, t);
        Some(PricedColumn { col, stats })
    };

    let dw_opts = DwOptions {
        max_rounds: dopts.max_rounds,
        threads: dopts.threads,
        repair_budget: budget,
        ..DwOptions::default()
    };
    let outcome = solve_dw(&rows, &statics, &mut states, seed, price, &dw_opts);

    // Hand the per-tenant caches back before any return path.
    let give_back = |states: Vec<TenantState>, caches: &mut HashMap<String, BasisCache>| {
        let mut plans = Vec::with_capacity(states.len());
        for st in states {
            caches.insert(st.name.clone(), st.cache);
            plans.push((st.block, st.model, st.op_map, st.edge_map, st.payloads));
        }
        plans
    };

    let Some(dws) = outcome else {
        give_back(states, tenant_caches);
        return solve_with_options(input, budget, mono_cache, opts);
    };
    let parts = give_back(states, tenant_caches);

    // ---- merge chosen columns into the union plan --------------------
    let n = input.ops.len();
    let mut p = vec![0u32; n];
    let mut x = vec![Vec::new(); n];
    let mut b = vec![0u32; n];
    let mut route = if has_flows { vec![Vec::new(); input.edges.len()] } else { Vec::new() };
    let mut edge_cons =
        if has_flows { vec![Vec::new(); input.edges.len()] } else { Vec::new() };
    let mut t_tenant = vec![0.0; nt];
    let mut status = dws.status;
    let mut stats = dws.stats;
    for (t, (block, model, op_map, edge_map, payloads)) in parts.into_iter().enumerate() {
        let sol = payloads[dws.chosen[t]].clone();
        if sol.status != Status::Optimal {
            status = Status::Limit;
        }
        let plan_t = decode(
            &block,
            sol,
            MilpStats::default(),
            &model.t_v,
            &model.p_v,
            &model.x_v,
            &model.b_v,
            &model.flow_v,
        );
        for (bi, &ui) in op_map.iter().enumerate() {
            p[ui] = plan_t.p[bi];
            x[ui] = plan_t.x[bi].clone();
            b[ui] = plan_t.b[bi];
        }
        if has_flows {
            for (bei, &uei) in edge_map.iter().enumerate() {
                route[uei] = plan_t.route[bei].clone();
                edge_cons[uei] = plan_t.edge_cons[bei].clone();
            }
        }
        t_tenant[t] = plan_t.t_tenant[0];
    }
    stats.wall = start.elapsed();
    SchedulePlan {
        p,
        x,
        b,
        route,
        t_pred: t_tenant.iter().sum(),
        t_tenant,
        edge_cons,
        obj: dws.obj,
        status,
        stats,
    }
}
