//! Rolling-update state machine (paper §6.5–6.6): per-operator tracking of
//! `(n_old, n_new)` with the single-transition invariant — at most one
//! pending configuration transition per operator; new recommendations are
//! buffered until the current transition completes.

/// Per-operator rolling configuration state.
#[derive(Debug, Clone)]
pub struct RollingState {
    /// Configuration all `n_old` instances currently run.
    pub current: Vec<f64>,
    /// Candidate configuration mid-rollout (None = steady state).
    pub candidate: Option<Vec<f64>>,
    pub ut_cand: f64,
    pub n_new: u32,
    pub n_old: u32,
    /// Recommendation buffered while a transition is in flight.
    buffered: Option<(Vec<f64>, f64)>,
    /// Transitions committed (stats).
    pub transitions: u64,
}

impl RollingState {
    pub fn new(initial_config: Vec<f64>, n_inst: u32) -> Self {
        RollingState {
            current: initial_config,
            candidate: None,
            ut_cand: 0.0,
            n_new: 0,
            n_old: n_inst,
            buffered: None,
            transitions: 0,
        }
    }

    pub fn in_transition(&self) -> bool {
        self.candidate.is_some()
    }

    /// Offer a recommendation from the adaptation layer.  Returns true if
    /// it became the active candidate; buffered otherwise (single-transition
    /// invariant).
    pub fn offer(&mut self, config: Vec<f64>, ut_cand: f64) -> bool {
        if config == self.current {
            return false; // nothing to do
        }
        if self.in_transition() {
            if self.candidate.as_deref() != Some(&config[..]) {
                self.buffered = Some((config, ut_cand));
            } else {
                self.ut_cand = ut_cand; // refreshed estimate
            }
            false
        } else {
            self.candidate = Some(config);
            self.ut_cand = ut_cand;
            true
        }
    }

    /// Record that the executor switched `b` instances this round and the
    /// operator now has `p` instances total.  Completes the transition when
    /// no old-config instances remain.
    pub fn apply_round(&mut self, b: u32, p: u32) {
        if self.candidate.is_none() {
            self.n_old = p;
            self.n_new = 0;
            return;
        }
        let b = b.min(self.n_old);
        self.n_new += b;
        // p may shrink/grow; old instances absorb the difference.
        self.n_old = p.saturating_sub(self.n_new);
        if b > 0 {
            self.transitions += 1;
        }
        if self.n_old == 0 {
            // Transition complete: candidate becomes current.
            if let Some(c) = self.candidate.take() {
                self.current = c;
            }
            self.n_old = p;
            self.n_new = 0;
            // Un-buffer the next recommendation, if any.
            if let Some((cfg, ut)) = self.buffered.take() {
                if cfg != self.current {
                    self.candidate = Some(cfg);
                    self.ut_cand = ut;
                }
            }
        }
    }

    /// A node failure removed instances with no drain: clamp the books to
    /// what actually survived.  Failed instances are treated as
    /// already-stopped — they owe no stop cost, and a dead candidate
    /// instance no longer counts toward `n_new` (so the scheduler's
    /// `p >= n_new` floor never demands capacity that no longer exists).
    pub fn on_capacity_loss(&mut self, p_live: u32) {
        self.n_new = self.n_new.min(p_live);
        self.sync_count(p_live);
    }

    /// Sync instance count without a transition round (plan with b=0).
    pub fn sync_count(&mut self, p: u32) {
        if self.candidate.is_none() {
            self.n_old = p;
        } else {
            self.n_old = p.saturating_sub(self.n_new);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_until_offer() {
        let mut rs = RollingState::new(vec![16.0], 4);
        assert!(!rs.in_transition());
        assert!(rs.offer(vec![32.0], 5.0));
        assert!(rs.in_transition());
        assert_eq!(rs.n_old, 4);
        assert_eq!(rs.n_new, 0);
    }

    #[test]
    fn identical_config_rejected() {
        let mut rs = RollingState::new(vec![16.0], 4);
        assert!(!rs.offer(vec![16.0], 5.0));
        assert!(!rs.in_transition());
    }

    #[test]
    fn rolling_completes_over_rounds() {
        let mut rs = RollingState::new(vec![16.0], 4);
        rs.offer(vec![32.0], 5.0);
        rs.apply_round(2, 4);
        assert_eq!((rs.n_new, rs.n_old), (2, 2));
        assert!(rs.in_transition());
        rs.apply_round(2, 4);
        assert!(!rs.in_transition(), "transition complete");
        assert_eq!(rs.current, vec![32.0]);
        assert_eq!((rs.n_new, rs.n_old), (0, 4));
    }

    #[test]
    fn single_transition_invariant_buffers() {
        let mut rs = RollingState::new(vec![16.0], 4);
        assert!(rs.offer(vec![32.0], 5.0));
        // Second recommendation arrives mid-transition: buffered.
        assert!(!rs.offer(vec![64.0], 7.0));
        assert_eq!(rs.candidate.as_deref(), Some(&[32.0][..]));
        rs.apply_round(4, 4);
        // Completion activates the buffered config.
        assert!(rs.in_transition());
        assert_eq!(rs.candidate.as_deref(), Some(&[64.0][..]));
        assert_eq!(rs.ut_cand, 7.0);
    }

    #[test]
    fn parallelism_changes_mid_transition() {
        let mut rs = RollingState::new(vec![16.0], 6);
        rs.offer(vec![32.0], 5.0);
        rs.apply_round(2, 8); // scale up during rollout
        assert_eq!((rs.n_new, rs.n_old), (2, 6));
        rs.apply_round(0, 5); // scale down, no transitions
        assert_eq!((rs.n_new, rs.n_old), (2, 3));
    }
}
