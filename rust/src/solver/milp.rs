//! Branch & bound for mixed-integer linear programs.
//!
//! Best-first search over LP relaxations (`simplex::solve_lp`), branching on
//! the most fractional integer variable, with:
//! * a rounding heuristic at every node to find incumbents early,
//! * bound-based pruning against the incumbent,
//! * a wall-clock budget (the scheduler runs re-optimization off the
//!   critical path, but Algorithm 2 still wants an answer per round).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

use super::model::{Problem, Solution, Status};
use super::simplex::solve_lp;

const INT_TOL: f64 = 1e-5;
/// Relative optimality gap at which branches are pruned.
const REL_GAP_TOL: f64 = 1e-4;

struct Node {
    bound: f64, // LP relaxation objective (upper bound for maximization)
    lo: Vec<f64>,
    up: Vec<f64>,
    depth: usize,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap on bound (best-first).
        self.bound.partial_cmp(&other.bound).unwrap_or(Ordering::Equal)
    }
}

/// Statistics from a MILP solve (reported by the RQ6 overhead bench).
#[derive(Debug, Clone, Default)]
pub struct MilpStats {
    pub nodes: usize,
    pub lp_solves: usize,
    pub wall: Duration,
    pub gap: f64,
}

/// Solve `p` as a MILP.  Returns the best integer-feasible solution found
/// within `budget`, with `Status::Optimal` when the search tree was
/// exhausted and `Status::Limit` when the budget expired first.
pub fn solve_milp(p: &Problem, budget: Duration) -> (Solution, MilpStats) {
    solve_milp_from(p, budget, None)
}

/// Like [`solve_milp`] but seeded with a feasible warm-start point, which
/// becomes the initial incumbent (pruning bound).  The point is verified;
/// an infeasible warm start is ignored.
pub fn solve_milp_from(
    p: &Problem,
    budget: Duration,
    warm: Option<Vec<f64>>,
) -> (Solution, MilpStats) {
    let start = Instant::now();
    let mut stats = MilpStats::default();

    let mut incumbent: Option<Solution> = warm.and_then(|x| {
        if p.is_feasible(&x, 1e-6) {
            let obj = p.eval_obj(&x);
            Some(Solution { status: Status::Optimal, obj, x })
        } else {
            None
        }
    });
    let mut heap: BinaryHeap<Node> = BinaryHeap::new();
    heap.push(Node { bound: f64::INFINITY, lo: p.lo.clone(), up: p.up.clone(), depth: 0 });

    let mut exhausted = true;
    while let Some(node) = heap.pop() {
        if start.elapsed() > budget {
            exhausted = false;
            break;
        }
        if let Some(inc) = &incumbent {
            // Prune on absolute or small relative gap: the scheduler does
            // not benefit from the last <0.5% of objective.
            if node.bound <= inc.obj + 1e-9 || node.bound <= inc.obj * (1.0 + REL_GAP_TOL) {
                continue;
            }
        }
        // Solve the node LP.
        let mut sub = p.clone();
        sub.lo = node.lo.clone();
        sub.up = node.up.clone();
        // Guard against crossed bounds introduced by branching.
        if sub.lo.iter().zip(&sub.up).any(|(l, u)| l > u) {
            continue;
        }
        stats.lp_solves += 1;
        stats.nodes += 1;
        let rel = solve_lp(&sub);
        match rel.status {
            Status::Infeasible => continue,
            Status::Unbounded => {
                // Integer restriction cannot fix an unbounded relaxation
                // in our models (all scheduler vars are bounded); treat as
                // an error status propagated to the caller.
                return (
                    Solution { status: Status::Unbounded, obj: f64::INFINITY, x: vec![] },
                    stats,
                );
            }
            Status::Optimal | Status::Limit => {}
        }
        if let Some(inc) = &incumbent {
            if rel.obj <= inc.obj + 1e-9 || rel.obj <= inc.obj * (1.0 + REL_GAP_TOL) {
                continue;
            }
        }

        // Find most fractional integer variable.
        let mut branch: Option<(usize, f64)> = None;
        let mut best_frac = INT_TOL;
        for j in 0..p.n_vars() {
            if !p.integer[j] {
                continue;
            }
            let f = (rel.x[j] - rel.x[j].round()).abs();
            if f > best_frac {
                best_frac = f;
                branch = Some((j, rel.x[j]));
            }
        }

        match branch {
            None => {
                // Integer feasible.
                let cand = Solution { status: Status::Optimal, obj: rel.obj, x: rel.x };
                if incumbent.as_ref().map(|i| cand.obj > i.obj).unwrap_or(true) {
                    incumbent = Some(cand);
                }
            }
            Some((j, xj)) => {
                // Rounding heuristic: snap all integer vars and re-check.
                let mut rounded = rel.x.clone();
                for k in 0..p.n_vars() {
                    if p.integer[k] {
                        rounded[k] = rounded[k].round().clamp(p.lo[k], p.up[k]);
                    }
                }
                if p.is_feasible(&rounded, 1e-6) {
                    let obj = p.eval_obj(&rounded);
                    if incumbent.as_ref().map(|i| obj > i.obj).unwrap_or(true) {
                        incumbent = Some(Solution { status: Status::Optimal, obj, x: rounded });
                    }
                }

                // Branch j <= floor, j >= ceil.
                let (fl, ce) = (xj.floor(), xj.ceil());
                let mut up_child = node.up.clone();
                up_child[j] = fl;
                if node.lo[j] <= fl {
                    heap.push(Node { bound: rel.obj, lo: node.lo.clone(), up: up_child, depth: node.depth + 1 });
                }
                let mut lo_child = node.lo.clone();
                lo_child[j] = ce;
                if ce <= node.up[j] {
                    heap.push(Node { bound: rel.obj, lo: lo_child, up: node.up.clone(), depth: node.depth + 1 });
                }
            }
        }
    }

    stats.wall = start.elapsed();
    match incumbent {
        Some(mut sol) => {
            let bound = heap
                .peek()
                .map(|n| n.bound)
                .unwrap_or(sol.obj)
                .max(sol.obj);
            stats.gap = if sol.obj.abs() > 1e-12 {
                ((bound - sol.obj) / sol.obj.abs()).max(0.0)
            } else {
                0.0
            };
            sol.status = if exhausted { Status::Optimal } else { Status::Limit };
            (sol, stats)
        }
        None => (
            Solution {
                status: if exhausted { Status::Infeasible } else { Status::Limit },
                obj: f64::NEG_INFINITY,
                x: vec![],
            },
            stats,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;
    use crate::solver::model::{Cmp, Problem};

    fn budget() -> Duration {
        Duration::from_secs(10)
    }

    #[test]
    fn knapsack_small() {
        // max 10a+13b+7c st 3a+4b+2c<=6, binary -> a=0,b=1,c=1 = 20
        let mut p = Problem::new();
        let a = p.int("a", 0.0, 1.0, 10.0);
        let b = p.int("b", 0.0, 1.0, 13.0);
        let c = p.int("c", 0.0, 1.0, 7.0);
        p.constrain("w", vec![(a, 3.0), (b, 4.0), (c, 2.0)], Cmp::Le, 6.0);
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 20.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn integer_rounding_not_lp_rounding() {
        // Classic: max x+y st -x+y<=0.5, x+y<=3.5 ints -> best (1,1) or (2,1):
        // x=2,y=1 obj 3 ; LP opt is (1.5, 2.0) obj 3.5.
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0, 1.0);
        let y = p.int("y", 0.0, 10.0, 1.0);
        p.constrain("c1", vec![(x, -1.0), (y, 1.0)], Cmp::Le, 0.5);
        p.constrain("c2", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 3.5);
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 3.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn assignment_problem_integral() {
        // 3x3 assignment: maximize total weight; optimal = 5+6+4 = 15
        let w = [[5.0, 1.0, 2.0], [2.0, 6.0, 3.0], [1.0, 2.0, 4.0]];
        let mut p = Problem::new();
        let mut v = vec![];
        for i in 0..3 {
            for j in 0..3 {
                v.push(p.int(&format!("x{i}{j}"), 0.0, 1.0, w[i][j]));
            }
        }
        for i in 0..3 {
            p.constrain(
                &format!("r{i}"),
                (0..3).map(|j| (v[i * 3 + j], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
            p.constrain(
                &format!("c{i}"),
                (0..3).map(|j| (v[j * 3 + i], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
        }
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 15.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn mixed_integer_continuous() {
        // max 2x + 3y, x int <=3.7 bound, y cont, x+2y<=8 -> x=3, y=2.5, obj 13.5
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 3.7, 2.0);
        let y = p.cont("y", 0.0, f64::INFINITY, 3.0);
        p.constrain("c", vec![(x, 1.0), (y, 2.0)], Cmp::Le, 8.0);
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 13.5).abs() < 1e-6, "{s:?}");
        assert!((s.x[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_milp() {
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0, 1.0);
        p.constrain("a", vec![(x, 2.0)], Cmp::Eq, 3.0); // 2x=3 has no integer solution
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Infeasible);
    }

    /// Brute-force optimum over integer grids for small random MILPs.
    fn brute_force(p: &Problem, maxv: i64) -> Option<f64> {
        let n = p.n_vars();
        let mut best: Option<f64> = None;
        let mut x = vec![0.0; n];
        fn rec(p: &Problem, x: &mut Vec<f64>, j: usize, maxv: i64, best: &mut Option<f64>) {
            if j == p.n_vars() {
                if p.is_feasible(x, 1e-9) {
                    let o = p.eval_obj(x);
                    if best.map(|b| o > b).unwrap_or(true) {
                        *best = Some(o);
                    }
                }
                return;
            }
            let hi = p.up[j].min(maxv as f64) as i64;
            let lo = p.lo[j].max(0.0) as i64;
            for v in lo..=hi {
                x[j] = v as f64;
                rec(p, x, j + 1, maxv, best);
            }
        }
        rec(p, &mut x, 0, maxv, &mut best);
        best
    }

    #[test]
    fn random_milps_match_brute_force() {
        let mut rng = Rng::new(4242);
        for case in 0..40 {
            let nv = 2 + rng.below(3); // 2..4 int vars
            let nc = 1 + rng.below(3);
            let mut p = Problem::new();
            let vars: Vec<_> = (0..nv)
                .map(|i| p.int(&format!("v{i}"), 0.0, 4.0, rng.uniform(-3.0, 5.0)))
                .collect();
            for c in 0..nc {
                let coeffs: Vec<_> = vars
                    .iter()
                    .map(|&v| (v, rng.uniform(-1.0, 3.0)))
                    .collect();
                p.constrain(&format!("c{c}"), coeffs, Cmp::Le, rng.uniform(2.0, 12.0));
            }
            let (s, _) = solve_milp(&p, budget());
            let bf = brute_force(&p, 4);
            match bf {
                None => assert_eq!(s.status, Status::Infeasible, "case {case}"),
                Some(opt) => {
                    assert_eq!(s.status, Status::Optimal, "case {case}");
                    assert!(
                        (s.obj - opt).abs() < 1e-6,
                        "case {case}: milp {} vs brute {}",
                        s.obj,
                        opt
                    );
                    assert!(p.is_feasible(&s.x, 1e-6), "case {case}");
                }
            }
        }
    }
}
