//! Branch & bound for mixed-integer linear programs.
//!
//! Best-first search over LP relaxations, branching on the most
//! fractional integer variable, with:
//! * **basis warm starts** — a child node inherits its parent's optimal
//!   basis ([`revised::BasisSnapshot`]) and re-optimizes with a handful
//!   of dual pivots after the single bound change, instead of re-running
//!   a two-phase solve from scratch.  Nodes carry bound *deltas* from the
//!   root (one `(var, side, value)` triple per branch), reconstructed
//!   into full bound vectors on pop — no per-node `lo`/`up` clones;
//! * a rounding heuristic at every node to find incumbents early;
//! * bound-based pruning against the incumbent;
//! * a wall-clock budget (the scheduler runs re-optimization off the
//!   critical path, but Algorithm 2 still wants an answer per round) and
//!   an optional deterministic node cap for machine-independent benches;
//! * a selectable LP backend: the sparse revised solver (default) or the
//!   dense tableau reference (`milp-bench`'s pivot baseline).  Revised
//!   solves that fail numerically or return an infeasible point fall
//!   back to the dense solver per node, so results never degrade.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use super::model::{Cmp, Problem, Solution, Status};
use super::revised::{outcome_to_solution, BasisSnapshot, LpSolver};
use super::simplex;

const INT_TOL: f64 = 1e-5;
/// Relative optimality gap at which branches are pruned.
const REL_GAP_TOL: f64 = 1e-4;

/// Which LP solver backs the node relaxations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpBackend {
    /// Sparse revised simplex with dual warm starts (production path).
    Revised,
    /// Dense two-phase tableau (reference / pivot-count baseline).
    Dense,
}

/// Branch-and-bound knobs beyond the wall-clock budget.
#[derive(Debug, Clone)]
pub struct MilpOptions {
    pub backend: LpBackend,
    /// Let children inherit the parent basis (Revised backend only).
    pub warm_basis: bool,
    /// Deterministic node cap: stop after this many explored nodes
    /// regardless of wall clock (benches compare backends at equal node
    /// counts so pivot totals are machine-independent).
    pub max_nodes: Option<usize>,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions { backend: LpBackend::Revised, warm_basis: true, max_nodes: None }
    }
}

struct Node {
    bound: f64, // LP relaxation objective (upper bound for maximization)
    /// Bound changes relative to the root problem: (var, is_upper, value).
    deltas: Vec<(u32, bool, f64)>,
    /// Parent's optimal basis (shared by both children).
    basis: Option<Rc<BasisSnapshot>>,
    depth: usize,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap on bound (best-first).
        self.bound.partial_cmp(&other.bound).unwrap_or(Ordering::Equal)
    }
}

/// Statistics from a MILP solve (reported by `milp-bench` and the RQ6
/// overhead bench).
#[derive(Debug, Clone, Default)]
pub struct MilpStats {
    pub nodes: usize,
    pub lp_solves: usize,
    pub wall: Duration,
    pub gap: f64,
    /// Total simplex pivots across all node LPs (the RQ6 cost driver).
    pub pivots: usize,
    /// Pivots spent restoring primal feasibility (phase-1 equivalent;
    /// warm-started children should spend ~none here).
    pub phase1_pivots: usize,
    /// Node LPs that re-optimized from an inherited/cached basis.
    pub warm_solves: usize,
    /// Node LPs solved from scratch.
    pub cold_solves: usize,
    /// Revised-solver failures that fell back to the dense reference.
    pub dense_fallbacks: usize,
    /// Whether the *root* LP warm-started (the cross-round basis cache
    /// hit, as opposed to parent→child inheritance inside the tree).
    pub root_warm: bool,
    /// Wall-clock per phase, in milliseconds: problem/column-store build,
    /// the root LP relaxation, the rest of the B&B tree, and — on the
    /// decomposed path only — the column-generation pricing rounds.
    /// Phase timings turn the pivot-count proxies in RQ6 into real time.
    pub build_ms: f64,
    pub root_lp_ms: f64,
    pub bnb_ms: f64,
    pub pricing_ms: f64,
    /// Dantzig–Wolfe pricing rounds run (0 on the monolithic path).
    pub pricing_rounds: usize,
    /// Columns generated across all pricing rounds (0 on monolithic).
    pub columns: usize,
}

impl MilpStats {
    /// Fraction of node LPs that started from a warm basis.
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_solves + self.cold_solves;
        if total == 0 {
            0.0
        } else {
            self.warm_solves as f64 / total as f64
        }
    }

    /// Fold a subproblem/child solve's counters into an aggregate (used by
    /// the decomposed path to report totals across master + pricing
    /// solves).  Wall and phase timings are summed; `root_warm` is OR-ed.
    pub fn absorb(&mut self, other: &MilpStats) {
        self.nodes += other.nodes;
        self.lp_solves += other.lp_solves;
        self.wall += other.wall;
        self.pivots += other.pivots;
        self.phase1_pivots += other.phase1_pivots;
        self.warm_solves += other.warm_solves;
        self.cold_solves += other.cold_solves;
        self.dense_fallbacks += other.dense_fallbacks;
        self.root_warm |= other.root_warm;
        self.build_ms += other.build_ms;
        self.root_lp_ms += other.root_lp_ms;
        self.bnb_ms += other.bnb_ms;
        self.pricing_ms += other.pricing_ms;
        self.pricing_rounds += other.pricing_rounds;
        self.columns += other.columns;
    }
}

/// Solve `p` as a MILP.  Returns the best integer-feasible solution found
/// within `budget`, with `Status::Optimal` when the search tree was
/// exhausted and `Status::Limit` when the budget expired first.
pub fn solve_milp(p: &Problem, budget: Duration) -> (Solution, MilpStats) {
    let (sol, stats, _) = solve_milp_opts(p, budget, None, None, &MilpOptions::default());
    (sol, stats)
}

/// Like [`solve_milp`] but seeded with a feasible warm-start point, which
/// becomes the initial incumbent (pruning bound).  The point is verified;
/// an infeasible warm start is ignored.
pub fn solve_milp_from(
    p: &Problem,
    budget: Duration,
    warm: Option<Vec<f64>>,
) -> (Solution, MilpStats) {
    let (sol, stats, _) = solve_milp_opts(p, budget, warm, None, &MilpOptions::default());
    (sol, stats)
}

/// Full-control entry point: optional incumbent point, optional root LP
/// basis (the cross-round warm start — round r+1's constraint matrix
/// differs from round r only in drifted coefficients, so round r's root
/// basis is primal-feasible-or-near and converges in few pivots), and
/// [`MilpOptions`].  Returns the root LP's optimal basis for the caller
/// to cache.
pub fn solve_milp_opts(
    p: &Problem,
    budget: Duration,
    warm: Option<Vec<f64>>,
    root_basis: Option<&BasisSnapshot>,
    opts: &MilpOptions,
) -> (Solution, MilpStats, Option<BasisSnapshot>) {
    let start = Instant::now();
    let mut stats = MilpStats::default();
    let n = p.n_vars();

    let build_t = Instant::now();
    let mut solver = match opts.backend {
        LpBackend::Revised => Some(LpSolver::new(p)),
        LpBackend::Dense => None,
    };
    stats.build_ms = build_t.elapsed().as_secs_f64() * 1e3;
    let mut root_snapshot: Option<BasisSnapshot> = None;

    let mut incumbent: Option<Solution> = warm.and_then(|x| {
        if p.is_feasible(&x, 1e-6) {
            let obj = p.eval_obj(&x);
            Some(Solution { status: Status::Optimal, obj, x })
        } else {
            None
        }
    });
    let mut heap: BinaryHeap<Node> = BinaryHeap::new();
    heap.push(Node {
        bound: f64::INFINITY,
        deltas: Vec::new(),
        basis: root_basis.map(|b| Rc::new(b.clone())),
        depth: 0,
    });

    let mut lo_buf = vec![0.0; n];
    let mut up_buf = vec![0.0; n];

    let mut exhausted = true;
    while let Some(node) = heap.pop() {
        if start.elapsed() > budget {
            exhausted = false;
            break;
        }
        if let Some(cap) = opts.max_nodes {
            if stats.nodes >= cap {
                exhausted = false;
                break;
            }
        }
        if let Some(inc) = &incumbent {
            // Prune on absolute or small relative gap: the scheduler does
            // not benefit from the last <0.5% of objective.
            if node.bound <= inc.obj + 1e-9 || node.bound <= inc.obj * (1.0 + REL_GAP_TOL) {
                continue;
            }
        }
        // Reconstruct this node's bounds: root bounds + branch deltas.
        lo_buf.copy_from_slice(&p.lo);
        up_buf.copy_from_slice(&p.up);
        for &(j, is_up, v) in &node.deltas {
            if is_up {
                up_buf[j as usize] = v;
            } else {
                lo_buf[j as usize] = v;
            }
        }
        // Guard against crossed bounds introduced by branching.
        if lo_buf.iter().zip(&up_buf).any(|(l, u)| l > u) {
            continue;
        }
        stats.lp_solves += 1;
        stats.nodes += 1;
        let warm_basis = if opts.warm_basis { node.basis.as_deref() } else { None };
        let warm_before = stats.warm_solves;
        let node_t = Instant::now();
        let (rel, rel_basis) =
            solve_node(p, &mut solver, &lo_buf, &up_buf, warm_basis, &mut stats);
        if node.depth == 0 {
            root_snapshot = rel_basis.clone();
            stats.root_warm = stats.warm_solves > warm_before;
            stats.root_lp_ms = node_t.elapsed().as_secs_f64() * 1e3;
        }
        match rel.status {
            Status::Infeasible => continue,
            Status::Unbounded => {
                // Integer restriction cannot fix an unbounded relaxation
                // in our models (all scheduler vars are bounded); treat as
                // an error status propagated to the caller.
                return (
                    Solution { status: Status::Unbounded, obj: f64::INFINITY, x: vec![] },
                    stats,
                    root_snapshot,
                );
            }
            Status::Optimal | Status::Limit => {}
        }
        if let Some(inc) = &incumbent {
            if rel.obj <= inc.obj + 1e-9 || rel.obj <= inc.obj * (1.0 + REL_GAP_TOL) {
                continue;
            }
        }

        // Find most fractional integer variable.
        let mut branch: Option<(usize, f64)> = None;
        let mut best_frac = INT_TOL;
        for j in 0..n {
            if !p.integer[j] {
                continue;
            }
            let f = (rel.x[j] - rel.x[j].round()).abs();
            if f > best_frac {
                best_frac = f;
                branch = Some((j, rel.x[j]));
            }
        }

        match branch {
            None => {
                // Integer feasible.
                let cand = Solution { status: Status::Optimal, obj: rel.obj, x: rel.x };
                if incumbent.as_ref().map(|i| cand.obj > i.obj).unwrap_or(true) {
                    incumbent = Some(cand);
                }
            }
            Some((j, xj)) => {
                // Rounding heuristic: snap all integer vars and re-check.
                let mut rounded = rel.x.clone();
                for k in 0..n {
                    if p.integer[k] {
                        rounded[k] = rounded[k].round().clamp(p.lo[k], p.up[k]);
                    }
                }
                if p.is_feasible(&rounded, 1e-6) {
                    let obj = p.eval_obj(&rounded);
                    if incumbent.as_ref().map(|i| obj > i.obj).unwrap_or(true) {
                        incumbent = Some(Solution { status: Status::Optimal, obj, x: rounded });
                    }
                }

                // Branch j <= floor, j >= ceil; children share the parent
                // basis (Rc) and extend the delta chain by one entry.
                let (fl, ce) = (xj.floor(), xj.ceil());
                let child_basis = rel_basis.map(Rc::new);
                if lo_buf[j] <= fl {
                    let mut d = node.deltas.clone();
                    d.push((j as u32, true, fl));
                    heap.push(Node {
                        bound: rel.obj,
                        deltas: d,
                        basis: child_basis.clone(),
                        depth: node.depth + 1,
                    });
                }
                if ce <= up_buf[j] {
                    let mut d = node.deltas.clone();
                    d.push((j as u32, false, ce));
                    heap.push(Node {
                        bound: rel.obj,
                        deltas: d,
                        basis: child_basis,
                        depth: node.depth + 1,
                    });
                }
            }
        }
    }

    stats.wall = start.elapsed();
    stats.bnb_ms =
        (stats.wall.as_secs_f64() * 1e3 - stats.build_ms - stats.root_lp_ms).max(0.0);
    match incumbent {
        Some(mut sol) => {
            let bound = heap.peek().map(|n| n.bound).unwrap_or(sol.obj).max(sol.obj);
            stats.gap = if sol.obj.abs() > 1e-12 {
                ((bound - sol.obj) / sol.obj.abs()).max(0.0)
            } else {
                0.0
            };
            sol.status = if exhausted { Status::Optimal } else { Status::Limit };
            (sol, stats, root_snapshot)
        }
        None => (
            Solution {
                status: if exhausted { Status::Infeasible } else { Status::Limit },
                obj: f64::NEG_INFINITY,
                x: vec![],
            },
            stats,
            root_snapshot,
        ),
    }
}

/// Solve one node LP: the revised solver with an optional warm basis,
/// falling back to the dense reference on numerical failure or a point
/// that fails the feasibility re-check.
fn solve_node(
    p: &Problem,
    solver: &mut Option<LpSolver>,
    lo: &[f64],
    up: &[f64],
    warm: Option<&BasisSnapshot>,
    stats: &mut MilpStats,
) -> (Solution, Option<BasisSnapshot>) {
    if let Some(s) = solver.as_mut() {
        if let Some(out) = s.solve(lo, up, warm) {
            let usable = match out.status {
                Status::Optimal | Status::Limit => point_feasible(p, lo, up, &out.x),
                _ => true,
            };
            if usable {
                if out.warm {
                    stats.warm_solves += 1;
                } else {
                    stats.cold_solves += 1;
                }
                stats.pivots += out.pivots;
                stats.phase1_pivots += out.phase1_pivots;
                let basis = out.basis.clone();
                return (outcome_to_solution(p, out), basis);
            }
        }
        stats.dense_fallbacks += 1;
    }
    let mut sub = p.clone();
    sub.lo = lo.to_vec();
    sub.up = up.to_vec();
    let (sol, iters) = simplex::solve_lp_counted(&sub);
    stats.pivots += iters;
    stats.cold_solves += 1;
    (sol, None)
}

/// Defensive feasibility re-check of a revised-solver point against the
/// node bounds and all rows (scale-relative tolerance).  A false
/// negative only costs one dense re-solve, so this errs conservative.
fn point_feasible(p: &Problem, lo: &[f64], up: &[f64], x: &[f64]) -> bool {
    if x.len() != p.n_vars() {
        return false;
    }
    for j in 0..p.n_vars() {
        let tol = 1e-6 * (1.0 + lo[j].abs().min(up[j].abs()));
        if x[j] < lo[j] - tol || x[j] > up[j] + tol {
            return false;
        }
    }
    for row in &p.rows {
        let lhs: f64 = row.coeffs.iter().map(|&(j, c)| c * x[j]).sum();
        let tol = 1e-6 * (1.0 + lhs.abs().max(row.rhs.abs()));
        let ok = match row.cmp {
            Cmp::Le => lhs <= row.rhs + tol,
            Cmp::Ge => lhs >= row.rhs - tol,
            Cmp::Eq => (lhs - row.rhs).abs() <= tol,
        };
        if !ok {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;
    use crate::solver::model::{Cmp, Problem};

    fn budget() -> Duration {
        Duration::from_secs(10)
    }

    #[test]
    fn knapsack_small() {
        // max 10a+13b+7c st 3a+4b+2c<=6, binary -> a=0,b=1,c=1 = 20
        let mut p = Problem::new();
        let a = p.int("a", 0.0, 1.0, 10.0);
        let b = p.int("b", 0.0, 1.0, 13.0);
        let c = p.int("c", 0.0, 1.0, 7.0);
        p.constrain("w", vec![(a, 3.0), (b, 4.0), (c, 2.0)], Cmp::Le, 6.0);
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 20.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn integer_rounding_not_lp_rounding() {
        // Classic: max x+y st -x+y<=0.5, x+y<=3.5 ints -> best (1,1) or (2,1):
        // x=2,y=1 obj 3 ; LP opt is (1.5, 2.0) obj 3.5.
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0, 1.0);
        let y = p.int("y", 0.0, 10.0, 1.0);
        p.constrain("c1", vec![(x, -1.0), (y, 1.0)], Cmp::Le, 0.5);
        p.constrain("c2", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 3.5);
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 3.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn assignment_problem_integral() {
        // 3x3 assignment: maximize total weight; optimal = 5+6+4 = 15
        let w = [[5.0, 1.0, 2.0], [2.0, 6.0, 3.0], [1.0, 2.0, 4.0]];
        let mut p = Problem::new();
        let mut v = vec![];
        for i in 0..3 {
            for j in 0..3 {
                v.push(p.int(&format!("x{i}{j}"), 0.0, 1.0, w[i][j]));
            }
        }
        for i in 0..3 {
            p.constrain(
                &format!("r{i}"),
                (0..3).map(|j| (v[i * 3 + j], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
            p.constrain(
                &format!("c{i}"),
                (0..3).map(|j| (v[j * 3 + i], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
        }
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 15.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn mixed_integer_continuous() {
        // max 2x + 3y, x int <=3.7 bound, y cont, x+2y<=8 -> x=3, y=2.5, obj 13.5
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 3.7, 2.0);
        let y = p.cont("y", 0.0, f64::INFINITY, 3.0);
        p.constrain("c", vec![(x, 1.0), (y, 2.0)], Cmp::Le, 8.0);
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.obj - 13.5).abs() < 1e-6, "{s:?}");
        assert!((s.x[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_milp() {
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0, 1.0);
        p.constrain("a", vec![(x, 2.0)], Cmp::Eq, 3.0); // 2x=3 has no integer solution
        let (s, _) = solve_milp(&p, budget());
        assert_eq!(s.status, Status::Infeasible);
    }

    /// Brute-force optimum over integer grids for small random MILPs.
    fn brute_force(p: &Problem, maxv: i64) -> Option<f64> {
        let n = p.n_vars();
        let mut best: Option<f64> = None;
        let mut x = vec![0.0; n];
        fn rec(p: &Problem, x: &mut Vec<f64>, j: usize, maxv: i64, best: &mut Option<f64>) {
            if j == p.n_vars() {
                if p.is_feasible(x, 1e-9) {
                    let o = p.eval_obj(x);
                    if best.map(|b| o > b).unwrap_or(true) {
                        *best = Some(o);
                    }
                }
                return;
            }
            let hi = p.up[j].min(maxv as f64) as i64;
            let lo = p.lo[j].max(0.0) as i64;
            for v in lo..=hi {
                x[j] = v as f64;
                rec(p, x, j + 1, maxv, best);
            }
        }
        rec(p, &mut x, 0, maxv, &mut best);
        best
    }

    #[test]
    fn random_milps_match_brute_force() {
        let mut rng = Rng::new(4242);
        for case in 0..40 {
            let nv = 2 + rng.below(3); // 2..4 int vars
            let nc = 1 + rng.below(3);
            let mut p = Problem::new();
            let vars: Vec<_> = (0..nv)
                .map(|i| p.int(&format!("v{i}"), 0.0, 4.0, rng.uniform(-3.0, 5.0)))
                .collect();
            for c in 0..nc {
                let coeffs: Vec<_> = vars
                    .iter()
                    .map(|&v| (v, rng.uniform(-1.0, 3.0)))
                    .collect();
                p.constrain(&format!("c{c}"), coeffs, Cmp::Le, rng.uniform(2.0, 12.0));
            }
            let (s, _) = solve_milp(&p, budget());
            let bf = brute_force(&p, 4);
            match bf {
                None => assert_eq!(s.status, Status::Infeasible, "case {case}"),
                Some(opt) => {
                    assert_eq!(s.status, Status::Optimal, "case {case}");
                    assert!(
                        (s.obj - opt).abs() < 1e-6,
                        "case {case}: milp {} vs brute {}",
                        s.obj,
                        opt
                    );
                    assert!(p.is_feasible(&s.x, 1e-6), "case {case}");
                }
            }
        }
    }

    /// The warm-started revised backend and the dense baseline must agree
    /// on every random MILP (status; objective within the B&B pruning
    /// gap; feasible points) — the solver-parity satellite, unit flavor.
    #[test]
    fn warm_and_dense_backends_agree_on_random_milps() {
        let dense = MilpOptions {
            backend: LpBackend::Dense,
            warm_basis: false,
            max_nodes: None,
        };
        let mut rng = Rng::new(1717);
        for case in 0..30 {
            let nv = 2 + rng.below(4);
            let mut p = Problem::new();
            let vars: Vec<_> = (0..nv)
                .map(|i| {
                    if i % 2 == 0 {
                        p.int(&format!("v{i}"), 0.0, 6.0, rng.uniform(-2.0, 4.0))
                    } else {
                        p.cont(&format!("v{i}"), 0.0, rng.uniform(2.0, 8.0), rng.uniform(-1.0, 3.0))
                    }
                })
                .collect();
            let le: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.2, 2.0))).collect();
            p.constrain("le", le, Cmp::Le, rng.uniform(3.0, 15.0));
            if case % 3 == 0 {
                let ge: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.2, 1.0))).collect();
                p.constrain("ge", ge, Cmp::Ge, rng.uniform(0.2, 1.5));
            }
            let (sw, stw, _) = solve_milp_opts(&p, budget(), None, None, &MilpOptions::default());
            let (sd, _, _) = solve_milp_opts(&p, budget(), None, None, &dense);
            assert_eq!(sw.status, sd.status, "case {case}");
            if sw.status == Status::Optimal {
                let tol = 1e-6 + 2.0 * REL_GAP_TOL * sd.obj.abs();
                assert!(
                    (sw.obj - sd.obj).abs() <= tol,
                    "case {case}: warm {} vs dense {}",
                    sw.obj,
                    sd.obj
                );
                assert!(p.is_feasible(&sw.x, 1e-5), "case {case}: warm point");
                assert!(p.is_feasible(&sd.x, 1e-5), "case {case}: dense point");
                // The revised backend must not silently live off the
                // dense fallback.
                assert!(
                    stw.dense_fallbacks <= stw.lp_solves / 2,
                    "case {case}: {} fallbacks / {} solves",
                    stw.dense_fallbacks,
                    stw.lp_solves
                );
            }
        }
    }

    /// Children actually inherit bases: a branchy instance must report
    /// warm-started node LPs, and a cached root basis must warm round 2.
    #[test]
    fn warm_starts_are_taken() {
        // An assignment-like instance with a fractional LP optimum.
        let w = [[5.0, 4.9, 2.0], [4.8, 5.0, 3.0], [1.0, 2.0, 4.1]];
        let mut p = Problem::new();
        let mut v = vec![];
        for i in 0..3 {
            for j in 0..3 {
                v.push(p.int(&format!("x{i}{j}"), 0.0, 1.0, w[i][j]));
            }
        }
        for i in 0..3 {
            p.constrain(
                &format!("r{i}"),
                (0..3).map(|j| (v[i * 3 + j], 1.0)).collect(),
                Cmp::Le,
                1.0,
            );
            p.constrain(
                &format!("c{i}"),
                (0..3).map(|j| (v[j * 3 + i], 1.0)).collect(),
                Cmp::Le,
                1.0,
            );
        }
        // Couple rows so the relaxation is fractional enough to branch.
        p.constrain(
            "budget",
            v.iter().map(|&x| (x, 1.0)).collect(),
            Cmp::Le,
            2.5,
        );
        let (s, stats, root) =
            solve_milp_opts(&p, budget(), None, None, &MilpOptions::default());
        assert_eq!(s.status, Status::Optimal);
        if stats.nodes > 1 {
            assert!(
                stats.warm_solves > 0,
                "children must warm start: {stats:?}"
            );
        }
        let root = root.expect("root basis returned for caching");
        // Round 2 from the cached basis: the root LP itself is warm.
        let (s2, stats2, _) =
            solve_milp_opts(&p, budget(), None, Some(&root), &MilpOptions::default());
        assert_eq!(s2.status, Status::Optimal);
        assert!((s2.obj - s.obj).abs() < 1e-6);
        assert!(stats2.warm_solves > 0, "cached root must warm start: {stats2:?}");
        assert!(stats2.root_warm, "root warm flag must be set: {stats2:?}");
        assert!(stats2.warm_hit_rate() > 0.0);
    }
}
