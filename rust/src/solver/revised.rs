//! Sparse revised simplex with bounded variables — the LP core behind
//! `solve_lp` and branch & bound.
//!
//! The Trident MILP's constraint matrix is ~95% zeros (capacity and
//! egress rows touch only co-located variables), so the matrix lives in a
//! CSC-style column store and every pivot does work proportional to
//! nonzeros plus one O(m²) explicit-inverse update — not the O(m·n) dense
//! row elimination of the old tableau.  Two solve modes share the basis
//! machinery:
//!
//! * **primal** (Dantzig pricing, bounded-variable ratio test with bound
//!   flips, Bland fallback against cycling) — phase 2 and post-restore
//!   cleanup;
//! * **dual** (max-violation row, bounded dual ratio test) — the warm
//!   restart workhorse: a branch-and-bound child inherits its parent's
//!   optimal basis, whose reduced costs stay dual feasible after a bound
//!   change, so a handful of dual pivots re-optimize what a cold solve
//!   pays a full two-phase run for.  With a zero cost vector the same
//!   loop is a feasibility restorer (reduced costs identically zero are
//!   trivially dual feasible), which is how cold solves and cross-round
//!   cached bases reach primal feasibility without artificial variables.
//!
//! Logical (slack) variables close the formulation: row `a·x + s = rhs`
//! with `s ∈ [0, ∞)` for `Le`, `s ∈ (-∞, 0]` for `Ge`, `s ∈ [0, 0]` for
//! `Eq`.  The all-logical basis is the identity, so a cold start never
//! factorizes.  Numerical failures (singular warm basis, zero pivots,
//! iteration caps) are reported as `None` and the caller falls back to
//! the dense two-phase solver (`simplex.rs`), which stays the reference
//! implementation — parity is pinned by the unit suite here and by
//! `tests/solver_parity.rs`.

use super::model::{Cmp, Problem, Solution, Status};

const EPS: f64 = 1e-9;
/// Reduced-cost (dual feasibility) tolerance.
const DUAL_TOL: f64 = 1e-7;
/// Bound-violation (primal feasibility) tolerance.
const PRIMAL_TOL: f64 = 1e-7;
/// Smallest acceptable pivot magnitude.
const PIVOT_TOL: f64 = 1e-8;
/// Hard per-loop iteration cap (failure, not `Status::Limit`: the caller
/// falls back to the dense solver so results never degrade).
const MAX_ITERS: usize = 200_000;
/// Refactorize the explicit inverse every this many pivots.
const REFACTOR_EVERY: usize = 120;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum VStat {
    Lower,
    Upper,
    Basic,
}

/// A saved basis: which variable sits in each row plus every variable's
/// nonbasic side.  Compact (one `u32` per row, one byte per column), so
/// branch-and-bound nodes and the cross-round cache share them freely.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BasisSnapshot {
    basis: Vec<u32>,
    stat: Vec<u8>, // 0 = Lower, 1 = Upper, 2 = Basic
}

impl BasisSnapshot {
    /// Map this snapshot onto a problem whose variable/row sets differ,
    /// matching columns and rows **by name** — the *restricted warm
    /// start* behind topology changes in the scheduling layer.  A node
    /// failure removes that node's columns and rows from the MILP; the
    /// cached basis is repaired instead of discarded: surviving basic
    /// columns keep their rows, a row whose basic column vanished prices
    /// it out and seats its own logical, fresh columns rest nonbasic on a
    /// finite bound, and fresh rows start with a basic logical.  The
    /// repaired basis is usually primal-infeasible; the dual simplex
    /// restores feasibility in a few pivots — and if the restriction
    /// turns out singular, `LpSolver::solve` rejects it and falls back to
    /// a cold start, so a bad repair can never degrade results.
    ///
    /// Returns `None` when fewer than half of the new rows carry a
    /// surviving basic column (the repair would be no better than cold).
    pub fn remap_to(
        &self,
        old_vars: &[String],
        old_rows: &[String],
        p: &Problem,
    ) -> Option<BasisSnapshot> {
        use std::collections::HashMap;
        let ns_old = old_vars.len();
        let m_old = old_rows.len();
        if self.basis.len() != m_old || self.stat.len() != ns_old + m_old {
            return None;
        }
        let ns_new = p.n_vars();
        let m_new = p.rows.len();
        if m_new == 0 {
            return None;
        }
        let new_var_idx: HashMap<&str, usize> =
            p.names.iter().enumerate().map(|(j, n)| (n.as_str(), j)).collect();
        let new_row_idx: HashMap<&str, usize> =
            p.rows.iter().enumerate().map(|(i, r)| (r.name.as_str(), i)).collect();
        let old_var_idx: HashMap<&str, usize> =
            old_vars.iter().enumerate().map(|(j, n)| (n.as_str(), j)).collect();
        let old_row_idx: HashMap<&str, usize> =
            old_rows.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
        // Old column (structural or logical) → new column, by name.
        let old_to_new = |j: usize| -> Option<usize> {
            if j < ns_old {
                new_var_idx.get(old_vars[j].as_str()).copied()
            } else {
                new_row_idx.get(old_rows[j - ns_old].as_str()).map(|&i| ns_new + i)
            }
        };
        // Nonbasic resting sides: carried structural columns keep their
        // old side (validity-checked against the new bounds), fresh ones
        // rest on a finite bound; logicals sit at 0 on the side their
        // comparison admits.
        let mut stat = vec![0u8; ns_new + m_new];
        for (j, name) in p.names.iter().enumerate() {
            let old_side = old_var_idx.get(name.as_str()).map(|&oj| self.stat[oj]);
            stat[j] = match old_side {
                Some(1) if p.up[j].is_finite() => 1,
                Some(0) | Some(1) | Some(2) | None if p.lo[j].is_finite() => 0,
                _ if p.up[j].is_finite() => 1,
                _ => 0,
            };
        }
        for (i, row) in p.rows.iter().enumerate() {
            stat[ns_new + i] = match row.cmp {
                Cmp::Ge => 1,
                _ => 0,
            };
        }
        // Seat basic columns: surviving old basics keep their (renamed)
        // rows; everything else prices out to the row's own logical.
        let mut basis: Vec<Option<usize>> = vec![None; m_new];
        let mut used = vec![false; ns_new + m_new];
        let mut matched = 0usize;
        for (i, row) in p.rows.iter().enumerate() {
            if let Some(&oi) = old_row_idx.get(row.name.as_str()) {
                if let Some(nb) = old_to_new(self.basis[oi] as usize) {
                    if !used[nb] {
                        used[nb] = true;
                        basis[i] = Some(nb);
                        matched += 1;
                    }
                }
            }
        }
        if matched * 2 < m_new {
            return None;
        }
        for (i, b) in basis.iter_mut().enumerate() {
            if b.is_none() {
                let slack = ns_new + i;
                if used[slack] {
                    // A degenerate old basis seated this logical in a
                    // different row; repairing that is not worth it.
                    return None;
                }
                used[slack] = true;
                *b = Some(slack);
            }
        }
        let basis: Vec<u32> = basis.into_iter().map(|b| b.unwrap() as u32).collect();
        for &b in &basis {
            stat[b as usize] = 2;
        }
        Some(BasisSnapshot { basis, stat })
    }
}

/// Result of one LP solve through [`LpSolver`].
#[derive(Debug, Clone)]
pub struct LpOutcome {
    pub status: Status,
    pub obj: f64,
    /// Structural variable values (empty when infeasible/unbounded).
    pub x: Vec<f64>,
    /// Final basis for warm-starting descendants (optimal solves only).
    pub basis: Option<BasisSnapshot>,
    pub pivots: usize,
    /// Pivots spent restoring primal feasibility (phase 1 equivalent).
    pub phase1_pivots: usize,
    /// True when the solve started from a caller-provided basis.
    pub warm: bool,
    /// Row duals y = c_B B⁻¹ at the final basis (optimal solves only;
    /// empty otherwise).  One entry per problem row, in row order — the
    /// price the objective pays per unit of that row's RHS, which is what
    /// Dantzig–Wolfe pricing charges subproblems for coupling-row usage.
    pub duals: Vec<f64>,
}

/// Reusable solve context: the sparse column store is built once per
/// `Problem` shape; `solve` is then called per bound set (every B&B node
/// re-uses the store, and the scheduling layer re-uses it across rounds
/// via [`BasisSnapshot`]s).
pub struct LpSolver {
    m: usize,
    ns: usize,
    n: usize, // ns structural + m logical
    cols: Vec<Vec<(u32, f64)>>,
    rhs: Vec<f64>,
    obj: Vec<f64>,
    log_lo: Vec<f64>,
    log_up: Vec<f64>,
    // Working state (valid between solves; `basis_current` says whether
    // `binv` matches `basis`, letting a child that continues its parent's
    // basis skip the O(m³) refactorization).
    lo: Vec<f64>,
    up: Vec<f64>,
    basis: Vec<usize>,
    stat: Vec<VStat>,
    binv: Vec<f64>, // m × m row-major
    xb: Vec<f64>,
    rc: Vec<f64>,
    binv_current: bool,
    pivots_since_factor: usize,
}

impl LpSolver {
    /// Build the sparse column store for `p`.  Bounds are *not* baked in:
    /// they are inputs to [`LpSolver::solve`], which is what makes B&B
    /// bound changes free.
    pub fn new(p: &Problem) -> LpSolver {
        let ns = p.n_vars();
        let m = p.rows.len();
        let n = ns + m;
        let mut cols: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
        let mut rhs = vec![0.0; m];
        let mut log_lo = vec![0.0; m];
        let mut log_up = vec![0.0; m];
        for (i, row) in p.rows.iter().enumerate() {
            rhs[i] = row.rhs;
            for &(j, c) in &row.coeffs {
                if c != 0.0 {
                    cols[j].push((i as u32, c));
                }
            }
            cols[ns + i].push((i as u32, 1.0));
            let (l, u) = match row.cmp {
                Cmp::Le => (0.0, f64::INFINITY),
                Cmp::Ge => (f64::NEG_INFINITY, 0.0),
                Cmp::Eq => (0.0, 0.0),
            };
            log_lo[i] = l;
            log_up[i] = u;
        }
        let mut obj = vec![0.0; n];
        obj[..ns].copy_from_slice(&p.obj);
        LpSolver {
            m,
            ns,
            n,
            cols,
            rhs,
            obj,
            log_lo,
            log_up,
            lo: vec![0.0; n],
            up: vec![0.0; n],
            basis: Vec::new(),
            stat: Vec::new(),
            binv: vec![0.0; m * m],
            xb: vec![0.0; m],
            rc: vec![0.0; n],
            binv_current: false,
            pivots_since_factor: 0,
        }
    }

    pub fn n_struct(&self) -> usize {
        self.ns
    }

    /// Solve with structural bounds `lo/up` (length `n_struct`), warm
    /// starting from `warm` when given.  `None` signals a numerical
    /// failure — the caller should fall back to the dense solver; LP
    /// status outcomes (optimal / infeasible / unbounded / limit) are all
    /// `Some`.
    pub fn solve(
        &mut self,
        lo_s: &[f64],
        up_s: &[f64],
        warm: Option<&BasisSnapshot>,
    ) -> Option<LpOutcome> {
        debug_assert_eq!(lo_s.len(), self.ns);
        self.lo[..self.ns].copy_from_slice(lo_s);
        self.up[..self.ns].copy_from_slice(up_s);
        self.lo[self.ns..].copy_from_slice(&self.log_lo);
        self.up[self.ns..].copy_from_slice(&self.log_up);

        if let Some(snap) = warm {
            if snap.basis.len() == self.m && snap.stat.len() == self.n {
                if let Some(out) = self.attempt(Some(snap)) {
                    return Some(out);
                }
            }
        }
        // Cold attempt (all-logical basis).
        self.attempt(None)
    }

    /// One solve attempt from a given (or the all-logical) basis.
    fn attempt(&mut self, snap: Option<&BasisSnapshot>) -> Option<LpOutcome> {
        let warm = snap.is_some();
        match snap {
            Some(s) => {
                // Skip the O(m³) refactorization when the requested basis
                // is the one the inverse already represents (the common
                // parent→child case in best-first B&B).
                let same = self.binv_current
                    && self.basis.len() == self.m
                    && self.stat.len() == self.n
                    && self
                        .basis
                        .iter()
                        .zip(&s.basis)
                        .all(|(&a, &b)| a == b as usize)
                    && self
                        .stat
                        .iter()
                        .zip(&s.stat)
                        .all(|(&a, &b)| a as u8 == b);
                if !same {
                    self.basis = s.basis.iter().map(|&v| v as usize).collect();
                    self.stat = s
                        .stat
                        .iter()
                        .map(|&v| match v {
                            0 => VStat::Lower,
                            1 => VStat::Upper,
                            _ => VStat::Basic,
                        })
                        .collect();
                    if !self.factorize() {
                        self.binv_current = false;
                        return None;
                    }
                }
                // A nonbasic variable resting on an infinite bound (only
                // possible if bounds changed side) would poison xb.
                for j in 0..self.n {
                    if self.stat[j] != VStat::Basic && !self.nb_val(j).is_finite() {
                        self.binv_current = false;
                        return None;
                    }
                }
            }
            None => {
                self.basis = (self.ns..self.n).collect();
                self.stat = vec![VStat::Lower; self.n];
                for j in 0..self.n {
                    if self.stat_default_upper(j) {
                        self.stat[j] = VStat::Upper;
                    }
                }
                for i in 0..self.m {
                    self.stat[self.ns + i] = VStat::Basic;
                }
                // B = I: the inverse is the identity.
                self.binv.fill(0.0);
                for i in 0..self.m {
                    self.binv[i * self.m + i] = 1.0;
                }
                self.pivots_since_factor = 0;
            }
        }
        self.binv_current = true;
        self.compute_xb();
        self.price();

        let mut pivots = 0usize;
        let mut phase1 = 0usize;

        // ---- restore primal feasibility -------------------------------
        if self.max_violation().is_some() {
            let dual_ok = self.dual_feasible();
            let status = self.dual_loop(!dual_ok, &mut pivots)?;
            phase1 = pivots;
            if status == Status::Infeasible {
                return Some(LpOutcome {
                    status: Status::Infeasible,
                    obj: f64::NEG_INFINITY,
                    x: Vec::new(),
                    basis: None,
                    pivots,
                    phase1_pivots: phase1,
                    warm,
                    duals: Vec::new(),
                });
            }
            // Reduced costs after a zero-cost restore are for the zero
            // objective; re-price for the real one.
            self.price();
        }

        // ---- primal optimization --------------------------------------
        let status = self.primal_loop(&mut pivots)?;
        if status == Status::Unbounded {
            return Some(LpOutcome {
                status: Status::Unbounded,
                obj: f64::INFINITY,
                x: Vec::new(),
                basis: None,
                pivots,
                phase1_pivots: phase1,
                warm,
                duals: Vec::new(),
            });
        }

        // Drift check: recompute basic values from scratch; a basis this
        // far out of bounds means the inverse has degraded — refactorize
        // and polish once.
        self.compute_xb();
        if status == Status::Optimal && self.max_violation().is_some() {
            if !self.factorize() {
                return None;
            }
            self.compute_xb();
            self.price();
            if self.max_violation().is_some() {
                self.dual_loop(!self.dual_feasible(), &mut pivots)?;
                self.price();
            }
            self.primal_loop(&mut pivots)?;
            self.compute_xb();
        }

        let x = self.extract_x();
        let obj = self.obj[..self.ns]
            .iter()
            .zip(&x)
            .map(|(c, v)| c * v)
            .sum();
        let basis = (status == Status::Optimal).then(|| self.snapshot());
        let duals = if status == Status::Optimal {
            self.compute_duals()
        } else {
            Vec::new()
        };
        Some(LpOutcome {
            status,
            obj,
            x,
            basis,
            pivots,
            phase1_pivots: phase1,
            warm,
            duals,
        })
    }

    /// y = c_B B⁻¹ at the current basis — the same vector `price`
    /// forms internally, exposed for column-generation callers.
    fn compute_duals(&self) -> Vec<f64> {
        let m = self.m;
        let mut y = vec![0.0; m];
        for r in 0..m {
            let cb = self.obj[self.basis[r]];
            if cb != 0.0 {
                let row = &self.binv[r * m..(r + 1) * m];
                for (yi, &bv) in y.iter_mut().zip(row) {
                    *yi += cb * bv;
                }
            }
        }
        y
    }

    fn snapshot(&self) -> BasisSnapshot {
        BasisSnapshot {
            basis: self.basis.iter().map(|&v| v as u32).collect(),
            stat: self
                .stat
                .iter()
                .map(|&s| match s {
                    VStat::Lower => 0,
                    VStat::Upper => 1,
                    VStat::Basic => 2,
                })
                .collect(),
        }
    }

    /// A variable with no finite lower bound must rest at its upper one.
    fn stat_default_upper(&self, j: usize) -> bool {
        !self.lo[j].is_finite() && self.up[j].is_finite()
    }

    /// Value of a nonbasic variable (free variables rest at 0).
    fn nb_val(&self, j: usize) -> f64 {
        let b = match self.stat[j] {
            VStat::Lower => self.lo[j],
            VStat::Upper => self.up[j],
            VStat::Basic => unreachable!("nb_val of a basic variable"),
        };
        if b.is_finite() {
            b
        } else if self.lo[j].is_finite() {
            self.lo[j]
        } else if self.up[j].is_finite() {
            self.up[j]
        } else {
            0.0
        }
    }

    /// Rebuild the explicit inverse from the basis columns (Gauss-Jordan
    /// with partial pivoting).  False on a (near-)singular basis.
    fn factorize(&mut self) -> bool {
        let m = self.m;
        if m == 0 {
            self.pivots_since_factor = 0;
            return true;
        }
        let w = 2 * m;
        let mut aug = vec![0.0; m * w];
        for (r, &j) in self.basis.iter().enumerate() {
            for &(i, v) in &self.cols[j] {
                aug[i as usize * w + r] = v;
            }
        }
        for i in 0..m {
            aug[i * w + m + i] = 1.0;
        }
        for c in 0..m {
            let mut piv_row = c;
            let mut best = aug[c * w + c].abs();
            for r in (c + 1)..m {
                let a = aug[r * w + c].abs();
                if a > best {
                    best = a;
                    piv_row = r;
                }
            }
            if best < 1e-11 {
                return false;
            }
            if piv_row != c {
                for k in 0..w {
                    aug.swap(piv_row * w + k, c * w + k);
                }
            }
            let inv = 1.0 / aug[c * w + c];
            for k in 0..w {
                aug[c * w + k] *= inv;
            }
            for r in 0..m {
                if r == c {
                    continue;
                }
                let f = aug[r * w + c];
                if f.abs() > 1e-14 {
                    for k in 0..w {
                        let v = aug[c * w + k];
                        aug[r * w + k] -= f * v;
                    }
                    aug[r * w + c] = 0.0;
                }
            }
        }
        for r in 0..m {
            self.binv[r * m..(r + 1) * m].copy_from_slice(&aug[r * w + m..r * w + w]);
        }
        self.pivots_since_factor = 0;
        true
    }

    /// xb = B⁻¹ (rhs − N x_N).
    fn compute_xb(&mut self) {
        let m = self.m;
        let mut b = self.rhs.clone();
        for j in 0..self.n {
            if self.stat[j] == VStat::Basic {
                continue;
            }
            let v = self.nb_val(j);
            if v != 0.0 {
                for &(i, a) in &self.cols[j] {
                    b[i as usize] -= a * v;
                }
            }
        }
        for r in 0..m {
            let row = &self.binv[r * m..(r + 1) * m];
            self.xb[r] = row.iter().zip(&b).map(|(x, y)| x * y).sum();
        }
    }

    /// Reduced costs rc = c − (c_B B⁻¹) A for the real objective.
    fn price(&mut self) {
        let m = self.m;
        let mut y = vec![0.0; m];
        for r in 0..m {
            let cb = self.obj[self.basis[r]];
            if cb != 0.0 {
                let row = &self.binv[r * m..(r + 1) * m];
                for (yi, &bv) in y.iter_mut().zip(row) {
                    *yi += cb * bv;
                }
            }
        }
        for j in 0..self.n {
            if self.stat[j] == VStat::Basic {
                self.rc[j] = 0.0;
                continue;
            }
            let mut v = self.obj[j];
            for &(i, a) in &self.cols[j] {
                v -= y[i as usize] * a;
            }
            self.rc[j] = v;
        }
    }

    /// Maximization dual feasibility: rc ≤ tol at lower, rc ≥ −tol at
    /// upper (range-0 variables are feasible on either side).
    fn dual_feasible(&self) -> bool {
        for j in 0..self.n {
            let fixed = self.up[j] - self.lo[j] <= EPS;
            match self.stat[j] {
                VStat::Basic => {}
                VStat::Lower => {
                    if self.rc[j] > DUAL_TOL && !fixed {
                        return false;
                    }
                }
                VStat::Upper => {
                    if self.rc[j] < -DUAL_TOL && !fixed {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Worst bound violation among basic variables: (row, signed size)
    /// where positive means below lower.
    fn max_violation(&self) -> Option<(usize, f64)> {
        let mut worst: Option<(usize, f64)> = None;
        for r in 0..self.m {
            let j = self.basis[r];
            let below = self.lo[j] - self.xb[r];
            let above = self.xb[r] - self.up[j];
            let v = below.max(above);
            if v > PRIMAL_TOL && worst.map(|(_, w)| v > w).unwrap_or(true) {
                worst = Some((r, v));
            }
        }
        worst
    }

    /// w = B⁻¹ a_q.
    fn ftran(&self, q: usize, out: &mut Vec<f64>) {
        let m = self.m;
        out.clear();
        out.resize(m, 0.0);
        for &(i, a) in &self.cols[q] {
            let ci = i as usize;
            for r in 0..m {
                out[r] += a * self.binv[r * m + ci];
            }
        }
    }

    /// B⁻¹ ← E_r B⁻¹ after `q` entered the basis in row `r` with pivot
    /// column `w` (= B⁻¹ a_q).
    fn update_binv(&mut self, r: usize, w: &[f64]) {
        let m = self.m;
        let inv = 1.0 / w[r];
        let (before, rest) = self.binv.split_at_mut(r * m);
        let (row_r, after) = rest.split_at_mut(m);
        for v in row_r.iter_mut() {
            *v *= inv;
        }
        for (i, chunk) in before.chunks_exact_mut(m).enumerate() {
            let f = w[i];
            if f.abs() > 1e-14 {
                for (x, &pr) in chunk.iter_mut().zip(row_r.iter()) {
                    *x -= f * pr;
                }
            }
        }
        for (k, chunk) in after.chunks_exact_mut(m).enumerate() {
            let f = w[r + 1 + k];
            if f.abs() > 1e-14 {
                for (x, &pr) in chunk.iter_mut().zip(row_r.iter()) {
                    *x -= f * pr;
                }
            }
        }
        self.pivots_since_factor += 1;
    }

    fn maybe_refactor(&mut self) -> Option<()> {
        if self.pivots_since_factor >= REFACTOR_EVERY {
            if !self.factorize() {
                self.binv_current = false;
                return None;
            }
            self.compute_xb();
            self.price();
        }
        Some(())
    }

    /// Primal simplex on the real objective from a primal-feasible basis.
    /// `Some(status)` is Optimal / Unbounded / Limit; `None` = numerical
    /// failure.
    fn primal_loop(&mut self, pivots: &mut usize) -> Option<Status> {
        let bland_after = 20 * (self.m + self.n);
        let mut iters = 0usize;
        let mut degenerate_retries = 0u32;
        let mut w: Vec<f64> = Vec::new();
        loop {
            if iters > MAX_ITERS {
                return Some(Status::Limit);
            }
            let bland = iters > bland_after;
            iters += 1;

            // Entering variable.
            let mut enter: Option<(usize, f64)> = None;
            let mut best = DUAL_TOL;
            for j in 0..self.n {
                if self.up[j] - self.lo[j] <= EPS {
                    continue; // fixed: cannot move
                }
                let (dir, score) = match self.stat[j] {
                    VStat::Basic => continue,
                    VStat::Lower => (1.0, self.rc[j]),
                    VStat::Upper => (-1.0, -self.rc[j]),
                };
                if score > best {
                    enter = Some((j, dir));
                    if bland {
                        break;
                    }
                    best = score;
                }
            }
            let Some((q, dir)) = enter else {
                return Some(Status::Optimal);
            };

            self.ftran(q, &mut w);

            // Bounded ratio test: x_q moves by t·dir, basics by −t·dir·w.
            let range_q = self.up[q] - self.lo[q];
            let mut t_max = if range_q.is_finite() { range_q } else { f64::INFINITY };
            let mut leave: Option<(usize, VStat)> = None;
            for r in 0..self.m {
                let d = dir * w[r];
                let bi = self.basis[r];
                if d > EPS {
                    if self.lo[bi].is_finite() {
                        let t = (self.xb[r] - self.lo[bi]) / d;
                        if t < t_max - EPS
                            || (t < t_max + EPS
                                && leave
                                    .map(|(lr, _)| w[lr].abs() < w[r].abs())
                                    .unwrap_or(true))
                        {
                            t_max = t.max(0.0);
                            leave = Some((r, VStat::Lower));
                        }
                    }
                } else if d < -EPS && self.up[bi].is_finite() {
                    let t = (self.up[bi] - self.xb[r]) / (-d);
                    if t < t_max - EPS
                        || (t < t_max + EPS
                            && leave
                                .map(|(lr, _)| w[lr].abs() < w[r].abs())
                                .unwrap_or(true))
                    {
                        t_max = t.max(0.0);
                        leave = Some((r, VStat::Upper));
                    }
                }
            }
            if t_max.is_infinite() {
                return Some(Status::Unbounded);
            }
            let t = t_max;

            match leave {
                None => {
                    // Bound flip.
                    for r in 0..self.m {
                        self.xb[r] -= t * dir * w[r];
                    }
                    self.stat[q] = if dir > 0.0 { VStat::Upper } else { VStat::Lower };
                }
                Some((r, to)) => {
                    if w[r].abs() < PIVOT_TOL {
                        // Degenerate pivot element: refactorize and retry
                        // a bounded number of times, else give up to the
                        // dense fallback (an unbounded retry would re-pay
                        // the O(m³) factorization on every pass).
                        degenerate_retries += 1;
                        if degenerate_retries > 2 || !self.factorize() {
                            self.binv_current = false;
                            return None;
                        }
                        self.compute_xb();
                        self.price();
                        continue;
                    }
                    degenerate_retries = 0;
                    let new_val = self.nb_val(q) + t * dir;
                    let leaving = self.basis[r];
                    for i in 0..self.m {
                        self.xb[i] -= t * dir * w[i];
                    }
                    self.stat[leaving] = to;
                    self.stat[q] = VStat::Basic;
                    self.basis[r] = q;
                    self.xb[r] = new_val;
                    self.update_binv(r, &w);
                    *pivots += 1;
                    self.price();
                    self.maybe_refactor()?;
                }
            }
        }
    }

    /// Dual simplex until primal feasible.  With `zero_cost` the reduced
    /// costs are treated as identically zero (trivially dual feasible) —
    /// the feasibility-restoration mode; otherwise `self.rc` must be dual
    /// feasible for the real objective (warm restart after bound
    /// changes).  `Some(Optimal)` = primal feasible; `Some(Infeasible)` =
    /// certified infeasible; `None` = numerical failure / stall.
    fn dual_loop(&mut self, zero_cost: bool, pivots: &mut usize) -> Option<Status> {
        let bland_after = 20 * (self.m + self.n);
        let mut iters = 0usize;
        let mut degenerate_retries = 0u32;
        let mut w: Vec<f64> = Vec::new();
        let mut rho: Vec<f64> = Vec::new();
        loop {
            if iters > MAX_ITERS {
                return None;
            }
            let bland = iters > bland_after;
            iters += 1;

            // Leaving row: worst violation (Bland: lowest row index).
            let leaving = if bland {
                (0..self.m).find(|&r| {
                    let j = self.basis[r];
                    self.lo[j] - self.xb[r] > PRIMAL_TOL || self.xb[r] - self.up[j] > PRIMAL_TOL
                })
            } else {
                self.max_violation().map(|(r, _)| r)
            };
            let Some(r) = leaving else {
                return Some(Status::Optimal);
            };
            let bl = self.basis[r];
            let below = self.xb[r] < self.lo[bl];
            let target = if below { self.lo[bl] } else { self.up[bl] };

            // Row r of B⁻¹A over nonbasic columns.
            rho.clear();
            rho.extend_from_slice(&self.binv[r * self.m..(r + 1) * self.m]);
            // Entering candidate: min |rc|/|α| over the sign-eligible set
            // (zero-cost mode: all ratios are 0 — pick the largest |α|).
            // Two tiers: a fixed (lo == up) column — an Eq-row slack —
            // entering the basis necessarily leaves its bound, creating a
            // fresh violation to repair, so prefer any movable column and
            // fall back to fixed ones only when nothing else is eligible
            // (excluding them outright would break the infeasibility
            // certificate below).
            let mut best: Option<(usize, f64, f64)> = None; // (col, alpha, ratio)
            let mut best_fixed: Option<(usize, f64, f64)> = None;
            for j in 0..self.n {
                if self.stat[j] == VStat::Basic {
                    continue;
                }
                let mut alpha = 0.0;
                for &(i, a) in &self.cols[j] {
                    alpha += rho[i as usize] * a;
                }
                let eligible = if below {
                    (self.stat[j] == VStat::Lower && alpha < -PIVOT_TOL)
                        || (self.stat[j] == VStat::Upper && alpha > PIVOT_TOL)
                } else {
                    (self.stat[j] == VStat::Lower && alpha > PIVOT_TOL)
                        || (self.stat[j] == VStat::Upper && alpha < -PIVOT_TOL)
                };
                if !eligible {
                    continue;
                }
                let fixed = self.up[j] - self.lo[j] <= EPS;
                if bland && !fixed {
                    best = Some((j, alpha, 0.0));
                    break;
                }
                let ratio = if zero_cost || bland {
                    0.0
                } else {
                    (self.rc[j].abs() / alpha.abs()).max(0.0)
                };
                let slot = if fixed { &mut best_fixed } else { &mut best };
                let better = match *slot {
                    None => true,
                    Some((_, ba, br)) => {
                        ratio < br - 1e-12 || (ratio < br + 1e-12 && alpha.abs() > ba.abs())
                    }
                };
                if better {
                    *slot = Some((j, alpha, ratio));
                }
            }
            let Some((q, alpha_rq, _)) = best.or(best_fixed) else {
                // No column can repair the row: primal infeasible.
                return Some(Status::Infeasible);
            };

            self.ftran(q, &mut w);
            // Recompute the pivot from the fresh FTRAN (more accurate
            // than the row product); bail out if it collapsed.
            let piv = w[r];
            if piv.abs() < PIVOT_TOL || piv.signum() != alpha_rq.signum() {
                degenerate_retries += 1;
                if degenerate_retries > 2 || !self.factorize() {
                    self.binv_current = false;
                    return None;
                }
                self.compute_xb();
                self.price();
                continue;
            }
            degenerate_retries = 0;
            let t = (self.xb[r] - target) / piv;
            let new_val = self.nb_val(q) + t;
            for i in 0..self.m {
                self.xb[i] -= t * w[i];
            }
            self.stat[bl] = if below { VStat::Lower } else { VStat::Upper };
            self.stat[q] = VStat::Basic;
            self.basis[r] = q;
            self.xb[r] = new_val;
            self.update_binv(r, &w);
            *pivots += 1;
            self.price();
            self.maybe_refactor()?;
        }
    }

    /// Structural solution vector from the current basis.
    fn extract_x(&self) -> Vec<f64> {
        let mut x = vec![0.0; self.ns];
        for j in 0..self.ns {
            if self.stat[j] != VStat::Basic {
                x[j] = self.nb_val(j);
            }
        }
        for (r, &j) in self.basis.iter().enumerate() {
            if j < self.ns {
                x[j] = self.xb[r];
            }
        }
        x
    }
}

/// Solve the LP relaxation of `p` (integrality ignored) with the sparse
/// revised simplex; falls back to the dense two-phase reference solver on
/// numerical failure.  Public contract identical to the historic dense
/// `solve_lp`.
pub fn solve_lp(p: &Problem) -> Solution {
    let mut s = LpSolver::new(p);
    match s.solve(&p.lo, &p.up, None) {
        Some(out) => outcome_to_solution(p, out),
        None => super::simplex::solve_lp(p),
    }
}

/// Convert an [`LpOutcome`] into the public [`Solution`] shape.
pub fn outcome_to_solution(p: &Problem, out: LpOutcome) -> Solution {
    match out.status {
        Status::Infeasible => Solution {
            status: Status::Infeasible,
            obj: f64::NEG_INFINITY,
            x: vec![],
        },
        Status::Unbounded => Solution {
            status: Status::Unbounded,
            obj: f64::INFINITY,
            x: vec![],
        },
        _ => {
            let obj = p.eval_obj(&out.x);
            Solution {
                status: out.status,
                obj,
                x: out.x,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::model::{Cmp, Problem};
    use crate::solver::simplex;

    fn assert_opt(sol: &Solution, obj: f64, tol: f64) {
        assert_eq!(sol.status, Status::Optimal, "{sol:?}");
        assert!((sol.obj - obj).abs() < tol, "obj={} expect={}", sol.obj, obj);
    }

    /// The dense two-phase solver is the reference: on every unit LP both
    /// paths must agree on status and objective.
    fn assert_dense_parity(p: &Problem) {
        let dense = simplex::solve_lp(p);
        let rev = solve_lp(p);
        assert_eq!(rev.status, dense.status, "status parity");
        if dense.status == Status::Optimal {
            assert!(
                (rev.obj - dense.obj).abs() < 1e-6 * (1.0 + dense.obj.abs()),
                "objective parity: revised {} vs dense {}",
                rev.obj,
                dense.obj
            );
            assert!(p.is_feasible(&rev.x, 1e-6), "revised point feasible");
        }
    }

    #[test]
    fn basic_2d() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY, 3.0);
        let y = p.cont("y", 0.0, f64::INFINITY, 2.0);
        p.constrain("c1", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        p.constrain("c2", vec![(x, 1.0), (y, 3.0)], Cmp::Le, 6.0);
        assert_opt(&solve_lp(&p), 12.0, 1e-6);
        assert_dense_parity(&p);
    }

    #[test]
    fn upper_bounds_implicit() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 2.0, 1.0);
        let y = p.cont("y", 0.0, 3.0, 1.0);
        p.constrain("c", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        let s = solve_lp(&p);
        assert_opt(&s, 4.0, 1e-6);
        assert!(s.x[0] <= 2.0 + 1e-9 && s.x[1] <= 3.0 + 1e-9);
        assert_dense_parity(&p);
    }

    #[test]
    fn ge_and_eq_constraints() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY, -1.0);
        let y = p.cont("y", 0.0, f64::INFINITY, -1.0);
        p.constrain("g", vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 3.0);
        p.constrain("e", vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 1.0);
        let s = solve_lp(&p);
        assert_opt(&s, -3.0, 1e-6);
        assert!((s.x[0] - 2.0).abs() < 1e-6);
        assert!((s.x[1] - 1.0).abs() < 1e-6);
        assert_dense_parity(&p);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 1.0, 1.0);
        p.constrain("c", vec![(x, 1.0)], Cmp::Ge, 5.0);
        assert_eq!(solve_lp(&p).status, Status::Infeasible);
        assert_dense_parity(&p);
    }

    #[test]
    fn unbounded_detected() {
        let mut p = Problem::new();
        let _ = p.cont("x", 0.0, f64::INFINITY, 1.0);
        assert_eq!(solve_lp(&p).status, Status::Unbounded);
    }

    #[test]
    fn negative_lower_bounds() {
        let mut p = Problem::new();
        let x = p.cont("x", -5.0, -2.0, 1.0);
        p.constrain("c", vec![(x, 1.0)], Cmp::Ge, -10.0);
        let s = solve_lp(&p);
        assert_opt(&s, -2.0, 1e-6);
        assert_dense_parity(&p);
    }

    #[test]
    fn degenerate_transportation() {
        let mut p = Problem::new();
        let x11 = p.cont("x11", 0.0, f64::INFINITY, -1.0);
        let x12 = p.cont("x12", 0.0, f64::INFINITY, -4.0);
        let x21 = p.cont("x21", 0.0, f64::INFINITY, -2.0);
        let x22 = p.cont("x22", 0.0, f64::INFINITY, -1.0);
        p.constrain("s1", vec![(x11, 1.0), (x12, 1.0)], Cmp::Eq, 3.0);
        p.constrain("s2", vec![(x21, 1.0), (x22, 1.0)], Cmp::Eq, 2.0);
        p.constrain("d1", vec![(x11, 1.0), (x21, 1.0)], Cmp::Eq, 2.0);
        p.constrain("d2", vec![(x12, 1.0), (x22, 1.0)], Cmp::Eq, 3.0);
        let s = solve_lp(&p);
        assert_opt(&s, -8.0, 1e-6);
        assert_dense_parity(&p);
    }

    #[test]
    fn random_lps_dense_parity() {
        use crate::rngx::Rng;
        let mut rng = Rng::new(99);
        for case in 0..60 {
            let nv = 2 + rng.below(6);
            let nc = 1 + rng.below(6);
            let mut p = Problem::new();
            let vars: Vec<_> = (0..nv)
                .map(|i| {
                    p.cont(&format!("v{i}"), 0.0, rng.uniform(0.5, 10.0), rng.uniform(-2.0, 3.0))
                })
                .collect();
            for c in 0..nc {
                let coeffs: Vec<_> =
                    vars.iter().map(|&v| (v, rng.uniform(0.0, 2.0))).collect();
                p.constrain(&format!("c{c}"), coeffs, Cmp::Le, rng.uniform(1.0, 20.0));
            }
            let s = solve_lp(&p);
            assert_eq!(s.status, Status::Optimal, "case {case}");
            assert!(p.is_feasible(&s.x, 1e-6), "case {case}: {:?}", s.x);
            assert!(s.obj >= -1e-9, "case {case}: obj {}", s.obj);
            assert_dense_parity(&p);
        }
    }

    /// Random LPs with Ge/Eq rows: the zero-cost dual restore must reach
    /// the same optimum the dense artificial-variable phase 1 does.
    #[test]
    fn random_mixed_rows_dense_parity() {
        use crate::rngx::Rng;
        let mut rng = Rng::new(7);
        for case in 0..40 {
            let nv = 2 + rng.below(4);
            let mut p = Problem::new();
            let vars: Vec<_> = (0..nv)
                .map(|i| {
                    p.cont(&format!("v{i}"), 0.0, rng.uniform(2.0, 8.0), rng.uniform(-2.0, 2.0))
                })
                .collect();
            // One Le row keeping things bounded, one Ge row forcing work,
            // and (half the time) one Eq row.
            let le: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.5, 2.0))).collect();
            p.constrain("le", le, Cmp::Le, rng.uniform(4.0, 20.0));
            let ge: Vec<_> = vars.iter().map(|&v| (v, rng.uniform(0.2, 1.0))).collect();
            p.constrain("ge", ge, Cmp::Ge, rng.uniform(0.5, 2.0));
            if case % 2 == 0 {
                let eq = vec![(vars[0], 1.0), (vars[1 % nv], 1.0)];
                p.constrain("eq", eq, Cmp::Eq, rng.uniform(0.5, 3.0));
            }
            assert_dense_parity(&p);
        }
    }

    /// Warm restart after a bound tightening reaches the cold optimum in
    /// (far) fewer pivots and at the same objective.
    #[test]
    fn warm_restart_matches_cold_after_bound_change() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 10.0, 5.0);
        let y = p.cont("y", 0.0, 10.0, 2.0);
        let z = p.cont("z", 0.0, 10.0, 1.0);
        p.constrain("c1", vec![(x, 1.0), (y, 1.0), (z, 1.0)], Cmp::Le, 9.0);
        p.constrain("c2", vec![(x, 2.0), (y, 1.0)], Cmp::Le, 11.0);
        let mut s = LpSolver::new(&p);
        let root = s.solve(&p.lo, &p.up, None).expect("root solves");
        assert_eq!(root.status, Status::Optimal);
        let snap = root.basis.clone().expect("optimal basis");

        // Tighten x (a branching-style change) and re-solve both ways.
        let mut up2 = p.up.clone();
        up2[0] = 2.0;
        let warm = s.solve(&p.lo, &up2, Some(&snap)).expect("warm solves");
        assert_eq!(warm.status, Status::Optimal);
        assert!(warm.warm, "warm path must be taken");
        let mut s2 = LpSolver::new(&p);
        let cold = s2.solve(&p.lo, &up2, None).expect("cold solves");
        assert_eq!(cold.status, Status::Optimal);
        assert!(
            (warm.obj - cold.obj).abs() < 1e-6,
            "warm {} vs cold {}",
            warm.obj,
            cold.obj
        );
        assert!(
            warm.pivots <= cold.pivots + 1,
            "warm restart must not pivot materially more: {} vs {}",
            warm.pivots,
            cold.pivots
        );
    }

    /// A bound change that makes the child infeasible must be certified
    /// by the dual restart, exactly like a cold solve.
    #[test]
    fn warm_restart_detects_infeasible_child() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 5.0, 1.0);
        let y = p.cont("y", 0.0, 5.0, 1.0);
        p.constrain("need", vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 6.0);
        let mut s = LpSolver::new(&p);
        let root = s.solve(&p.lo, &p.up, None).expect("root solves");
        assert_eq!(root.status, Status::Optimal);
        let snap = root.basis.clone().unwrap();
        let mut up2 = p.up.clone();
        up2[0] = 0.0; // now y alone cannot reach 6
        let warm = s.solve(&p.lo, &up2, Some(&snap)).expect("warm completes");
        assert_eq!(warm.status, Status::Infeasible);
    }
}
