//! Two-phase primal simplex with bounded variables (dense tableau).
//!
//! Since the sparse revised solver (`revised.rs`) became the production
//! LP core this module is the *reference and fallback* implementation:
//! `revised.rs` pins objective parity against it in unit and integration
//! tests, numerical failures in the revised path fall back to it, and
//! `milp-bench` uses it (via [`solve_lp_counted`]) as the dense pivot
//! baseline the warm-start speedup is measured against.
//!
//! Bounded-variable simplex keeps `lo <= x <= up` implicit (nonbasic
//! variables rest at either bound; the ratio test allows bound flips), so
//! the Trident MILP's ~10^2 bound constraints never enter the tableau.
//! Phase 1 minimizes artificial infeasibility; phase 2 maximizes the real
//! objective.  Bland's rule engages after a stall threshold to break
//! degenerate cycles.

use super::model::{Cmp, Problem, Solution, Status};

const EPS: f64 = 1e-9;
/// Dual feasibility tolerance for entering-variable selection.
const DUAL_TOL: f64 = 1e-7;
const MAX_ITERS: usize = 200_000;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum NbStatus {
    Lower,
    Upper,
    Basic,
}

struct Tableau {
    m: usize,
    n: usize,          // total columns (struct + slack + artificial)
    n_struct: usize,
    a: Vec<f64>,       // m x n row-major
    xb: Vec<f64>,      // basic values (of shifted vars)
    basis: Vec<usize>, // var per row
    status: Vec<NbStatus>,
    ubound: Vec<f64>,  // shifted upper bounds (lo already subtracted)
    rc: Vec<f64>,      // reduced costs for the active objective
    obj_val: f64,
    iters: usize,
}

impl Tableau {
    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    /// Nonbasic current value in shifted coordinates.
    #[inline]
    fn nb_val(&self, j: usize) -> f64 {
        match self.status[j] {
            NbStatus::Lower => 0.0,
            NbStatus::Upper => self.ubound[j],
            NbStatus::Basic => unreachable!(),
        }
    }

    /// Recompute reduced costs and objective for cost vector `c`
    /// (over all columns): rc = c - c_B^T B^{-1} A, using the tableau
    /// which already stores B^{-1} A.
    fn price(&mut self, c: &[f64]) {
        let mut rc = c.to_vec();
        for i in 0..self.m {
            let cb = c[self.basis[i]];
            if cb == 0.0 {
                continue;
            }
            let row = &self.a[i * self.n..(i + 1) * self.n];
            for (r, &aij) in rc.iter_mut().zip(row) {
                *r -= cb * aij;
            }
        }
        for i in 0..self.m {
            rc[self.basis[i]] = 0.0;
        }
        self.rc = rc;
        // Objective value = c_B x_B + sum over nonbasic-at-upper c_j u_j.
        let mut z = 0.0;
        for i in 0..self.m {
            z += c[self.basis[i]] * self.xb[i];
        }
        for j in 0..self.n {
            if self.status[j] == NbStatus::Upper {
                z += c[j] * self.ubound[j];
            }
        }
        self.obj_val = z;
    }

    /// One simplex iteration.  Returns false when optimal (no entering
    /// column) — errors are reported via `Err(Status)`.
    fn step(&mut self, bland: bool) -> Result<bool, Status> {
        // --- entering variable -------------------------------------------
        let mut enter: Option<(usize, f64)> = None; // (col, direction)
        let mut best_score = DUAL_TOL;
        for j in 0..self.n {
            let (dir, score) = match self.status[j] {
                NbStatus::Basic => continue,
                NbStatus::Lower => (1.0, self.rc[j]),
                NbStatus::Upper => (-1.0, -self.rc[j]),
            };
            if score > best_score {
                enter = Some((j, dir));
                if bland {
                    break; // first eligible (Bland)
                }
                best_score = score;
            }
        }
        let Some((q, dir)) = enter else { return Ok(false) };

        // --- ratio test ----------------------------------------------------
        // Moving x_q by t*dir changes basics: xb_i -= t*dir*T[i][q].
        let mut t_max = self.ubound[q]; // bound-flip limit
        let mut leave: Option<(usize, NbStatus)> = None; // (row, leaving-to)
        for i in 0..self.m {
            let aiq = dir * self.at(i, q);
            let bi = self.basis[i];
            if aiq > EPS {
                // xb_i decreases toward 0
                let t = self.xb[i] / aiq;
                if t < t_max - EPS || (t < t_max + EPS && leave.is_none()) {
                    if t < t_max - EPS || leave.is_none() {
                        t_max = t.max(0.0);
                        leave = Some((i, NbStatus::Lower));
                    }
                }
            } else if aiq < -EPS && self.ubound[bi].is_finite() {
                // xb_i increases toward its upper bound
                let t = (self.ubound[bi] - self.xb[i]) / (-aiq);
                if t < t_max - EPS || (t < t_max + EPS && leave.is_none()) {
                    if t < t_max - EPS || leave.is_none() {
                        t_max = t.max(0.0);
                        leave = Some((i, NbStatus::Upper));
                    }
                }
            }
        }
        if t_max.is_infinite() {
            return Err(Status::Unbounded);
        }

        // --- apply move ------------------------------------------------------
        let t = t_max;
        for i in 0..self.m {
            self.xb[i] -= t * dir * self.at(i, q);
        }
        self.obj_val += t * dir.abs() * self.rc[q] * dir.signum(); // rc gain along dir
        // NB: dir=+1 gain = t*rc; dir=-1 gain = -t*rc. Simplify below:
        // (kept explicit for clarity)
        // fix up: the expression above equals t*rc*dir
        // (dir.abs()*dir.signum() == dir)

        match leave {
            None => {
                // Pure bound flip.
                self.status[q] = if dir > 0.0 { NbStatus::Upper } else { NbStatus::Lower };
            }
            Some((r, to)) => {
                let new_val = self.nb_val(q) + t * dir;
                let leaving = self.basis[r];
                self.status[leaving] = to;
                self.status[q] = NbStatus::Basic;
                self.basis[r] = q;
                self.xb[r] = new_val;
                self.eliminate(r, q);
            }
        }
        self.iters += 1;
        Ok(true)
    }

    /// Gauss-eliminate column `q` using pivot row `r` (and update rc row).
    fn eliminate(&mut self, r: usize, q: usize) {
        let n = self.n;
        let piv = self.a[r * n + q];
        debug_assert!(piv.abs() > EPS, "zero pivot");
        let inv = 1.0 / piv;
        for v in self.a[r * n..(r + 1) * n].iter_mut() {
            *v *= inv;
        }
        // Split borrows: copy pivot row once.
        let prow: Vec<f64> = self.a[r * n..(r + 1) * n].to_vec();
        for i in 0..self.m {
            if i == r {
                continue;
            }
            let f = self.a[i * n + q];
            if f.abs() > EPS {
                let row = &mut self.a[i * n..(i + 1) * n];
                for (x, &pv) in row.iter_mut().zip(&prow) {
                    *x -= f * pv;
                }
                row[q] = 0.0;
            }
        }
        let f = self.rc[q];
        if f.abs() > EPS {
            for (x, &pv) in self.rc.iter_mut().zip(&prow) {
                *x -= f * pv;
            }
            self.rc[q] = 0.0;
        }
    }

    fn run(&mut self, c: &[f64]) -> Status {
        self.price(c);
        let bland_after = 20 * (self.m + self.n);
        loop {
            if self.iters > MAX_ITERS {
                return Status::Limit;
            }
            match self.step(self.iters > bland_after) {
                Ok(true) => continue,
                Ok(false) => return Status::Optimal,
                Err(s) => return s,
            }
        }
    }
}

/// Solve the LP relaxation of `p` (integrality ignored).
pub fn solve_lp(p: &Problem) -> Solution {
    solve_lp_counted(p).0
}

/// Like [`solve_lp`] but also reports the simplex iteration (pivot)
/// count — the dense-baseline metric `milp-bench` compares the revised
/// warm-started solver against.
pub fn solve_lp_counted(p: &Problem) -> (Solution, usize) {
    let ns = p.n_vars();
    let m = p.rows.len();

    // Shift variables to x' = x - lo ∈ [0, u'] and normalize rows to rhs>=0.
    let shift: Vec<f64> = p.lo.clone();
    let mut ub: Vec<f64> = p
        .lo
        .iter()
        .zip(&p.up)
        .map(|(l, u)| if u.is_finite() { u - l } else { f64::INFINITY })
        .collect();

    // Column count: structural + one slack per Le/Ge row + artificials.
    let n_slack = p.rows.iter().filter(|r| r.cmp != Cmp::Eq).count();
    // Worst case every row needs an artificial.
    let n_total_max = ns + n_slack + m;

    let mut a = vec![0.0; m * n_total_max];
    let mut rhs = vec![0.0; m];
    let mut basis = vec![usize::MAX; m];
    let mut slack_idx = ns;
    let mut art_idx = ns + n_slack;
    let mut art_cols: Vec<usize> = Vec::new();

    for (i, row) in p.rows.iter().enumerate() {
        let mut b = row.rhs;
        for &(j, c) in &row.coeffs {
            b -= c * shift[j];
        }
        // Flip the row so b >= 0.
        let (flip, cmp) = if b < 0.0 {
            (
                -1.0,
                match row.cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                },
            )
        } else {
            (1.0, row.cmp)
        };
        let b = b * flip;
        rhs[i] = b;
        for &(j, c) in &row.coeffs {
            a[i * n_total_max + j] += flip * c;
        }
        match cmp {
            Cmp::Le => {
                a[i * n_total_max + slack_idx] = 1.0;
                basis[i] = slack_idx;
                slack_idx += 1;
            }
            Cmp::Ge => {
                a[i * n_total_max + slack_idx] = -1.0;
                slack_idx += 1;
                a[i * n_total_max + art_idx] = 1.0;
                basis[i] = art_idx;
                art_cols.push(art_idx);
                art_idx += 1;
            }
            Cmp::Eq => {
                a[i * n_total_max + art_idx] = 1.0;
                basis[i] = art_idx;
                art_cols.push(art_idx);
                art_idx += 1;
            }
        }
    }
    let n = art_idx;

    // Compact tableau to the true column count.
    let mut a2 = vec![0.0; m * n];
    for i in 0..m {
        a2[i * n..(i + 1) * n].copy_from_slice(&a[i * n_total_max..i * n_total_max + n]);
    }

    ub.resize(n, f64::INFINITY);
    // Artificials are [0, inf) in phase 1; pinned to 0 in phase 2.
    let mut status = vec![NbStatus::Lower; n];
    for i in 0..m {
        status[basis[i]] = NbStatus::Basic;
    }

    let mut t = Tableau {
        m,
        n,
        n_struct: ns,
        a: a2,
        xb: rhs,
        basis,
        status,
        ubound: ub,
        rc: vec![0.0; n],
        obj_val: 0.0,
        iters: 0,
    };

    // ---- Phase 1: maximize -sum(artificials) ------------------------------
    if !art_cols.is_empty() {
        let mut c1 = vec![0.0; n];
        for &j in &art_cols {
            c1[j] = -1.0;
        }
        let s = t.run(&c1);
        if s == Status::Unbounded {
            let iters = t.iters;
            return (
                Solution { status: Status::Infeasible, obj: f64::NEG_INFINITY, x: vec![] },
                iters,
            );
        }
        if t.obj_val < -1e-6 {
            let iters = t.iters;
            return (
                Solution { status: Status::Infeasible, obj: f64::NEG_INFINITY, x: vec![] },
                iters,
            );
        }
        // Pin artificials to zero so they never re-enter.
        for &j in &art_cols {
            t.ubound[j] = 0.0;
        }
    }

    // ---- Phase 2: maximize the real objective -----------------------------
    let mut c2 = vec![0.0; n];
    c2[..ns].copy_from_slice(&p.obj);
    let s2 = t.run(&c2);
    if s2 == Status::Unbounded {
        let iters = t.iters;
        return (Solution { status: Status::Unbounded, obj: f64::INFINITY, x: vec![] }, iters);
    }

    // ---- Extract ----------------------------------------------------------
    let mut x = vec![0.0; ns];
    for j in 0..ns {
        x[j] = shift[j]
            + match t.status[j] {
                NbStatus::Lower => 0.0,
                NbStatus::Upper => t.ubound[j],
                NbStatus::Basic => 0.0, // filled below
            };
    }
    for i in 0..m {
        let j = t.basis[i];
        if j < ns {
            x[j] = shift[j] + t.xb[i];
        }
    }
    let obj = p.eval_obj(&x);
    let status = if s2 == Status::Limit { Status::Limit } else { Status::Optimal };
    (Solution { status, obj, x }, t.iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::model::{Cmp, Problem};

    fn assert_opt(sol: &Solution, obj: f64, tol: f64) {
        assert_eq!(sol.status, Status::Optimal, "{sol:?}");
        assert!((sol.obj - obj).abs() < tol, "obj={} expect={}", sol.obj, obj);
    }

    #[test]
    fn basic_2d() {
        // max 3x+2y st x+y<=4, x+3y<=6, x,y>=0 -> (4,0) obj 12
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY, 3.0);
        let y = p.cont("y", 0.0, f64::INFINITY, 2.0);
        p.constrain("c1", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        p.constrain("c2", vec![(x, 1.0), (y, 3.0)], Cmp::Le, 6.0);
        assert_opt(&solve_lp(&p), 12.0, 1e-6);
    }

    #[test]
    fn upper_bounds_implicit() {
        // max x+y st x<=2 (bound), y<=3 (bound), x+y<=4 -> obj 4
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 2.0, 1.0);
        let y = p.cont("y", 0.0, 3.0, 1.0);
        p.constrain("c", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        let s = solve_lp(&p);
        assert_opt(&s, 4.0, 1e-6);
        assert!(s.x[0] <= 2.0 + 1e-9 && s.x[1] <= 3.0 + 1e-9);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // max -x-y st x+y>=3, x-y=1 -> x=2,y=1 obj -3
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY, -1.0);
        let y = p.cont("y", 0.0, f64::INFINITY, -1.0);
        p.constrain("g", vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 3.0);
        p.constrain("e", vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 1.0);
        let s = solve_lp(&p);
        assert_opt(&s, -3.0, 1e-6);
        assert!((s.x[0] - 2.0).abs() < 1e-6);
        assert!((s.x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 1.0, 1.0);
        p.constrain("c", vec![(x, 1.0)], Cmp::Ge, 5.0);
        assert_eq!(solve_lp(&p).status, Status::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut p = Problem::new();
        let _ = p.cont("x", 0.0, f64::INFINITY, 1.0);
        assert_eq!(solve_lp(&p).status, Status::Unbounded);
    }

    #[test]
    fn negative_lower_bounds() {
        // max x st -5<=x<=-2 -> -2
        let mut p = Problem::new();
        let x = p.cont("x", -5.0, -2.0, 1.0);
        p.constrain("c", vec![(x, 1.0)], Cmp::Ge, -10.0);
        let s = solve_lp(&p);
        assert_opt(&s, -2.0, 1e-6);
    }

    #[test]
    fn degenerate_transportation() {
        // Balanced 2x2 transportation problem (equalities, degenerate).
        // supplies [3,2], demands [2,3]; costs minimize: c11=1,c12=4,c21=2,c22=1
        // min -> max of negative: optimum ships x11=2, x12=1, x22=2 cost 8.
        let mut p = Problem::new();
        let x11 = p.cont("x11", 0.0, f64::INFINITY, -1.0);
        let x12 = p.cont("x12", 0.0, f64::INFINITY, -4.0);
        let x21 = p.cont("x21", 0.0, f64::INFINITY, -2.0);
        let x22 = p.cont("x22", 0.0, f64::INFINITY, -1.0);
        p.constrain("s1", vec![(x11, 1.0), (x12, 1.0)], Cmp::Eq, 3.0);
        p.constrain("s2", vec![(x21, 1.0), (x22, 1.0)], Cmp::Eq, 2.0);
        p.constrain("d1", vec![(x11, 1.0), (x21, 1.0)], Cmp::Eq, 2.0);
        p.constrain("d2", vec![(x12, 1.0), (x22, 1.0)], Cmp::Eq, 3.0);
        let s = solve_lp(&p);
        assert_opt(&s, -8.0, 1e-6);
    }

    #[test]
    fn random_lps_respect_constraints() {
        use crate::rngx::Rng;
        // property: for random feasible-by-construction LPs the returned
        // point satisfies every constraint and bound.
        let mut rng = Rng::new(99);
        for case in 0..60 {
            let nv = 2 + rng.below(6);
            let nc = 1 + rng.below(6);
            let mut p = Problem::new();
            let vars: Vec<_> = (0..nv)
                .map(|i| {
                    p.cont(&format!("v{i}"), 0.0, rng.uniform(0.5, 10.0), rng.uniform(-2.0, 3.0))
                })
                .collect();
            for c in 0..nc {
                let coeffs: Vec<_> = vars
                    .iter()
                    .map(|&v| (v, rng.uniform(0.0, 2.0)))
                    .collect();
                // rhs chosen >= 0 so x=0 is feasible
                p.constrain(&format!("c{c}"), coeffs, Cmp::Le, rng.uniform(1.0, 20.0));
            }
            let s = solve_lp(&p);
            assert_eq!(s.status, Status::Optimal, "case {case}");
            assert!(p.is_feasible(&s.x, 1e-6), "case {case}: {:?}", s.x);
            // optimal must be at least as good as origin (obj 0 requires all
            // positive-coefficient vars... just check >= sum of negatives)
            assert!(s.obj >= -1e-9, "case {case}: obj {}", s.obj);
        }
    }
}
