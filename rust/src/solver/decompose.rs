//! Dantzig–Wolfe decomposition: price-and-branch column generation for
//! block-structured MILPs.
//!
//! The scheduling MILP couples tenants only through shared node-capacity
//! and egress rows; everything else (throughput, placement, rolling
//! batches, flow routing) is block-diagonal per tenant.  This module is
//! the generic engine: a **restricted master LP** over per-block columns
//! (one λ variable per generated block solution, a convexity row Σλ = 1
//! per block, plus caller-supplied coupling rows and static variables),
//! alternated with caller-priced **subproblems** that propose new columns
//! against the master's dual prices.  Rounds terminate when no block can
//! produce a column with positive reduced cost (maximization), after
//! which an **integrality repair** pass re-solves the master with binary
//! λ (price-and-branch on the fractional convexity rows) to pick exactly
//! one column per block.
//!
//! Determinism contract: blocks are priced independently and collected in
//! block order, so the engine is bit-identical at any thread count — the
//! fan-out mirrors the sharded-sim harness (`std::thread::scope` over
//! disjoint chunks of per-block state).
//!
//! The engine knows nothing about tenants or schedules: the scheduling
//! layer supplies coupling rows, static variables, seed columns, and the
//! pricing oracle (`scheduling::solve_decomposed`), and maps chosen
//! columns back into a `SchedulePlan`.  Any failure path (numerical
//! failure in the master LP, non-optimal master, infeasible repair,
//! artificial usage in the repair solution) returns `None` and the caller
//! falls back to the monolithic MILP, so the decomposed path can only
//! ever *save* time, never change feasibility.

use std::time::{Duration, Instant};

use super::milp::{solve_milp_from, MilpStats};
use super::model::{Cmp, Problem, Status, Var};
use super::revised::LpSolver;

/// One coupling row of the master (shared across blocks).
#[derive(Debug, Clone)]
pub struct DwRow {
    pub name: String,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A static (non-column) master variable, e.g. the max-min epigraph
/// `T_min` or the egress budget `E_max`.  `coeffs` index coupling rows.
#[derive(Debug, Clone)]
pub struct DwStatic {
    pub name: String,
    pub obj: f64,
    pub lo: f64,
    pub up: f64,
    pub coeffs: Vec<(usize, f64)>,
}

/// One generated column: a block solution projected onto the master.
/// `coeffs` are the column's usage of each coupling row; `tag` is a
/// caller-side payload id (the caller keeps the full block solution and
/// maps the chosen tag back to it after the repair pass).
#[derive(Debug, Clone)]
pub struct DwColumn {
    pub obj: f64,
    pub coeffs: Vec<(usize, f64)>,
    pub tag: usize,
}

/// A column plus the subproblem solve's counters, folded into the
/// aggregate stats so pricing cost is visible in `MilpStats`.
#[derive(Debug, Clone)]
pub struct PricedColumn {
    pub col: DwColumn,
    pub stats: MilpStats,
}

/// Dual prices handed to the pricing oracle: one per coupling row (in
/// `DwRow` order) and one per block (the convexity row).  A block's new
/// column improves the master iff
/// `obj − Σ y_coupling·a − σ_block > tol`.
#[derive(Debug, Clone)]
pub struct DwDuals {
    pub coupling: Vec<f64>,
    pub convexity: Vec<f64>,
}

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct DwOptions {
    /// Reduced-cost acceptance threshold (columns below it are noise).
    pub tol: f64,
    /// Hard cap on pricing rounds (termination is normally on
    /// no-positive-reduced-cost; the cap bounds pathological tailing).
    pub max_rounds: usize,
    /// Worker threads for the pricing fan-out (0 = available parallelism).
    pub threads: usize,
    /// Wall budget for the integrality-repair MILP.
    pub repair_budget: Duration,
    /// Penalty on the artificial feasibility columns.  Must dominate any
    /// attainable objective; artificial usage above `tol` in the repair
    /// solution aborts the decomposed path.
    pub big_m: f64,
}

impl Default for DwOptions {
    fn default() -> Self {
        DwOptions {
            tol: 1e-7,
            max_rounds: 25,
            threads: 0,
            repair_budget: Duration::from_secs(5),
            big_m: 1e7,
        }
    }
}

/// Result of a successful decomposed solve.
#[derive(Debug, Clone)]
pub struct DwSolve {
    pub status: Status,
    /// Integer (repaired) master objective.
    pub obj: f64,
    /// LP master objective at termination (the Dantzig–Wolfe bound).
    pub lp_obj: f64,
    /// Chosen column tag per block.
    pub chosen: Vec<usize>,
    /// Columns accepted per pricing round (seed round excluded).
    pub round_columns: Vec<usize>,
    /// Aggregate counters: master + all subproblem solves, with
    /// `pricing_rounds` / `columns` / `pricing_ms` filled in.
    pub stats: MilpStats,
}

/// Variable layout of one master assembly.
struct MasterLayout {
    statics: Vec<Var>,
    lambdas: Vec<Vec<Var>>,
    arts: Vec<Var>,
}

/// Assemble the restricted master over the current column pool.  Row
/// order is coupling rows then one convexity row per block — the dual
/// vector is sliced on that contract.
fn build_master(
    coupling: &[DwRow],
    statics: &[DwStatic],
    columns: &[Vec<DwColumn>],
    integer_lambda: bool,
    big_m: f64,
) -> (Problem, MasterLayout) {
    let mut prob = Problem::new();
    let s_v: Vec<Var> = statics
        .iter()
        .map(|s| prob.cont(&s.name, s.lo, s.up, s.obj))
        .collect();
    let mut l_v: Vec<Vec<Var>> = Vec::with_capacity(columns.len());
    for (b, cols) in columns.iter().enumerate() {
        let mut row = Vec::with_capacity(cols.len());
        for (c, col) in cols.iter().enumerate() {
            let name = format!("lam_{b}_{c}");
            row.push(if integer_lambda {
                prob.int(&name, 0.0, 1.0, col.obj)
            } else {
                prob.cont(&name, 0.0, 1.0, col.obj)
            });
        }
        l_v.push(row);
    }
    // Artificial feasibility columns: one per inequality coupling row
    // (sign chosen to relax it), a ± pair per equality row.
    let mut arts: Vec<Var> = Vec::new();
    let mut art_terms: Vec<Vec<(Var, f64)>> = vec![Vec::new(); coupling.len()];
    for (r, row) in coupling.iter().enumerate() {
        match row.cmp {
            Cmp::Le => {
                let a = prob.cont(&format!("art_{r}"), 0.0, f64::INFINITY, -big_m);
                art_terms[r].push((a, -1.0));
                arts.push(a);
            }
            Cmp::Ge => {
                let a = prob.cont(&format!("art_{r}"), 0.0, f64::INFINITY, -big_m);
                art_terms[r].push((a, 1.0));
                arts.push(a);
            }
            Cmp::Eq => {
                let ap = prob.cont(&format!("artp_{r}"), 0.0, f64::INFINITY, -big_m);
                let am = prob.cont(&format!("artm_{r}"), 0.0, f64::INFINITY, -big_m);
                art_terms[r].push((ap, 1.0));
                art_terms[r].push((am, -1.0));
                arts.push(ap);
                arts.push(am);
            }
        }
    }
    for (r, row) in coupling.iter().enumerate() {
        let mut terms: Vec<(Var, f64)> = Vec::new();
        for (s, sv) in statics.iter().zip(&s_v) {
            for &(sr, c) in &s.coeffs {
                if sr == r {
                    terms.push((*sv, c));
                }
            }
        }
        for (cols, lv) in columns.iter().zip(&l_v) {
            for (col, &l) in cols.iter().zip(lv) {
                for &(cr, c) in &col.coeffs {
                    if cr == r {
                        terms.push((l, c));
                    }
                }
            }
        }
        terms.extend_from_slice(&art_terms[r]);
        prob.constrain(&row.name, terms, row.cmp, row.rhs);
    }
    for (b, lv) in l_v.iter().enumerate() {
        let terms: Vec<(Var, f64)> = lv.iter().map(|&l| (l, 1.0)).collect();
        prob.constrain(&format!("convex_{b}"), terms, Cmp::Eq, 1.0);
    }
    (prob, MasterLayout { statics: s_v, lambdas: l_v, arts })
}

/// Deterministic parallel map over per-block mutable state: contiguous
/// chunks across `threads` scoped workers, results collected in block
/// order (bit-identical at any thread count — each block's computation
/// is independent and deterministic).
fn par_map_blocks<S, R, F>(states: &mut [S], threads: usize, f: F) -> Vec<R>
where
    S: Send,
    R: Send,
    F: Fn(usize, &mut S) -> R + Sync,
{
    let n = states.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    }
    .clamp(1, n);
    if threads == 1 {
        return states.iter_mut().enumerate().map(|(i, s)| f(i, s)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        for (ci, (sc, oc)) in states.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate() {
            scope.spawn(move || {
                for (j, (s, o)) in sc.iter_mut().zip(oc.iter_mut()).enumerate() {
                    *o = Some(f(ci * chunk + j, s));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("block result filled")).collect()
}

/// Reduced cost of a candidate column under the current duals.
fn reduced_cost(col: &DwColumn, duals: &DwDuals, block: usize) -> f64 {
    let mut rc = col.obj - duals.convexity[block];
    for &(r, c) in &col.coeffs {
        rc -= duals.coupling[r] * c;
    }
    rc
}

/// Structural duplicate test against a block's existing pool: an
/// identical column cannot improve the master and re-adding it every
/// round would stall termination.
fn is_duplicate(col: &DwColumn, pool: &[DwColumn]) -> bool {
    pool.iter().any(|p| {
        (p.obj - col.obj).abs() <= 1e-9 * (1.0 + col.obj.abs())
            && p.coeffs.len() == col.coeffs.len()
            && p
                .coeffs
                .iter()
                .zip(&col.coeffs)
                .all(|(&(ra, ca), &(rb, cb))| ra == rb && (ca - cb).abs() <= 1e-9 * (1.0 + cb.abs()))
    })
}

/// Run price-and-branch column generation.
///
/// * `seed(b, state)` returns the block's initial columns (at least one;
///   `None` aborts to the monolithic fallback).
/// * `price(b, state, duals)` returns the block's best candidate under
///   the given duals, or `None` when the subproblem found nothing usable.
///   The engine applies the reduced-cost and duplicate filters, so the
///   oracle just returns its optimum.
///
/// `None` means the decomposed path could not produce a trustworthy
/// integer solution; the caller must fall back to the monolithic solve.
pub fn solve_dw<S, FSeed, FPrice>(
    coupling: &[DwRow],
    statics: &[DwStatic],
    states: &mut [S],
    seed: FSeed,
    price: FPrice,
    opts: &DwOptions,
) -> Option<DwSolve>
where
    S: Send,
    FSeed: Fn(usize, &mut S) -> Option<Vec<PricedColumn>> + Sync,
    FPrice: Fn(usize, &mut S, &DwDuals) -> Option<PricedColumn> + Sync,
{
    let n_blocks = states.len();
    if n_blocks == 0 {
        return None;
    }
    let mut stats = MilpStats::default();
    let mut columns: Vec<Vec<DwColumn>> = vec![Vec::new(); n_blocks];

    // ---- seed: one standalone solve per block, in parallel ------------
    let seed_t = Instant::now();
    let seeded = par_map_blocks(states, opts.threads, |b, s| seed(b, s));
    stats.pricing_ms += seed_t.elapsed().as_secs_f64() * 1e3;
    for (b, got) in seeded.into_iter().enumerate() {
        let cols = got?;
        if cols.is_empty() {
            return None;
        }
        for pc in cols {
            stats.absorb(&pc.stats);
            stats.columns += 1;
            columns[b].push(pc.col);
        }
    }

    // ---- pricing rounds ----------------------------------------------
    let mut round_columns: Vec<usize> = Vec::new();
    let mut last_lambda: Vec<Vec<f64>> = Vec::new();
    let mut lp_obj = f64::NEG_INFINITY;
    for _round in 0..opts.max_rounds {
        let (prob, layout) = build_master(coupling, statics, &columns, false, opts.big_m);
        let mut lp = LpSolver::new(&prob);
        let out = lp.solve(&prob.lo, &prob.up, None)?;
        if out.status != Status::Optimal {
            return None;
        }
        stats.lp_solves += 1;
        stats.pivots += out.pivots;
        stats.phase1_pivots += out.phase1_pivots;
        lp_obj = out.obj;
        last_lambda = layout
            .lambdas
            .iter()
            .map(|lv| lv.iter().map(|&l| out.x[l.0]).collect())
            .collect();
        let duals = DwDuals {
            coupling: out.duals[..coupling.len()].to_vec(),
            convexity: out.duals[coupling.len()..coupling.len() + n_blocks].to_vec(),
        };

        let price_t = Instant::now();
        let candidates = par_map_blocks(states, opts.threads, |b, s| price(b, s, &duals));
        stats.pricing_ms += price_t.elapsed().as_secs_f64() * 1e3;
        stats.pricing_rounds += 1;

        let mut added = 0usize;
        for (b, cand) in candidates.into_iter().enumerate() {
            let Some(pc) = cand else { continue };
            stats.absorb(&pc.stats);
            if reduced_cost(&pc.col, &duals, b) > opts.tol && !is_duplicate(&pc.col, &columns[b]) {
                columns[b].push(pc.col);
                stats.columns += 1;
                added += 1;
            }
        }
        round_columns.push(added);
        if added == 0 {
            break;
        }
    }

    // ---- integrality repair: binary λ over the full column pool -------
    let (prob, layout) = build_master(coupling, statics, &columns, true, opts.big_m);
    // Warm incumbent: round the final LP's per-block argmax λ (ties to
    // the lowest column index for determinism) and keep it only if the
    // rounding is actually feasible.
    let warm = repair_warm_point(&prob, &layout, statics, &columns, &last_lambda);
    let (sol, rstats) = solve_milp_from(&prob, opts.repair_budget, warm);
    stats.absorb(&rstats);
    if sol.x.is_empty() {
        return None;
    }
    if layout.arts.iter().any(|&a| sol.x[a.0] > 1e-6) {
        // The chosen combination needed artificial slack: the column pool
        // cannot cover the coupling rows integrally.
        return None;
    }
    let mut chosen = Vec::with_capacity(n_blocks);
    for (b, lv) in layout.lambdas.iter().enumerate() {
        let c = lv
            .iter()
            .position(|&l| sol.x[l.0] > 0.5)?;
        chosen.push(columns[b][c].tag);
    }
    Some(DwSolve {
        status: sol.status,
        obj: sol.obj,
        lp_obj,
        chosen,
        round_columns,
        stats,
    })
}

/// Greedy rounding of the final LP master into a warm incumbent for the
/// repair MILP: per block take the largest-λ column, set statics to the
/// cheapest values consistent with the rounded columns, artificials to
/// zero — and only return it when feasible.
fn repair_warm_point(
    prob: &Problem,
    layout: &MasterLayout,
    statics: &[DwStatic],
    columns: &[Vec<DwColumn>],
    last_lambda: &[Vec<f64>],
) -> Option<Vec<f64>> {
    if last_lambda.len() != columns.len() {
        return None;
    }
    let mut x = vec![0.0; prob.n_vars()];
    let mut picks: Vec<usize> = Vec::with_capacity(columns.len());
    for (b, lam) in last_lambda.iter().enumerate() {
        if lam.is_empty() || lam.len() != layout.lambdas[b].len() {
            return None;
        }
        let mut best = 0usize;
        for (c, &v) in lam.iter().enumerate() {
            if v > lam[best] + 1e-12 {
                best = c;
            }
        }
        x[layout.lambdas[b][best].0] = 1.0;
        picks.push(best);
    }
    // Usage of each coupling row by the rounded selection.
    let mut usage = vec![0.0; statics.iter().flat_map(|s| &s.coeffs).map(|&(r, _)| r + 1).max().unwrap_or(0)];
    for (b, &c) in picks.iter().enumerate() {
        for &(r, v) in &columns[b][c].coeffs {
            if r >= usage.len() {
                usage.resize(r + 1, 0.0);
            }
            usage[r] += v;
        }
    }
    // Statics: pick the bound that the objective prefers, then let the
    // feasibility check below veto the point if a coupling row needs a
    // different value.  For the scheduling master this resolves exactly:
    // E_max (obj < 0) must cover the max egress row usage, T_min
    // (obj > 0) is capped by the max-min rows.
    for (s, &sv) in statics.iter().zip(&layout.statics) {
        if s.obj < 0.0 {
            // Minimized: smallest value covering its rows.  Coeff −1 on a
            // ≤ row means the static must be ≥ the row's usage − rhs.
            let mut need = s.lo.max(0.0);
            for &(r, c) in &s.coeffs {
                if c < 0.0 {
                    let u = usage.get(r).copied().unwrap_or(0.0);
                    need = need.max((u - row_rhs(prob, r)) / -c);
                }
            }
            x[sv.0] = need;
        } else {
            // Maximized: largest value the Le rows allow.
            let mut cap = f64::INFINITY;
            for &(r, c) in &s.coeffs {
                if c > 0.0 {
                    let u = usage.get(r).copied().unwrap_or(0.0);
                    cap = cap.min((row_rhs(prob, r) - u) / c);
                }
            }
            x[sv.0] = if cap.is_finite() { cap.max(s.lo) } else { s.lo.max(0.0) };
        }
    }
    prob.is_feasible(&x, 1e-6).then_some(x)
}

fn row_rhs(prob: &Problem, r: usize) -> f64 {
    prob.rows[r].rhs
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two blocks, one shared ≤-capacity row.  Block b's columns are
    /// integer points v ∈ {0..4} with obj v and capacity usage v; the
    /// shared capacity is 5, so the joint optimum is v0 + v1 = 5.
    #[test]
    fn two_block_capacity_split() {
        let coupling = [DwRow { name: "cap".into(), cmp: Cmp::Le, rhs: 5.0 }];
        let statics: [DwStatic; 0] = [];
        // State: per-block list of generated values (for dedup/tags).
        let mut states: Vec<Vec<f64>> = vec![Vec::new(), Vec::new()];
        let seed = |_b: usize, s: &mut Vec<f64>| {
            // Standalone optimum: take everything (v = 4).
            s.push(4.0);
            Some(vec![PricedColumn {
                col: DwColumn { obj: 4.0, coeffs: vec![(0, 4.0)], tag: 0 },
                stats: MilpStats::default(),
            }])
        };
        let price = |_b: usize, s: &mut Vec<f64>, d: &DwDuals| {
            // Subproblem: max (1 − y)·v over v ∈ {0..4}.
            let y = d.coupling[0];
            let v = if 1.0 - y > 0.0 { 4.0 } else { 0.0 };
            let tag = s.len();
            s.push(v);
            Some(PricedColumn {
                col: DwColumn { obj: v, coeffs: vec![(0, v)], tag },
                stats: MilpStats::default(),
            })
        };
        let out = solve_dw(
            &coupling,
            &statics,
            &mut states,
            seed,
            price,
            &DwOptions::default(),
        )
        .expect("decomposition solves");
        assert_eq!(out.status, Status::Optimal);
        let total: f64 = out
            .chosen
            .iter()
            .zip(&states)
            .map(|(&tag, s)| s[tag])
            .sum();
        assert!(total <= 5.0 + 1e-9, "capacity respected: {total}");
        assert!((out.obj - total).abs() < 1e-9);
        assert!(out.obj >= 4.0 - 1e-9, "at least one block takes its fill: {}", out.obj);
        assert!(out.stats.columns >= 2);
    }

    /// A single block degenerates to picking its best seed column.
    #[test]
    fn single_block_picks_best_column() {
        let coupling = [DwRow { name: "cap".into(), cmp: Cmp::Le, rhs: 10.0 }];
        let statics: [DwStatic; 0] = [];
        let mut states = vec![()];
        let seed = |_b: usize, _s: &mut ()| {
            Some(vec![
                PricedColumn {
                    col: DwColumn { obj: 1.0, coeffs: vec![(0, 1.0)], tag: 0 },
                    stats: MilpStats::default(),
                },
                PricedColumn {
                    col: DwColumn { obj: 3.0, coeffs: vec![(0, 3.0)], tag: 1 },
                    stats: MilpStats::default(),
                },
            ])
        };
        let price = |_b: usize, _s: &mut (), _d: &DwDuals| None;
        let out = solve_dw(
            &coupling,
            &statics,
            &mut states,
            seed,
            price,
            &DwOptions::default(),
        )
        .expect("solves");
        assert_eq!(out.chosen, vec![1]);
        assert!((out.obj - 3.0).abs() < 1e-9);
    }

    /// Jointly infeasible pools must abort (artificial usage), not
    /// silently return a capacity-violating plan.
    #[test]
    fn infeasible_pool_falls_back() {
        let coupling = [DwRow { name: "cap".into(), cmp: Cmp::Le, rhs: 1.0 }];
        let statics: [DwStatic; 0] = [];
        let mut states = vec![(), ()];
        // Both blocks only ever offer a column using 2.0 of capacity 1.0.
        let seed = |_b: usize, _s: &mut ()| {
            Some(vec![PricedColumn {
                col: DwColumn { obj: 1.0, coeffs: vec![(0, 2.0)], tag: 0 },
                stats: MilpStats::default(),
            }])
        };
        let price = |_b: usize, _s: &mut (), _d: &DwDuals| None;
        let out = solve_dw(&coupling, &statics, &mut states, seed, price, &DwOptions::default());
        assert!(out.is_none(), "must fall back on joint infeasibility");
    }
}
