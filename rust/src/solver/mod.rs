//! LP/MILP solver substrate: problem builder, bounded-variable two-phase
//! simplex, and best-first branch & bound.  Built from scratch because the
//! offline environment has no solver crates; exactness on the scheduler's
//! small instances (≲2k vars) is what matters.

pub mod milp;
pub mod model;
pub mod simplex;

pub use milp::{solve_milp, solve_milp_from, MilpStats};
pub use model::{Cmp, Problem, Solution, Status, Var};
pub use simplex::solve_lp;
