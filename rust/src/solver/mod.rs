//! LP/MILP solver substrate: problem builder, sparse revised simplex with
//! bounded variables and dual warm starts (the production LP core), the
//! dense two-phase tableau kept as reference/fallback, and best-first
//! branch & bound with basis inheritance.  Built from scratch because the
//! offline environment has no solver crates; exactness on the scheduler's
//! small instances (≲2k vars) is what matters, and warm restarts keep
//! online re-optimization cheap at multi-tenant scale.

pub mod decompose;
pub mod milp;
pub mod model;
pub mod revised;
pub mod simplex;

pub use decompose::{
    solve_dw, DwColumn, DwDuals, DwOptions, DwRow, DwSolve, DwStatic, PricedColumn,
};
pub use milp::{solve_milp, solve_milp_from, solve_milp_opts, LpBackend, MilpOptions, MilpStats};
pub use model::{Cmp, Problem, Solution, Status, Var};
pub use revised::{solve_lp, BasisSnapshot, LpOutcome, LpSolver};
