//! Problem-builder API for linear / mixed-integer programs.
//!
//! The scheduling layer constructs its MILP (Eqs. 10–26) through this
//! interface; `simplex.rs` solves the LP relaxation and `milp.rs` wraps it
//! in branch & bound.  Maximization convention throughout.

/// Variable handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Var(pub usize);

/// Constraint comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One linear constraint: `sum coeffs · vars  cmp  rhs`.
#[derive(Debug, Clone)]
pub struct Row {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
    pub name: String,
}

/// A linear or mixed-integer program (maximize `obj`).
#[derive(Debug, Clone, Default)]
pub struct Problem {
    pub obj: Vec<f64>,
    pub lo: Vec<f64>,
    pub up: Vec<f64>,
    pub integer: Vec<bool>,
    pub names: Vec<String>,
    pub rows: Vec<Row>,
}

impl Problem {
    pub fn new() -> Self {
        Problem::default()
    }

    pub fn n_vars(&self) -> usize {
        self.obj.len()
    }

    /// Add a variable with bounds `[lo, up]` (`up` may be `f64::INFINITY`),
    /// objective coefficient `obj`, and integrality flag.
    pub fn add_var(&mut self, name: impl Into<String>, lo: f64, up: f64, obj: f64, integer: bool) -> Var {
        assert!(lo <= up, "bad bounds for {:?}", name.into());
        self.obj.push(obj);
        self.lo.push(lo);
        self.up.push(up);
        self.integer.push(integer);
        self.names.push(String::new());
        Var(self.obj.len() - 1)
    }

    /// Convenience: continuous variable in `[lo, up]`.
    pub fn cont(&mut self, name: &str, lo: f64, up: f64, obj: f64) -> Var {
        let v = self.add_var(name, lo, up, obj, false);
        self.names[v.0] = name.to_string();
        v
    }

    /// Convenience: integer variable in `[lo, up]`.
    pub fn int(&mut self, name: &str, lo: f64, up: f64, obj: f64) -> Var {
        let v = self.add_var(name, lo, up, obj, true);
        self.names[v.0] = name.to_string();
        v
    }

    /// Add `sum coeffs  cmp  rhs`.  Coefficients on the same variable are
    /// accumulated.
    pub fn constrain(&mut self, name: &str, coeffs: Vec<(Var, f64)>, cmp: Cmp, rhs: f64) {
        let mut acc: Vec<(usize, f64)> = Vec::with_capacity(coeffs.len());
        for (v, c) in coeffs {
            debug_assert!(v.0 < self.n_vars(), "constraint references unknown var");
            if c == 0.0 {
                continue;
            }
            if let Some(slot) = acc.iter_mut().find(|(i, _)| *i == v.0) {
                slot.1 += c;
            } else {
                acc.push((v.0, c));
            }
        }
        self.rows.push(Row { coeffs: acc, cmp, rhs, name: name.to_string() });
    }

    /// Evaluate the objective at a point.
    pub fn eval_obj(&self, x: &[f64]) -> f64 {
        self.obj.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Check feasibility of a point within tolerance (bounds, rows,
    /// integrality for integer vars).
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.n_vars() {
            return false;
        }
        for j in 0..self.n_vars() {
            if x[j] < self.lo[j] - tol || x[j] > self.up[j] + tol {
                return false;
            }
            if self.integer[j] && (x[j] - x[j].round()).abs() > tol {
                return false;
            }
        }
        for row in &self.rows {
            let lhs: f64 = row.coeffs.iter().map(|&(j, c)| c * x[j]).sum();
            let ok = match row.cmp {
                Cmp::Le => lhs <= row.rhs + tol,
                Cmp::Ge => lhs >= row.rhs - tol,
                Cmp::Eq => (lhs - row.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }
}

/// Solver termination status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Optimal,
    Infeasible,
    Unbounded,
    /// Best incumbent at time/iteration limit (MILP) or iteration cap (LP).
    Limit,
}

/// Solution: status, objective value, and the variable assignment.
#[derive(Debug, Clone)]
pub struct Solution {
    pub status: Status,
    pub obj: f64,
    pub x: Vec<f64>,
}

impl Solution {
    pub fn value(&self, v: Var) -> f64 {
        self.x[v.0]
    }

    pub fn int_value(&self, v: Var) -> i64 {
        self.x[v.0].round() as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_duplicate_coeffs() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 10.0, 1.0);
        p.constrain("r", vec![(x, 1.0), (x, 2.0)], Cmp::Le, 6.0);
        assert_eq!(p.rows[0].coeffs, vec![(0, 3.0)]);
    }

    #[test]
    fn feasibility_check() {
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 5.0, 1.0);
        let y = p.cont("y", 0.0, 5.0, 1.0);
        p.constrain("c", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        assert!(p.is_feasible(&[2.0, 1.5], 1e-9));
        assert!(!p.is_feasible(&[2.5, 1.0], 1e-9)); // fractional int
        assert!(!p.is_feasible(&[3.0, 2.0], 1e-9)); // row violated
        assert!(!p.is_feasible(&[-1.0, 0.0], 1e-9)); // bound violated
    }
}
