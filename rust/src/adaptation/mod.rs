//! Adaptation layer (paper §5): online workload categorization and
//! memory-constrained configuration tuning.

pub mod bo;
pub mod cluster_metrics;
pub mod offline_cluster;
pub mod online_cluster;
pub mod tuner;

pub use bo::{ConfigTuner, Evaluation, Strategy, TunerConfig};
pub use online_cluster::{Cluster, ClusterConfig, OnlineClustering, TuneStatus};
pub use tuner::{OperatorAdaptation, Recommendation};
