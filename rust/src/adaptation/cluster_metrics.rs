//! Clustering evaluation metrics: purity and Adjusted Rand Index (Table 4).

use std::collections::HashMap;

/// Purity: fraction of points whose cluster's majority truth label matches
/// their own.  Noise labels (usize::MAX) count as singletons.
pub fn purity(assign: &[usize], truth: &[u8]) -> f64 {
    assert_eq!(assign.len(), truth.len());
    if assign.is_empty() {
        return 0.0;
    }
    let mut per_cluster: HashMap<usize, HashMap<u8, usize>> = HashMap::new();
    for (&a, &t) in assign.iter().zip(truth) {
        *per_cluster.entry(a).or_default().entry(t).or_default() += 1;
    }
    let correct: usize = per_cluster
        .values()
        .map(|h| h.values().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / assign.len() as f64
}

/// Adjusted Rand Index.
pub fn ari(assign: &[usize], truth: &[u8]) -> f64 {
    assert_eq!(assign.len(), truth.len());
    let n = assign.len();
    if n < 2 {
        return 1.0;
    }
    let choose2 = |x: usize| -> f64 { (x as f64) * (x as f64 - 1.0) / 2.0 };
    let mut table: HashMap<(usize, u8), usize> = HashMap::new();
    let mut rows: HashMap<usize, usize> = HashMap::new();
    let mut cols: HashMap<u8, usize> = HashMap::new();
    for (&a, &t) in assign.iter().zip(truth) {
        *table.entry((a, t)).or_default() += 1;
        *rows.entry(a).or_default() += 1;
        *cols.entry(t).or_default() += 1;
    }
    let sum_ij: f64 = table.values().map(|&v| choose2(v)).sum();
    let sum_a: f64 = rows.values().map(|&v| choose2(v)).sum();
    let sum_b: f64 = cols.values().map(|&v| choose2(v)).sum();
    let total = choose2(n);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0;
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering() {
        let assign = [0, 0, 1, 1, 2, 2];
        let truth = [5u8, 5, 7, 7, 9, 9];
        assert_eq!(purity(&assign, &truth), 1.0);
        assert!((ari(&assign, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_clustering_has_low_ari() {
        // Alternating assignment against block truth: ARI near 0.
        let assign: Vec<usize> = (0..200).map(|i| i % 2).collect();
        let truth: Vec<u8> = (0..200).map(|i| (i / 100) as u8).collect();
        let a = ari(&assign, &truth);
        assert!(a.abs() < 0.05, "ari {a}");
        assert!((purity(&assign, &truth) - 0.5).abs() < 0.05);
    }

    #[test]
    fn over_segmentation_keeps_purity_high_but_ari_lower() {
        // Each point its own cluster: purity 1, ARI ~0.
        let assign: Vec<usize> = (0..50).collect();
        let truth: Vec<u8> = (0..50).map(|i| (i / 25) as u8).collect();
        assert_eq!(purity(&assign, &truth), 1.0);
        assert!(ari(&assign, &truth) < 0.1);
    }

    #[test]
    fn known_small_example() {
        // scikit-learn doc example: ARI of this labelling is 0.24242...
        let assign = [0usize, 0, 1, 1];
        let truth = [0u8, 0, 1, 2];
        let a = ari(&assign, &truth);
        assert!((a - 0.5714285714).abs() < 1e-6, "ari {a}");
    }
}
