//! Offline clustering baselines for Table 4: K-means (k-means++ init,
//! Lloyd iterations, multi-restart) and DBSCAN — both given the *complete*
//! dataset, unlike Trident's incremental algorithm.

use crate::rngx::Rng;

fn d2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// K-means with k-means++ seeding; returns (assignments, inertia).
pub fn kmeans(data: &[Vec<f64>], k: usize, restarts: usize, seed: u64) -> (Vec<usize>, f64) {
    assert!(k >= 1 && !data.is_empty());
    let mut rng = Rng::new(seed);
    let mut best: Option<(Vec<usize>, f64)> = None;
    for _ in 0..restarts {
        // k-means++ init
        let mut centers: Vec<Vec<f64>> = vec![data[rng.below(data.len())].clone()];
        while centers.len() < k {
            let w: Vec<f64> = data
                .iter()
                .map(|x| centers.iter().map(|c| d2(x, c)).fold(f64::INFINITY, f64::min))
                .collect();
            let total: f64 = w.iter().sum();
            let idx = if total <= 1e-12 { rng.below(data.len()) } else { rng.categorical(&w) };
            centers.push(data[idx].clone());
        }
        // Lloyd
        let mut assign = vec![0usize; data.len()];
        for _ in 0..60 {
            let mut changed = false;
            for (i, x) in data.iter().enumerate() {
                let a = (0..k)
                    .min_by(|&a, &b| d2(x, &centers[a]).partial_cmp(&d2(x, &centers[b])).unwrap())
                    .unwrap();
                if a != assign[i] {
                    assign[i] = a;
                    changed = true;
                }
            }
            for (c, center) in centers.iter_mut().enumerate() {
                let members: Vec<&Vec<f64>> = data
                    .iter()
                    .zip(&assign)
                    .filter(|(_, &a)| a == c)
                    .map(|(x, _)| x)
                    .collect();
                if members.is_empty() {
                    continue;
                }
                for j in 0..center.len() {
                    center[j] = members.iter().map(|m| m[j]).sum::<f64>() / members.len() as f64;
                }
            }
            if !changed {
                break;
            }
        }
        let inertia: f64 = data.iter().zip(&assign).map(|(x, &a)| d2(x, &centers[a])).sum();
        if best.as_ref().map(|(_, bi)| inertia < *bi).unwrap_or(true) {
            best = Some((assign, inertia));
        }
    }
    best.unwrap()
}

/// DBSCAN; label -1 (here `usize::MAX`) = noise.
pub fn dbscan(data: &[Vec<f64>], eps: f64, min_pts: usize) -> Vec<usize> {
    const NOISE: usize = usize::MAX;
    const UNSEEN: usize = usize::MAX - 1;
    let n = data.len();
    let eps2 = eps * eps;
    let mut labels = vec![UNSEEN; n];
    let neighbors = |i: usize| -> Vec<usize> {
        (0..n).filter(|&j| d2(&data[i], &data[j]) <= eps2).collect()
    };
    let mut cluster = 0usize;
    for i in 0..n {
        if labels[i] != UNSEEN {
            continue;
        }
        let nb = neighbors(i);
        if nb.len() < min_pts {
            labels[i] = NOISE;
            continue;
        }
        labels[i] = cluster;
        let mut frontier = nb;
        let mut qi = 0;
        while qi < frontier.len() {
            let j = frontier[qi];
            qi += 1;
            if labels[j] == NOISE {
                labels[j] = cluster;
            }
            if labels[j] != UNSEEN {
                continue;
            }
            labels[j] = cluster;
            let nbj = neighbors(j);
            if nbj.len() >= min_pts {
                frontier.extend(nbj);
            }
        }
        cluster += 1;
    }
    labels
}

/// Number of non-noise clusters in a DBSCAN labelling.
pub fn dbscan_n_clusters(labels: &[usize]) -> usize {
    labels.iter().filter(|&&l| l != usize::MAX).map(|&l| l + 1).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[[f64; 2]], n_each: usize, sigma: f64) -> (Vec<Vec<f64>>, Vec<u8>) {
        let mut data = Vec::new();
        let mut truth = Vec::new();
        for (t, c) in centers.iter().enumerate() {
            for _ in 0..n_each {
                data.push(vec![c[0] + rng.normal(0.0, sigma), c[1] + rng.normal(0.0, sigma)]);
                truth.push(t as u8);
            }
        }
        (data, truth)
    }

    #[test]
    fn kmeans_recovers_blobs() {
        let mut rng = Rng::new(0);
        let (data, truth) = blobs(&mut rng, &[[0.0, 0.0], [3.0, 0.0], [0.0, 3.0]], 100, 0.2);
        let (assign, _) = kmeans(&data, 3, 4, 1);
        let p = super::super::cluster_metrics::purity(&assign, &truth);
        assert!(p > 0.98, "purity {p}");
    }

    #[test]
    fn dbscan_recovers_blobs_and_marks_noise() {
        let mut rng = Rng::new(2);
        let (mut data, truth) = blobs(&mut rng, &[[0.0, 0.0], [4.0, 0.0]], 120, 0.15);
        data.push(vec![100.0, 100.0]); // lone outlier
        let labels = dbscan(&data, 0.6, 4);
        assert_eq!(dbscan_n_clusters(&labels[..240]), 2);
        assert_eq!(labels[240], usize::MAX, "outlier must be noise");
        let p = super::super::cluster_metrics::purity(&labels[..240], &truth);
        assert!(p > 0.98, "purity {p}");
    }

    #[test]
    fn kmeans_single_cluster_and_k1() {
        let data = vec![vec![1.0, 1.0]; 20];
        let (assign, inertia) = kmeans(&data, 1, 2, 0);
        assert!(assign.iter().all(|&a| a == 0));
        assert!(inertia < 1e-12);
    }
}
