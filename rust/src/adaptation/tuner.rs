//! Adaptation-layer control flow (paper Algorithm 1) for one tunable
//! operator: workload categorization → tuning-trigger check → forwarding
//! recommendations to the scheduling layer.
//!
//! Tuning evaluations run on a live *probe instance* orchestrated by the
//! coordinator: the layer proposes a candidate θ, the coordinator restarts
//! the probe with it, measures a sustained window, and reports
//! (UT, peak-mem, OOM) back.

use crate::adaptation::bo::{ConfigTuner, Strategy, TunerConfig};
use crate::adaptation::online_cluster::{ClusterConfig, OnlineClustering, TuneStatus};
use crate::config::{ConfigSpace, TridentConfig};
use crate::runtime::GpBackend;
use crate::sim::OpMetrics;

/// A configuration recommendation for the scheduling layer (→ MILP's
/// `UT_i^cand`).
#[derive(Debug, Clone)]
pub struct Recommendation {
    pub config: Vec<f64>,
    pub ut_cand: f64,
}

/// Per-operator adaptation state.
pub struct OperatorAdaptation {
    pub op: usize,
    space: ConfigSpace,
    pub clustering: OnlineClustering,
    /// Active tuning job: (cluster id, tuner, in-flight candidate).
    job: Option<(u64, ConfigTuner, Option<Vec<f64>>)>,
    tune_trigger: usize,
    tuner_cfg: TunerConfig,
    /// Clusters already queued for tuning (FIFO).
    queue: Vec<u64>,
}

impl OperatorAdaptation {
    pub fn new(op: usize, space: ConfigSpace, cfg: &TridentConfig, mem_cap_mb: f64, seed: u64) -> Self {
        OperatorAdaptation {
            op,
            space,
            clustering: OnlineClustering::new(ClusterConfig {
                tau_d: cfg.tau_d,
                l_max: cfg.l_max,
                gamma: cfg.gamma,
                ..Default::default()
            }),
            job: None,
            tune_trigger: cfg.tune_trigger,
            tuner_cfg: TunerConfig {
                strategy: Strategy::ConstrainedBo,
                budget: cfg.bo_budget,
                n_init: cfg.bo_init,
                eta: cfg.eta,
                mem_limit_mb: mem_cap_mb - cfg.delta_mb,
                seed,
            },
            queue: Vec::new(),
        }
    }

    /// Override the search strategy (ablations / Table 5 variants).
    pub fn set_strategy(&mut self, s: Strategy) {
        self.tuner_cfg.strategy = s;
    }

    /// Phase 1 + 2 of Algorithm 1: ingest this window's request features,
    /// update clusters, enqueue tuning jobs on trigger.
    pub fn ingest(&mut self, m: &OpMetrics) {
        for (f, _) in &m.cluster_samples {
            let c = self.clustering.assign(&f[..]);
            let cl = self.clustering.get_mut(c).unwrap();
            if cl.status == TuneStatus::Pending
                && cl.count >= self.tune_trigger as f64
                && !self.queue.contains(&c)
            {
                cl.status = TuneStatus::Tuning;
                self.queue.push(c);
            }
        }
        self.clustering.decay();
    }

    /// Next probe configuration to evaluate, if a tuning job is active (or
    /// can start).  Returns `None` when no tuning work is pending.
    pub fn probe_request(&mut self, backend: &GpBackend) -> Option<Vec<f64>> {
        if self.job.is_none() {
            let cluster = self.queue.first().copied()?;
            let seed = self.tuner_cfg.seed ^ cluster.wrapping_mul(0x9E37);
            let mut cfg = self.tuner_cfg.clone();
            cfg.seed = seed;
            self.job = Some((cluster, ConfigTuner::new(self.space.clone(), cfg), None));
        }
        let (_, tuner, inflight) = self.job.as_mut().unwrap();
        if inflight.is_some() {
            return inflight.clone(); // waiting for the coordinator's report
        }
        if tuner.done() {
            return None;
        }
        let cand = tuner.next_candidate(backend);
        *inflight = Some(cand.clone());
        Some(cand)
    }

    /// Report the probe measurement for the in-flight candidate.
    /// Completes the job when the budget is exhausted.
    pub fn probe_result(&mut self, ut: f64, mem_mb: f64, oom: bool) {
        let Some((cluster, tuner, inflight)) = self.job.as_mut() else {
            return;
        };
        let Some(theta) = inflight.take() else { return };
        tuner.record(theta, ut, mem_mb, oom);
        if tuner.done() {
            let cluster = *cluster;
            let best = tuner.best().map(|e| (e.theta.clone(), e.ut));
            let ooms = tuner.oom_count();
            self.job = None;
            self.queue.retain(|&c| c != cluster);
            if let Some(cl) = self.clustering.get_mut(cluster) {
                cl.status = TuneStatus::Tuned;
                if let Some((config, ut)) = best {
                    cl.best_config = Some(config);
                    cl.best_ut = ut;
                }
            }
            let _ = ooms;
        }
    }

    /// Phase 3: the dominant cluster's recommendation, if tuned (paper
    /// lines 10–13).
    pub fn recommendation(&self) -> Option<Recommendation> {
        let dom = self.clustering.dominant()?;
        if dom.status != TuneStatus::Tuned {
            return None;
        }
        let config = dom.best_config.clone()?;
        Some(Recommendation { config, ut_cand: dom.best_ut })
    }

    pub fn is_tuning(&self) -> bool {
        self.job.is_some() || !self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::metrics::InstanceMetrics;

    fn metrics_with_samples(samples: Vec<([f64; 2], u8)>) -> OpMetrics {
        OpMetrics {
            op: 0,
            window_s: 5.0,
            records_in: 0,
            records_out: 0,
            rate_per_inst: 1.0,
            utilization: 0.9,
            queue_begin: 10,
            queue_end: 10,
            queue_avg: 10.0,
            feat_mean: [500.0, 100.0, 0.0, 1.0],
            feat_std: [0.0; 4],
            peak_mem_mb: 0.0,
            oom_events: 0,
            n_active: 1,
            cluster_samples: samples,
            per_instance: Vec::<InstanceMetrics>::new(),
        }
    }

    fn adaptation() -> OperatorAdaptation {
        let mut cfg = TridentConfig::default();
        cfg.tune_trigger = 16;
        cfg.bo_budget = 8;
        cfg.bo_init = 3;
        OperatorAdaptation::new(0, crate::config::ConfigSpace::llm_engine(), &cfg, 65536.0, 7)
    }

    #[test]
    fn trigger_then_tune_then_recommend() {
        let mut ad = adaptation();
        let b = GpBackend::Native;
        // Feed one stable regime until the trigger fires.
        for _ in 0..6 {
            let samples = (0..8).map(|_| ([0.4, 0.2], 0u8)).collect();
            ad.ingest(&metrics_with_samples(samples));
        }
        assert!(ad.is_tuning(), "trigger must enqueue a tuning job");
        // Drive the probe loop.
        let mut evals = 0;
        while let Some(theta) = ad.probe_request(&b) {
            let ut = 5.0 + theta[0] / 16.0; // bigger batch better
            ad.probe_result(ut, 30_000.0, false);
            evals += 1;
            assert!(evals <= 8, "must stop at budget");
        }
        assert_eq!(evals, 8);
        assert!(!ad.is_tuning());
        let rec = ad.recommendation().expect("dominant cluster is tuned");
        assert!(rec.ut_cand >= 5.0);
        assert_eq!(rec.config.len(), 6);
    }

    #[test]
    fn no_recommendation_while_dominant_untuned() {
        let mut ad = adaptation();
        let samples = (0..4).map(|_| ([0.4, 0.2], 0u8)).collect();
        ad.ingest(&metrics_with_samples(samples));
        assert!(ad.recommendation().is_none());
    }

    #[test]
    fn regime_shift_triggers_second_job() {
        let mut ad = adaptation();
        let b = GpBackend::Native;
        for _ in 0..6 {
            ad.ingest(&metrics_with_samples((0..8).map(|_| ([0.3, 0.2], 0u8)).collect()));
        }
        while let Some(theta) = ad.probe_request(&b) {
            let _ = theta;
            ad.probe_result(4.0, 30_000.0, false);
        }
        assert!(!ad.is_tuning());
        // Shift to a new regime far away in feature space (long enough for
        // the new cluster to dominate the recent-assignment history).
        for _ in 0..40 {
            ad.ingest(&metrics_with_samples((0..8).map(|_| ([2.5, 1.8], 1u8)).collect()));
        }
        assert!(ad.is_tuning(), "new regime must enqueue tuning");
        assert!(ad.clustering.n_clusters() >= 2);
        // Old recommendation no longer applies: dominant is the new cluster.
        assert!(ad.recommendation().is_none());
    }
}
