//! Memory-constrained Bayesian optimization (paper §5.3, Eqs. 4–9) plus
//! the Table-5 search baselines (Sobol random search, grid search,
//! unconstrained BO).
//!
//! Surrogates (UT and peak-memory GPs) and the constrained acquisition
//! α(θ) = EI_UT(θ)·PoF(θ) are evaluated through [`GpBackend`] — i.e., on
//! the AOT-compiled PJRT artifact in production.  All search happens in the
//! unit cube; θ is materialized through the operator's [`ConfigSpace`].

use crate::config::ConfigSpace;
use crate::rngx::{sobol::Sobol, Rng};
use crate::runtime::{fit_hyper, GpBackend};

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub theta: Vec<f64>,
    pub unit: Vec<f64>,
    /// Sustainable throughput measured on the probe (records/s/instance).
    pub ut: f64,
    /// Peak device memory, MB.  For OOM evaluations this is censored at
    /// slightly above the device capacity.
    pub mem_mb: f64,
    pub oom: bool,
}

/// Search strategy selector (Table 5 comparisons share one engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// EI × PoF with feasibility threshold η (Trident).
    ConstrainedBo,
    /// Standard EI, memory ignored.
    UnconstrainedBo,
    /// Sobol quasi-random search.
    RandomSearch,
    /// Axis-aligned grid.
    GridSearch,
}

#[derive(Debug, Clone)]
pub struct TunerConfig {
    pub strategy: Strategy,
    pub budget: usize,
    pub n_init: usize,
    /// Feasibility threshold η.
    pub eta: f64,
    /// Device capacity minus safety margin Δ, MB.
    pub mem_limit_mb: f64,
    pub seed: u64,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            strategy: Strategy::ConstrainedBo,
            budget: 30,
            n_init: 5,
            eta: 0.6,
            mem_limit_mb: 65536.0 - 2048.0,
            seed: 0,
        }
    }
}

/// Configuration tuner for one (operator, workload-cluster) pair.
pub struct ConfigTuner {
    pub cfg: TunerConfig,
    pub space: ConfigSpace,
    pub evals: Vec<Evaluation>,
    sobol: Sobol,
    rng: Rng,
    grid: Vec<Vec<f64>>,
}

impl ConfigTuner {
    pub fn new(space: ConfigSpace, cfg: TunerConfig) -> Self {
        let dims = space.dims().max(1);
        let mut rng = Rng::new(cfg.seed ^ 0xB0B0);
        let grid = if cfg.strategy == Strategy::GridSearch {
            let mut g = grid_points(dims, cfg.budget);
            rng.shuffle(&mut g);
            g
        } else {
            Vec::new()
        };
        ConfigTuner { sobol: Sobol::new(dims.min(10)), rng, grid, cfg, space, evals: Vec::new() }
    }

    pub fn done(&self) -> bool {
        self.evals.len() >= self.cfg.budget
    }

    /// Propose the next configuration to evaluate (Eq. 9 for BO modes).
    pub fn next_candidate(&mut self, backend: &GpBackend) -> Vec<f64> {
        let u = self.next_unit(backend);
        self.space.from_unit(&u)
    }

    fn next_unit(&mut self, backend: &GpBackend) -> Vec<f64> {
        let k = self.evals.len();
        match self.cfg.strategy {
            Strategy::RandomSearch => self.sobol.next_point(),
            Strategy::GridSearch => {
                self.grid.get(k).cloned().unwrap_or_else(|| self.sobol.next_point())
            }
            Strategy::ConstrainedBo | Strategy::UnconstrainedBo => {
                if k < self.cfg.n_init {
                    return self.sobol.next_point();
                }
                self.acquire(backend)
            }
        }
    }

    /// Maximize the acquisition over a candidate pool (quasi-random +
    /// perturbations of the incumbent).
    fn acquire(&mut self, backend: &GpBackend) -> Vec<f64> {
        let mut cands: Vec<Vec<f64>> = self.sobol.take_points(96);
        if let Some(best_unit) = self.best_feasible().map(|e| e.unit.clone()) {
            for _ in 0..32 {
                let mut p = best_unit.clone();
                for v in p.iter_mut() {
                    *v = (*v + self.rng.normal(0.0, 0.08)).clamp(0.0, 1.0);
                }
                cands.push(p);
            }
        }
        let thetas: Vec<Vec<f64>> = self.evals.iter().map(|e| e.unit.clone()).collect();
        let uts: Vec<f64> = self.evals.iter().map(|e| e.ut).collect();
        // Memory in GB keeps the GP well-scaled.
        let mems: Vec<f64> = self.evals.iter().map(|e| e.mem_mb / 1024.0).collect();
        let hyper_ut = fit_hyper(&thetas, &uts);
        let hyper_mem = fit_hyper(&thetas, &mems);
        let best_ut = self
            .evals
            .iter()
            .filter(|e| self.feasible(e))
            .map(|e| e.ut)
            .fold(0.0, f64::max);
        let limit_gb = self.cfg.mem_limit_mb / 1024.0;
        let acq = backend
            .acquisition(&thetas, &uts, &mems, &cands, hyper_ut, hyper_mem, best_ut, limit_gb)
            .unwrap_or_default();
        if acq.is_empty() {
            return self.sobol.next_point();
        }
        let pick = match self.cfg.strategy {
            Strategy::UnconstrainedBo => acq
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.ei.partial_cmp(&b.1.ei).unwrap()),
            _ => {
                // Constrained: α = EI·PoF subject to PoF >= η; if nothing
                // passes η, fall back to the most-feasible candidate.
                let passing = acq
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| a.pof >= self.cfg.eta)
                    .max_by(|a, b| a.1.alpha.partial_cmp(&b.1.alpha).unwrap());
                passing.or_else(|| {
                    acq.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.pof.partial_cmp(&b.1.pof).unwrap())
                })
            }
        };
        cands[pick.map(|(i, _)| i).unwrap_or(0)].clone()
    }

    /// Record a probe measurement.  OOM evaluations censor memory just
    /// above the device limit and contribute zero throughput.
    pub fn record(&mut self, theta: Vec<f64>, ut: f64, mem_mb: f64, oom: bool) {
        let unit = self.space.to_unit(&theta);
        let mem_mb = if oom {
            (self.cfg.mem_limit_mb * 1.08).max(mem_mb)
        } else {
            mem_mb
        };
        self.evals.push(Evaluation { theta, unit, ut: if oom { 0.0 } else { ut }, mem_mb, oom });
    }

    fn feasible(&self, e: &Evaluation) -> bool {
        !e.oom && e.mem_mb <= self.cfg.mem_limit_mb
    }

    fn best_feasible(&self) -> Option<&Evaluation> {
        self.evals
            .iter()
            .filter(|e| self.feasible(e))
            .max_by(|a, b| a.ut.partial_cmp(&b.ut).unwrap())
    }

    /// Final recommendation after the budget is exhausted.
    /// Constrained mode keeps the safety mechanism inside the tuning loop:
    /// only feasible evaluations qualify.  Unconstrained mode picks the
    /// nominal best regardless of memory (the Table 5 † behaviour).
    pub fn best(&self) -> Option<&Evaluation> {
        match self.cfg.strategy {
            Strategy::UnconstrainedBo => self
                .evals
                .iter()
                .filter(|e| !e.oom) // a crashed eval has no throughput at all
                .max_by(|a, b| a.ut.partial_cmp(&b.ut).unwrap()),
            _ => self.best_feasible(),
        }
    }

    pub fn oom_count(&self) -> usize {
        self.evals.iter().filter(|e| e.oom).count()
    }
}

/// Axis-aligned grid with ~budget points: per-dim level counts chosen so
/// the full factorial stays near the budget.
fn grid_points(dims: usize, budget: usize) -> Vec<Vec<f64>> {
    let levels = (budget as f64).powf(1.0 / dims as f64).round().max(2.0) as usize;
    let mut pts: Vec<Vec<f64>> = vec![vec![]];
    for d in 0..dims {
        let mut next = Vec::new();
        for p in &pts {
            for l in 0..levels {
                let v = if levels == 1 { 0.5 } else { l as f64 / (levels - 1) as f64 };
                let mut q = p.clone();
                q.push(v);
                next.push(q);
            }
        }
        pts = next;
        // Full factorial too large: fill remaining dims with midpoints.
        if pts.len() >= budget * 4 {
            for p in pts.iter_mut() {
                p.resize(dims, 0.5);
            }
            let _ = d;
            break;
        }
    }
    pts
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic ground truth with an interior optimum and a memory cliff:
    /// ut rises with u0 but memory explodes past 0.7.
    fn eval_fn(u: &[f64]) -> (f64, f64, bool) {
        let ut = 5.0 + 10.0 * u[0] - 3.0 * (u[1] - 0.4).powi(2);
        let mem = 20_000.0 + 60_000.0 * u[0] * u[0];
        let oom = mem > 64_000.0;
        (ut, mem.min(66_000.0), oom)
    }

    fn space() -> ConfigSpace {
        ConfigSpace {
            params: vec![
                crate::config::ConfigParam { name: "a".into(), lo: 0.0, hi: 1.0, integer: false, log2: false, default: 0.1 },
                crate::config::ConfigParam { name: "b".into(), lo: 0.0, hi: 1.0, integer: false, log2: false, default: 0.5 },
            ],
        }
    }

    fn run(strategy: Strategy, seed: u64) -> ConfigTuner {
        let cfg = TunerConfig {
            strategy,
            budget: 30,
            n_init: 5,
            eta: 0.6,
            mem_limit_mb: 62_000.0,
            seed,
        };
        let mut t = ConfigTuner::new(space(), cfg);
        let b = GpBackend::Native;
        while !t.done() {
            let theta = t.next_candidate(&b);
            let u = t.space.to_unit(&theta);
            let (ut, mem, oom) = eval_fn(&u);
            t.record(theta, ut, mem, oom);
        }
        t
    }

    #[test]
    fn constrained_bo_stays_feasible_and_finds_good_config() {
        let t = run(Strategy::ConstrainedBo, 1);
        let best = t.best().expect("has feasible best");
        assert!(!best.oom);
        assert!(best.mem_mb <= 62_000.0);
        // Feasible optimum is at u0 ~= sqrt(42/60) = 0.836... memory-limited
        // to u0 with mem<=62k -> u0 <= 0.837; ut* ~= 13.3
        assert!(best.ut > 11.0, "constrained best {}", best.ut);
    }

    #[test]
    fn constrained_bo_ooms_less_than_unconstrained() {
        let mut c_ooms = 0;
        let mut u_ooms = 0;
        for seed in 0..5 {
            c_ooms += run(Strategy::ConstrainedBo, seed).oom_count();
            u_ooms += run(Strategy::UnconstrainedBo, seed).oom_count();
        }
        assert!(
            c_ooms * 2 < u_ooms.max(1) * 1 + c_ooms + 8,
            "constrained {c_ooms} vs unconstrained {u_ooms}"
        );
        assert!(c_ooms <= u_ooms, "constrained {c_ooms} vs unconstrained {u_ooms}");
    }

    #[test]
    fn bo_beats_random_and_grid_on_average() {
        let score = |s: Strategy| -> f64 {
            (0..4)
                .map(|seed| run(s, seed).best().map(|e| e.ut).unwrap_or(0.0))
                .sum::<f64>()
                / 4.0
        };
        let bo = score(Strategy::ConstrainedBo);
        let rs = score(Strategy::RandomSearch);
        let gs = score(Strategy::GridSearch);
        assert!(bo >= rs - 0.3, "bo {bo} vs random {rs}");
        assert!(bo >= gs - 0.3, "bo {bo} vs grid {gs}");
    }

    #[test]
    fn grid_points_cover_corners() {
        let g = grid_points(2, 30);
        assert!(g.iter().any(|p| p == &vec![0.0, 0.0]));
        assert!(g.iter().any(|p| p == &vec![1.0, 1.0]));
        assert!(g.len() >= 25);
    }

    #[test]
    fn oom_recording_censors_memory() {
        let mut t = ConfigTuner::new(space(), TunerConfig::default());
        t.record(vec![0.9, 0.5], 99.0, 50_000.0, true);
        assert_eq!(t.evals[0].ut, 0.0);
        assert!(t.evals[0].mem_mb > t.cfg.mem_limit_mb);
        assert!(t.best().is_none(), "an OOM eval can never be best");
    }
}
