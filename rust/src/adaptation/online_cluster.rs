//! Online workload clustering (paper §5.2): incremental centroid updates,
//! distance-threshold assignment, closest-pair merging at the cluster cap,
//! and exponential count decay for drift adaptation.

/// Tuning status of a workload cluster (paper: Pending / Tuning / Tuned).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneStatus {
    Pending,
    Tuning,
    Tuned,
}

#[derive(Debug, Clone)]
pub struct Cluster {
    pub id: u64,
    pub centroid: Vec<f64>,
    pub count: f64,
    pub status: TuneStatus,
    /// θ* once tuned, with its estimated sustainable throughput.
    pub best_config: Option<Vec<f64>>,
    pub best_ut: f64,
}

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Assignment distance threshold τ_d.
    pub tau_d: f64,
    /// Cluster cap L_max.
    pub l_max: usize,
    /// Count decay γ (applied per `decay()` call).
    pub gamma: f64,
    /// Clusters below this count are forgotten.
    pub min_count: f64,
    /// Recent-assignment window for dominant-cluster detection.
    pub history: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { tau_d: 0.35, l_max: 8, gamma: 0.995, min_count: 1.0, history: 256 }
    }
}

/// Incremental clustering state for one operator.
pub struct OnlineClustering {
    pub cfg: ClusterConfig,
    pub clusters: Vec<Cluster>,
    next_id: u64,
    recent: std::collections::VecDeque<u64>,
}

fn dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

impl OnlineClustering {
    pub fn new(cfg: ClusterConfig) -> Self {
        OnlineClustering { cfg, clusters: Vec::new(), next_id: 0, recent: Default::default() }
    }

    /// ASSIGNCLUSTER + UPDATECLUSTERSTATS (Algorithm 1, phase 1).
    /// Returns the assigned cluster id.
    pub fn assign(&mut self, x: &[f64]) -> u64 {
        let nearest = self
            .clusters
            .iter()
            .enumerate()
            .map(|(i, c)| (i, dist(&c.centroid, x)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let id = match nearest {
            Some((i, d)) if d <= self.cfg.tau_d => {
                let c = &mut self.clusters[i];
                c.count += 1.0;
                let n = c.count;
                for (cj, xj) in c.centroid.iter_mut().zip(x) {
                    *cj += (xj - *cj) / n;
                }
                c.id
            }
            _ => {
                if self.clusters.len() >= self.cfg.l_max {
                    self.merge_closest_pair();
                }
                let id = self.next_id;
                self.next_id += 1;
                self.clusters.push(Cluster {
                    id,
                    centroid: x.to_vec(),
                    count: 1.0,
                    status: TuneStatus::Pending,
                    best_config: None,
                    best_ut: 0.0,
                });
                id
            }
        };
        self.recent.push_back(id);
        if self.recent.len() > self.cfg.history {
            self.recent.pop_front();
        }
        id
    }

    fn merge_closest_pair(&mut self) {
        if self.clusters.len() < 2 {
            return;
        }
        let (mut bi, mut bj, mut bd) = (0, 1, f64::INFINITY);
        for i in 0..self.clusters.len() {
            for j in (i + 1)..self.clusters.len() {
                let d = dist(&self.clusters[i].centroid, &self.clusters[j].centroid);
                if d < bd {
                    (bi, bj, bd) = (i, j, d);
                }
            }
        }
        let cj = self.clusters.remove(bj);
        let ci = &mut self.clusters[bi];
        let total = ci.count + cj.count;
        for (a, b) in ci.centroid.iter_mut().zip(&cj.centroid) {
            *a = (*a * ci.count + b * cj.count) / total;
        }
        ci.count = total;
        // Keep the better-tuned side's configuration.
        if cj.status == TuneStatus::Tuned && (ci.status != TuneStatus::Tuned || cj.best_ut > ci.best_ut)
        {
            ci.status = cj.status;
            ci.best_config = cj.best_config;
            ci.best_ut = cj.best_ut;
        }
    }

    /// Periodic maintenance: decay counts, drop stale clusters.
    pub fn decay(&mut self) {
        let g = self.cfg.gamma;
        for c in &mut self.clusters {
            c.count *= g;
        }
        let min = self.cfg.min_count;
        self.clusters.retain(|c| c.count >= min);
    }

    /// GETDOMINANTCLUSTER: majority of recent assignments.
    pub fn dominant(&self) -> Option<&Cluster> {
        let mut counts: std::collections::HashMap<u64, usize> = Default::default();
        for &id in &self.recent {
            *counts.entry(id).or_default() += 1;
        }
        let id = counts.into_iter().max_by_key(|&(_, n)| n)?.0;
        self.clusters.iter().find(|c| c.id == id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Cluster> {
        self.clusters.iter_mut().find(|c| c.id == id)
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn cfg() -> ClusterConfig {
        ClusterConfig::default()
    }

    #[test]
    fn discovers_separated_regimes() {
        let mut oc = OnlineClustering::new(cfg());
        let mut rng = Rng::new(0);
        let centers = [[0.2, 0.1], [1.4, 0.8], [0.4, 1.6]];
        for i in 0..600 {
            let c = centers[i % 3];
            let x = [c[0] + rng.normal(0.0, 0.05), c[1] + rng.normal(0.0, 0.05)];
            oc.assign(&x);
        }
        assert_eq!(oc.n_clusters(), 3, "must discover exactly 3 regimes");
        // centroids near the truth
        for c in &oc.clusters {
            let ok = centers
                .iter()
                .any(|t| ((c.centroid[0] - t[0]).powi(2) + (c.centroid[1] - t[1]).powi(2)).sqrt() < 0.1);
            assert!(ok, "stray centroid {:?}", c.centroid);
        }
    }

    #[test]
    fn sequential_regimes_and_dominance() {
        let mut oc = OnlineClustering::new(cfg());
        let mut rng = Rng::new(1);
        for _ in 0..300 {
            oc.assign(&[0.2 + rng.normal(0.0, 0.03), 0.2]);
        }
        let d1 = oc.dominant().unwrap().id;
        for _ in 0..300 {
            oc.assign(&[1.5 + rng.normal(0.0, 0.03), 1.5]);
        }
        let d2 = oc.dominant().unwrap().id;
        assert_ne!(d1, d2, "dominant cluster must track the regime shift");
    }

    #[test]
    fn cap_enforced_by_merging() {
        let mut oc = OnlineClustering::new(ClusterConfig { l_max: 4, tau_d: 0.01, ..cfg() });
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            oc.assign(&[rng.f64() * 10.0, rng.f64() * 10.0]);
        }
        assert!(oc.n_clusters() <= 4);
    }

    #[test]
    fn decay_forgets_stale_clusters() {
        let mut oc = OnlineClustering::new(ClusterConfig { gamma: 0.5, ..cfg() });
        oc.assign(&[0.0, 0.0]);
        oc.assign(&[5.0, 5.0]);
        for _ in 0..10 {
            oc.assign(&[5.0, 5.0]);
            oc.decay();
        }
        assert_eq!(oc.n_clusters(), 1, "stale cluster should be forgotten");
        assert!(dist(&oc.clusters[0].centroid, &[5.0, 5.0]) < 0.5);
    }

    #[test]
    fn merge_keeps_tuned_config() {
        let mut oc = OnlineClustering::new(ClusterConfig { l_max: 2, tau_d: 0.01, ..cfg() });
        let a = oc.assign(&[0.0, 0.0]);
        let _b = oc.assign(&[1.0, 1.0]);
        oc.get_mut(a).unwrap().status = TuneStatus::Tuned;
        oc.get_mut(a).unwrap().best_config = Some(vec![42.0]);
        oc.get_mut(a).unwrap().best_ut = 9.0;
        // Third distinct point forces a merge of the closest pair.
        oc.assign(&[5.0, 5.0]);
        assert_eq!(oc.n_clusters(), 2);
        let tuned: Vec<_> = oc.clusters.iter().filter(|c| c.status == TuneStatus::Tuned).collect();
        assert_eq!(tuned.len(), 1);
        assert_eq!(tuned[0].best_config.as_deref(), Some(&[42.0][..]));
    }
}
