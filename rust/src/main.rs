//! Trident CLI launcher.
//!
//! ```text
//! trident run   --pipeline pdf|video|speech --policy trident|static|raydata|ds2|conttune|scoot
//!               [--duration 1800] [--nodes 8] [--seed 0] [--items 20000]
//!               [--native-gp] [--config cfg.json]
//! trident run   --pipelines pdf,speech [--weights 2,1]          # multi-tenant shared cluster
//! trident run   --tenancy tenancy.json                          # full tenant control
//! trident run   --pipelines pdf,speech --dynamics churn.json    # scripted cluster dynamics
//! trident run   --pipeline pdf --mtbf 600 --mttr 60             # stochastic node churn
//! trident run   --pipelines pdf,speech --shards 4               # sharded parallel sim tick
//! trident run   --pipelines pdf,speech --shards 4 --workers 2   # shard-pool worker threads
//! trident compare --pipeline pdf [--duration 1800] [--jobs J]   # all policies, parallel
//! trident compare --pipelines pdf,speech                        # multi-tenant comparison
//! trident sweep --pipeline pdf --seeds 4 --jobs 4 [--policies static,trident]
//!               [--duration 1800] [--seed 0]      # variant × seed grid, mean ± std
//! trident run   --pipelines pdf,speech --solver decomposed        # Dantzig–Wolfe solve path
//! trident milp-bench [--nodes 8|16]               # RQ6 solve times + cold-vs-warm pivots
//!               [--max-pivots N] [--assert-speedup S]   # solver perf gates (CI)
//!               [--decomp-tenants 64] [--assert-decomp-speedup S] # decomposition rung gate
//! trident bench-perf [--windows 4] [--rungs two-tenant-96,...] [--out BENCH_9.json]
//!               [--milp-budget-ms 10000] [--assert-speedup 2]  # RQ8 perf trajectory
//!               [--assert-shard-speedup 1.5]   # K=4 vs K=1 scaling gate (stress-512)
//!               [--assert-worker-speedup 1.3]  # W=4 vs W=1 gate (oversubscribed stress-10k)
//!               [--assert-trace-overhead 5]    # flight-recorder overhead gate (two-tenant-96)
//! trident run   --pipeline pdf --trace run.jsonl [--trace-format jsonl|chrome]
//!                                                 # flight-recorder trace (also compare|sweep)
//! trident trace-summary run.jsonl                 # bottleneck attribution + RunReport cross-check
//! ```
//!
//! A tenancy JSON file:
//! `{"tenants": [{"pipeline": "pdf", "id": "heavy", "weight": 2.0,
//!                "source_rate": 0.0, "items": 20000}, ...]}`

use std::time::{Duration, Instant};

use trident::config::{ClusterSpec, Json, Tenancy, TenantSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::dynamics::{DynamicsSpec, RecoveryPolicy};
use trident::harness::{self, Job};
use trident::report::{f2, Table};
use trident::sim::ItemAttrs;
use trident::trace::TraceFormat;
use trident::workload::{pdf, speech, video, Trace};

struct Args {
    map: std::collections::HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut map = std::collections::HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    map.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { map, flags }
    }

    fn get(&self, k: &str, default: &str) -> String {
        self.map.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    fn f64(&self, k: &str, default: f64) -> f64 {
        self.map.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn flag(&self, k: &str) -> bool {
        self.flags.iter().any(|f| f == k)
    }
}

fn try_policy_of(s: &str) -> Option<Policy> {
    match s.trim().to_ascii_lowercase().as_str() {
        "static" => Some(Policy::Static),
        "raydata" | "ray-data" => Some(Policy::RayData),
        "ds2" => Some(Policy::Ds2),
        "conttune" => Some(Policy::ContTune),
        "scoot" => Some(Policy::Scoot),
        "trident" => Some(Policy::Trident),
        _ => None,
    }
}

/// Strict: a typo'd policy name must not silently run a different
/// scheduler (the flag's absence still defaults to trident upstream).
fn policy_of(s: &str) -> Policy {
    match try_policy_of(s) {
        Some(p) => p,
        None => {
            eprintln!(
                "unknown policy '{}' (expected static|raydata|ds2|conttune|scoot|trident)",
                s.trim()
            );
            std::process::exit(2);
        }
    }
}

/// Strict: a typo'd pipeline name must not silently run a different
/// workload (same contract as `policy_of`; the flag's absence still
/// defaults to pdf upstream).
fn pipeline_of(name: &str, items: u64) -> (trident::config::PipelineSpec, Box<dyn Trace>, ItemAttrs) {
    match name.trim().to_ascii_lowercase().as_str() {
        "pdf" => (pdf::pipeline(), Box::new(pdf::trace(items)) as Box<dyn Trace>, pdf::src_attrs()),
        "video" => (video::pipeline(), Box::new(video::trace(items)), video::src_attrs()),
        "speech" => (speech::pipeline(), Box::new(speech::trace(items)), speech::src_attrs()),
        other => {
            eprintln!("unknown pipeline '{other}' (expected pdf|video|speech)");
            std::process::exit(2);
        }
    }
}

fn build_cfg(args: &Args) -> TridentConfig {
    let mut cfg = if let Some(path) = args.map.get("config") {
        let text = std::fs::read_to_string(path).expect("read --config file");
        TridentConfig::from_json(&Json::parse(&text).expect("parse --config json"))
    } else {
        TridentConfig::default()
    };
    if args.flag("native-gp") {
        cfg.native_gp = true;
    }
    if args.flag("join-colocate") {
        cfg.milp_join_colocation = true;
    }
    if let Some(v) = args.map.get("shards") {
        cfg.sim_shards = v.parse().unwrap_or_else(|_| {
            eprintln!("invalid --shards '{v}' (expected a positive integer)");
            std::process::exit(2);
        });
        if cfg.sim_shards == 0 {
            eprintln!("--shards must be at least 1");
            std::process::exit(2);
        }
    }
    if let Some(v) = args.map.get("workers") {
        cfg.sim_workers = v.parse().unwrap_or_else(|_| {
            eprintln!("invalid --workers '{v}' (expected a positive integer)");
            std::process::exit(2);
        });
        if cfg.sim_workers == 0 {
            // 0 (auto) is the config-file spelling; on the CLI the flag's
            // absence already means auto, so an explicit 0 is a typo.
            eprintln!("--workers must be at least 1 (omit the flag for auto)");
            std::process::exit(2);
        }
    }
    if let Some(v) = args.map.get("solver") {
        // Strict, mirroring --policy: a typo'd backend must not silently
        // run the other solve path.
        cfg.solver = trident::config::SolverBackend::parse(v.trim()).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    }
    cfg
}

/// True when the invocation names more than one tenant (either flag).
fn multi_tenant(args: &Args) -> bool {
    args.map.contains_key("tenancy") || args.map.contains_key("pipelines")
}

/// Cluster-dynamics spec from the CLI: `--dynamics file.json` (scripted
/// timeline) and/or `--mtbf S [--mttr S]` (stochastic node churn), with
/// `--recovery requeue|loss`.  Strict, mirroring `--pipeline`: parse
/// errors, unknown event kinds, and bad timestamps abort with exit
/// code 2 rather than silently running a different scenario.
fn dynamics_of(args: &Args) -> Option<DynamicsSpec> {
    let mut spec = if let Some(path) = args.map.get("dynamics") {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("cannot read --dynamics file '{path}': {e}");
            std::process::exit(2);
        });
        let j = Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("cannot parse --dynamics json: {e}");
            std::process::exit(2);
        });
        DynamicsSpec::from_json(&j).unwrap_or_else(|e| {
            eprintln!("invalid --dynamics spec: {e}");
            std::process::exit(2);
        })
    } else if args.map.contains_key("mtbf") || args.map.contains_key("mttr") {
        DynamicsSpec::default()
    } else {
        return None;
    };
    if let Some(v) = args.map.get("mtbf") {
        spec.mtbf_s = v.parse().unwrap_or_else(|_| {
            eprintln!("invalid --mtbf '{v}' (expected seconds)");
            std::process::exit(2);
        });
    }
    if let Some(v) = args.map.get("mttr") {
        spec.mttr_s = v.parse().unwrap_or_else(|_| {
            eprintln!("invalid --mttr '{v}' (expected seconds)");
            std::process::exit(2);
        });
    }
    if spec.mtbf_s > 0.0 && spec.mttr_s <= 0.0 {
        // Strict, matching the JSON path: never silently invent a repair
        // time the user did not ask for.
        eprintln!("--mtbf requires a positive --mttr (mean time to recovery, seconds)");
        std::process::exit(2);
    }
    if let Some(v) = args.map.get("recovery") {
        spec.recovery = RecoveryPolicy::parse(v).unwrap_or_else(|e| {
            eprintln!("invalid --recovery: {e}");
            std::process::exit(2);
        });
    }
    Some(spec)
}

/// `--weights 2,1` parallel to `--pipelines` (strict: counts must match,
/// entries must parse).
fn weights_of(args: &Args, n: usize) -> Vec<f64> {
    match args.map.get("weights") {
        None => vec![1.0; n],
        Some(list) => {
            let ws: Vec<f64> = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse().unwrap_or_else(|_| {
                        eprintln!("invalid --weights entry '{s}' (expected a number)");
                        std::process::exit(2);
                    })
                })
                .collect();
            if ws.len() != n {
                eprintln!("--weights names {} entries for {} pipelines", ws.len(), n);
                std::process::exit(2);
            }
            ws
        }
    }
}

/// Tenant list from the CLI: `--tenancy file.json` (full control) or
/// `--pipelines a,b[,c]` (ids = pipeline names, weights from `--weights`).
/// Strict, mirroring `--pipeline`: unknown pipeline names and duplicate
/// tenant ids abort with exit code 2 rather than silently running a
/// different tenancy.
fn tenancy_of(args: &Args) -> (Tenancy, Vec<Box<dyn Trace>>, Vec<ItemAttrs>) {
    let default_items = args.f64("items", 50_000.0) as u64;
    let mut tenants = Vec::new();
    let mut traces: Vec<Box<dyn Trace>> = Vec::new();
    let mut srcs = Vec::new();
    if let Some(path) = args.map.get("tenancy") {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("cannot read --tenancy file '{path}': {e}");
            std::process::exit(2);
        });
        let j = Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("cannot parse --tenancy json: {e}");
            std::process::exit(2);
        });
        let Some(arr) = j.get("tenants").and_then(Json::as_arr) else {
            eprintln!("--tenancy json must carry a tenants[] array");
            std::process::exit(2);
        };
        for tj in arr {
            let pname = tj.str_or("pipeline", "").to_string();
            if pname.is_empty() {
                eprintln!("--tenancy entry missing its pipeline name");
                std::process::exit(2);
            }
            let items = tj.f64_or("items", default_items as f64) as u64;
            let (pl, trace, src) = pipeline_of(&pname, items);
            tenants.push(TenantSpec {
                id: tj.str_or("id", &pname).to_string(),
                pipeline: pl,
                weight: tj.f64_or("weight", 1.0),
                source_rate: tj.f64_or("source_rate", 0.0),
            });
            traces.push(trace);
            srcs.push(src);
        }
    } else {
        let list = args.get("pipelines", "");
        let names: Vec<&str> =
            list.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        if names.is_empty() {
            eprintln!("--pipelines must name at least one pipeline (e.g. --pipelines pdf,speech)");
            std::process::exit(2);
        }
        let weights = weights_of(args, names.len());
        for (name, w) in names.iter().zip(weights) {
            let (pl, trace, src) = pipeline_of(name, default_items);
            tenants.push(TenantSpec { id: pl.name.clone(), pipeline: pl, weight: w, source_rate: 0.0 });
            traces.push(trace);
            srcs.push(src);
        }
    }
    let tenancy = Tenancy { tenants };
    if let Err(e) = tenancy.validate() {
        eprintln!("invalid tenancy: {e}");
        std::process::exit(2);
    }
    (tenancy, traces, srcs)
}

/// Variant for a CLI-selected policy (SCOOT gets its offline-tuned
/// initial configs; under a multi-tenant invocation they are tuned per
/// merged operator against each tenant's own nominal attrs).
fn variant_of(args: &Args, policy: Policy) -> Variant {
    match policy {
        Policy::Trident => Variant::trident(),
        Policy::Scoot => {
            if multi_tenant(args) {
                let (tenancy, _, srcs) = tenancy_of(args);
                let (spec, view) = tenancy.merged().unwrap_or_else(|e| {
                    eprintln!("invalid tenancy: {e}");
                    std::process::exit(2);
                });
                harness::scoot_variant_merged(&spec, &view, &srcs)
            } else {
                let items = args.f64("items", 50_000.0) as u64;
                let (pl, _, src) = pipeline_of(&args.get("pipeline", "pdf"), items);
                harness::scoot_variant(&pl, src)
            }
        }
        p => Variant::baseline(p),
    }
}

/// Build a coordinator from the CLI flags for one (variant, seed) cell.
fn build_coordinator(args: &Args, variant: Variant, seed: u64) -> Coordinator {
    let nodes = args.f64("nodes", 8.0) as usize;
    let cluster = ClusterSpec::homogeneous(nodes, 256.0, 1024.0, 8, 65536.0, 12_500.0);
    let cfg = build_cfg(args);
    let mut coord = if multi_tenant(args) {
        let (tenancy, traces, srcs) = tenancy_of(args);
        Coordinator::new_tenancy(tenancy, cluster, traces, cfg, variant, srcs, seed)
            .unwrap_or_else(|e| {
                eprintln!("invalid tenancy: {e}");
                std::process::exit(2);
            })
    } else {
        let items = args.f64("items", 50_000.0) as u64;
        let (pl, trace, src) = pipeline_of(&args.get("pipeline", "pdf"), items);
        Coordinator::new(pl, cluster, trace, cfg, variant, src, seed)
    };
    if let Some(spec) = dynamics_of(args) {
        coord.set_dynamics(spec).unwrap_or_else(|e| {
            eprintln!("invalid --dynamics spec: {e}");
            std::process::exit(2);
        });
    }
    coord
}

/// `--trace <path>` (optionally `--trace-format jsonl|chrome`).  Strict:
/// a bare `--trace`, a `--trace-format` without `--trace`, or an unknown
/// format all abort with exit 2 instead of silently running untraced.
fn trace_of(args: &Args) -> Option<(String, TraceFormat)> {
    if args.flag("trace") {
        eprintln!("--trace needs a file path");
        std::process::exit(2);
    }
    if args.flag("trace-format") {
        eprintln!("--trace-format needs a value (jsonl|chrome)");
        std::process::exit(2);
    }
    let path = args.map.get("trace").cloned();
    let fmt_s = args.map.get("trace-format").cloned();
    if path.is_none() && fmt_s.is_some() {
        eprintln!("--trace-format requires --trace <path>");
        std::process::exit(2);
    }
    let path = path?;
    let fmt = match fmt_s.as_deref() {
        None => TraceFormat::Jsonl,
        Some(s) => TraceFormat::parse(s).unwrap_or_else(|| {
            eprintln!("unknown --trace-format {s:?} (expected jsonl or chrome)");
            std::process::exit(2);
        }),
    };
    Some((path, fmt))
}

fn run_one(args: &Args, policy: Policy) -> trident::coordinator::RunReport {
    let variant = variant_of(args, policy);
    let mut coord = build_coordinator(args, variant, args.f64("seed", 0.0) as u64);
    if let Some((path, fmt)) = trace_of(args) {
        coord.set_trace(&path, fmt);
    }
    coord.run(args.f64("duration", 1800.0))
}

/// Policies named by `--policies a,b,c` (default: all but SCOOT, whose
/// offline tuning phase is opt-in).  Tokens are trimmed and unknown names
/// abort rather than silently substituting a different scheduler.
fn policies_of(args: &Args, key: &str, default: &str) -> Vec<Policy> {
    args.get(key, default)
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(policy_of)
        .collect()
}

/// OpSched rows for a (possibly merged) spec against nominal attrs.
/// `with_candidates` adds a mid-rollout candidate config per tunable op
/// (the rq6 shape): the rolling `b_i` variables go fractional in the
/// relaxation, so the instance actually branches — the regime where
/// basis warm starts pay off.
fn bench_ops(
    spec: &trident::config::PipelineSpec,
    nominal: &[ItemAttrs],
    d_i: &[f64],
    nodes: usize,
    with_candidates: bool,
) -> Vec<trident::scheduling::OpSched> {
    spec.operators
        .iter()
        .enumerate()
        .map(|(i, o)| trident::scheduling::OpSched {
            name: o.name.clone(),
            ut_cur: trident::sim::service::true_unit_rate(
                &o.service,
                &o.config_space.default_config(),
                &nominal[i],
            ),
            ut_cand: (with_candidates && o.tunable).then_some(1.5),
            n_new: 0,
            n_old: if with_candidates && o.tunable { 4 } else { 0 },
            cpu: o.cpu,
            mem_gb: o.mem_gb,
            accels: o.accels,
            out_mb: o.out_mb,
            d_i: d_i[i],
            h_start: o.start_s,
            h_stop: o.stop_s,
            h_cold: o.cold_s,
            cur_x: vec![0; nodes],
        })
        .collect()
}

/// The joint two-tenant pdf+speech MILP input (union of operators,
/// weighted max-min objective over shared nodes) — the `milp-bench`
/// headline scenario.
fn two_tenant_input(nodes: usize, with_candidates: bool) -> trident::scheduling::MilpInput {
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec { id: "speech".into(), pipeline: speech::pipeline(), weight: 1.0, source_rate: 0.0 },
        ],
    };
    let (spec, view) = tenancy.merged().expect("pdf+speech tenancy is valid");
    let cluster = ClusterSpec::homogeneous(nodes, 256.0, 1024.0, 8, 65536.0, 12_500.0);
    let roots: Vec<(usize, ItemAttrs)> = view
        .sources
        .iter()
        .copied()
        .zip(vec![pdf::src_attrs(), speech::src_attrs()])
        .collect();
    let nominal = trident::coordinator::nominal_attrs_rooted(&spec, &roots);
    let (d_i, d_o) = spec.amplification();
    trident::scheduling::MilpInput {
        ops: bench_ops(&spec, &nominal, &d_i, nodes, with_candidates),
        edges: spec.edges.clone(),
        nodes: cluster.nodes,
        d_o,
        tenants: trident::scheduling::MilpTenant::from_view(&view),
        op_tenant: view.op_tenant.clone(),
        t_sched: 30.0,
        lambda1: 1e-4,
        lambda2: 1e-6,
        b_max: 2,
        placement_aware: true,
        join_colocate: false,
        all_at_once: false,
    }
}

fn round2d(v: &[f64]) -> Vec<f64> {
    v.iter().map(|t| (t * 100.0).round() / 100.0).collect()
}

/// `nt` heterogeneous stress-chain tenants sharing a small CPU cluster —
/// the `milp-bench` decomposition rung.  Network-agnostic (no flow rows)
/// so the union MILP is pure capacity coupling; per-tenant skews on rate,
/// weight, and CPU footprint keep the weighted max-min LP relaxation
/// fractional, so the monolithic branch-and-bound really has to branch
/// across tenants while every per-tenant pricing block stays at 4 ops.
fn decomp_stress_input(nt: usize, nodes: usize) -> trident::scheduling::MilpInput {
    let spec = stress_spec();
    let (d_i, d_o) = spec.amplification();
    let cluster = ClusterSpec::homogeneous(nodes, 64.0, 512.0, 0, 0.0, 12_500.0);
    let cpu_skew = [1.0, 1.3, 0.9, 1.1];
    let mut ops = Vec::new();
    let mut edges = Vec::new();
    let mut op_tenant = Vec::new();
    let mut tenants = Vec::new();
    for t in 0..nt {
        let base = ops.len();
        for (i, o) in spec.operators.iter().enumerate() {
            ops.push(trident::scheduling::OpSched {
                name: format!("s{t:02}.{}", o.name),
                ut_cur: 50.0 + (t as f64) * 0.7 + (i as f64) * 3.0,
                ut_cand: None,
                n_new: 0,
                n_old: 0,
                cpu: o.cpu * cpu_skew[(t + i) % cpu_skew.len()],
                mem_gb: o.mem_gb,
                accels: 0,
                out_mb: o.out_mb,
                d_i: d_i[i],
                h_start: o.start_s,
                h_stop: o.stop_s,
                h_cold: o.cold_s,
                cur_x: vec![0; nodes],
            });
            op_tenant.push(t);
        }
        for &(u, v) in &spec.edges {
            edges.push((base + u, base + v));
        }
        tenants.push(trident::scheduling::MilpTenant {
            name: format!("stress-{t:02}"),
            weight: 1.0 + ((t % 7) as f64) * 0.25,
            d_o,
        });
    }
    trident::scheduling::MilpInput {
        ops,
        edges,
        nodes: cluster.nodes,
        d_o,
        tenants,
        op_tenant,
        t_sched: 30.0,
        lambda1: 1e-4,
        lambda2: 1e-6,
        b_max: 2,
        placement_aware: false,
        join_colocate: false,
        all_at_once: false,
    }
}

/// `trident milp-bench`: single-tenant solve times, then the two-tenant
/// pdf+speech cold-vs-warm pivot comparison (the RQ6 overhead headline):
/// the dense baseline and the warm-started revised backend solve the
/// identical MILP at an equal deterministic node cap (pivot totals are
/// machine-independent), plus a drifted round-2 re-solve through the
/// cross-round basis cache.  `--max-pivots N` bounds the warm pivot
/// total and `--assert-speedup S` requires dense ≥ S× warm pivots with
/// matching plans — CI uses these so solver perf regressions fail
/// loudly instead of silently inflating RQ6.
fn milp_bench(args: &Args) {
    use trident::scheduling::{solve_with_options, BasisCache};
    use trident::solver::{LpBackend, MilpOptions};

    let nodes = args.f64("nodes", 8.0) as usize;
    for pipeline in ["pdf", "video", "speech"] {
        let (pl, _, src) = pipeline_of(pipeline, 1000);
        let cluster = ClusterSpec::homogeneous(nodes, 256.0, 1024.0, 8, 65536.0, 12_500.0);
        let nominal = trident::coordinator::nominal_attrs(&pl, src);
        let (d_i, d_o) = pl.amplification();
        let input = trident::scheduling::MilpInput {
            ops: bench_ops(&pl, &nominal, &d_i, nodes, false),
            edges: pl.edges.clone(),
            nodes: cluster.nodes,
            d_o,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        };
        let t0 = Instant::now();
        let plan = trident::scheduling::solve(&input, Duration::from_secs(10));
        println!(
            "{pipeline} @ {nodes} nodes: {:.0} ms, T={:.2}, status {:?} ({} B&B nodes, {} pivots, warm-start hit rate {:.1}%)",
            t0.elapsed().as_secs_f64() * 1e3,
            plan.t_pred,
            plan.status,
            plan.stats.nodes,
            plan.stats.pivots,
            plan.stats.warm_hit_rate() * 100.0,
        );
    }

    let cap = 96usize;
    let budget = Duration::from_secs(120);
    let dense_opts =
        MilpOptions { backend: LpBackend::Dense, warm_basis: false, max_nodes: Some(cap) };
    let warm_opts = MilpOptions { max_nodes: Some(cap), ..MilpOptions::default() };

    let input = two_tenant_input(nodes, true);
    let t0 = Instant::now();
    let dense = solve_with_options(&input, budget, &mut BasisCache::new(), &dense_opts);
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut cache = BasisCache::new();
    let t0 = Instant::now();
    let warm = solve_with_options(&input, budget, &mut cache, &warm_opts);
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Round 2: same shape, drifted rates — what the coordinator's next
    // scheduling round hands the solver.
    let mut input2 = input.clone();
    for o in &mut input2.ops {
        o.ut_cur *= 1.03;
    }
    let t0 = Instant::now();
    let round2 = solve_with_options(&input2, budget, &mut cache, &warm_opts);
    let round2_ms = t0.elapsed().as_secs_f64() * 1e3;

    let pb_equal = dense.p == warm.p && dense.b == warm.b;
    let plans_identical = pb_equal && dense.x == warm.x;
    // The well-defined "pure speed change" contract under degenerate
    // optima (free node-0 placement, 1e-4 B&B pruning gap) is objective
    // equality; exact plan equality is reported but only asserted at the
    // objective level.  Bit-identical *production* behavior is pinned by
    // tests/policy_parity.rs and tests/tenancy.rs.
    let obj_equal = dense
        .t_tenant
        .iter()
        .zip(&warm.t_tenant)
        .all(|(a, b)| (a - b).abs() <= 1e-3 * (1.0 + a.abs()));
    let speedup = dense.stats.pivots as f64 / warm.stats.pivots.max(1) as f64;
    println!("pdf+speech @ {nodes} nodes, node cap {cap}:");
    println!(
        "  dense-cold   : {dense_ms:.0} ms, pivots={} phase1={} nodes={} T={:?} status {:?}",
        dense.stats.pivots,
        dense.stats.phase1_pivots,
        dense.stats.nodes,
        round2d(&dense.t_tenant),
        dense.status,
    );
    println!(
        "  revised-warm : {warm_ms:.0} ms, pivots={} phase1={} nodes={} T={:?} status {:?}, warm-start hit rate {:.1}%",
        warm.stats.pivots,
        warm.stats.phase1_pivots,
        warm.stats.nodes,
        round2d(&warm.t_tenant),
        warm.status,
        warm.stats.warm_hit_rate() * 100.0,
    );
    println!(
        "  round2-cached: {round2_ms:.0} ms, pivots={} root_warm={} warm-start hit rate {:.1}%",
        round2.stats.pivots,
        round2.stats.root_warm,
        round2.stats.warm_hit_rate() * 100.0,
    );
    println!(
        "  pivot-speedup={speedup:.2}x objectives-equal={obj_equal} \
         plans-identical={plans_identical} p/b-equal={pb_equal}"
    );

    // ---- decomposition rung: 64 heterogeneous stress tenants ---------
    // The union MILP couples tenants only through shared node capacity;
    // monolithic pays O(m^2)-per-pivot on the union's ~600 rows and
    // branches over every tenant's integer columns at once, while the
    // decomposed path prices 64 four-op subproblems against a small
    // master.  Identical `MilpInput` feeds both paths.
    let dec_nt = args.f64("decomp-tenants", 64.0) as usize;
    let dec_budget = Duration::from_secs(120);
    let dinput = decomp_stress_input(dec_nt, 6);
    let t0 = Instant::now();
    let mono = solve_with_options(&dinput, dec_budget, &mut BasisCache::new(), &MilpOptions::default());
    let mono_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut tenant_caches = std::collections::HashMap::new();
    let t0 = Instant::now();
    let dec = trident::scheduling::solve_decomposed(
        &dinput,
        dec_budget,
        &mut BasisCache::new(),
        &mut tenant_caches,
        &MilpOptions::default(),
        &trident::scheduling::DecompOptions::default(),
    );
    let dec_ms = t0.elapsed().as_secs_f64() * 1e3;
    let decomp_speedup = mono_ms / dec_ms.max(1e-9);
    // One-sided: the decomposed plan must be within 0.5% of monolithic
    // (beating a budget-capped monolithic incumbent is fine).
    let decomp_obj_ok = dec.obj >= mono.obj - 0.005 * mono.obj.abs();
    println!("decomposition @ {dec_nt} stress tenants, 6 nodes:");
    println!(
        "  monolithic : {mono_ms:.0} ms, obj={:.6} status {:?} ({} B&B nodes, {} pivots, \
         build {:.0} ms, root LP {:.0} ms, B&B {:.0} ms)",
        mono.obj,
        mono.status,
        mono.stats.nodes,
        mono.stats.pivots,
        mono.stats.build_ms,
        mono.stats.root_lp_ms,
        mono.stats.bnb_ms,
    );
    println!(
        "  decomposed : {dec_ms:.0} ms, obj={:.6} status {:?} (pricing rounds={} columns={} \
         pricing {:.0} ms, {} pivots, warm-start hit rate {:.1}%)",
        dec.obj,
        dec.status,
        dec.stats.pricing_rounds,
        dec.stats.columns,
        dec.stats.pricing_ms,
        dec.stats.pivots,
        dec.stats.warm_hit_rate() * 100.0,
    );
    println!(
        "  decomp-speedup={decomp_speedup:.2}x objective-within-0.5%={decomp_obj_ok}"
    );

    let mut failed = false;
    if let Some(s) = args.map.get("assert-decomp-speedup").and_then(|v| v.parse::<f64>().ok()) {
        if decomp_speedup < s {
            eprintln!("FAIL: decomposition speedup {decomp_speedup:.2}x below required {s}x");
            failed = true;
        }
        if !decomp_obj_ok {
            eprintln!(
                "FAIL: decomposed objective {:.6} below monolithic {:.6} - 0.5%",
                dec.obj, mono.obj
            );
            failed = true;
        }
    }
    if let Some(maxp) = args.map.get("max-pivots").and_then(|v| v.parse::<usize>().ok()) {
        if warm.stats.pivots > maxp {
            eprintln!("FAIL: warm two-tenant pivots {} exceed budget {maxp}", warm.stats.pivots);
            failed = true;
        }
    }
    if let Some(s) = args.map.get("assert-speedup").and_then(|v| v.parse::<f64>().ok()) {
        if speedup < s {
            eprintln!("FAIL: pivot speedup {speedup:.2}x below required {s}x");
            failed = true;
        }
        if !obj_equal {
            eprintln!("FAIL: dense and warm objectives disagree (pure speed change violated)");
            failed = true;
        }
        if !round2.stats.root_warm {
            eprintln!("FAIL: round-2 solve did not warm start from the cached basis");
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
}

/// One rung of the `bench-perf` scale ladder (pinned: trajectory numbers
/// are only comparable across PRs if the scenario never moves).
struct Rung {
    name: &'static str,
    nodes: usize,
    /// Simulated seconds per measured window.
    window_s: f64,
    /// 0 = the pinned two-tenant pdf+speech scenario; >0 = that many
    /// synthetic stress-chain tenants (the shard-scaling rungs: K shards
    /// need ≥K tenants to spread over).
    stress_tenants: usize,
}

const BENCH_RUNGS: &[Rung] = &[
    Rung { name: "two-tenant-16", nodes: 16, window_s: 30.0, stress_tenants: 0 },
    Rung { name: "two-tenant-96", nodes: 96, window_s: 10.0, stress_tenants: 0 },
    Rung { name: "two-tenant-512", nodes: 512, window_s: 5.0, stress_tenants: 0 },
    Rung { name: "stress-512", nodes: 512, window_s: 2.0, stress_tenants: 8 },
    Rung { name: "stress-10k", nodes: 10_000, window_s: 2.0, stress_tenants: 100 },
];

/// Raw-speed measurement of one rung in one transfer mode.
struct ModeStats {
    wall_ms: Vec<f64>,
    events: u64,
    records: u64,
    peak_heap: usize,
    peak_in_flight: usize,
}

impl ModeStats {
    fn wall_s(&self) -> f64 {
        (self.wall_ms.iter().sum::<f64>() / 1e3).max(1e-9)
    }

    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s()
    }

    fn records_per_sec(&self) -> f64 {
        self.records as f64 / self.wall_s()
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("events", Json::num(self.events as f64)),
            ("records", Json::num(self.records as f64)),
            ("events_per_sec", Json::num(self.events_per_sec().round())),
            ("records_per_sec", Json::num(self.records_per_sec().round())),
            ("peak_heap_entries", Json::num(self.peak_heap as f64)),
            ("peak_in_flight_transfers", Json::num(self.peak_in_flight as f64)),
            (
                "wall_ms_per_window",
                Json::arr_f64(
                    &self.wall_ms.iter().map(|m| (m * 10.0).round() / 10.0).collect::<Vec<f64>>(),
                ),
            ),
        ])
    }
}

/// Endless item mix for the synthetic stress rung: ~1 MB records so the
/// 200 MB/s bench links, not the CPUs, are the scarce resource.
fn stress_dist() -> trident::workload::ItemDist {
    trident::workload::ItemDist {
        tokens_in: (4.0, 0.3),
        tokens_out: (3.0, 0.3),
        pixels_m: (0.0, 0.1),
        frames: (0.0, 0.0),
        size_mb: (0.0, 0.25),
    }
}

/// 4-op CPU chain for the 10k-node stress rung: no accelerators (placement
/// can never fail for capacity) and every hop forced cross-node by the
/// bench's round-robin placement.
fn stress_spec() -> trident::config::PipelineSpec {
    use trident::config::{
        ConfigSpace, CostW, FeatureExtractor, OperatorKind, OperatorSpec, PipelineSpec,
        ServiceModel,
    };
    let cpu = |name: &str| OperatorSpec {
        name: name.into(),
        kind: OperatorKind::CpuSync,
        cpu: 1.0,
        mem_gb: 1.0,
        accels: 0,
        fanout: 1.0,
        out_mb: 1.0,
        start_s: 0.5,
        stop_s: 0.5,
        cold_s: 2.0,
        tunable: false,
        config_space: ConfigSpace::default(),
        service: ServiceModel::Cpu {
            base_rate: 50.0,
            ref_cost: 1.0,
            cost: CostW { konst: 1.0, ..Default::default() },
        },
        features: FeatureExtractor::Cost,
        child_scale: [1.0; 4],
        queue_cap: 64,
    };
    PipelineSpec::chain("stress", vec![cpu("ingest"), cpu("decode"), cpu("transform"), cpu("sink")])
}

/// Static placement plan: instances of op `i` land on nodes
/// `(i + k·n_ops) mod nodes`, so successive operators sit on different
/// nodes and (nearly) every pipeline edge pays a real cross-node
/// transfer — the transfer-heavy regime the overhaul targets.
fn bench_placement(
    spec: &trident::config::PipelineSpec,
    nodes: usize,
) -> Vec<(usize, usize, Vec<f64>)> {
    let n_ops = spec.n_ops();
    let per_op = (nodes / n_ops).max(1);
    let mut plan = Vec::new();
    for (i, o) in spec.operators.iter().enumerate() {
        let theta = o.config_space.default_config();
        for k in 0..per_op {
            plan.push((i, (i + k * n_ops) % nodes, theta.clone()));
        }
    }
    plan
}

/// `n` identical stress-chain tenants with one endless uniform trace
/// each.  Ids are unique ("stress-00"…); `Tenancy::merged` namespaces the
/// duplicated operator names per tenant, so the merged spec stays valid.
fn stress_tenancy(n: usize) -> (Tenancy, Vec<Box<dyn Trace>>) {
    let tenants = (0..n)
        .map(|t| TenantSpec {
            id: format!("stress-{t:02}"),
            pipeline: stress_spec(),
            weight: 1.0,
            source_rate: 0.0,
        })
        .collect();
    let traces = (0..n)
        .map(|_| {
            Box::new(trident::workload::UniformTrace { dist: stress_dist(), regime: 0 })
                as Box<dyn Trace>
        })
        .collect();
    (Tenancy { tenants }, traces)
}

/// The rung's merged scenario — byte-identical inputs for the serial and
/// sharded builds (the drift check compares their event/record totals).
fn bench_scenario(
    rung: &Rung,
) -> (trident::config::PipelineSpec, trident::config::TenancyView, Vec<Box<dyn Trace>>) {
    let (tenancy, traces) = if rung.stress_tenants > 0 {
        stress_tenancy(rung.stress_tenants)
    } else {
        let tenancy = Tenancy {
            tenants: vec![
                TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
                TenantSpec { id: "speech".into(), pipeline: speech::pipeline(), weight: 1.0, source_rate: 0.0 },
            ],
        };
        let traces: Vec<Box<dyn Trace>> =
            vec![Box::new(pdf::trace(10_000_000)), Box::new(speech::trace(10_000_000))];
        (tenancy, traces)
    };
    let (spec, view) = tenancy.merged().expect("bench tenancy is valid");
    (spec, view, traces)
}

/// Low egress (vs the 12.5 GB/s production default) keeps the rungs
/// link-bound: thousands of records serialize behind the links, which is
/// exactly the population the two transfer modes store differently.
fn bench_cluster(rung: &Rung) -> ClusterSpec {
    ClusterSpec::homogeneous(rung.nodes, 256.0, 1024.0, 8, 65536.0, 200.0)
}

/// Build the rung's simulator with static placement; `seed_stream` picks
/// the legacy one-event-per-record transfer path (the measured baseline)
/// or the batched link FIFOs.  Both modes get byte-identical inputs.
fn bench_sim(rung: &Rung, seed_stream: bool) -> trident::sim::PipelineSim {
    let (spec, view, traces) = bench_scenario(rung);
    let plan = bench_placement(&spec, rung.nodes);
    let mut sim =
        trident::sim::PipelineSim::new_tenancy(spec, view, bench_cluster(rung), traces, 11);
    sim.set_seed_event_stream(seed_stream);
    for (op, node, theta) in plan {
        let placed = (0..rung.nodes)
            .any(|probe| sim.add_instance(op, (node + probe) % rung.nodes, theta.clone()).is_ok());
        assert!(placed, "bench placement failed for op {op} on rung {}", rung.name);
    }
    sim
}

/// The same scenario partitioned over `shards` tenant shards advanced by
/// `workers` pool threads (0 = auto; batched transfer mode — the sharded
/// path has no seed-stream arm).
fn bench_sim_sharded(rung: &Rung, shards: usize, workers: usize) -> trident::sim::ShardedSim {
    let (spec, view, traces) = bench_scenario(rung);
    let plan = bench_placement(&spec, rung.nodes);
    let mut sim =
        trident::sim::ShardedSim::new_tenancy(spec, view, bench_cluster(rung), traces, 11, shards);
    sim.set_workers(workers);
    for (op, node, theta) in plan {
        let placed = (0..rung.nodes)
            .any(|probe| sim.add_instance(op, (node + probe) % rung.nodes, theta.clone()).is_ok());
        assert!(placed, "bench placement failed for op {op} on rung {}", rung.name);
    }
    sim
}

/// Drive one simulator through `windows` windows, timing each.
fn bench_run(rung: &Rung, seed_stream: bool, windows: usize) -> ModeStats {
    let mut sim = bench_sim(rung, seed_stream);
    let mut wall_ms = Vec::with_capacity(windows);
    for w in 0..windows {
        let t_end = (w + 1) as f64 * rung.window_s;
        let (_, ms) = harness::stopwatch_ms(|| sim.run_until(t_end));
        wall_ms.push(ms);
    }
    ModeStats {
        wall_ms,
        events: sim.engine.events_processed,
        records: sim.processed_total.iter().sum(),
        peak_heap: sim.peak_heap_entries(),
        peak_in_flight: sim.peak_in_flight_transfers(),
    }
}

/// Drive one sharded simulator through `windows` windows, timing each.
/// Returns the stats plus the clamps the sim actually ran with
/// (`k_effective`, `workers_effective`) so clamped rungs are visible in
/// the artifact instead of hidden.
fn bench_run_sharded(
    rung: &Rung,
    shards: usize,
    workers: usize,
    windows: usize,
) -> (ModeStats, usize, usize) {
    let mut sim = bench_sim_sharded(rung, shards, workers);
    let (k_eff, w_eff) = (sim.shard_count(), sim.workers_effective());
    let mut wall_ms = Vec::with_capacity(windows);
    for w in 0..windows {
        let t_end = (w + 1) as f64 * rung.window_s;
        let (_, ms) = harness::stopwatch_ms(|| sim.run_until(t_end));
        wall_ms.push(ms);
    }
    let stats = ModeStats {
        wall_ms,
        events: sim.events_processed(),
        records: (0..sim.spec.n_ops()).map(|op| sim.processed_total(op)).sum(),
        peak_heap: sim.peak_heap_entries(),
        peak_in_flight: sim.peak_in_flight_transfers(),
    };
    (stats, k_eff, w_eff)
}

/// One arm of the trace-overhead pair: drive the rung's sharded sim one
/// window at a time with a per-window metrics flush (the coordinator
/// always pays that), and — when `traced` — the flight recorder's OOM
/// buffer plus the per-window record emission into an in-memory sink.
/// The untraced arm flushes metrics too, so the traced/untraced wall
/// ratio isolates what recording itself costs.  Returns (total wall ms,
/// records emitted).
fn bench_trace_arm(rung: &Rung, shards: usize, windows: usize, traced: bool) -> (f64, usize) {
    let mut sim = bench_sim_sharded(rung, shards, shards);
    let mut ts = trident::trace::TraceSink::new();
    if traced {
        sim.set_trace_ooms(true);
        ts.header(vec![
            ("pipeline", Json::str(&sim.spec.name)),
            ("policy", Json::str("bench")),
            ("seed", Json::num(11.0)),
            ("shards", Json::num(sim.shard_count() as f64)),
            ("workers", Json::num(sim.workers_effective() as f64)),
        ]);
    }
    let mut total_ms = 0.0;
    for w in 0..windows {
        let t_end = (w + 1) as f64 * rung.window_s;
        let (_, ms) = harness::stopwatch_ms(|| {
            sim.run_until(t_end);
            let (metrics, outs) = sim.flush_metrics();
            if !traced {
                return;
            }
            for (t, op, gid) in sim.take_trace_ooms() {
                ts.sim_event(
                    t,
                    "oom",
                    vec![
                        ("op", Json::str(&sim.spec.operators[op].name)),
                        ("op_idx", Json::num(op as f64)),
                        ("inst", Json::num(gid as f64)),
                    ],
                );
            }
            ts.sim_event(
                t_end,
                "window",
                vec![
                    ("index", Json::num(w as f64)),
                    ("t0", Json::num(w as f64 * rung.window_s)),
                    ("t1", Json::num(t_end)),
                    ("outs", Json::Arr(outs.iter().map(|&o| Json::num(o as f64)).collect())),
                ],
            );
            for m in &metrics {
                if m.records_in == 0 && m.records_out == 0 && m.oom_events == 0 {
                    continue;
                }
                ts.sim_event(
                    t_end,
                    "op_window",
                    vec![
                        ("op", Json::str(&sim.spec.operators[m.op].name)),
                        ("records_in", Json::num(m.records_in as f64)),
                        ("records_out", Json::num(m.records_out as f64)),
                        ("utilization", Json::num(m.utilization)),
                        ("queue_avg", Json::num(m.queue_avg)),
                        ("oom_events", Json::num(f64::from(m.oom_events))),
                    ],
                );
            }
        });
        total_ms += ms;
    }
    (total_ms, ts.len())
}

/// The rung's MILP solve (solver cost is part of the trajectory: the
/// scheduler must stay cheap as the sim gets fast).  Node count is capped
/// at 512 — the stress rung's 10k-node MILP is not a thing the
/// coordinator would ever solve whole (`milp.nodes` records the cap).
fn bench_milp(rung: &Rung, budget: Duration) -> Json {
    use trident::scheduling::{solve_with_options, BasisCache};
    use trident::solver::MilpOptions;

    let milp_nodes = rung.nodes.min(512);
    // Stress rungs solve the single 4-op chain (the scheduler sees one
    // tenant's LP at a time there; the merged 4·N-op MILP is not a thing
    // the coordinator would ever solve whole).
    let input = if rung.stress_tenants > 0 {
        let spec = stress_spec();
        let src = ItemAttrs { tokens_in: 55.0, tokens_out: 20.0, pixels_m: 1.0, frames: 1.0 };
        let nominal = trident::coordinator::nominal_attrs(&spec, src);
        let (d_i, d_o) = spec.amplification();
        let cluster = ClusterSpec::homogeneous(milp_nodes, 256.0, 1024.0, 8, 65536.0, 12_500.0);
        trident::scheduling::MilpInput {
            ops: bench_ops(&spec, &nominal, &d_i, milp_nodes, false),
            edges: spec.edges.clone(),
            nodes: cluster.nodes,
            d_o,
            tenants: Vec::new(),
            op_tenant: Vec::new(),
            t_sched: 30.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            b_max: 2,
            placement_aware: true,
            join_colocate: false,
            all_at_once: false,
        }
    } else {
        two_tenant_input(milp_nodes, true)
    };
    let opts = MilpOptions { max_nodes: Some(96), ..MilpOptions::default() };
    let (plan, ms) =
        harness::stopwatch_ms(|| solve_with_options(&input, budget, &mut BasisCache::new(), &opts));
    Json::obj(vec![
        ("nodes", Json::num(milp_nodes as f64)),
        ("solve_ms", Json::num((ms * 10.0).round() / 10.0)),
        ("pivots", Json::num(plan.stats.pivots as f64)),
        ("phase1_pivots", Json::num(plan.stats.phase1_pivots as f64)),
        ("bnb_nodes", Json::num(plan.stats.nodes as f64)),
        ("status", Json::str(&format!("{:?}", plan.status))),
    ])
}

/// `trident bench-perf`: the pinned scale ladder behind `BENCH_9.json`
/// (schema `trident-bench-perf/v2`, superseding `BENCH_7.json`'s v1).
/// Each rung runs twice from byte-identical inputs — once through the
/// legacy seed event stream (one heap event per record transfer), once
/// through the batched link FIFOs — so the speedup is a same-binary
/// wall-clock ratio, not a cross-commit guess, and the event/record
/// totals double as a cross-mode parity check (they must match exactly;
/// any drift fails the bench).  On top of that every rung runs the
/// sharded tick at K ∈ {1, 2, 4} with W = K workers (thread-per-shard —
/// the historical PR 7 curve), then a worker-scaling sweep at the rung's
/// full K (= tenant count) with W ∈ {1, 2, 4} plus W = auto (cores − 1)
/// on the stress rungs — the oversubscribed K = 100 regime the pool
/// exists for.  Every (K, W) cell must reproduce the serial batched
/// event/record totals exactly (tenant-sharding is a partition of the
/// serial run and workers only decide who advances a shard, so any
/// drift is a determinism bug and fails the bench).  `--assert-speedup
/// S` gates the 96-node two-tenant rung, `--assert-shard-speedup S`
/// gates stress-512's K=4-vs-K=1 events/sec ratio (the two-tenant rungs
/// clamp K to 2 tenants and cannot scale past 2x by construction), and
/// `--assert-worker-speedup S` gates stress-10k's W=4-vs-W=1 ratio at
/// K = 100.
fn bench_perf(args: &Args) {
    let windows = (args.f64("windows", 4.0) as usize).max(1);
    let budget = Duration::from_millis(args.f64("milp-budget-ms", 10_000.0) as u64);
    let out_path = args.get("out", "BENCH_9.json");
    let selected: Vec<&Rung> = match args.map.get("rungs") {
        None => BENCH_RUNGS.iter().collect(),
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|name| {
                BENCH_RUNGS.iter().find(|r| r.name == name).unwrap_or_else(|| {
                    eprintln!(
                        "unknown bench rung '{name}' (expected one of {})",
                        BENCH_RUNGS.iter().map(|r| r.name).collect::<Vec<_>>().join("|")
                    );
                    std::process::exit(2);
                })
            })
            .collect(),
    };

    let mut table = Table::new(
        "bench-perf scale ladder (seed stream vs batched links vs sharded tick)",
        &[
            "Rung", "nodes", "seed ev/s", "batched ev/s", "speedup", "K=4 ev/s", "K4/K1",
            "W=4 ev/s", "W4/W1", "MILP ms",
        ],
    );
    let mut rung_jsons = Vec::new();
    let mut gate_speedup: Option<f64> = None;
    let mut gate_shard_speedup: Option<f64> = None;
    let mut gate_worker_speedup: Option<f64> = None;
    let mut gate_trace_overhead: Option<f64> = None;
    let mut failed = false;
    for &rung in &selected {
        eprintln!("rung {} ({} nodes): seed event stream...", rung.name, rung.nodes);
        let seed = bench_run(rung, true, windows);
        eprintln!("rung {}: batched transfers...", rung.name);
        let batched = bench_run(rung, false, windows);
        if seed.events != batched.events || seed.records != batched.records {
            eprintln!(
                "FAIL: rung {} diverged across transfer modes (events {} vs {}, records {} vs {})",
                rung.name, seed.events, batched.events, seed.records, batched.records
            );
            failed = true;
        }
        let speedup = batched.events_per_sec() / seed.events_per_sec().max(1e-9);
        if rung.name == "two-tenant-96" {
            gate_speedup = Some(speedup);
        }
        // Sharded scaling curve at W = K (thread-per-shard, the PR 7
        // semantics the historical curves were measured under): every K
        // must land on the serial batched totals exactly (the sharded
        // tick is a partition, not an approximation, of the serial run).
        let n_tenants = if rung.stress_tenants > 0 { rung.stress_tenants } else { 2 };
        let mut shard_jsons = Vec::new();
        let mut eps_k: Vec<(usize, f64)> = Vec::new();
        for k in [1usize, 2, 4] {
            eprintln!("rung {}: sharded tick K={k} (W={k})...", rung.name);
            let (sh, k_eff, w_eff) = bench_run_sharded(rung, k, k, windows);
            if sh.events != batched.events || sh.records != batched.records {
                eprintln!(
                    "FAIL: rung {} sharded K={k} drifted from serial (events {} vs {}, records {} vs {})",
                    rung.name, sh.events, batched.events, sh.records, batched.records
                );
                failed = true;
            }
            eps_k.push((k, sh.events_per_sec()));
            shard_jsons.push(Json::obj(vec![
                ("shards", Json::num(k as f64)),
                ("k_effective", Json::num(k_eff as f64)),
                ("workers", Json::num(k as f64)),
                ("workers_effective", Json::num(w_eff as f64)),
                ("stats", sh.json()),
            ]));
        }
        let eps1 = eps_k[0].1.max(1e-9);
        let eps4 = eps_k[2].1;
        let shard_speedup = eps4 / eps1;
        if rung.name == "stress-512" {
            gate_shard_speedup = Some(shard_speedup);
        }
        // Worker-scaling sweep at the rung's full shard count (one shard
        // per tenant): W varies while the partition — and therefore every
        // float — stays fixed, so this isolates the pool's contribution.
        // Stress rungs add W = auto (cores − 1): the oversubscribed
        // K ≫ W regime the work-stealing pool exists for.
        let worker_ws: &[usize] =
            if rung.stress_tenants > 0 { &[1, 2, 4, 0] } else { &[1, 2, 4] };
        let mut worker_jsons = Vec::new();
        let mut eps_w: Vec<(usize, f64)> = Vec::new();
        for &w in worker_ws {
            eprintln!("rung {}: worker scaling K={n_tenants} W={w} (0=auto)...", rung.name);
            let (sh, k_eff, w_eff) = bench_run_sharded(rung, n_tenants, w, windows);
            if sh.events != batched.events || sh.records != batched.records {
                eprintln!(
                    "FAIL: rung {} K={n_tenants} W={w} drifted from serial (events {} vs {}, records {} vs {})",
                    rung.name, sh.events, batched.events, sh.records, batched.records
                );
                failed = true;
            }
            eps_w.push((w, sh.events_per_sec()));
            worker_jsons.push(Json::obj(vec![
                ("shards", Json::num(n_tenants as f64)),
                ("k_effective", Json::num(k_eff as f64)),
                ("workers", Json::num(w as f64)),
                ("workers_effective", Json::num(w_eff as f64)),
                ("stats", sh.json()),
            ]));
        }
        let eps_w1 = eps_w[0].1.max(1e-9);
        let eps_w4 = eps_w[2].1;
        let worker_speedup = eps_w4 / eps_w1;
        if rung.name == "stress-10k" {
            gate_worker_speedup = Some(worker_speedup);
        }
        // Trace-overhead arm (headline rung only): same windowed drive,
        // metrics flushed either way, flight recorder on vs off.
        let mut trace_json: Option<Json> = None;
        if rung.name == "two-tenant-96" {
            eprintln!("rung {}: trace-overhead arm (untraced)...", rung.name);
            let (off_ms, _) = bench_trace_arm(rung, n_tenants, windows, false);
            eprintln!("rung {}: trace-overhead arm (traced)...", rung.name);
            let (on_ms, recs) = bench_trace_arm(rung, n_tenants, windows, true);
            let pct = (on_ms / off_ms.max(1e-9) - 1.0) * 100.0;
            gate_trace_overhead = Some(pct);
            trace_json = Some(Json::obj(vec![
                ("untraced_ms", Json::num((off_ms * 10.0).round() / 10.0)),
                ("traced_ms", Json::num((on_ms * 10.0).round() / 10.0)),
                ("records", Json::num(recs as f64)),
                ("overhead_pct", Json::num((pct * 100.0).round() / 100.0)),
            ]));
        }
        let milp = bench_milp(rung, budget);
        table.row(vec![
            rung.name.to_string(),
            rung.nodes.to_string(),
            format!("{:.0}", seed.events_per_sec()),
            format!("{:.0}", batched.events_per_sec()),
            format!("{speedup:.2}x"),
            format!("{eps4:.0}"),
            format!("{shard_speedup:.2}x"),
            format!("{eps_w4:.0}"),
            format!("{worker_speedup:.2}x"),
            format!("{:.0}", milp.f64_or("solve_ms", -1.0)),
        ]);
        let mut rung_fields = vec![
            ("name", Json::str(rung.name)),
            ("nodes", Json::num(rung.nodes as f64)),
            ("tenants", Json::num(n_tenants as f64)),
            ("window_s", Json::num(rung.window_s)),
            ("windows", Json::num(windows as f64)),
            ("seed_event_stream", seed.json()),
            ("batched", batched.json()),
            ("shard_scaling", Json::Arr(shard_jsons)),
            ("worker_scaling", Json::Arr(worker_jsons)),
            ("events_per_sec", Json::num(batched.events_per_sec().round())),
            ("records_per_sec", Json::num(batched.records_per_sec().round())),
            ("speedup_events_per_sec", Json::num((speedup * 100.0).round() / 100.0)),
            ("shard_speedup_k4", Json::num((shard_speedup * 100.0).round() / 100.0)),
            ("worker_speedup_w4", Json::num((worker_speedup * 100.0).round() / 100.0)),
            ("milp", milp),
        ];
        if let Some(tj) = trace_json {
            rung_fields.push(("trace_overhead", tj));
        }
        rung_jsons.push(Json::obj(rung_fields));
    }
    table.emit("bench_perf");

    let report = Json::obj(vec![
        ("schema", Json::str("trident-bench-perf/v2")),
        ("baseline_mode", Json::str("seed-event-stream")),
        ("generated_by", Json::str("trident bench-perf")),
        ("rungs", Json::Arr(rung_jsons)),
    ]);
    std::fs::write(&out_path, report.to_string_pretty() + "\n").unwrap_or_else(|e| {
        eprintln!("cannot write {out_path}: {e}");
        std::process::exit(2);
    });
    println!("wrote {out_path}");

    if let Some(s) = args.map.get("assert-speedup").and_then(|v| v.parse::<f64>().ok()) {
        match gate_speedup {
            Some(got) if got < s => {
                eprintln!("FAIL: two-tenant-96 events/sec speedup {got:.2}x below required {s}x");
                failed = true;
            }
            Some(got) => println!("two-tenant-96 speedup {got:.2}x >= {s}x"),
            None => {
                eprintln!("--assert-speedup requires the two-tenant-96 rung in --rungs");
                failed = true;
            }
        }
    }
    if let Some(s) = args.map.get("assert-shard-speedup").and_then(|v| v.parse::<f64>().ok()) {
        match gate_shard_speedup {
            Some(got) if got < s => {
                eprintln!(
                    "FAIL: stress-512 K=4 vs K=1 events/sec ratio {got:.2}x below required {s}x"
                );
                failed = true;
            }
            Some(got) => println!("stress-512 shard speedup {got:.2}x >= {s}x"),
            None => {
                eprintln!("--assert-shard-speedup requires the stress-512 rung in --rungs");
                failed = true;
            }
        }
    }
    if let Some(s) = args.map.get("assert-worker-speedup").and_then(|v| v.parse::<f64>().ok()) {
        match gate_worker_speedup {
            Some(got) if got < s => {
                eprintln!(
                    "FAIL: stress-10k W=4 vs W=1 events/sec ratio {got:.2}x below required {s}x"
                );
                failed = true;
            }
            Some(got) => println!("stress-10k worker speedup {got:.2}x >= {s}x"),
            None => {
                eprintln!("--assert-worker-speedup requires the stress-10k rung in --rungs");
                failed = true;
            }
        }
    }
    if let Some(s) = args.map.get("assert-trace-overhead").and_then(|v| v.parse::<f64>().ok()) {
        match gate_trace_overhead {
            Some(got) if got > s => {
                eprintln!("FAIL: two-tenant-96 trace overhead {got:.2}% above allowed {s}%");
                failed = true;
            }
            Some(got) => println!("two-tenant-96 trace overhead {got:.2}% <= {s}%"),
            None => {
                eprintln!("--assert-trace-overhead requires the two-tenant-96 rung in --rungs");
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let args = Args::parse(&argv[1.min(argv.len())..]);

    match cmd.as_str() {
        "run" => {
            let policy = policy_of(&args.get("policy", "trident"));
            let r = run_one(&args, policy);
            println!(
                "[{}] {}: throughput {:.3} items/s over {:.0}s ({} records out, {} OOMs, {:.0}s OOM downtime, {} transitions)",
                r.pipeline, r.variant, r.throughput, r.duration_s, r.items_processed,
                r.oom_events, r.oom_downtime_s, r.config_transitions
            );
            if r.tenants.len() > 1 {
                for t in &r.tenants {
                    println!(
                        "  tenant {} (w={}): {:.3} items/s ({} records out, {} admitted)",
                        t.id, t.weight, t.throughput, t.items_processed, t.items_admitted
                    );
                }
            }
            if !r.milp_ms.is_empty() {
                let mean = r.milp_ms.iter().sum::<f64>() / r.milp_ms.len() as f64;
                println!("MILP solves: {} (mean {:.0} ms)", r.milp_ms.len(), mean);
                println!(
                    "  solver: {} pivots, {} B&B nodes, {} pricing rounds ({} columns), warm-hit {:.0}%",
                    r.milp_pivots,
                    r.milp_bnb_nodes,
                    r.milp_pricing_rounds,
                    r.milp_columns,
                    r.milp_warm_hit_rate * 100.0
                );
                println!(
                    "  phases (ms): build {:.0} / root-LP {:.0} / B&B {:.0} / pricing {:.0} · {} plans committed",
                    r.milp_phase_ms[0],
                    r.milp_phase_ms[1],
                    r.milp_phase_ms[2],
                    r.milp_phase_ms[3],
                    r.plans_committed
                );
            }
            if r.pool_epochs > 0 {
                println!(
                    "shard pool: {} workers, {} epochs, {} steals, {:.0} ms waiting",
                    r.workers_effective, r.pool_epochs, r.pool_steals, r.pool_wait_ms
                );
            }
            if let Some(path) = args.map.get("trace") {
                println!("trace: {path}");
            }
            if !r.events.is_empty() {
                println!(
                    "dynamics: {} events, {} records lost",
                    r.events.len(),
                    r.lost_records
                );
                for ev in &r.events {
                    let fmt_opt = |v: Option<f64>| match v {
                        Some(s) => format!("{s:.0}s"),
                        None => "-".to_string(),
                    };
                    println!(
                        "  [{:.0}s] {}: replan {} recover(90%) {} lost {}",
                        ev.at_s,
                        ev.label,
                        fmt_opt(ev.replan_s),
                        fmt_opt(ev.recovered_s),
                        ev.lost_records
                    );
                }
            }
        }
        "compare" => {
            let duration = args.f64("duration", 1800.0);
            let seed = args.f64("seed", 0.0) as u64;
            let workers = args.f64("jobs", harness::default_workers() as f64) as usize;
            let order = [
                Policy::Static,
                Policy::RayData,
                Policy::Ds2,
                Policy::ContTune,
                Policy::Trident,
            ];
            let jobs: Vec<Job> = order
                .iter()
                .map(|&p| Job::timed(p.name(), variant_of(&args, p), seed, duration))
                .collect();
            let trace_cfg = trace_of(&args);
            let reports =
                harness::run_grid(&jobs, workers, |_, job| {
                    let mut coord = build_coordinator(&args, job.variant.clone(), job.seed);
                    if let Some((path, fmt)) = &trace_cfg {
                        // One trace file per grid cell, suffixed by label+seed.
                        coord.set_trace(&format!("{path}.{}-{}", job.label, job.seed), *fmt);
                    }
                    coord
                });
            let mut table = Table::new(
                "End-to-end throughput (items/s, speedup vs Static)",
                &["Method", "items/s", "speedup"],
            );
            let static_thr = reports[0].throughput.max(1e-12);
            for (policy, r) in order.iter().zip(&reports) {
                table.row(vec![
                    policy.name().into(),
                    f2(r.throughput),
                    format!("{:.2}x", r.throughput / static_thr),
                ]);
                eprintln!("done: {}", policy.name());
            }
            table.emit("cli_compare");
            // Multi-tenant invocation: per-tenant breakdown per policy.
            if reports.first().map(|r| r.tenants.len() > 1).unwrap_or(false) {
                let ids: Vec<String> =
                    reports[0].tenants.iter().map(|t| format!("{} items/s", t.id)).collect();
                let mut cols: Vec<&str> = vec!["Method"];
                cols.extend(ids.iter().map(String::as_str));
                let mut tt = Table::new("Per-tenant throughput", &cols);
                for (policy, r) in order.iter().zip(&reports) {
                    let mut row = vec![policy.name().to_string()];
                    row.extend(r.tenants.iter().map(|t| f2(t.throughput)));
                    tt.row(row);
                }
                tt.emit("cli_compare_tenants");
            }
        }
        "sweep" => {
            let duration = args.f64("duration", 1800.0);
            let seeds = (args.f64("seeds", 4.0) as u64).max(1);
            let base_seed = args.f64("seed", 0.0) as u64;
            let workers = args.f64("jobs", harness::default_workers() as f64) as usize;
            let policies = policies_of(&args, "policies", "static,raydata,ds2,conttune,trident");
            // Paired design: every policy sees the same seed list, so
            // per-seed workload draws are directly comparable.
            let jobs: Vec<Job> = policies
                .iter()
                .flat_map(|&p| {
                    let variant = variant_of(&args, p);
                    (0..seeds).map(move |s| {
                        Job::timed(p.name(), variant.clone(), base_seed + s, duration)
                    })
                })
                .collect();
            let t0 = Instant::now();
            let trace_cfg = trace_of(&args);
            let reports = harness::run_grid(&jobs, workers, |_, job| {
                let mut coord = build_coordinator(&args, job.variant.clone(), job.seed);
                if let Some((path, fmt)) = &trace_cfg {
                    // One trace file per grid cell, suffixed by label+seed.
                    coord.set_trace(&format!("{path}.{}-{}", job.label, job.seed), *fmt);
                }
                coord
            });
            let wall = t0.elapsed().as_secs_f64();
            let summaries = harness::summarize(&jobs, &reports);
            let mut table = Table::new(
                &format!(
                    "Sweep: {} policies x {} seeds ({}s sim each)",
                    policies.len(),
                    seeds,
                    duration
                ),
                &["Method", "items/s (mean ± std)", "speedup", "OOMs", "transitions"],
            );
            // Speedup is relative to Static; without it in the grid the
            // column has no referent.
            let static_mean = summaries
                .iter()
                .find(|s| s.label == Policy::Static.name())
                .map(|s| s.throughput.mean.max(1e-12));
            for s in &summaries {
                let speedup = match static_mean {
                    Some(base) => format!("{:.2}x", s.throughput.mean / base),
                    None => "-".to_string(),
                };
                table.row(vec![
                    s.label.clone(),
                    s.throughput.pm(),
                    speedup,
                    format!("{:.1}", s.oom_events.mean),
                    format!("{:.1}", s.transitions.mean),
                ]);
            }
            table.emit("cli_sweep");
            println!(
                "{} cells on {} workers in {:.1}s wall-clock",
                jobs.len(),
                workers.clamp(1, jobs.len().max(1)),
                wall
            );
        }
        "trace-summary" => {
            let path = argv
                .get(1)
                .filter(|s| !s.starts_with("--"))
                .cloned()
                .or_else(|| args.map.get("input").cloned())
                .unwrap_or_else(|| {
                    eprintln!("usage: trident trace-summary <trace.jsonl>");
                    std::process::exit(2);
                });
            let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                eprintln!("trace-summary: cannot read {path}: {e}");
                std::process::exit(2);
            });
            let summary = trident::trace::summarize_jsonl(&text).unwrap_or_else(|e| {
                eprintln!("trace-summary: {path}: {e}");
                std::process::exit(2);
            });
            print!("{}", summary.render());
            let errs = summary.check();
            if !errs.is_empty() {
                for e in &errs {
                    eprintln!("cross-check FAIL: {e}");
                }
                std::process::exit(1);
            }
            println!("cross-check OK: aggregates match the embedded run_summary");
        }
        "milp-bench" => milp_bench(&args),
        "bench-perf" => bench_perf(&args),
        _ => {
            println!(
                "usage: trident <run|compare|sweep|milp-bench|bench-perf|trace-summary> [--pipeline pdf|video|speech] \
                 [--pipelines pdf,speech [--weights 2,1]] [--tenancy file.json] [--policy ...] \
                 [--policies a,b,c] [--seeds N] [--jobs J] [--duration S] [--nodes N] [--seed S] \
                 [--native-gp] [--join-colocate] [--shards K] [--workers W] \
                 [--solver monolithic|decomposed] \
                 [--dynamics file.json] [--mtbf S] [--mttr S] [--recovery requeue|loss] \
                 [--max-pivots N] [--assert-speedup S]   (milp-bench solver-perf gates) \
                 [--decomp-tenants N] [--assert-decomp-speedup S]   (milp-bench decomposition gate) \
                 [--windows W] [--rungs a,b] [--out BENCH_9.json] [--milp-budget-ms MS] \
                 [--assert-speedup S] [--assert-shard-speedup S] [--assert-worker-speedup S] \
                 [--assert-trace-overhead PCT] (bench-perf -> BENCH_9.json) \
                 [--trace out.jsonl [--trace-format jsonl|chrome]]   (run|compare|sweep) \
                 trace-summary <trace.jsonl>   (bottleneck attribution + RunReport cross-check)"
            );
        }
    }
}
