//! Flight-recorder trace layer: deterministic, structured events from the
//! closed observation→adaptation→scheduling loop.
//!
//! The sink records two *lanes*:
//!
//! * **sim** — values derived only from simulated time and deterministic
//!   counters: window boundaries, per-op window summaries, OOM and
//!   admission errors, plan decisions (diff sizes, rolling batch sums),
//!   rolling-update waves, path-⑨/topology invalidations, dynamics
//!   events with time-to-replan / time-to-recover milestones, and the
//!   final run summary.  Two runs at the same seed produce byte-identical
//!   sim-lane JSONL.
//! * **wall** — host-dependent measurements: MILP solve wall clock with
//!   the full per-phase [`MilpStats`](crate::solver::MilpStats)
//!   breakdown, and shard-pool telemetry (per-worker task counts, steals,
//!   epoch waits).  Wall-lane *payloads* vary across hosts; the record
//!   *count and order* stay deterministic.
//!
//! The determinism contract that makes this a subsystem rather than a
//! bolt-on: tracing consumes no RNG, allocates nothing on the sim hot
//! path when disabled (a single `Option` check guards the one
//! instrumented simulator site), and never perturbs event order — the
//! parity suite pins bit-identical `RunReport`s with tracing on vs off
//! across every policy and the (K, W) shard/worker grid.
//!
//! Output formats: versioned JSONL (`trident-trace/v1`, one record per
//! line, first record is the header, last is `run_summary`) and the
//! Chrome trace-event JSON that Perfetto / `chrome://tracing` load
//! directly ("X" duration events for windows and solves, "i" instants
//! for everything else, sim seconds mapped to microseconds).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::config::Json;

/// Version tag carried by the header record of every trace.
pub const TRACE_SCHEMA: &str = "trident-trace/v1";

/// On-disk trace encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// One JSON record per line; the `trace-summary` input format.
    Jsonl,
    /// Chrome trace-event JSON (Perfetto-loadable).
    Chrome,
}

impl TraceFormat {
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "jsonl" => Some(TraceFormat::Jsonl),
            "chrome" => Some(TraceFormat::Chrome),
            _ => None,
        }
    }
}

/// In-memory recorder for one run.  Held as `Option<TraceSink>` by the
/// coordinator; `None` is the zero-overhead off state.
#[derive(Debug, Default)]
pub struct TraceSink {
    records: Vec<Json>,
    seq_sim: u64,
    seq_wall: u64,
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink::default()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn records(&self) -> &[Json] {
        &self.records
    }

    /// First record of every trace: schema version plus run identity.
    pub fn header(&mut self, fields: Vec<(&str, Json)>) {
        let mut all = vec![("schema", Json::str(TRACE_SCHEMA))];
        all.extend(fields);
        self.sim_event(0.0, "header", all);
    }

    /// Record a deterministic event on the sim lane at sim time `t`.
    pub fn sim_event(&mut self, t: f64, kind: &str, fields: Vec<(&str, Json)>) {
        let seq = self.seq_sim;
        self.seq_sim += 1;
        self.push(t, kind, "sim", seq, fields);
    }

    /// Record a host-dependent measurement on the wall lane.  `t` is the
    /// (deterministic) sim time the measurement was taken at; only the
    /// payload varies across hosts.
    pub fn wall_event(&mut self, t: f64, kind: &str, fields: Vec<(&str, Json)>) {
        let seq = self.seq_wall;
        self.seq_wall += 1;
        self.push(t, kind, "wall", seq, fields);
    }

    fn push(&mut self, t: f64, kind: &str, lane: &str, seq: u64, fields: Vec<(&str, Json)>) {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::str(kind));
        m.insert("lane".to_string(), Json::str(lane));
        m.insert("seq".to_string(), Json::num(seq as f64));
        m.insert("t".to_string(), Json::num(t));
        for (k, v) in fields {
            m.insert(k.to_string(), v);
        }
        self.records.push(Json::Obj(m));
    }

    /// Versioned JSONL: one compact record per line (BTreeMap keys give a
    /// stable field order, so same-seed runs serialize byte-identically
    /// on the sim lane).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for rec in &self.records {
            out.push_str(&rec.to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event JSON.  Windows and MILP solves become "X"
    /// duration events; everything else is an "i" instant.  Sim seconds
    /// map to trace microseconds; the wall lane lands on tid 1.
    pub fn to_chrome(&self) -> String {
        let mut evs = Vec::new();
        for rec in &self.records {
            let kind = rec.str_or("kind", "?").to_string();
            let lane = rec.str_or("lane", "sim").to_string();
            let t = rec.f64_or("t", 0.0);
            let tid = if lane == "wall" { 1.0 } else { 0.0 };
            let mut e = vec![
                ("name", Json::str(&kind)),
                ("cat", Json::str(&lane)),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(tid)),
                ("args", rec.clone()),
            ];
            match kind.as_str() {
                "window" => {
                    let t0 = rec.f64_or("t0", t);
                    let t1 = rec.f64_or("t1", t0);
                    e.push(("ph", Json::str("X")));
                    e.push(("ts", Json::num(t0 * 1e6)));
                    e.push(("dur", Json::num((t1 - t0).max(0.0) * 1e6)));
                }
                "solve" => {
                    let ms = rec.f64_or("milp_ms", 0.0);
                    e.push(("ph", Json::str("X")));
                    e.push(("ts", Json::num(t * 1e6)));
                    e.push(("dur", Json::num(ms.max(0.0) * 1e3)));
                }
                _ => {
                    e.push(("ph", Json::str("i")));
                    e.push(("ts", Json::num(t * 1e6)));
                    e.push(("s", Json::str("t")));
                }
            }
            evs.push(Json::obj(e));
        }
        let top = Json::obj(vec![
            ("traceEvents", Json::Arr(evs)),
            ("displayTimeUnit", Json::str("ms")),
        ]);
        let mut s = top.to_string_compact();
        s.push('\n');
        s
    }

    pub fn write(&self, path: &str, fmt: TraceFormat) -> std::io::Result<()> {
        let body = match fmt {
            TraceFormat::Jsonl => self.to_jsonl(),
            TraceFormat::Chrome => self.to_chrome(),
        };
        std::fs::write(path, body)
    }
}

// ---------------------------------------------------------------------
// Analyzer: trace-summary
// ---------------------------------------------------------------------

/// Per-operator aggregates over all window summaries.
#[derive(Debug, Default, Clone)]
pub struct OpAgg {
    pub windows: usize,
    pub util_sum: f64,
    pub queue_avg_sum: f64,
    pub records_in: u64,
    pub records_out: u64,
    pub oom_events: u64,
    pub peak_mem_mb: f64,
}

impl OpAgg {
    pub fn mean_util(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.util_sum / self.windows as f64
        }
    }

    pub fn mean_queue(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.queue_avg_sum / self.windows as f64
        }
    }
}

/// Aggregates recomputed from a JSONL trace, cross-checkable against the
/// embedded `run_summary` record (which the producing coordinator filled
/// from its own `RunReport`).
#[derive(Debug, Default)]
pub struct TraceSummary {
    pub schema: String,
    pub lines: usize,
    pub sim_records: usize,
    pub wall_records: usize,
    pub windows: usize,
    pub duration_s: f64,
    /// Per-tenant record totals summed from window records.
    pub tenant_out: Vec<u64>,
    /// Instant `oom` records (one per simulator OOM kill).
    pub ooms: u64,
    pub admission_errors: usize,
    pub dynamics_events: usize,
    /// `invalidation` records with `reason == "transition"` (path ⑨).
    pub transitions: u64,
    pub invalidations: usize,
    pub waves: usize,
    pub plans: usize,
    pub plans_committed: u64,
    pub solves: usize,
    pub milp_ms_sum: f64,
    pub pivots: u64,
    pub bnb_nodes: u64,
    pub pricing_rounds: u64,
    pub columns: u64,
    /// build / root-LP / B&B / pricing wall sums, milliseconds.
    pub phase_ms: [f64; 4],
    pub pool_steals: u64,
    pub pool_epochs: u64,
    pub pool_wait_ms: f64,
    pub replan_latencies: Vec<f64>,
    pub recover_latencies: Vec<f64>,
    pub lost_records: u64,
    pub ops: BTreeMap<String, OpAgg>,
    pub header: Option<Json>,
    pub run_summary: Option<Json>,
}

/// Parse and validate a JSONL trace: every line must parse, the first
/// record must be a `header` with the supported schema, and per-lane
/// `seq` counters must be gapless from 0.
pub fn summarize_jsonl(text: &str) -> Result<TraceSummary, String> {
    let mut s = TraceSummary::default();
    let mut next_sim = 0u64;
    let mut next_wall = 0u64;
    for (i, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let rec = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        s.lines += 1;
        let kind = rec.str_or("kind", "").to_string();
        if kind.is_empty() {
            return Err(format!("line {}: record has no kind", i + 1));
        }
        let lane = rec.str_or("lane", "").to_string();
        let seq = rec.f64_or("seq", -1.0);
        match lane.as_str() {
            "sim" => {
                if seq != next_sim as f64 {
                    return Err(format!("line {}: sim seq {seq}, expected {next_sim}", i + 1));
                }
                next_sim += 1;
                s.sim_records += 1;
            }
            "wall" => {
                if seq != next_wall as f64 {
                    return Err(format!("line {}: wall seq {seq}, expected {next_wall}", i + 1));
                }
                next_wall += 1;
                s.wall_records += 1;
            }
            other => return Err(format!("line {}: unknown lane {other:?}", i + 1)),
        }
        if s.lines == 1 {
            if kind != "header" {
                return Err(format!("first record is {kind:?}, expected header"));
            }
            let schema = rec.str_or("schema", "");
            if schema != TRACE_SCHEMA {
                return Err(format!(
                    "unsupported schema {schema:?} (this build reads {TRACE_SCHEMA})"
                ));
            }
            s.schema = schema.to_string();
            s.header = Some(rec);
            continue;
        }
        ingest(&mut s, &kind, &rec);
    }
    if s.lines == 0 {
        return Err("empty trace".to_string());
    }
    Ok(s)
}

fn ingest(s: &mut TraceSummary, kind: &str, rec: &Json) {
    match kind {
        "window" => {
            s.windows += 1;
            s.duration_s = s.duration_s.max(rec.f64_or("t1", 0.0));
            if let Some(outs) = rec.get("outs").and_then(Json::as_arr) {
                if s.tenant_out.len() < outs.len() {
                    s.tenant_out.resize(outs.len(), 0);
                }
                for (i, o) in outs.iter().enumerate() {
                    s.tenant_out[i] += o.as_f64().unwrap_or(0.0) as u64;
                }
            }
        }
        "op_window" => {
            let name = rec.str_or("op", "?").to_string();
            let agg = s.ops.entry(name).or_default();
            agg.windows += 1;
            agg.util_sum += rec.f64_or("utilization", 0.0);
            agg.queue_avg_sum += rec.f64_or("queue_avg", 0.0);
            agg.records_in += rec.f64_or("records_in", 0.0) as u64;
            agg.records_out += rec.f64_or("records_out", 0.0) as u64;
            agg.oom_events += rec.f64_or("oom_events", 0.0) as u64;
            agg.peak_mem_mb = agg.peak_mem_mb.max(rec.f64_or("peak_mem_mb", 0.0));
        }
        "oom" => s.ooms += 1,
        "admission_error" => s.admission_errors += 1,
        "dynamics" => {
            s.dynamics_events += 1;
            s.lost_records += rec.f64_or("lost", 0.0) as u64;
        }
        "invalidation" => {
            s.invalidations += 1;
            if rec.str_or("reason", "") == "transition" {
                s.transitions += 1;
            }
        }
        "rolling_wave" => s.waves += 1,
        "plan" => {
            s.plans += 1;
            if rec.get("acted").and_then(Json::as_bool) == Some(true) {
                s.plans_committed += 1;
            }
        }
        "solve" => {
            s.solves += 1;
            s.milp_ms_sum += rec.f64_or("milp_ms", 0.0);
            s.pivots += rec.f64_or("pivots", 0.0) as u64;
            s.bnb_nodes += rec.f64_or("nodes", 0.0) as u64;
            s.pricing_rounds += rec.f64_or("pricing_rounds", 0.0) as u64;
            s.columns += rec.f64_or("columns", 0.0) as u64;
            s.phase_ms[0] += rec.f64_or("build_ms", 0.0);
            s.phase_ms[1] += rec.f64_or("root_lp_ms", 0.0);
            s.phase_ms[2] += rec.f64_or("bnb_ms", 0.0);
            s.phase_ms[3] += rec.f64_or("pricing_ms", 0.0);
        }
        "pool" => {
            // Counters are cumulative; the last record carries the totals.
            s.pool_steals = rec.f64_or("steals", 0.0) as u64;
            s.pool_epochs = rec.f64_or("epochs", 0.0) as u64;
            s.pool_wait_ms = rec.f64_or("wait_ms", 0.0);
        }
        "replan" => s.replan_latencies.push(rec.f64_or("latency_s", 0.0)),
        "recover" => s.recover_latencies.push(rec.f64_or("latency_s", 0.0)),
        "run_summary" => s.run_summary = Some(rec.clone()),
        _ => {}
    }
}

fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

impl TraceSummary {
    pub fn total_items(&self) -> u64 {
        self.tenant_out.iter().sum()
    }

    /// Diff the recomputed aggregates against the embedded `run_summary`
    /// record.  Returns one line per mismatch; empty means the trace is
    /// internally consistent with the producing run's `RunReport`.
    pub fn check(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let Some(rs) = self.run_summary.as_ref() else {
            errs.push("trace has no run_summary record (truncated?)".to_string());
            return errs;
        };
        let mut chk = |name: &str, got: f64| match rs.get(name).and_then(Json::as_f64) {
            None => errs.push(format!("run_summary is missing {name:?}")),
            Some(want) if want != got => {
                errs.push(format!("{name}: trace says {got}, run_summary says {want}"))
            }
            _ => {}
        };
        chk("items", self.total_items() as f64);
        chk("oom_events", self.ooms as f64);
        chk("config_transitions", self.transitions as f64);
        chk("dynamics_events", self.dynamics_events as f64);
        chk("plans_committed", self.plans_committed as f64);
        chk("solves", self.solves as f64);
        chk("replans", self.replan_latencies.len() as f64);
        chk("recovers", self.recover_latencies.len() as f64);
        chk("lost_records", self.lost_records as f64);
        chk("windows", self.windows as f64);
        drop(chk);
        if let Some(rows) = rs.get("tenants").and_then(Json::as_arr) {
            if rows.len() != self.tenant_out.len() && !self.tenant_out.is_empty() {
                errs.push(format!(
                    "tenant count: trace windows carry {}, run_summary has {}",
                    self.tenant_out.len(),
                    rows.len()
                ));
            }
            for (i, row) in rows.iter().enumerate() {
                let want = row.f64_or("items", -1.0);
                let got = self.tenant_out.get(i).copied().unwrap_or(0) as f64;
                if want != got {
                    let id = row.str_or("id", "?");
                    errs.push(format!(
                        "tenant {id}: trace windows sum {got} records, run_summary says {want}"
                    ));
                }
            }
        } else {
            errs.push("run_summary is missing \"tenants\"".to_string());
        }
        errs
    }

    /// Human-readable bottleneck attribution.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace {} · {} records ({} sim + {} wall) · {} windows over {:.0}s",
            self.schema, self.lines, self.sim_records, self.wall_records, self.windows,
            self.duration_s
        );
        if let Some(h) = self.header.as_ref() {
            let _ = writeln!(
                out,
                "run: pipeline {} · policy {} · seed {} · shards {} · workers {}",
                h.str_or("pipeline", "?"),
                h.str_or("policy", "?"),
                h.f64_or("seed", 0.0),
                h.f64_or("shards", 1.0),
                h.f64_or("workers", 1.0)
            );
        }
        let _ = writeln!(
            out,
            "records out: {} total across {} tenants",
            self.total_items(),
            self.tenant_out.len()
        );
        if !self.ops.is_empty() {
            let _ = writeln!(out, "per-op utilization (window means):");
            let mut hot: Option<(&String, f64)> = None;
            for (name, agg) in &self.ops {
                let util = agg.mean_util();
                let _ = writeln!(
                    out,
                    "  {name:<16} util {util:>6.3}  queue~{:>8.2}  in {:>8} out {:>8}  ooms {}",
                    agg.mean_queue(),
                    agg.records_in,
                    agg.records_out,
                    agg.oom_events
                );
                if hot.is_none_or(|(_, u)| util > u) {
                    hot = Some((name, util));
                }
            }
            if let Some((name, util)) = hot {
                let _ = writeln!(out, "bottleneck: {name} (mean utilization {util:.3})");
            }
        }
        let _ = writeln!(
            out,
            "plans: {} consulted, {} committed · solves: {} ({:.1} ms total)",
            self.plans, self.plans_committed, self.solves, self.milp_ms_sum
        );
        if self.solves > 0 {
            let _ = writeln!(
                out,
                "solve phases (ms): build {:.1} / root-LP {:.1} / B&B {:.1} / pricing {:.1} \
                 · {} pivots · {} nodes · {} pricing rounds ({} columns)",
                self.phase_ms[0],
                self.phase_ms[1],
                self.phase_ms[2],
                self.phase_ms[3],
                self.pivots,
                self.bnb_nodes,
                self.pricing_rounds,
                self.columns
            );
        }
        let _ = writeln!(
            out,
            "dynamics: {} events · {} lost records · replans {} (mean {:.1}s) · \
             recoveries {} (mean {:.1}s)",
            self.dynamics_events,
            self.lost_records,
            self.replan_latencies.len(),
            mean(&self.replan_latencies),
            self.recover_latencies.len(),
            mean(&self.recover_latencies)
        );
        let _ = writeln!(
            out,
            "sim health: {} OOM kills · {} admission errors · {} invalidations \
             ({} transitions) · {} rolling waves",
            self.ooms, self.admission_errors, self.invalidations, self.transitions, self.waves
        );
        if self.pool_epochs > 0 {
            let _ = writeln!(
                out,
                "shard pool: {} epochs · {} steals · {:.1} ms waiting",
                self.pool_epochs, self.pool_steals, self.pool_wait_ms
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_sink() -> TraceSink {
        let mut ts = TraceSink::new();
        ts.header(vec![
            ("pipeline", Json::str("pdf")),
            ("policy", Json::str("Trident")),
            ("seed", Json::num(7.0)),
            ("shards", Json::num(1.0)),
            ("workers", Json::num(1.0)),
        ]);
        ts.sim_event(
            30.0,
            "window",
            vec![
                ("index", Json::num(0.0)),
                ("t0", Json::num(0.0)),
                ("t1", Json::num(30.0)),
                ("thr", Json::num(4.0)),
                ("outs", Json::Arr(vec![Json::num(120.0)])),
            ],
        );
        ts.sim_event(
            30.0,
            "op_window",
            vec![
                ("op", Json::str("decode")),
                ("records_in", Json::num(120.0)),
                ("records_out", Json::num(120.0)),
                ("utilization", Json::num(0.9)),
                ("queue_avg", Json::num(2.0)),
                ("oom_events", Json::num(0.0)),
            ],
        );
        ts.sim_event(
            30.0,
            "plan",
            vec![("acted", Json::Bool(true)), ("placement_diff", Json::num(2.0))],
        );
        ts.wall_event(
            30.0,
            "solve",
            vec![
                ("milp_ms", Json::num(12.5)),
                ("pivots", Json::num(40.0)),
                ("nodes", Json::num(3.0)),
                ("build_ms", Json::num(1.0)),
                ("root_lp_ms", Json::num(4.0)),
                ("bnb_ms", Json::num(7.0)),
                ("pricing_ms", Json::num(0.0)),
                ("pricing_rounds", Json::num(0.0)),
                ("columns", Json::num(0.0)),
            ],
        );
        ts.sim_event(
            60.0,
            "run_summary",
            vec![
                ("items", Json::num(120.0)),
                ("oom_events", Json::num(0.0)),
                ("config_transitions", Json::num(0.0)),
                ("dynamics_events", Json::num(0.0)),
                ("plans_committed", Json::num(1.0)),
                ("solves", Json::num(1.0)),
                ("replans", Json::num(0.0)),
                ("recovers", Json::num(0.0)),
                ("lost_records", Json::num(0.0)),
                ("windows", Json::num(1.0)),
                (
                    "tenants",
                    Json::Arr(vec![Json::obj(vec![
                        ("id", Json::str("pdf")),
                        ("items", Json::num(120.0)),
                        ("throughput", Json::num(2.0)),
                    ])]),
                ),
            ],
        );
        ts
    }

    #[test]
    fn jsonl_roundtrips_and_cross_checks_clean() {
        let ts = mini_sink();
        let text = ts.to_jsonl();
        let s = summarize_jsonl(&text).expect("valid trace");
        assert_eq!(s.schema, TRACE_SCHEMA);
        assert_eq!(s.windows, 1);
        assert_eq!(s.total_items(), 120);
        assert_eq!(s.solves, 1);
        assert_eq!(s.pivots, 40);
        assert_eq!(s.plans_committed, 1);
        let errs = s.check();
        assert!(errs.is_empty(), "unexpected mismatches: {errs:?}");
        let rendered = s.render();
        assert!(rendered.contains("bottleneck: decode"));
    }

    #[test]
    fn cross_check_flags_mismatches() {
        let mut ts = mini_sink();
        // Tamper: claim one more item than the windows carried.
        ts.sim_event(
            61.0,
            "run_summary",
            vec![
                ("items", Json::num(121.0)),
                ("oom_events", Json::num(0.0)),
                ("config_transitions", Json::num(0.0)),
                ("dynamics_events", Json::num(0.0)),
                ("plans_committed", Json::num(1.0)),
                ("solves", Json::num(1.0)),
                ("replans", Json::num(0.0)),
                ("recovers", Json::num(0.0)),
                ("lost_records", Json::num(0.0)),
                ("windows", Json::num(1.0)),
                ("tenants", Json::Arr(vec![])),
            ],
        );
        let s = summarize_jsonl(&ts.to_jsonl()).expect("valid trace");
        assert!(s.check().iter().any(|e| e.starts_with("items:")));
    }

    #[test]
    fn rejects_bad_lines_schema_and_seq_gaps() {
        assert!(summarize_jsonl("").is_err());
        assert!(summarize_jsonl("not json\n").is_err());
        let mut ts = TraceSink::new();
        ts.sim_event(0.0, "window", vec![]);
        // First record is not a header.
        assert!(summarize_jsonl(&ts.to_jsonl()).is_err());
        let ts = mini_sink();
        let jsonl = ts.to_jsonl();
        let mut lines: Vec<&str> = jsonl.lines().collect();
        let dropped = lines.remove(1); // open a sim-lane seq gap
        assert!(dropped.contains("\"lane\":\"sim\""));
        let text = lines.join("\n");
        assert!(summarize_jsonl(&text).is_err());
        let bad = ts.to_jsonl().replace(TRACE_SCHEMA, "trident-trace/v999");
        assert!(summarize_jsonl(&bad).is_err());
    }

    #[test]
    fn sim_lane_is_stable_under_wall_payload_changes() {
        let keep_sim = |s: &str| -> String {
            s.lines().filter(|l| !l.contains("\"lane\":\"wall\"")).collect::<Vec<_>>().join("\n")
        };
        let a = mini_sink();
        let mut b = TraceSink::new();
        // Same sim events, different wall payloads (a faster host).
        for rec in a.records() {
            let kind = rec.str_or("kind", "?").to_string();
            let t = rec.f64_or("t", 0.0);
            if rec.str_or("lane", "sim") == "wall" {
                b.wall_event(t, &kind, vec![("milp_ms", Json::num(1.0))]);
            } else if kind == "header" {
                let mut fields = Vec::new();
                if let Json::Obj(m) = rec {
                    for (k, v) in m {
                        if !matches!(k.as_str(), "kind" | "lane" | "seq" | "t" | "schema") {
                            fields.push((k.as_str(), v.clone()));
                        }
                    }
                }
                b.header(fields);
            } else {
                let mut fields = Vec::new();
                if let Json::Obj(m) = rec {
                    for (k, v) in m {
                        if !matches!(k.as_str(), "kind" | "lane" | "seq" | "t") {
                            fields.push((k.as_str(), v.clone()));
                        }
                    }
                }
                b.sim_event(t, &kind, fields);
            }
        }
        assert_eq!(keep_sim(&a.to_jsonl()), keep_sim(&b.to_jsonl()));
        assert_ne!(a.to_jsonl(), b.to_jsonl());
    }

    #[test]
    fn chrome_export_is_valid_json_with_duration_events() {
        let ts = mini_sink();
        let j = Json::parse(ts.to_chrome().trim_end()).expect("chrome export parses");
        let evs = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(evs.len(), ts.len());
        let window = evs.iter().find(|e| e.str_or("name", "") == "window").unwrap();
        assert_eq!(window.str_or("ph", ""), "X");
        assert_eq!(window.f64_or("dur", -1.0), 30.0 * 1e6);
        let solve = evs.iter().find(|e| e.str_or("name", "") == "solve").unwrap();
        assert_eq!(solve.str_or("ph", ""), "X");
        assert_eq!(solve.str_or("cat", ""), "wall");
        assert_eq!(solve.f64_or("tid", -1.0), 1.0);
    }

    #[test]
    fn format_parse_is_strict() {
        assert_eq!(TraceFormat::parse("jsonl"), Some(TraceFormat::Jsonl));
        assert_eq!(TraceFormat::parse("chrome"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("chrme"), None);
        assert_eq!(TraceFormat::parse(""), None);
    }
}
