//! Typed specifications for clusters, operators, pipelines, and the
//! Trident controller — the public configuration surface of the library.
//!
//! Specs are plain data; the discrete-event simulator interprets the
//! `ServiceModel` ground truth (which the scheduler never reads — it only
//! sees metrics), and the scheduling stack reads the resource/flow fields.

use super::json::Json;

/// One server in the fixed-resource cluster.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub cpu_cores: f64,
    pub mem_gb: f64,
    /// Number of accelerator devices (NPU/GPU/TPU) on this node.
    pub accels: u32,
    /// Device memory per accelerator, MB.
    pub accel_mem_mb: f64,
    /// NIC egress bandwidth, MB/s.
    pub egress_mbps: f64,
}

#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
}

impl ClusterSpec {
    /// Homogeneous cluster builder (the paper's testbed shape).
    pub fn homogeneous(
        n_nodes: usize,
        cpu_cores: f64,
        mem_gb: f64,
        accels: u32,
        accel_mem_mb: f64,
        egress_mbps: f64,
    ) -> Self {
        ClusterSpec {
            nodes: (0..n_nodes)
                .map(|k| NodeSpec {
                    name: format!("node{k}"),
                    cpu_cores,
                    mem_gb,
                    accels,
                    accel_mem_mb,
                    egress_mbps,
                })
                .collect(),
        }
    }

    pub fn total_cpus(&self) -> f64 {
        self.nodes.iter().map(|n| n.cpu_cores).sum()
    }

    pub fn total_accels(&self) -> u32 {
        self.nodes.iter().map(|n| n.accels).sum()
    }
}

/// How an operator executes (drives both the sim service model and the
/// useful-time semantics the DS2-style estimators rely on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperatorKind {
    /// Synchronous, record-at-a-time CPU operator.
    CpuSync,
    /// Asynchronous accelerator operator with continuous batching
    /// (LLM inference, batched vision models).
    AccelAsync,
}

/// One tunable configuration dimension (mixed int/continuous space).
#[derive(Debug, Clone)]
pub struct ConfigParam {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub integer: bool,
    /// Search in log2 space (batch sizes, token budgets).
    pub log2: bool,
    pub default: f64,
}

impl ConfigParam {
    pub fn clampi(&self, v: f64) -> f64 {
        let v = v.clamp(self.lo, self.hi);
        if self.integer {
            v.round()
        } else {
            v
        }
    }

    /// Map a unit-cube coordinate into the parameter range.
    pub fn from_unit(&self, u: f64) -> f64 {
        let v = if self.log2 {
            let (l, h) = (self.lo.max(1e-9).log2(), self.hi.log2());
            (l + u * (h - l)).exp2()
        } else {
            self.lo + u * (self.hi - self.lo)
        };
        self.clampi(v)
    }

    /// Normalize a value to the unit cube (inverse of `from_unit`).
    pub fn to_unit(&self, v: f64) -> f64 {
        if self.log2 {
            let (l, h) = (self.lo.max(1e-9).log2(), self.hi.log2());
            ((v.max(1e-9).log2() - l) / (h - l)).clamp(0.0, 1.0)
        } else {
            ((v - self.lo) / (self.hi - self.lo).max(1e-12)).clamp(0.0, 1.0)
        }
    }
}

/// The operator's configuration search space (Θ_i in the paper).
#[derive(Debug, Clone, Default)]
pub struct ConfigSpace {
    pub params: Vec<ConfigParam>,
}

impl ConfigSpace {
    pub fn dims(&self) -> usize {
        self.params.len()
    }

    pub fn default_config(&self) -> Vec<f64> {
        self.params.iter().map(|p| p.default).collect()
    }

    pub fn from_unit(&self, u: &[f64]) -> Vec<f64> {
        self.params
            .iter()
            .zip(u)
            .map(|(p, &ui)| p.from_unit(ui))
            .collect()
    }

    pub fn to_unit(&self, theta: &[f64]) -> Vec<f64> {
        self.params
            .iter()
            .zip(theta)
            .map(|(p, &v)| p.to_unit(v))
            .collect()
    }

    pub fn clamp(&self, theta: &[f64]) -> Vec<f64> {
        self.params
            .iter()
            .zip(theta)
            .map(|(p, &v)| p.clampi(v))
            .collect()
    }

    /// vLLM-style inference-engine space used by the paper's Table 5.
    pub fn llm_engine() -> Self {
        ConfigSpace {
            params: vec![
                ConfigParam { name: "max_num_seqs".into(), lo: 1.0, hi: 128.0, integer: true, log2: true, default: 16.0 },
                ConfigParam { name: "max_num_batched_tokens".into(), lo: 512.0, hi: 16384.0, integer: true, log2: true, default: 2048.0 },
                ConfigParam { name: "block_size".into(), lo: 8.0, hi: 32.0, integer: true, log2: true, default: 16.0 },
                ConfigParam { name: "scheduler_delay_factor".into(), lo: 0.0, hi: 1.0, integer: false, log2: false, default: 0.0 },
                ConfigParam { name: "enable_chunked_prefill".into(), lo: 0.0, hi: 1.0, integer: true, log2: false, default: 0.0 },
                ConfigParam { name: "enable_prefix_caching".into(), lo: 0.0, hi: 1.0, integer: true, log2: false, default: 0.0 },
            ],
        }
    }

    /// Batched vision-model space (CLIP scoring, text detection).
    pub fn vision_engine() -> Self {
        ConfigSpace {
            params: vec![
                ConfigParam { name: "batch_size".into(), lo: 1.0, hi: 256.0, integer: true, log2: true, default: 32.0 },
                ConfigParam { name: "tile_px".into(), lo: 224.0, hi: 1024.0, integer: true, log2: true, default: 448.0 },
                ConfigParam { name: "fp16".into(), lo: 0.0, hi: 1.0, integer: true, log2: false, default: 1.0 },
            ],
        }
    }
}

/// Linear item-cost weights over [`ItemAttrs`] fields.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostW {
    pub tokens_in: f64,
    pub tokens_out: f64,
    pub pixels_m: f64,
    pub frames: f64,
    pub konst: f64,
}

/// Ground-truth service behaviour (sim-only; hidden from the scheduler).
#[derive(Debug, Clone)]
pub enum ServiceModel {
    /// Synchronous CPU operator: per-record service time =
    /// cost(attrs) / (base_rate * ref_cost).
    Cpu { base_rate: f64, ref_cost: f64, cost: CostW },
    /// Asynchronous continuous-batching accelerator operator.
    Accel {
        /// Token throughput at batch saturation with the default config.
        peak_tok_rate: f64,
        /// Half-saturation effective batch size.
        batch_half: f64,
        /// Decode tokens cost this much more than prefill tokens.
        decode_weight: f64,
        /// Fraction of cross-request prefix sharing in this workload
        /// (prefix caching only pays off when this is high).
        prefix_share: f64,
        /// Memory ground truth, MB.
        mem_base_mb: f64,
        kv_mb_per_token: f64,
        act_mb_per_token: f64,
        /// Lognormal sigma of allocator noise on peak memory.
        mem_noise_sigma: f64,
    },
}

/// Feature extractor wiring an operator's workload descriptors (observation
/// layer, §4.2) and regime features (adaptation layer, §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureExtractor {
    /// (mu_in, sigma_in, mu_out, sigma_out) over token lengths.
    LlmTokens,
    /// (mean resolution in Mpx, mean frames).
    Vision,
    /// (mean item cost) — generic CPU stage.
    Cost,
}

/// Full operator specification.
#[derive(Debug, Clone)]
pub struct OperatorSpec {
    pub name: String,
    pub kind: OperatorKind,
    /// CPU cores per instance (u_i).
    pub cpu: f64,
    /// Host memory per instance, GB (m_i).
    pub mem_gb: f64,
    /// Accelerator devices per instance (g_i).
    pub accels: u32,
    /// Output records per input record (data amplification source).
    pub fanout: f64,
    /// Size of each output record, MB (d_i^out).
    pub out_mb: f64,
    /// Instance lifecycle costs, seconds.
    pub start_s: f64,
    pub stop_s: f64,
    pub cold_s: f64,
    pub tunable: bool,
    pub config_space: ConfigSpace,
    pub service: ServiceModel,
    pub features: FeatureExtractor,
    /// Multipliers applied to (tokens_in, tokens_out, pixels_m, frames)
    /// when this operator fans an item out into children (e.g. a document
    /// split into ~120 blocks scales tokens by ~1/120).
    pub child_scale: [f64; 4],
    /// Per-instance input queue capacity, records (bounded buffers are the
    /// backpressure mechanism of the streaming executor).
    pub queue_cap: usize,
}

impl OperatorSpec {
    pub fn is_accel(&self) -> bool {
        self.accels > 0
    }
}

/// A linear pipeline of operators (the paper's dataflow shape).
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub name: String,
    pub operators: Vec<OperatorSpec>,
}

impl PipelineSpec {
    pub fn n_ops(&self) -> usize {
        self.operators.len()
    }

    /// Amplification factors D_i (input volume of operator i relative to
    /// pipeline input; D_1 = 1) and D_o at the output.
    pub fn amplification(&self) -> (Vec<f64>, f64) {
        let mut d = Vec::with_capacity(self.operators.len());
        let mut cur = 1.0;
        for op in &self.operators {
            d.push(cur);
            cur *= op.fanout;
        }
        (d, cur)
    }
}

/// Controller hyper-parameters (paper defaults in parentheses).
#[derive(Debug, Clone)]
pub struct TridentConfig {
    /// Rescheduling interval T_sched (multi-second; paper uses minutes on
    /// the real cluster, we default to 30 s of sim time).
    pub t_sched_s: f64,
    /// Metrics flush interval.
    pub metrics_interval_s: f64,
    /// Objective tiebreakers (1e-4, 1e-6).
    pub lambda1: f64,
    pub lambda2: f64,
    /// Stage-1 utilization threshold tau_u.
    pub tau_u: f64,
    /// Stage-2 residual threshold tau_z.
    pub tau_z: f64,
    /// Min filtered samples before GP takes over from EMA.
    pub n_min: usize,
    /// GP observation-buffer capacity (matches AOT N_TRAIN).
    pub gp_window: usize,
    /// EMA smoothing factor.
    pub ema_alpha: f64,
    /// BO feasibility threshold eta (0.6).
    pub eta: f64,
    /// Memory safety margin Delta, MB (2048).
    pub delta_mb: f64,
    /// Max clusters L_max.
    pub l_max: usize,
    /// Cluster assignment distance threshold tau_d (normalized space).
    pub tau_d: f64,
    /// Cluster count decay gamma.
    pub gamma: f64,
    /// Samples before a cluster triggers tuning.
    pub tune_trigger: usize,
    /// BO evaluation budget per tuning job (30) and random init (5).
    pub bo_budget: usize,
    pub bo_init: usize,
    /// Seconds each BO candidate is evaluated on a probe instance.
    pub bo_eval_s: f64,
    /// Rolling-update max batch B_max.
    pub b_max: usize,
    /// MILP solver wall-clock budget.
    pub milp_time_budget_ms: u64,
    /// Use the native Rust GP instead of PJRT artifacts.
    pub native_gp: bool,
}

impl Default for TridentConfig {
    fn default() -> Self {
        TridentConfig {
            t_sched_s: 90.0,
            metrics_interval_s: 5.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            tau_u: 0.6,
            tau_z: 3.0,
            n_min: 8,
            gp_window: 64,
            ema_alpha: 0.3,
            eta: 0.6,
            delta_mb: 2048.0,
            l_max: 8,
            tau_d: 0.30,
            gamma: 0.995,
            tune_trigger: 32,
            bo_budget: 16,
            bo_init: 5,
            bo_eval_s: 20.0,
            b_max: 8,
            milp_time_budget_ms: 600,
            native_gp: std::env::var("TRIDENT_NATIVE_GP").map(|v| v == "1").unwrap_or(false),
        }
    }
}

// ---------------------------------------------------------------------------
// JSON (de)serialization for the public spec types (cluster + controller);
// pipelines are built by the preset constructors or programmatically.
// ---------------------------------------------------------------------------

impl ClusterSpec {
    pub fn to_json(&self) -> Json {
        Json::Obj(
            [(
                "nodes".to_string(),
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::obj(vec![
                                ("name", Json::str(&n.name)),
                                ("cpu_cores", Json::num(n.cpu_cores)),
                                ("mem_gb", Json::num(n.mem_gb)),
                                ("accels", Json::num(n.accels as f64)),
                                ("accel_mem_mb", Json::num(n.accel_mem_mb)),
                                ("egress_mbps", Json::num(n.egress_mbps)),
                            ])
                        })
                        .collect(),
                ),
            )]
            .into_iter()
            .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let nodes = j
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or("cluster: missing nodes[]")?;
        Ok(ClusterSpec {
            nodes: nodes
                .iter()
                .enumerate()
                .map(|(k, n)| NodeSpec {
                    name: n.str_or("name", &format!("node{k}")).to_string(),
                    cpu_cores: n.f64_or("cpu_cores", 32.0),
                    mem_gb: n.f64_or("mem_gb", 128.0),
                    accels: n.f64_or("accels", 0.0) as u32,
                    accel_mem_mb: n.f64_or("accel_mem_mb", 65536.0),
                    egress_mbps: n.f64_or("egress_mbps", 12500.0),
                })
                .collect(),
        })
    }
}

impl TridentConfig {
    pub fn from_json(j: &Json) -> Self {
        let d = TridentConfig::default();
        TridentConfig {
            t_sched_s: j.f64_or("t_sched_s", d.t_sched_s),
            metrics_interval_s: j.f64_or("metrics_interval_s", d.metrics_interval_s),
            lambda1: j.f64_or("lambda1", d.lambda1),
            lambda2: j.f64_or("lambda2", d.lambda2),
            tau_u: j.f64_or("tau_u", d.tau_u),
            tau_z: j.f64_or("tau_z", d.tau_z),
            n_min: j.f64_or("n_min", d.n_min as f64) as usize,
            gp_window: j.f64_or("gp_window", d.gp_window as f64) as usize,
            ema_alpha: j.f64_or("ema_alpha", d.ema_alpha),
            eta: j.f64_or("eta", d.eta),
            delta_mb: j.f64_or("delta_mb", d.delta_mb),
            l_max: j.f64_or("l_max", d.l_max as f64) as usize,
            tau_d: j.f64_or("tau_d", d.tau_d),
            gamma: j.f64_or("gamma", d.gamma),
            tune_trigger: j.f64_or("tune_trigger", d.tune_trigger as f64) as usize,
            bo_budget: j.f64_or("bo_budget", d.bo_budget as f64) as usize,
            bo_init: j.f64_or("bo_init", d.bo_init as f64) as usize,
            bo_eval_s: j.f64_or("bo_eval_s", d.bo_eval_s),
            b_max: j.f64_or("b_max", d.b_max as f64) as usize,
            milp_time_budget_ms: j.f64_or("milp_time_budget_ms", d.milp_time_budget_ms as f64) as u64,
            native_gp: j.get("native_gp").and_then(Json::as_bool).unwrap_or(d.native_gp),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amplification_tracks_fanout() {
        let mk = |fanout: f64| OperatorSpec {
            name: "op".into(),
            kind: OperatorKind::CpuSync,
            cpu: 1.0,
            mem_gb: 1.0,
            accels: 0,
            fanout,
            out_mb: 0.1,
            start_s: 1.0,
            stop_s: 0.5,
            cold_s: 5.0,
            tunable: false,
            config_space: ConfigSpace::default(),
            service: ServiceModel::Cpu { base_rate: 10.0, ref_cost: 1.0, cost: CostW::default() },
            features: FeatureExtractor::Cost,
            child_scale: [1.0; 4],
            queue_cap: 512,
        };
        let p = PipelineSpec { name: "t".into(), operators: vec![mk(10.0), mk(0.5), mk(1.0)] };
        let (d, d_out) = p.amplification();
        assert_eq!(d, vec![1.0, 10.0, 5.0]);
        assert_eq!(d_out, 5.0);
    }

    #[test]
    fn config_param_unit_roundtrip() {
        let p = ConfigParam { name: "b".into(), lo: 1.0, hi: 128.0, integer: true, log2: true, default: 16.0 };
        for u in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let v = p.from_unit(u);
            assert!((1.0..=128.0).contains(&v));
            assert_eq!(v, v.round());
            let u2 = p.to_unit(v);
            assert!((p.from_unit(u2) - v).abs() < 1.0 + 1e-9);
        }
        assert_eq!(p.from_unit(0.0), 1.0);
        assert_eq!(p.from_unit(1.0), 128.0);
    }

    #[test]
    fn llm_space_shape() {
        let s = ConfigSpace::llm_engine();
        assert_eq!(s.dims(), 6);
        let d = s.default_config();
        assert_eq!(d[0], 16.0);
        let clamped = s.clamp(&[1e6, -5.0, 11.2, 0.5, 0.4, 0.9]);
        assert_eq!(clamped[0], 128.0);
        assert_eq!(clamped[1], 512.0);
        assert_eq!(clamped[2], 11.0);
        assert_eq!(clamped[4], 0.0);
        assert_eq!(clamped[5], 1.0);
    }

    #[test]
    fn cluster_json_roundtrip() {
        let c = ClusterSpec::homogeneous(3, 256.0, 1024.0, 8, 65536.0, 12500.0);
        let j = c.to_json();
        let c2 = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(c2.nodes.len(), 3);
        assert_eq!(c2.nodes[1].accels, 8);
        assert_eq!(c2.total_cpus(), 768.0);
    }

    #[test]
    fn trident_config_json_overrides() {
        let j = Json::parse(r#"{"eta": 0.8, "bo_budget": 10}"#).unwrap();
        let c = TridentConfig::from_json(&j);
        assert_eq!(c.eta, 0.8);
        assert_eq!(c.bo_budget, 10);
        assert_eq!(c.lambda1, 1e-4); // default preserved
    }
}
