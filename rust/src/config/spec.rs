//! Typed specifications for clusters, operators, pipelines, and the
//! Trident controller — the public configuration surface of the library.
//!
//! Specs are plain data; the discrete-event simulator interprets the
//! `ServiceModel` ground truth (which the scheduler never reads — it only
//! sees metrics), and the scheduling stack reads the resource/flow fields.

use std::collections::HashMap;

use super::json::Json;

/// Dense operator id: an index into `PipelineSpec::operators`, newtyped so
/// name-resolved handles are visibly distinct from raw loop indices.
///
/// Everything reachable from `PipelineSim::run_until` already speaks dense
/// `usize` ids; `OpId`/`EdgeId` plus [`SpecInterner`] are the *boundary*
/// API — names are resolved exactly once, when a spec (or a test/bench
/// harness) is built, and only ids cross into the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OpId(pub u32);

impl OpId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Dense edge id: an index into `PipelineSpec::edges`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EdgeId(pub u32);

impl EdgeId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// One-shot name → dense-id resolver, built from a spec by
/// [`PipelineSpec::interner`].  Replaces the ad-hoc
/// `operators.iter().position(|o| o.name == ...)` scans: O(1) lookups,
/// built once, and the returned ids are plain indices thereafter.
pub struct SpecInterner {
    ops: HashMap<String, OpId>,
    edges: HashMap<(u32, u32), EdgeId>,
}

impl SpecInterner {
    /// Resolve an operator by name; panics with the offending name on a
    /// miss (interner users are spec builders, where a bad name is a bug).
    pub fn op(&self, name: &str) -> OpId {
        *self.ops.get(name).unwrap_or_else(|| panic!("unknown operator '{name}'"))
    }

    pub fn try_op(&self, name: &str) -> Option<OpId> {
        self.ops.get(name).copied()
    }

    /// Resolve the edge `from -> to`; panics if the spec has no such edge.
    pub fn edge(&self, from: OpId, to: OpId) -> EdgeId {
        *self
            .edges
            .get(&(from.0, to.0))
            .unwrap_or_else(|| panic!("no edge {} -> {}", from.0, to.0))
    }
}

/// One server in the fixed-resource cluster.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub cpu_cores: f64,
    pub mem_gb: f64,
    /// Number of accelerator devices (NPU/GPU/TPU) on this node.
    pub accels: u32,
    /// Device memory per accelerator, MB.
    pub accel_mem_mb: f64,
    /// NIC egress bandwidth, MB/s.
    pub egress_mbps: f64,
}

#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
}

impl ClusterSpec {
    /// Homogeneous cluster builder (the paper's testbed shape).
    pub fn homogeneous(
        n_nodes: usize,
        cpu_cores: f64,
        mem_gb: f64,
        accels: u32,
        accel_mem_mb: f64,
        egress_mbps: f64,
    ) -> Self {
        ClusterSpec {
            nodes: (0..n_nodes)
                .map(|k| NodeSpec {
                    name: format!("node{k}"),
                    cpu_cores,
                    mem_gb,
                    accels,
                    accel_mem_mb,
                    egress_mbps,
                })
                .collect(),
        }
    }

    pub fn total_cpus(&self) -> f64 {
        self.nodes.iter().map(|n| n.cpu_cores).sum()
    }

    pub fn total_accels(&self) -> u32 {
        self.nodes.iter().map(|n| n.accels).sum()
    }
}

/// How an operator executes (drives both the sim service model and the
/// useful-time semantics the DS2-style estimators rely on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperatorKind {
    /// Synchronous, record-at-a-time CPU operator.
    CpuSync,
    /// Asynchronous accelerator operator with continuous batching
    /// (LLM inference, batched vision models).
    AccelAsync,
}

/// One tunable configuration dimension (mixed int/continuous space).
#[derive(Debug, Clone)]
pub struct ConfigParam {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub integer: bool,
    /// Search in log2 space (batch sizes, token budgets).
    pub log2: bool,
    pub default: f64,
}

impl ConfigParam {
    pub fn clampi(&self, v: f64) -> f64 {
        let v = v.clamp(self.lo, self.hi);
        if self.integer {
            v.round()
        } else {
            v
        }
    }

    /// Map a unit-cube coordinate into the parameter range.
    pub fn from_unit(&self, u: f64) -> f64 {
        let v = if self.log2 {
            let (l, h) = (self.lo.max(1e-9).log2(), self.hi.log2());
            (l + u * (h - l)).exp2()
        } else {
            self.lo + u * (self.hi - self.lo)
        };
        self.clampi(v)
    }

    /// Normalize a value to the unit cube (inverse of `from_unit`).
    pub fn to_unit(&self, v: f64) -> f64 {
        if self.log2 {
            let (l, h) = (self.lo.max(1e-9).log2(), self.hi.log2());
            ((v.max(1e-9).log2() - l) / (h - l)).clamp(0.0, 1.0)
        } else {
            ((v - self.lo) / (self.hi - self.lo).max(1e-12)).clamp(0.0, 1.0)
        }
    }
}

/// The operator's configuration search space (Θ_i in the paper).
#[derive(Debug, Clone, Default)]
pub struct ConfigSpace {
    pub params: Vec<ConfigParam>,
}

impl ConfigSpace {
    pub fn dims(&self) -> usize {
        self.params.len()
    }

    pub fn default_config(&self) -> Vec<f64> {
        self.params.iter().map(|p| p.default).collect()
    }

    pub fn from_unit(&self, u: &[f64]) -> Vec<f64> {
        self.params
            .iter()
            .zip(u)
            .map(|(p, &ui)| p.from_unit(ui))
            .collect()
    }

    pub fn to_unit(&self, theta: &[f64]) -> Vec<f64> {
        self.params
            .iter()
            .zip(theta)
            .map(|(p, &v)| p.to_unit(v))
            .collect()
    }

    pub fn clamp(&self, theta: &[f64]) -> Vec<f64> {
        self.params
            .iter()
            .zip(theta)
            .map(|(p, &v)| p.clampi(v))
            .collect()
    }

    /// vLLM-style inference-engine space used by the paper's Table 5.
    pub fn llm_engine() -> Self {
        ConfigSpace {
            params: vec![
                ConfigParam { name: "max_num_seqs".into(), lo: 1.0, hi: 128.0, integer: true, log2: true, default: 16.0 },
                ConfigParam { name: "max_num_batched_tokens".into(), lo: 512.0, hi: 16384.0, integer: true, log2: true, default: 2048.0 },
                ConfigParam { name: "block_size".into(), lo: 8.0, hi: 32.0, integer: true, log2: true, default: 16.0 },
                ConfigParam { name: "scheduler_delay_factor".into(), lo: 0.0, hi: 1.0, integer: false, log2: false, default: 0.0 },
                ConfigParam { name: "enable_chunked_prefill".into(), lo: 0.0, hi: 1.0, integer: true, log2: false, default: 0.0 },
                ConfigParam { name: "enable_prefix_caching".into(), lo: 0.0, hi: 1.0, integer: true, log2: false, default: 0.0 },
            ],
        }
    }

    /// Batched vision-model space (CLIP scoring, text detection).
    pub fn vision_engine() -> Self {
        ConfigSpace {
            params: vec![
                ConfigParam { name: "batch_size".into(), lo: 1.0, hi: 256.0, integer: true, log2: true, default: 32.0 },
                ConfigParam { name: "tile_px".into(), lo: 224.0, hi: 1024.0, integer: true, log2: true, default: 448.0 },
                ConfigParam { name: "fp16".into(), lo: 0.0, hi: 1.0, integer: true, log2: false, default: 1.0 },
            ],
        }
    }
}

/// Linear item-cost weights over [`ItemAttrs`] fields.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostW {
    pub tokens_in: f64,
    pub tokens_out: f64,
    pub pixels_m: f64,
    pub frames: f64,
    pub konst: f64,
}

/// Ground-truth service behaviour (sim-only; hidden from the scheduler).
#[derive(Debug, Clone)]
pub enum ServiceModel {
    /// Synchronous CPU operator: per-record service time =
    /// cost(attrs) / (base_rate * ref_cost).
    Cpu { base_rate: f64, ref_cost: f64, cost: CostW },
    /// Asynchronous continuous-batching accelerator operator.
    Accel {
        /// Token throughput at batch saturation with the default config.
        peak_tok_rate: f64,
        /// Half-saturation effective batch size.
        batch_half: f64,
        /// Decode tokens cost this much more than prefill tokens.
        decode_weight: f64,
        /// Fraction of cross-request prefix sharing in this workload
        /// (prefix caching only pays off when this is high).
        prefix_share: f64,
        /// Memory ground truth, MB.
        mem_base_mb: f64,
        kv_mb_per_token: f64,
        act_mb_per_token: f64,
        /// Lognormal sigma of allocator noise on peak memory.
        mem_noise_sigma: f64,
    },
}

/// Feature extractor wiring an operator's workload descriptors (observation
/// layer, §4.2) and regime features (adaptation layer, §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureExtractor {
    /// (mu_in, sigma_in, mu_out, sigma_out) over token lengths.
    LlmTokens,
    /// (mean resolution in Mpx, mean frames).
    Vision,
    /// (mean item cost) — generic CPU stage.
    Cost,
}

/// Full operator specification.
#[derive(Debug, Clone)]
pub struct OperatorSpec {
    pub name: String,
    pub kind: OperatorKind,
    /// CPU cores per instance (u_i).
    pub cpu: f64,
    /// Host memory per instance, GB (m_i).
    pub mem_gb: f64,
    /// Accelerator devices per instance (g_i).
    pub accels: u32,
    /// Output records per input record (data amplification source).
    pub fanout: f64,
    /// Size of each output record, MB (d_i^out).
    pub out_mb: f64,
    /// Instance lifecycle costs, seconds.
    pub start_s: f64,
    pub stop_s: f64,
    pub cold_s: f64,
    pub tunable: bool,
    pub config_space: ConfigSpace,
    pub service: ServiceModel,
    pub features: FeatureExtractor,
    /// Multipliers applied to (tokens_in, tokens_out, pixels_m, frames)
    /// when this operator fans an item out into children (e.g. a document
    /// split into ~120 blocks scales tokens by ~1/120).
    pub child_scale: [f64; 4],
    /// Per-instance input queue capacity, records (bounded buffers are the
    /// backpressure mechanism of the streaming executor).
    pub queue_cap: usize,
}

impl OperatorSpec {
    pub fn is_accel(&self) -> bool {
        self.accels > 0
    }
}

/// A pipeline of operators over an explicit edge-list DAG.
///
/// The paper's workloads are linear chains, which are the path-shaped
/// special case (`PipelineSpec::chain`).  General DAGs add two structural
/// roles, both derived from the edge list rather than declared:
///
/// * **fork** — an operator with several outgoing edges *replicates* each
///   output record onto every edge (modality-parallel branches see the
///   same items, e.g. ASR and captioning both consume the decoded clip);
/// * **join** — an operator with several incoming edges merges records
///   that share an item id (align-by-item-id), consuming one merged
///   record per aligned group.
///
/// Between a fork and its join every operator must emit at most one child
/// per input (fanout ≤ 1) so item ids survive the branch; the fork itself
/// may fan out freely (children are replicated with matching ids).
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub name: String,
    pub operators: Vec<OperatorSpec>,
    /// Dataflow edges `(from_op, to_op)`.  Operator 0 is the unique
    /// source; operators without outgoing edges are sinks.
    pub edges: Vec<(usize, usize)>,
}

impl PipelineSpec {
    /// A linear chain `0 -> 1 -> ... -> n-1` (the paper's shape).
    pub fn chain(name: impl Into<String>, operators: Vec<OperatorSpec>) -> Self {
        let edges = (1..operators.len()).map(|i| (i - 1, i)).collect();
        PipelineSpec { name: name.into(), operators, edges }
    }

    pub fn n_ops(&self) -> usize {
        self.operators.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Build the one-shot name → dense-id resolver for this spec.  On a
    /// duplicate operator name the last occurrence wins (merged tenancy
    /// specs namespace names per tenant, so collisions don't arise in
    /// practice).
    pub fn interner(&self) -> SpecInterner {
        let ops = self
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| (o.name.clone(), OpId(i as u32)))
            .collect();
        let edges = self
            .edges
            .iter()
            .enumerate()
            .map(|(i, &(f, t))| ((f as u32, t as u32), EdgeId(i as u32)))
            .collect();
        SpecInterner { ops, edges }
    }

    /// Edge ids leaving `op`, in edge-list order.
    pub fn out_edges(&self, op: usize) -> Vec<usize> {
        (0..self.edges.len()).filter(|&e| self.edges[e].0 == op).collect()
    }

    /// Edge ids entering `op`, in edge-list order.  A join's partial-result
    /// slots are indexed by position in this list.
    pub fn in_edges(&self, op: usize) -> Vec<usize> {
        (0..self.edges.len()).filter(|&e| self.edges[e].1 == op).collect()
    }

    pub fn in_degree(&self, op: usize) -> usize {
        self.edges.iter().filter(|&&(_, v)| v == op).count()
    }

    /// Joins are operators with more than one incoming edge.
    pub fn is_join(&self, op: usize) -> bool {
        self.in_degree(op) > 1
    }

    /// Operators with no outgoing edges (their outputs leave the pipeline).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.operators.len()).filter(|&i| self.out_edges(i).is_empty()).collect()
    }

    /// Deterministic topological order: repeatedly take the lowest-index
    /// operator whose predecessors are all placed.  Panics on cycles
    /// (`validate` reports them as errors instead).
    pub fn topo_order(&self) -> Vec<usize> {
        self.try_topo_order().expect("pipeline edge list contains a cycle")
    }

    /// The Kahn scan behind both [`topo_order`](Self::topo_order) and
    /// [`validate`](Self::validate).
    fn try_topo_order(&self) -> Result<Vec<usize>, String> {
        let n = self.operators.len();
        let mut indeg: Vec<usize> = (0..n).map(|i| self.in_degree(i)).collect();
        let mut order = Vec::with_capacity(n);
        let mut placed = vec![false; n];
        for _ in 0..n {
            let Some(next) = (0..n).find(|&i| !placed[i] && indeg[i] == 0) else {
                return Err("pipeline edge list contains a cycle".into());
            };
            placed[next] = true;
            order.push(next);
            for &(u, v) in &self.edges {
                if u == next {
                    indeg[v] -= 1;
                }
            }
        }
        Ok(order)
    }

    /// Structural sanity of the DAG: indices in range, no self-loops,
    /// duplicate edges, or cycles; operator 0 the unique source; every
    /// operator reachable; and the fork/join alignment invariants the
    /// executor's align-by-item-id joins depend on — every operator on a
    /// branch leading into a join must be strictly record-to-record
    /// (`fanout == 1`, so lineage ids survive and no group is orphaned),
    /// and all of a join's incoming edges must carry equal volume.
    /// Violations would not panic the executor; they would silently wedge
    /// it (incomplete join groups pile up until backpressure stops the
    /// pipeline), so they are rejected here instead.
    pub fn validate(&self) -> Result<(), String> {
        self.validate_with_sources(&[0])
    }

    /// [`validate`](Self::validate), generalized to a DAG whose roots are
    /// exactly `sources` — a merged multi-tenant union has one root per
    /// tenant; a standalone pipeline has the single root 0.
    pub fn validate_with_sources(&self, sources: &[usize]) -> Result<(), String> {
        let n = self.operators.len();
        for (ei, &(u, v)) in self.edges.iter().enumerate() {
            if u >= n || v >= n {
                return Err(format!("edge ({u}, {v}) out of range for {n} operators"));
            }
            if u == v {
                return Err(format!("self-loop on operator {u}"));
            }
            if self.edges[..ei].contains(&(u, v)) {
                return Err(format!("duplicate edge ({u}, {v})"));
            }
        }
        for i in 0..n {
            let root = sources.contains(&i);
            if root && self.in_degree(i) != 0 {
                return Err(format!("operator {i} must be a source (no incoming edges)"));
            }
            if !root && self.in_degree(i) == 0 {
                return Err(format!("operator {i} is unreachable (no incoming edges)"));
            }
        }
        // Cycle check (shared Kahn scan with topo_order).
        self.try_topo_order()?;
        // Acyclic from here on: edge volumes are well-defined.
        let vols = self.edge_volumes();
        // Fork/join alignment: walk each join's branches backwards to its
        // anchor — the nearest fork (out-degree > 1, whose replicas carry
        // matching ids), nested join (emits id-preserving merged records),
        // or the source.  Every operator passed on the way must have
        // fanout exactly 1 (so lineage ids survive and no group is
        // orphaned), and all branches must share ONE anchor: two distinct
        // forks both splitting (fanout > 1) would mint disjoint id sets
        // that can never align.
        for j in 0..n {
            if self.in_degree(j) <= 1 {
                continue;
            }
            let mut anchor: Option<usize> = None;
            for &e in &self.in_edges(j) {
                let mut u = self.edges[e].0;
                loop {
                    if self.out_edges(u).len() > 1 {
                        break; // fork anchor: replicas carry matching ids
                    }
                    if self.operators[u].fanout != 1.0 {
                        return Err(format!(
                            "operator {u} ({}) on a branch into join {j} ({}) has fanout {} — \
                             branch operators must be record-to-record for id alignment",
                            self.operators[u].name, self.operators[j].name, self.operators[u].fanout
                        ));
                    }
                    if self.in_degree(u) != 1 {
                        break; // source or nested join anchor
                    }
                    u = self.edges[self.in_edges(u)[0]].0;
                }
                match anchor {
                    None => anchor = Some(u),
                    Some(a) if a != u => {
                        return Err(format!(
                            "join {j} ({}) branches anchor at different operators \
                             ({a} and {u}) — their lineage-id streams cannot align",
                            self.operators[j].name
                        ));
                    }
                    Some(_) => {}
                }
            }
            // Equal volumes on every in-edge (amplification consistency).
            let first = vols[self.in_edges(j)[0]];
            for &e in &self.in_edges(j) {
                if (vols[e] - first).abs() > 1e-9 * first.max(1.0) {
                    return Err(format!(
                        "join {j} ({}) receives unequal edge volumes ({} vs {})",
                        self.operators[j].name, first, vols[e]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Volume carried by each edge relative to pipeline input: a fork
    /// replicates, so every outgoing edge of `u` carries `D_u * fanout_u`.
    pub fn edge_volumes(&self) -> Vec<f64> {
        let (d, _) = self.amplification();
        self.edges.iter().map(|&(u, _)| d[u] * self.operators[u].fanout).collect()
    }

    /// Amplification factors D_i (input volume of operator i relative to
    /// pipeline input; D_source = 1) and D_o at the output.
    ///
    /// Over the DAG: an operator with one incoming edge sees that edge's
    /// volume; a join consumes one merged record per aligned group, so it
    /// sees the volume of a *single* incoming edge (branches between a
    /// fork and its join carry equal volumes by construction — we take the
    /// first in-edge).  D_o sums the emissions of all sinks.  For a chain
    /// this reduces exactly to the old cumulative-fanout product.
    pub fn amplification(&self) -> (Vec<f64>, f64) {
        let n = self.operators.len();
        let mut d = vec![0.0; n];
        for &i in &self.topo_order() {
            d[i] = match self.in_edges(i).first() {
                None => 1.0,
                Some(&e) => {
                    let u = self.edges[e].0;
                    d[u] * self.operators[u].fanout
                }
            };
        }
        let d_o = self
            .sinks()
            .iter()
            .map(|&s| d[s] * self.operators[s].fanout)
            .sum();
        (d, d_o)
    }
}

/// One tenant in a multi-tenant deployment: a pipeline DAG plus its
/// scheduling weight and offered load, sharing the cluster with every
/// other tenant.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Unique tenant id (namespaces operator names in the merged DAG).
    pub id: String,
    pub pipeline: PipelineSpec,
    /// Weight w_t in the scheduler's weighted max-min throughput
    /// objective (must be > 0).
    pub weight: f64,
    /// Offered source rate, items/s.  0 = unpaced: the source emits as
    /// fast as downstream admission allows (the offline paradigm).
    pub source_rate: f64,
}

/// N pipelines sharing one fixed-resource cluster.  The single-tenant
/// tenancy ([`Tenancy::single`]) reproduces the classic one-pipeline
/// deployment exactly.
#[derive(Debug, Clone)]
pub struct Tenancy {
    pub tenants: Vec<TenantSpec>,
}

impl Tenancy {
    /// The trivial tenancy: one pipeline owning the whole cluster
    /// (weight 1, unpaced source, id = pipeline name).
    pub fn single(pipeline: PipelineSpec) -> Self {
        let id = pipeline.name.clone();
        Tenancy { tenants: vec![TenantSpec { id, pipeline, weight: 1.0, source_rate: 0.0 }] }
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Per-tenant validation: non-empty, unique non-empty ids, positive
    /// weights, non-negative source rates, and every pipeline DAG valid.
    pub fn validate(&self) -> Result<(), String> {
        if self.tenants.is_empty() {
            return Err("tenancy has no tenants".into());
        }
        for (ti, t) in self.tenants.iter().enumerate() {
            if t.id.is_empty() {
                return Err(format!("tenant {ti} has an empty id"));
            }
            if self.tenants[..ti].iter().any(|o| o.id == t.id) {
                return Err(format!("duplicate tenant id '{}'", t.id));
            }
            if !(t.weight > 0.0) {
                return Err(format!("tenant '{}' has non-positive weight {}", t.id, t.weight));
            }
            if t.source_rate < 0.0 {
                return Err(format!("tenant '{}' has negative source_rate {}", t.id, t.source_rate));
            }
            t.pipeline.validate().map_err(|e| format!("tenant '{}': {e}", t.id))?;
        }
        Ok(())
    }

    /// Merge the tenants' disjoint DAGs into one operator/edge list over
    /// shared nodes, plus the [`TenancyView`] mapping the union back to
    /// its tenants.  Single-tenant: the merged spec IS the tenant's
    /// pipeline, name and operator names untouched (exact pre-tenancy
    /// behavior).  Multi-tenant: operator names are namespaced `id:name`
    /// and the merged pipeline name joins the tenant ids with '+'.
    pub fn merged(&self) -> Result<(PipelineSpec, TenancyView), String> {
        self.validate()?;
        let ids: Vec<String> = self.tenants.iter().map(|t| t.id.clone()).collect();
        let weights: Vec<f64> = self.tenants.iter().map(|t| t.weight).collect();
        let source_rates: Vec<f64> = self.tenants.iter().map(|t| t.source_rate).collect();
        let d_o: Vec<f64> = self.tenants.iter().map(|t| t.pipeline.amplification().1).collect();
        if self.tenants.len() == 1 {
            let pipeline = self.tenants[0].pipeline.clone();
            let view = TenancyView {
                ids,
                weights,
                source_rates,
                d_o,
                sources: vec![0],
                op_tenant: vec![0; pipeline.n_ops()],
            };
            return Ok((pipeline, view));
        }
        let mut operators = Vec::new();
        let mut edges = Vec::new();
        let mut sources = Vec::new();
        let mut op_tenant = Vec::new();
        for (ti, t) in self.tenants.iter().enumerate() {
            let base = operators.len();
            sources.push(base);
            for o in &t.pipeline.operators {
                let mut o = o.clone();
                o.name = format!("{}:{}", t.id, o.name);
                operators.push(o);
                op_tenant.push(ti);
            }
            for &(u, v) in &t.pipeline.edges {
                edges.push((base + u, base + v));
            }
        }
        let name = ids.join("+");
        let view = TenancyView { ids, weights, source_rates, d_o, sources, op_tenant };
        Ok((PipelineSpec { name, operators, edges }, view))
    }
}

/// Resolved tenant structure of a merged multi-pipeline DAG: which tenant
/// each operator belongs to, where each tenant's source sits, and the
/// per-tenant amplification / weights the executor and scheduler need.
#[derive(Debug, Clone)]
pub struct TenancyView {
    pub ids: Vec<String>,
    /// Weight w_t per tenant (weighted max-min objective).
    pub weights: Vec<f64>,
    /// Offered source rate per tenant, items/s (0 = unpaced).
    pub source_rates: Vec<f64>,
    /// Per-tenant output amplification D_o^t.
    pub d_o: Vec<f64>,
    /// Global operator index of each tenant's source.
    pub sources: Vec<usize>,
    /// Tenant index per merged operator.
    pub op_tenant: Vec<usize>,
}

impl TenancyView {
    /// The trivial view of a single pipeline (tenant 0 owns every op).
    pub fn single_for(spec: &PipelineSpec) -> TenancyView {
        TenancyView {
            ids: vec![spec.name.clone()],
            weights: vec![1.0],
            source_rates: vec![0.0],
            d_o: vec![spec.amplification().1],
            sources: vec![0],
            op_tenant: vec![0; spec.n_ops()],
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.ids.len()
    }

    /// Operator indices belonging to tenant `t`.
    pub fn ops_of(&self, t: usize) -> Vec<usize> {
        (0..self.op_tenant.len()).filter(|&i| self.op_tenant[i] == t).collect()
    }
}

/// Which solve path backs the scheduling MILP each round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverBackend {
    /// The union MILP over all tenants in one branch-and-bound tree
    /// (the default; bit-identical to every release before the
    /// decomposed path existed).
    #[default]
    Monolithic,
    /// Dantzig–Wolfe price-and-branch: per-tenant pricing subproblems
    /// against a restricted master LP over the shared capacity/egress
    /// rows, falling back to `Monolithic` below a tenant-count threshold
    /// or on any engine abort (see `scheduling/decomposed.rs`).
    Decomposed,
}

impl SolverBackend {
    /// Strict parse (CLI `--solver` / config `"solver"`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "monolithic" => Ok(SolverBackend::Monolithic),
            "decomposed" => Ok(SolverBackend::Decomposed),
            other => Err(format!(
                "unknown solver '{other}' (expected monolithic|decomposed)"
            )),
        }
    }
}

/// Controller hyper-parameters (paper defaults in parentheses).
#[derive(Debug, Clone)]
pub struct TridentConfig {
    /// Rescheduling interval T_sched (multi-second; paper uses minutes on
    /// the real cluster, we default to 30 s of sim time).
    pub t_sched_s: f64,
    /// Metrics flush interval.
    pub metrics_interval_s: f64,
    /// Objective tiebreakers (1e-4, 1e-6).
    pub lambda1: f64,
    pub lambda2: f64,
    /// Stage-1 utilization threshold tau_u.
    pub tau_u: f64,
    /// Stage-2 residual threshold tau_z.
    pub tau_z: f64,
    /// Min filtered samples before GP takes over from EMA.
    pub n_min: usize,
    /// GP observation-buffer capacity (matches AOT N_TRAIN).
    pub gp_window: usize,
    /// EMA smoothing factor.
    pub ema_alpha: f64,
    /// BO feasibility threshold eta (0.6).
    pub eta: f64,
    /// Memory safety margin Delta, MB (2048).
    pub delta_mb: f64,
    /// Max clusters L_max.
    pub l_max: usize,
    /// Cluster assignment distance threshold tau_d (normalized space).
    pub tau_d: f64,
    /// Cluster count decay gamma.
    pub gamma: f64,
    /// Samples before a cluster triggers tuning.
    pub tune_trigger: usize,
    /// BO evaluation budget per tuning job (30) and random init (5).
    pub bo_budget: usize,
    pub bo_init: usize,
    /// Seconds each BO candidate is evaluated on a probe instance.
    pub bo_eval_s: f64,
    /// Rolling-update max batch B_max.
    pub b_max: usize,
    /// MILP solver wall-clock budget.
    pub milp_time_budget_ms: u64,
    /// Tie each join's in-edge consumption together per node in the MILP
    /// flow relaxation, so the egress budget sees the sibling-partial
    /// forwarding the executor actually pays (off by default; see
    /// `scheduling/milp_model.rs` module docs).
    pub milp_join_colocation: bool,
    /// Use the native Rust GP instead of PJRT artifacts.
    pub native_gp: bool,
    /// Debug/bench switch: route simulator cross-node transfers through
    /// the legacy one-heap-event-per-record stream instead of the
    /// batched link FIFOs.  Bit-identical results either way (the parity
    /// suite pins this); the batched default is simply faster.
    pub sim_seed_event_stream: bool,
    /// Shard count for the tenant-sharded parallel executor (`ShardedSim`):
    /// tenant `t` is owned by shard `t % K`, each shard advances on its own
    /// worker thread, and results are bit-identical to serial at any K
    /// (clamped to the tenant count; 1 = serial on the caller's thread).
    pub sim_shards: usize,
    /// Worker-thread count for the shard pool that advances the K shards
    /// (work-stealing, persistent across windows).  0 = auto
    /// (`cores − 1`); always clamped to [1, K].  Bit-identity holds at
    /// any (K, W) — workers decide only *who* advances a shard.
    pub sim_workers: usize,
    /// Which solve path backs each scheduling round.  `Monolithic`
    /// (default) is the classic union MILP and keeps historical runs
    /// bit-identical; `Decomposed` prices per-tenant subproblems against
    /// a restricted master LP (Dantzig–Wolfe) and falls back to
    /// monolithic below two tenants or on any engine abort.
    pub solver: SolverBackend,
}

impl Default for TridentConfig {
    fn default() -> Self {
        TridentConfig {
            t_sched_s: 90.0,
            metrics_interval_s: 5.0,
            lambda1: 1e-4,
            lambda2: 1e-6,
            tau_u: 0.6,
            tau_z: 3.0,
            n_min: 8,
            gp_window: 64,
            ema_alpha: 0.3,
            eta: 0.6,
            delta_mb: 2048.0,
            l_max: 8,
            tau_d: 0.30,
            gamma: 0.995,
            tune_trigger: 32,
            bo_budget: 16,
            bo_init: 5,
            bo_eval_s: 20.0,
            b_max: 8,
            milp_time_budget_ms: 600,
            milp_join_colocation: false,
            native_gp: std::env::var("TRIDENT_NATIVE_GP").map(|v| v == "1").unwrap_or(false),
            sim_seed_event_stream: false,
            sim_shards: 1,
            sim_workers: 0,
            solver: SolverBackend::Monolithic,
        }
    }
}

// ---------------------------------------------------------------------------
// JSON (de)serialization for the public spec types (cluster + controller);
// pipelines are built by the preset constructors or programmatically.
// ---------------------------------------------------------------------------

impl ClusterSpec {
    pub fn to_json(&self) -> Json {
        Json::Obj(
            [(
                "nodes".to_string(),
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::obj(vec![
                                ("name", Json::str(&n.name)),
                                ("cpu_cores", Json::num(n.cpu_cores)),
                                ("mem_gb", Json::num(n.mem_gb)),
                                ("accels", Json::num(n.accels as f64)),
                                ("accel_mem_mb", Json::num(n.accel_mem_mb)),
                                ("egress_mbps", Json::num(n.egress_mbps)),
                            ])
                        })
                        .collect(),
                ),
            )]
            .into_iter()
            .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let nodes = j
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or("cluster: missing nodes[]")?;
        Ok(ClusterSpec {
            nodes: nodes
                .iter()
                .enumerate()
                .map(|(k, n)| NodeSpec {
                    name: n.str_or("name", &format!("node{k}")).to_string(),
                    cpu_cores: n.f64_or("cpu_cores", 32.0),
                    mem_gb: n.f64_or("mem_gb", 128.0),
                    accels: n.f64_or("accels", 0.0) as u32,
                    accel_mem_mb: n.f64_or("accel_mem_mb", 65536.0),
                    egress_mbps: n.f64_or("egress_mbps", 12500.0),
                })
                .collect(),
        })
    }
}

impl TridentConfig {
    pub fn from_json(j: &Json) -> Self {
        let d = TridentConfig::default();
        TridentConfig {
            t_sched_s: j.f64_or("t_sched_s", d.t_sched_s),
            metrics_interval_s: j.f64_or("metrics_interval_s", d.metrics_interval_s),
            lambda1: j.f64_or("lambda1", d.lambda1),
            lambda2: j.f64_or("lambda2", d.lambda2),
            tau_u: j.f64_or("tau_u", d.tau_u),
            tau_z: j.f64_or("tau_z", d.tau_z),
            n_min: j.f64_or("n_min", d.n_min as f64) as usize,
            gp_window: j.f64_or("gp_window", d.gp_window as f64) as usize,
            ema_alpha: j.f64_or("ema_alpha", d.ema_alpha),
            eta: j.f64_or("eta", d.eta),
            delta_mb: j.f64_or("delta_mb", d.delta_mb),
            l_max: j.f64_or("l_max", d.l_max as f64) as usize,
            tau_d: j.f64_or("tau_d", d.tau_d),
            gamma: j.f64_or("gamma", d.gamma),
            tune_trigger: j.f64_or("tune_trigger", d.tune_trigger as f64) as usize,
            bo_budget: j.f64_or("bo_budget", d.bo_budget as f64) as usize,
            bo_init: j.f64_or("bo_init", d.bo_init as f64) as usize,
            bo_eval_s: j.f64_or("bo_eval_s", d.bo_eval_s),
            b_max: j.f64_or("b_max", d.b_max as f64) as usize,
            milp_time_budget_ms: j.f64_or("milp_time_budget_ms", d.milp_time_budget_ms as f64) as u64,
            milp_join_colocation: j
                .get("milp_join_colocation")
                .and_then(Json::as_bool)
                .unwrap_or(d.milp_join_colocation),
            native_gp: j.get("native_gp").and_then(Json::as_bool).unwrap_or(d.native_gp),
            sim_seed_event_stream: j
                .get("sim_seed_event_stream")
                .and_then(Json::as_bool)
                .unwrap_or(d.sim_seed_event_stream),
            sim_shards: j.f64_or("sim_shards", d.sim_shards as f64) as usize,
            sim_workers: j.f64_or("sim_workers", d.sim_workers as f64) as usize,
            solver: j
                .get("solver")
                .and_then(Json::as_str)
                .and_then(|s| SolverBackend::parse(s).ok())
                .unwrap_or(d.solver),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amplification_tracks_fanout() {
        let p = PipelineSpec::chain("t", vec![mk_op(10.0), mk_op(0.5), mk_op(1.0)]);
        assert_eq!(p.edges, vec![(0, 1), (1, 2)]);
        let (d, d_out) = p.amplification();
        assert_eq!(d, vec![1.0, 10.0, 5.0]);
        assert_eq!(d_out, 5.0);
    }

    fn mk_op(fanout: f64) -> OperatorSpec {
        OperatorSpec {
            name: "op".into(),
            kind: OperatorKind::CpuSync,
            cpu: 1.0,
            mem_gb: 1.0,
            accels: 0,
            fanout,
            out_mb: 0.1,
            start_s: 1.0,
            stop_s: 0.5,
            cold_s: 5.0,
            tunable: false,
            config_space: ConfigSpace::default(),
            service: ServiceModel::Cpu { base_rate: 10.0, ref_cost: 1.0, cost: CostW::default() },
            features: FeatureExtractor::Cost,
            child_scale: [1.0; 4],
            queue_cap: 512,
        }
    }

    /// Diamond: 0 -> {1, 2} -> 3 -> 4.  The fork replicates, the join
    /// consumes one merged record per aligned pair.
    fn diamond(fork_fanout: f64) -> PipelineSpec {
        PipelineSpec {
            name: "diamond".into(),
            operators: vec![mk_op(fork_fanout), mk_op(1.0), mk_op(1.0), mk_op(1.0), mk_op(1.0)],
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)],
        }
    }

    #[test]
    fn dag_helpers_classify_fork_and_join() {
        let p = diamond(1.0);
        assert!(p.validate().is_ok());
        assert_eq!(p.out_edges(0), vec![0, 1], "fork has two out-edges");
        assert_eq!(p.in_edges(3), vec![2, 3], "join has two in-edges");
        assert!(p.is_join(3));
        assert!(!p.is_join(1));
        assert_eq!(p.sinks(), vec![4]);
        assert_eq!(p.topo_order(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn dag_amplification_fork_replicates_join_aligns() {
        let p = diamond(3.0);
        let (d, d_o) = p.amplification();
        // Fork emits 3 children per input, replicated onto both branches.
        assert_eq!(d, vec![1.0, 3.0, 3.0, 3.0, 3.0]);
        assert_eq!(d_o, 3.0);
        let vols = p.edge_volumes();
        assert_eq!(vols, vec![3.0, 3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn validate_rejects_broken_topologies() {
        let mut cyc = diamond(1.0);
        cyc.edges.push((4, 0));
        assert!(cyc.validate().unwrap_err().contains("source"));
        let mut orphan = diamond(1.0);
        orphan.edges.retain(|&(_, v)| v != 4);
        assert!(orphan.validate().unwrap_err().contains("unreachable"));
        let mut oob = diamond(1.0);
        oob.edges.push((1, 9));
        assert!(oob.validate().unwrap_err().contains("out of range"));
        let mut dup = diamond(1.0);
        dup.edges.push((0, 1));
        assert!(dup.validate().unwrap_err().contains("duplicate"));
    }

    #[test]
    fn validate_rejects_misaligned_joins() {
        // A splitting operator on one branch re-mints lineage ids: the
        // join could never align its groups.
        let mut splitter = diamond(1.0);
        splitter.operators[1].fanout = 2.0;
        assert!(splitter.validate().unwrap_err().contains("record-to-record"));
        // Two independent splitting forks feeding one join: equal volumes,
        // but disjoint id sets — must anchor at one fork.
        let nested = PipelineSpec {
            name: "nested".into(),
            operators: vec![
                mk_op(1.0),
                mk_op(3.0),
                mk_op(3.0),
                mk_op(1.0),
                mk_op(1.0),
                mk_op(1.0),
            ],
            edges: vec![(0, 1), (0, 2), (1, 3), (1, 4), (2, 3), (2, 5)],
        };
        assert!(nested.validate().unwrap_err().contains("anchor"));
    }

    #[test]
    fn config_param_unit_roundtrip() {
        let p = ConfigParam { name: "b".into(), lo: 1.0, hi: 128.0, integer: true, log2: true, default: 16.0 };
        for u in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let v = p.from_unit(u);
            assert!((1.0..=128.0).contains(&v));
            assert_eq!(v, v.round());
            let u2 = p.to_unit(v);
            assert!((p.from_unit(u2) - v).abs() < 1.0 + 1e-9);
        }
        assert_eq!(p.from_unit(0.0), 1.0);
        assert_eq!(p.from_unit(1.0), 128.0);
    }

    #[test]
    fn llm_space_shape() {
        let s = ConfigSpace::llm_engine();
        assert_eq!(s.dims(), 6);
        let d = s.default_config();
        assert_eq!(d[0], 16.0);
        let clamped = s.clamp(&[1e6, -5.0, 11.2, 0.5, 0.4, 0.9]);
        assert_eq!(clamped[0], 128.0);
        assert_eq!(clamped[1], 512.0);
        assert_eq!(clamped[2], 11.0);
        assert_eq!(clamped[4], 0.0);
        assert_eq!(clamped[5], 1.0);
    }

    #[test]
    fn cluster_json_roundtrip() {
        let c = ClusterSpec::homogeneous(3, 256.0, 1024.0, 8, 65536.0, 12500.0);
        let j = c.to_json();
        let c2 = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(c2.nodes.len(), 3);
        assert_eq!(c2.nodes[1].accels, 8);
        assert_eq!(c2.total_cpus(), 768.0);
    }

    fn named_chain(name: &str, n: usize) -> PipelineSpec {
        PipelineSpec::chain(name, (0..n).map(|_| mk_op(1.0)).collect())
    }

    #[test]
    fn tenancy_single_merges_to_identity() {
        let t = Tenancy::single(named_chain("pdf", 3));
        assert!(t.validate().is_ok());
        let (spec, view) = t.merged().unwrap();
        assert_eq!(spec.name, "pdf");
        assert_eq!(spec.operators[0].name, "op", "single-tenant names untouched");
        assert_eq!(view.n_tenants(), 1);
        assert_eq!(view.sources, vec![0]);
        assert_eq!(view.op_tenant, vec![0, 0, 0]);
        assert_eq!(view.d_o, vec![1.0]);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn tenancy_merges_disjoint_dags_with_namespacing() {
        let t = Tenancy {
            tenants: vec![
                TenantSpec { id: "a".into(), pipeline: named_chain("a", 2), weight: 2.0, source_rate: 0.0 },
                TenantSpec { id: "b".into(), pipeline: diamond(3.0), weight: 1.0, source_rate: 5.0 },
            ],
        };
        let (spec, view) = t.merged().unwrap();
        assert_eq!(spec.name, "a+b");
        assert_eq!(spec.n_ops(), 7);
        assert_eq!(spec.operators[0].name, "a:op");
        assert_eq!(spec.operators[2].name, "b:op");
        assert_eq!(view.sources, vec![0, 2]);
        assert_eq!(view.op_tenant, vec![0, 0, 1, 1, 1, 1, 1]);
        assert_eq!(view.weights, vec![2.0, 1.0]);
        assert_eq!(view.source_rates, vec![0.0, 5.0]);
        assert_eq!(view.d_o, vec![1.0, 3.0]);
        assert_eq!(view.ops_of(1), vec![2, 3, 4, 5, 6]);
        // edges offset into the union
        assert_eq!(spec.edges[0], (0, 1));
        assert_eq!(spec.edges[1], (2, 3));
        // single-source validation rejects the union, multi-source accepts
        assert!(spec.validate().is_err());
        assert!(spec.validate_with_sources(&view.sources).is_ok());
    }

    #[test]
    fn tenancy_validation_rejects_bad_specs() {
        let dup = Tenancy {
            tenants: vec![
                TenantSpec { id: "x".into(), pipeline: named_chain("x", 2), weight: 1.0, source_rate: 0.0 },
                TenantSpec { id: "x".into(), pipeline: named_chain("y", 2), weight: 1.0, source_rate: 0.0 },
            ],
        };
        assert!(dup.validate().unwrap_err().contains("duplicate tenant id"));
        let bad_w = Tenancy {
            tenants: vec![TenantSpec {
                id: "x".into(),
                pipeline: named_chain("x", 2),
                weight: 0.0,
                source_rate: 0.0,
            }],
        };
        assert!(bad_w.validate().unwrap_err().contains("weight"));
        assert!(Tenancy { tenants: vec![] }.validate().is_err());
    }

    #[test]
    fn trident_config_json_overrides() {
        let j = Json::parse(r#"{"eta": 0.8, "bo_budget": 10}"#).unwrap();
        let c = TridentConfig::from_json(&j);
        assert_eq!(c.eta, 0.8);
        assert_eq!(c.bo_budget, 10);
        assert_eq!(c.lambda1, 1e-4); // default preserved
    }
}
