//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! vendored crate set).  Supports the full JSON grammar except `\u` escapes
//! beyond the BMP; numbers are f64 (adequate for config files and reports).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `obj.get(key)` with an f64 default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, false);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("unknown escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"pdf","ops":[{"cpu":2.5,"gpu":0},{"cpu":0,"gpu":1}],"ok":true}"#;
        let j = Json::parse(src).unwrap();
        for rendered in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(Json::parse(&rendered).unwrap(), j);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn typed_helpers() {
        let j = Json::parse(r#"{"x": 3, "s": "hi"}"#).unwrap();
        assert_eq!(j.f64_or("x", 0.0), 3.0);
        assert_eq!(j.f64_or("missing", 7.0), 7.0);
        assert_eq!(j.str_or("s", "no"), "hi");
    }
}
