//! Configuration: JSON parsing and the typed spec surface.
pub mod json;
pub mod spec;

pub use json::Json;
pub use spec::{
    ClusterSpec, ConfigParam, ConfigSpace, CostW, FeatureExtractor, NodeSpec, OperatorKind,
    OperatorSpec, PipelineSpec, ServiceModel, TenancyView, Tenancy, TenantSpec, TridentConfig,
};
