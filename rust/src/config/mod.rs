//! Configuration: JSON parsing and the typed spec surface.
pub mod json;
pub mod spec;

pub use json::Json;
pub use spec::{
    ClusterSpec, ConfigParam, ConfigSpace, CostW, EdgeId, FeatureExtractor, NodeSpec, OpId,
    OperatorKind, OperatorSpec, PipelineSpec, ServiceModel, SolverBackend, SpecInterner,
    TenancyView, Tenancy, TenantSpec, TridentConfig,
};
