//! Run outcome assembly: the [`RunReport`] consumed by the CLI, the
//! experiment harness, and every paper-reproduction bench.

use std::collections::HashMap;

use super::Coordinator;

/// Per-tenant section of a [`RunReport`] (a single entry for classic
/// one-pipeline runs).
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub id: String,
    /// Weight w_t in the scheduler's weighted max-min objective.
    pub weight: f64,
    /// Tenant throughput, in its own input records/s.
    pub throughput: f64,
    /// Records out of the tenant's sinks.
    pub items_processed: u64,
    /// Source items admitted for this tenant.
    pub items_admitted: u64,
    /// Distinct lineages this tenant lost to node failures
    /// (`RecoveryPolicy::Loss`; 0 under `Requeue` and absent dynamics).
    pub items_lost: u64,
}

/// Run outcome for reports and benches.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub pipeline: String,
    pub variant: String,
    pub duration_s: f64,
    /// Aggregate throughput, input records/s (sum of per-tenant
    /// throughputs; identical to the classic value for one tenant).
    pub throughput: f64,
    /// Per-tenant breakdown (one entry per tenant, in tenancy order).
    pub tenants: Vec<TenantReport>,
    /// (time, windowed throughput) series.
    pub series: Vec<(f64, f64)>,
    pub oom_events: u32,
    pub oom_downtime_s: f64,
    pub config_transitions: u64,
    /// Wall-clock of each MILP solve, ms.
    pub milp_ms: Vec<f64>,
    /// Mean per-invocation overhead of obs / adaptation layers, ms.
    pub obs_overhead_ms: f64,
    pub adapt_overhead_ms: f64,
    /// MAPE per estimator variant (Table 3), percent.
    pub estimator_mape: HashMap<&'static str, f64>,
    /// Clustering snapshots: per tunable op, (assignments, truth) samples.
    pub cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    pub items_processed: u64,
    /// Per-event recovery metrics (cluster dynamics): time-to-replan,
    /// time-to-90%-throughput, records lost.  Empty absent a dynamics
    /// timeline.
    pub events: Vec<crate::dynamics::EventReport>,
    /// Total records dropped by node failures across the run.
    pub lost_records: u64,
}

impl Coordinator {
    pub(super) fn report(&self, duration_s: f64) -> RunReport {
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        let view = &self.sim.tenancy;
        RunReport {
            pipeline: self.sim.spec.name.clone(),
            variant: self.variant.policy.name().to_string(),
            duration_s,
            throughput: self.sim.avg_throughput(),
            tenants: (0..view.n_tenants())
                .map(|t| TenantReport {
                    id: view.ids[t].clone(),
                    weight: view.weights[t],
                    throughput: self.sim.tenant_throughput(t),
                    items_processed: self.sim.out_records_t(t),
                    items_admitted: self.sim.items_emitted_t(t),
                    items_lost: self.sim.lost_items_t(t),
                })
                .collect(),
            series: self.series.clone(),
            oom_events: self.sim.oom_events_total(),
            oom_downtime_s: self.sim.oom_downtime_s_total(),
            config_transitions: self.transitions,
            milp_ms: self.milp_ms.clone(),
            obs_overhead_ms: mean(&self.obs_ms),
            adapt_overhead_ms: mean(&self.adapt_ms),
            estimator_mape: self
                .mape
                .iter()
                .map(|(&k, &(s, n))| (k, if n > 0 { s / n as f64 } else { 0.0 }))
                .collect(),
            cluster_eval: self.cluster_eval.clone(),
            items_processed: self.sim.out_records(),
            events: self.event_reports.clone(),
            lost_records: self.sim.lost_records_total(),
        }
    }
}
