//! Run outcome assembly: the [`RunReport`] consumed by the CLI, the
//! experiment harness, and every paper-reproduction bench.

use std::collections::HashMap;

use super::Coordinator;

/// Per-tenant section of a [`RunReport`] (a single entry for classic
/// one-pipeline runs).
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub id: String,
    /// Weight w_t in the scheduler's weighted max-min objective.
    pub weight: f64,
    /// Tenant throughput, in its own input records/s.
    pub throughput: f64,
    /// Records out of the tenant's sinks.
    pub items_processed: u64,
    /// Source items admitted for this tenant.
    pub items_admitted: u64,
    /// Distinct lineages this tenant lost to node failures
    /// (`RecoveryPolicy::Loss`; 0 under `Requeue` and absent dynamics).
    pub items_lost: u64,
}

/// Run outcome for reports and benches.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub pipeline: String,
    pub variant: String,
    pub duration_s: f64,
    /// Aggregate throughput, input records/s (sum of per-tenant
    /// throughputs; identical to the classic value for one tenant).
    pub throughput: f64,
    /// Per-tenant breakdown (one entry per tenant, in tenancy order).
    pub tenants: Vec<TenantReport>,
    /// (time, windowed throughput) series.
    pub series: Vec<(f64, f64)>,
    pub oom_events: u32,
    pub oom_downtime_s: f64,
    pub config_transitions: u64,
    /// Wall-clock of each MILP solve, ms.
    pub milp_ms: Vec<f64>,
    /// Scheduling rounds that committed a plan (placement / routes /
    /// transitions); a keep-everything round is consulted, not committed.
    pub plans_committed: u64,
    /// Simplex pivots across every solve (run-lifetime union of
    /// [`MilpStats`](crate::solver::MilpStats)).
    pub milp_pivots: u64,
    /// Branch-and-bound nodes expanded across every solve.
    pub milp_bnb_nodes: u64,
    /// Dantzig-Wolfe pricing rounds / columns generated across solves.
    pub milp_pricing_rounds: u64,
    pub milp_columns: u64,
    /// Warm-start hit rate over all LP solves (0 when nothing solved).
    pub milp_warm_hit_rate: f64,
    /// Solver wall per phase, ms: build / root-LP / B&B / pricing.
    pub milp_phase_ms: [f64; 4],
    /// Shard-pool telemetry (zeros on the sequential K=1 / W=1 path).
    pub pool_steals: u64,
    pub pool_epochs: u64,
    /// Wall-clock the drive loop spent blocked on pool epoch drains, ms.
    pub pool_wait_ms: f64,
    /// Lifetime tasks finished per pool worker.
    pub pool_tasks: Vec<u64>,
    /// Worker threads the sharded executor actually runs.
    pub workers_effective: usize,
    /// Mean per-invocation overhead of obs / adaptation layers, ms.
    pub obs_overhead_ms: f64,
    pub adapt_overhead_ms: f64,
    /// MAPE per estimator variant (Table 3), percent.
    pub estimator_mape: HashMap<&'static str, f64>,
    /// Clustering snapshots: per tunable op, (assignments, truth) samples.
    pub cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    pub items_processed: u64,
    /// Per-event recovery metrics (cluster dynamics): time-to-replan,
    /// time-to-90%-throughput, records lost.  Empty absent a dynamics
    /// timeline.
    pub events: Vec<crate::dynamics::EventReport>,
    /// Total records dropped by node failures across the run.
    pub lost_records: u64,
}

impl Coordinator {
    pub(super) fn report(&self, duration_s: f64) -> RunReport {
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        let view = &self.sim.tenancy;
        let pool = self.sim.pool_telemetry().unwrap_or_default();
        RunReport {
            pipeline: self.sim.spec.name.clone(),
            variant: self.variant.policy.name().to_string(),
            duration_s,
            throughput: self.sim.avg_throughput(),
            tenants: (0..view.n_tenants())
                .map(|t| TenantReport {
                    id: view.ids[t].clone(),
                    weight: view.weights[t],
                    throughput: self.sim.tenant_throughput(t),
                    items_processed: self.sim.out_records_t(t),
                    items_admitted: self.sim.items_emitted_t(t),
                    items_lost: self.sim.lost_items_t(t),
                })
                .collect(),
            series: self.series.clone(),
            oom_events: self.sim.oom_events_total(),
            oom_downtime_s: self.sim.oom_downtime_s_total(),
            config_transitions: self.transitions,
            milp_ms: self.milp_ms.clone(),
            plans_committed: self.plans_committed,
            milp_pivots: self.milp_stats.pivots as u64,
            milp_bnb_nodes: self.milp_stats.nodes as u64,
            milp_pricing_rounds: self.milp_stats.pricing_rounds as u64,
            milp_columns: self.milp_stats.columns as u64,
            milp_warm_hit_rate: self.milp_stats.warm_hit_rate(),
            milp_phase_ms: [
                self.milp_stats.build_ms,
                self.milp_stats.root_lp_ms,
                self.milp_stats.bnb_ms,
                self.milp_stats.pricing_ms,
            ],
            pool_steals: pool.steals,
            pool_epochs: pool.epochs,
            pool_wait_ms: pool.wait_ms,
            pool_tasks: pool.tasks,
            workers_effective: self.sim.workers_effective(),
            obs_overhead_ms: mean(&self.obs_ms),
            adapt_overhead_ms: mean(&self.adapt_ms),
            estimator_mape: self
                .mape
                .iter()
                .map(|(&k, &(s, n))| (k, if n > 0 { s / n as f64 } else { 0.0 }))
                .collect(),
            cluster_eval: self.cluster_eval.clone(),
            items_processed: self.sim.out_records(),
            events: self.event_reports.clone(),
            lost_records: self.sim.lost_records_total(),
        }
    }
}
