//! Observation-side of the closed loop: per-window metrics ingestion into
//! the observation and adaptation layers, the Table-3 estimator lattice
//! ([`EstimatorBank`]), BO probe evaluation, and the capacity estimates the
//! scheduler consumes ([`Coordinator::current_rates`]).
//!
//! DAG note: on fork/join pipelines a join operator's window metrics fold
//! its incomplete-group backlog into the queue signals (`queue_end`,
//! per-instance `queue_len`), so reactive policies and the queue-trend
//! features see branch-imbalance pressure; its observed attrs are the
//! *merged* records (branch token loads summed), which is also what
//! `probe_measure` evaluates candidate configs against.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::{Json, TridentConfig};
use crate::observation::{CapacityEstimator, ObsConfig, UsefulTimeEstimator};
use crate::sim::{ItemAttrs, OpMetrics, ShardedSim};

use super::{Coordinator, Policy};

/// Estimator lattice carried for Table 3 MAPE accounting.
pub(super) struct EstimatorBank {
    pub(super) true_rate: UsefulTimeEstimator,
    pub(super) ema_only: CapacityEstimator,
    pub(super) gp_raw: CapacityEstimator,
    pub(super) gp_signal: CapacityEstimator,
    pub(super) gp_full: CapacityEstimator,
}

impl EstimatorBank {
    pub(super) fn new(cfg: &TridentConfig, ex: crate::config::FeatureExtractor) -> Self {
        let base = ObsConfig::from_trident(cfg);
        EstimatorBank {
            true_rate: UsefulTimeEstimator::new(),
            ema_only: CapacityEstimator::new(
                ObsConfig { use_gp: false, model_filter: false, signal_filter: false, ..base.clone() },
                ex,
            ),
            gp_raw: CapacityEstimator::new(
                ObsConfig { signal_filter: false, model_filter: false, ..base.clone() },
                ex,
            ),
            gp_signal: CapacityEstimator::new(ObsConfig { model_filter: false, ..base.clone() }, ex),
            gp_full: CapacityEstimator::new(base, ex),
        }
    }
}

impl Coordinator {
    /// One metrics window tick: ingest metrics into every layer.
    pub(super) fn ingest_window(&mut self, metrics: &[OpMetrics]) {
        let t0 = Instant::now();
        for (i, m) in metrics.iter().enumerate() {
            self.useful_time[i].observe(m);
            if self.variant.use_observation {
                self.estimators[i].observe(m, &self.backend);
            }
            // Table 3 targets the asynchronous accelerator operators —
            // useful-time estimation is exact for synchronous CPU ops and
            // averaging them in would mask the effect the paper measures.
            let async_op = self.sim.spec.operators[i].kind
                == crate::config::OperatorKind::AccelAsync;
            if self.collect_mape && m.records_out > 0 && async_op {
                let bank = &mut self.banks[i];
                bank.true_rate.observe(m);
                bank.ema_only.observe(m, &self.backend);
                bank.gp_raw.observe(m, &self.backend);
                bank.gp_signal.observe(m, &self.backend);
                bank.gp_full.observe(m, &self.backend);
                // Score each estimator against the isolated-profiling
                // oracle at the op's current config + workload.
                let theta = &self.rolling[i].current;
                let truth = self.sim.true_unit_rate(i, theta);
                if truth > 1e-6 {
                    let score = |name: &'static str, est: f64, mape: &mut HashMap<_, (f64, u64)>| {
                        let e = ((est - truth) / truth).abs() * 100.0;
                        let ent = mape.entry(name).or_insert((0.0, 0));
                        ent.0 += e.min(300.0);
                        ent.1 += 1;
                    };
                    let (e1, _) = self.banks[i].ema_only.estimate(m, &self.backend);
                    let (e2, _) = self.banks[i].gp_raw.estimate(m, &self.backend);
                    let (e3, _) = self.banks[i].gp_signal.estimate(m, &self.backend);
                    let (e4, _) = self.banks[i].gp_full.estimate(m, &self.backend);
                    let tr = self.banks[i].true_rate.estimate();
                    score("true_rate", tr, &mut self.mape);
                    score("ema", e1, &mut self.mape);
                    score("gp_raw", e2, &mut self.mape);
                    score("gp_signal", e3, &mut self.mape);
                    score("gp_two_stage", e4, &mut self.mape);
                }
            }
        }
        self.obs_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        let t1 = Instant::now();
        for (i, ad) in self.adaptation.iter_mut().enumerate() {
            if let Some(ad) = ad {
                ad.ingest(&metrics[i]);
                // Probe evaluation (see module docs): synthesize one probe
                // measurement per window while a tuning job is active.
                if let Some(theta) = ad.probe_request(&self.backend) {
                    let (ut, mem, oom) = probe_measure(&self.sim, i, &theta);
                    ad.probe_result(ut, mem, oom);
                    if oom {
                        // The probe crash costs a real instance restart.
                        if let Some(&victim) = self.sim.instances_of(i).first() {
                            let cur = self.sim.instance(victim).theta.clone();
                            self.sim.restart_with_config(victim, cur);
                            let cold = self.sim.spec.operators[i].cold_s;
                            self.sim.note_oom(i, cold);
                            // Probe OOMs bypass the executor's OOM path, so
                            // the flight recorder logs them here — without
                            // this the trace's kill count would undercount
                            // the RunReport's.
                            if let Some(ts) = self.trace.as_mut() {
                                ts.sim_event(
                                    self.sim.now(),
                                    "oom",
                                    vec![
                                        ("op", Json::str(&self.sim.spec.operators[i].name)),
                                        ("op_idx", Json::num(i as f64)),
                                        ("inst", Json::num(victim as f64)),
                                        ("probe", Json::Bool(true)),
                                    ],
                                );
                            }
                        }
                    }
                }
                // Collect clustering evaluation samples.
                if self.cluster_eval.len() <= i {
                    self.cluster_eval.resize_with(i + 1, || (Vec::new(), Vec::new()));
                }
                for (f, truth) in &metrics[i].cluster_samples {
                    // Re-assign for evaluation only (cheap): nearest centroid.
                    let assigned = ad
                        .clustering
                        .clusters
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| {
                            let da: f64 = a.centroid.iter().zip(f).map(|(x, y)| (x - y) * (x - y)).sum();
                            let db: f64 = b.centroid.iter().zip(f).map(|(x, y)| (x - y) * (x - y)).sum();
                            da.partial_cmp(&db).unwrap()
                        })
                        .map(|(idx, _)| idx)
                        .unwrap_or(0);
                    self.cluster_eval[i].0.push(assigned);
                    self.cluster_eval[i].1.push(*truth);
                }
            }
        }
        self.adapt_ms.push(t1.elapsed().as_secs_f64() * 1e3);

        // Deployed-config OOM safety fallback (transition layer).
        self.oom_safety_fallback(metrics);
    }

    /// Current capacity estimates for the scheduler (per-op records/s per
    /// instance), from whichever observation path the variant uses.
    pub(super) fn current_rates(&self, metrics: &[OpMetrics]) -> Vec<f64> {
        let use_obs = match self.variant.policy {
            Policy::Trident => self.variant.use_observation,
            _ => self.variant.shared_observation,
        };
        (0..self.sim.spec.n_ops())
            .map(|i| {
                if use_obs {
                    let (e, _) = self.estimators[i].estimate(&metrics[i], &self.backend);
                    e
                } else {
                    self.useful_time[i].estimate().max(1e-6)
                }
            })
            .collect()
    }
}

/// Synthesized probe measurement: what a dedicated probe instance would
/// report after a sustained evaluation window at config θ (ground-truth
/// service model + measurement noise; OOM when the noisy peak crosses the
/// device limit).
fn probe_measure(sim: &ShardedSim, op: usize, theta: &[f64]) -> (f64, f64, bool) {
    let attrs = sim.mean_attrs(op).unwrap_or(ItemAttrs {
        tokens_in: 512.0,
        tokens_out: 64.0,
        pixels_m: 1.0,
        frames: 1.0,
    });
    let o = &sim.spec.operators[op];
    // Deterministic per-(op, theta) noise so repeated probes agree.
    let mut h = 0u64;
    for &v in theta {
        h = h.wrapping_mul(31).wrapping_add(v.to_bits());
    }
    let mut rng = crate::rngx::Rng::new(h ^ (op as u64) << 32 ^ sim.now().to_bits());
    let ut = crate::sim::service::true_unit_rate(&o.service, theta, &attrs)
        * rng.lognormal(0.0, 0.05);
    // Peak-of-window telemetry (NVML-style max), not the mean: a sustained
    // evaluation sees the upper tail of the allocator noise, which is what
    // the memory surrogate must learn to stay OOM-safe after deployment.
    let peak_factor = (2.0 * 0.03f64).exp();
    let mem = crate::sim::service::expected_mem(&o.service, theta, &attrs)
        * rng.lognormal(0.02, 0.03)
        * peak_factor;
    let cap = sim.cluster.nodes[0].accel_mem_mb;
    (ut, mem, mem > cap)
}
