//! Coordinator unit tests (moved out of `mod.rs` with the policy split;
//! behavior-parity regression tests live in `tests/policy_parity.rs`).

use super::{nominal_attrs, Coordinator, Policy, Variant};
use crate::config::{ClusterSpec, TridentConfig};
use crate::workload::pdf;

fn mini_cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0)
}

fn mk(variant: Variant, seed: u64) -> Coordinator {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 1500;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 10;
    cfg.bo_init = 4;
    let trace = Box::new(pdf::trace(100_000));
    let src = crate::sim::ItemAttrs {
        tokens_in: 36_000.0,
        tokens_out: 7_200.0,
        pixels_m: 12.0,
        frames: 12.0,
    };
    Coordinator::new(pdf::pipeline(), mini_cluster(), trace, cfg, variant, src, seed)
}

#[test]
fn static_deploys_and_flows() {
    let mut c = mk(Variant::baseline(Policy::Static), 1);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0, "static must process documents: {r:?}");
    assert!(r.items_processed > 0);
    // all accel ops placed
    for i in 0..c.sim.spec.n_ops() {
        if c.sim.spec.operators[i].accels > 0 {
            assert!(!c.sim.instances_of(i).is_empty(), "op {i} placed");
        }
    }
}

#[test]
fn trident_beats_nothing_crashes_and_schedules() {
    let mut c = mk(Variant::trident(), 2);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0);
    assert!(!r.milp_ms.is_empty(), "trident must re-solve the MILP");
}

#[test]
fn raydata_reacts() {
    let mut c = mk(Variant::baseline(Policy::RayData), 3);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0);
}

#[test]
fn ds2_runs() {
    let mut c = mk(Variant::baseline(Policy::Ds2), 4);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0);
}

#[test]
fn nominal_attrs_propagate_scaling() {
    let pl = pdf::pipeline();
    let src = crate::sim::ItemAttrs {
        tokens_in: 36_000.0,
        tokens_out: 7_200.0,
        pixels_m: 12.0,
        frames: 12.0,
    };
    let nom = nominal_attrs(&pl, src);
    let ocr = pl.operators.iter().position(|o| o.name == "text_ocr").unwrap();
    // per-block tokens at the OCR stage = 36000 / 120 = 300
    assert!((nom[ocr].tokens_in - 300.0).abs() < 1.0, "{}", nom[ocr].tokens_in);
}
