//! Coordinator unit tests (moved out of `mod.rs` with the policy split;
//! behavior-parity regression tests live in `tests/policy_parity.rs`).

use super::{nominal_attrs, Coordinator, Policy, Variant};
use crate::config::{ClusterSpec, TridentConfig};
use crate::workload::pdf;

fn mini_cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0)
}

fn mk(variant: Variant, seed: u64) -> Coordinator {
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 1500;
    cfg.tune_trigger = 32;
    cfg.bo_budget = 10;
    cfg.bo_init = 4;
    let trace = Box::new(pdf::trace(100_000));
    let src = crate::sim::ItemAttrs {
        tokens_in: 36_000.0,
        tokens_out: 7_200.0,
        pixels_m: 12.0,
        frames: 12.0,
    };
    Coordinator::new(pdf::pipeline(), mini_cluster(), trace, cfg, variant, src, seed)
}

#[test]
fn static_deploys_and_flows() {
    let mut c = mk(Variant::baseline(Policy::Static), 1);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0, "static must process documents: {r:?}");
    assert!(r.items_processed > 0);
    // all accel ops placed
    for i in 0..c.sim.spec.n_ops() {
        if c.sim.spec.operators[i].accels > 0 {
            assert!(!c.sim.instances_of(i).is_empty(), "op {i} placed");
        }
    }
}

#[test]
fn trident_beats_nothing_crashes_and_schedules() {
    let mut c = mk(Variant::trident(), 2);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0);
    assert!(!r.milp_ms.is_empty(), "trident must re-solve the MILP");
}

#[test]
fn raydata_reacts() {
    let mut c = mk(Variant::baseline(Policy::RayData), 3);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0);
}

#[test]
fn ds2_runs() {
    let mut c = mk(Variant::baseline(Policy::Ds2), 4);
    let r = c.run(400.0);
    assert!(r.throughput > 0.0);
}

/// Path ⑨ under multi-tenancy: a rolling transition on one tenant's
/// branch operator invalidates that operator's samples and its downstream
/// join's — and touches nothing in the other tenant.
#[test]
fn join_transition_invalidates_only_its_tenant() {
    use crate::config::{Tenancy, TenantSpec};
    use crate::workload::speech;
    let tenancy = Tenancy {
        tenants: vec![
            TenantSpec { id: "pdf".into(), pipeline: pdf::pipeline(), weight: 1.0, source_rate: 0.0 },
            TenantSpec {
                id: "speech".into(),
                pipeline: speech::pipeline(),
                weight: 1.0,
                source_rate: 0.0,
            },
        ],
    };
    let mut cfg = TridentConfig::default();
    cfg.native_gp = true;
    cfg.milp_time_budget_ms = 1500;
    let src = crate::sim::ItemAttrs {
        tokens_in: 36_000.0,
        tokens_out: 7_200.0,
        pixels_m: 12.0,
        frames: 12.0,
    };
    let mut c = Coordinator::new_tenancy(
        tenancy,
        mini_cluster(),
        vec![
            Box::new(pdf::trace(2000)) as Box<dyn crate::workload::Trace>,
            Box::new(speech::trace(2000)),
        ],
        cfg,
        Variant::baseline(Policy::Static),
        vec![src, speech::src_attrs()],
        3,
    )
    .expect("two-tenant tenancy is valid");
    c.run(200.0); // deploy + settle; Static never transitions on its own
    let n_pdf = pdf::pipeline().n_ops();
    let asr = n_pdf + 2; // speech ASR branch (feeds the join)
    let join = n_pdf + 4; // speech align_merge (in-degree 2)
    assert!(c.sim.spec.is_join(join), "merged indexing: op {join} is the join");
    assert!(
        !c.sim.instances_of(asr).is_empty(),
        "speech ASR branch must be deployed"
    );
    let before: Vec<u64> =
        (0..c.sim.spec.n_ops()).map(|i| c.estimators[i].stats.invalidations).collect();
    // Hand the branch op a candidate config and start one rolling step.
    let mut cand = c.sim.spec.operators[asr].config_space.default_config();
    cand[0] = (cand[0] * 2.0).min(128.0);
    assert!(c.rolling[asr].offer(cand, 10.0), "candidate accepted");
    c.start_transition(asr, 1);
    assert!(
        c.estimators[asr].stats.invalidations > before[asr],
        "transitioned op's samples invalidated"
    );
    assert!(
        c.estimators[join].stats.invalidations > before[join],
        "downstream join's samples invalidated (path ⑨)"
    );
    for i in 0..n_pdf {
        assert_eq!(
            c.estimators[i].stats.invalidations, before[i],
            "pdf tenant untouched by a speech transition (op {i})"
        );
    }
}

#[test]
fn nominal_attrs_propagate_scaling() {
    let pl = pdf::pipeline();
    let src = crate::sim::ItemAttrs {
        tokens_in: 36_000.0,
        tokens_out: 7_200.0,
        pixels_m: 12.0,
        frames: 12.0,
    };
    let nom = nominal_attrs(&pl, src);
    let ocr = pl.interner().op("text_ocr").idx();
    // per-block tokens at the OCR stage = 36000 / 120 = 300
    assert!((nom[ocr].tokens_in - 300.0).abs() < 1.0, "{}", nom[ocr].tokens_in);
}
