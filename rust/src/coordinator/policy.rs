//! The scheduling-policy abstraction: every scheduler in the evaluation —
//! Trident's MILP and all baselines — implements [`SchedulingPolicy`] over
//! the same read-only round context ([`PolicyCtx`]) and returns a [`Plan`]
//! that the coordinator applies through one shared path.  Comparisons
//! therefore differ only in policy, never in plumbing (the RQ1/RQ2
//! protocol), and a new scheduler is one `impl` block away.
//!
//! Static, SCOOT, and Trident live here; the Ray Data, DS2, and ContTune
//! implementations live in [`crate::baselines`] next to their models.

use std::time::{Duration, Instant};

use crate::adaptation::Strategy;
use crate::baselines::Placement;
use crate::config::{ClusterSpec, PipelineSpec, TenancyView, TridentConfig};
use crate::scheduling::{self, MilpInput, MilpTenant, OpSched, RollingState};
use crate::sim::OpMetrics;

/// Full experiment variant: policy + layer toggles (RQ2 sharing, RQ5
/// ablations, Table 5/6 strategies).
#[derive(Debug, Clone)]
pub struct Variant {
    pub policy: Policy,
    /// RQ2: give baselines Trident's observation-layer estimates.
    pub shared_observation: bool,
    /// RQ2: give baselines Trident's adaptation recommendations
    /// (applied all-at-once).
    pub shared_adaptation: bool,
    /// RQ5 w/o Observation: Trident falls back to useful-time rates.
    pub use_observation: bool,
    /// RQ5 w/o Adaptation: disable clustering + tuning.
    pub use_adaptation: bool,
    /// RQ5 w/o Placement: network-agnostic MILP.
    pub placement_aware: bool,
    /// RQ5 w/o Rolling: all-at-once config switches.
    pub rolling: bool,
    /// Tuning strategy (Table 5/6).
    pub strategy: Strategy,
    /// Initial per-op configs (SCOOT's offline-tuned configs).
    pub initial_configs: Option<Vec<Option<Vec<f64>>>>,
}

impl Variant {
    pub fn trident() -> Self {
        Variant {
            policy: Policy::Trident,
            shared_observation: false,
            shared_adaptation: false,
            use_observation: true,
            use_adaptation: true,
            placement_aware: true,
            rolling: true,
            strategy: Strategy::ConstrainedBo,
            initial_configs: None,
        }
    }

    pub fn baseline(policy: Policy) -> Self {
        Variant { policy, use_adaptation: false, ..Variant::trident() }
    }

    /// RQ2: baseline with Trident's observation + adaptation layers.
    pub fn controlled(policy: Policy) -> Self {
        Variant {
            policy,
            shared_observation: true,
            shared_adaptation: true,
            use_adaptation: true,
            rolling: false,
            ..Variant::trident()
        }
    }
}

/// Which scheduling policy drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Fixed manually-tuned allocation (one-shot nominal MILP).
    Static,
    /// Ray Data's reactive threshold autoscaler.
    RayData,
    /// DS2: useful-time rates + waterfall parallelism.
    Ds2,
    /// ContTune: DS2 + conservative parallelism BO.
    ContTune,
    /// SCOOT: offline per-op config tuning + Static allocation.
    Scoot,
    /// The full Trident MILP.
    Trident,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Static => "Static",
            Policy::RayData => "Ray Data",
            Policy::Ds2 => "DS2",
            Policy::ContTune => "ContTune",
            Policy::Scoot => "SCOOT",
            Policy::Trident => "Trident",
        }
    }

    /// Instantiate the policy implementation that drives a run.
    pub fn build(self) -> Box<dyn SchedulingPolicy> {
        match self {
            // SCOOT = offline-tuned initial configs + Static allocation;
            // at runtime both never re-plan.
            Policy::Static | Policy::Scoot => Box::new(StaticPolicy),
            Policy::RayData => Box::new(crate::baselines::RayDataAutoscaler::default()),
            Policy::Ds2 => Box::new(crate::baselines::Ds2::default()),
            Policy::ContTune => Box::new(crate::baselines::ContTune::default()),
            Policy::Trident => Box::new(TridentPolicy::default()),
        }
    }
}

/// Read-only view of the coordinator state a policy may consult when
/// planning one scheduling round (the inputs of Algorithm 2).
pub struct PolicyCtx<'a> {
    pub spec: &'a PipelineSpec,
    pub cluster: &'a ClusterSpec,
    pub cfg: &'a TridentConfig,
    pub variant: &'a Variant,
    /// Metrics of the last completed window, one entry per operator.
    pub metrics: &'a [OpMetrics],
    /// Per-instance capacity estimates (records/s) from whichever
    /// observation path the variant uses.
    pub rates: &'a [f64],
    /// Live instance count per operator.
    pub cur_p: &'a [u32],
    /// Live placement `x[op][node]`.
    pub placement: &'a [Vec<u32>],
    /// Rolling-update state per operator (candidate config, n_old/n_new).
    pub rolling: &'a [RollingState],
    /// Tenant structure of the (merged) spec: op → tenant map, per-tenant
    /// weights and output amplification.  Trivial for one tenant.
    pub tenancy: &'a TenancyView,
    /// Node availability (cluster dynamics): policies must not place on
    /// a down node.  All-true absent a dynamics timeline.
    pub node_up: &'a [bool],
    /// Tenant activity (dynamic tenancy): dormant/departed tenants' ops
    /// get no instances.  All-true absent a dynamics timeline.
    pub tenant_active: &'a [bool],
    /// Pipeline throughput observed over the previous round.
    pub last_throughput: f64,
    /// Simulation clock, seconds.
    pub now: f64,
}

impl PolicyCtx<'_> {
    /// True when the full cluster and tenancy are live (the classic,
    /// dynamics-free case — every pre-dynamics code path).
    pub fn all_active(&self) -> bool {
        self.node_up.iter().all(|&u| u) && self.tenant_active.iter().all(|&a| a)
    }

    /// Whether op `i` belongs to an active tenant.
    pub fn op_active(&self, i: usize) -> bool {
        self.tenant_active[self.tenancy.op_tenant[i]]
    }
}

/// How configuration transitions are applied this round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransitionCmd {
    /// Leave rolling state untouched (Static / SCOOT).
    None,
    /// Restart every instance of an op mid-transition at once (baselines
    /// under the RQ2 shared-adaptation protocol; w/o-rolling ablation).
    AllAtOnce,
    /// Trident: restart `b[i]` old-config instances of operator `i`
    /// (rolling update, paper §6.5).
    Rolling(Vec<u32>),
}

/// A policy's decision for one scheduling round.  Everything is optional:
/// `Plan::keep()` leaves the deployment untouched.
pub struct Plan {
    /// Target placement (`None` = keep the current deployment).
    pub placement: Option<Placement>,
    /// Placement-aware routing fractions keyed by pipeline edge id
    /// (`PipelineSpec::edges` order; Trident MILP only).
    pub routes: Option<Vec<Vec<Vec<f64>>>>,
    pub transitions: TransitionCmd,
    /// Wall-clock of the MILP solve backing this plan, ms (RQ6).
    pub milp_ms: Option<f64>,
    /// Full solver counters for the solve backing this plan (flight
    /// recorder's wall lane + the RunReport solver breakdown).
    pub stats: Option<crate::solver::MilpStats>,
}

impl Plan {
    /// Keep the current deployment as-is.
    pub fn keep() -> Plan {
        Plan {
            placement: None,
            routes: None,
            transitions: TransitionCmd::None,
            milp_ms: None,
            stats: None,
        }
    }
}

/// One scheduler in the evaluation: consumes the shared observation /
/// adaptation state through [`PolicyCtx`] and emits a [`Plan`] the
/// coordinator applies identically for every policy.
pub trait SchedulingPolicy: Send {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> Plan;
}

/// Static and SCOOT: deploy once, never re-plan.
pub struct StaticPolicy;

impl SchedulingPolicy for StaticPolicy {
    fn plan(&mut self, _ctx: &PolicyCtx<'_>) -> Plan {
        Plan::keep()
    }
}

/// The full Trident MILP (paper §6, Algorithm 2): joint parallelism /
/// placement / transition planning on the observation-layer estimates.
///
/// Holds the cross-round [`scheduling::BasisCache`]: round r+1's MILP has
/// the same shape as round r's (same operators, nodes, edges — only the
/// estimated coefficients drift), so the incumbent root basis warm-starts
/// the next solve and online re-optimization stays cheap.  A shape change
/// (tenant set, topology, or cluster size) drops the entry automatically.
///
/// Under [`SolverBackend::Decomposed`] each tenant additionally owns a
/// per-name cache in `tenant_caches` that warm-starts its pricing
/// subproblem across rounds; keying by tenant *name* (not index) keeps
/// the warm starts valid across dynamic tenancy arrivals/departures.
#[derive(Default)]
pub struct TridentPolicy {
    cache: scheduling::BasisCache,
    tenant_caches: std::collections::HashMap<String, scheduling::BasisCache>,
}

impl SchedulingPolicy for TridentPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> Plan {
        let (input, scope) = milp_input(ctx);
        if input.ops.is_empty() || input.nodes.is_empty() {
            // Every tenant departed or every node down: nothing to plan.
            return Plan::keep();
        }
        let t0 = Instant::now();
        let budget = Duration::from_millis(ctx.cfg.milp_time_budget_ms);
        let plan = match ctx.cfg.solver {
            crate::config::SolverBackend::Monolithic => {
                scheduling::solve_cached(&input, budget, &mut self.cache)
            }
            crate::config::SolverBackend::Decomposed => scheduling::solve_decomposed(
                &input,
                budget,
                &mut self.cache,
                &mut self.tenant_caches,
                &Default::default(),
                &scheduling::DecompOptions::default(),
            ),
        };
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let stats = plan.stats.clone();
        if plan.t_pred <= 0.0 {
            // Keep the previous feasible plan (paper §7).
            return Plan { milp_ms: Some(ms), stats: Some(stats), ..Plan::keep() };
        }
        if std::env::var("TRIDENT_DEBUG").is_ok() {
            eprintln!(
                "[{:.0}s] plan: T={:.2} p={:?} b={:?}",
                ctx.now, plan.t_pred, plan.p, plan.b
            );
            for (row, o) in input.ops.iter().enumerate() {
                let i = scope.ops[row];
                if o.ut_cand.is_some() || ctx.spec.operators[i].tunable {
                    eprintln!(
                        "    op{i} {}: ut_cur={:.2} ut_cand={:?} n_old={} n_new={} util={:.2}",
                        o.name, o.ut_cur, o.ut_cand, o.n_old, o.n_new,
                        ctx.metrics.get(i).map(|m| m.utilization).unwrap_or(0.0)
                    );
                }
            }
        }
        if scope.is_identity() {
            // The classic full-cluster round: pass the plan through
            // untouched (bit-identical to the pre-dynamics path).
            return Plan {
                placement: Some(plan.x),
                routes: ctx.variant.placement_aware.then_some(plan.route),
                transitions: TransitionCmd::Rolling(plan.b),
                milp_ms: Some(ms),
                stats: Some(stats),
            };
        }
        Plan {
            placement: Some(scope.expand_x(&plan.x)),
            routes: ctx
                .variant
                .placement_aware
                .then(|| scope.expand_routes(&plan.route)),
            transitions: TransitionCmd::Rolling(scope.expand_b(&plan.b)),
            milp_ms: Some(ms),
            stats: Some(stats),
        }
    }
}

/// Which rows/columns of the full merged spec a round's MILP covers: the
/// surviving node set and the active tenants' operators/edges.  Identity
/// absent cluster dynamics.  The solved sub-plan is expanded back to the
/// full shape the coordinator applies (excluded ops and down nodes get
/// zero instances, so a departed tenant's instances drain and nothing is
/// placed on a dead node).
#[derive(Debug, Clone)]
pub struct PlanScope {
    /// Full-spec op index per MILP op row.
    pub ops: Vec<usize>,
    /// Full-cluster node index per MILP node column.
    pub nodes: Vec<usize>,
    /// Full-spec edge id per MILP edge.
    pub edges: Vec<usize>,
    pub n_ops: usize,
    pub n_nodes: usize,
    pub n_edges: usize,
}

impl PlanScope {
    pub fn is_identity(&self) -> bool {
        self.ops.len() == self.n_ops && self.nodes.len() == self.n_nodes
    }

    /// Expand a scoped placement to the full (op × node) shape.
    pub fn expand_x(&self, x: &[Vec<u32>]) -> Vec<Vec<u32>> {
        let mut full = vec![vec![0u32; self.n_nodes]; self.n_ops];
        for (p, &i) in self.ops.iter().enumerate() {
            for (q, &kk) in self.nodes.iter().enumerate() {
                full[i][kk] = x[p][q];
            }
        }
        full
    }

    /// Expand scoped rolling batches to the full op list (excluded ops
    /// transition nothing).
    pub fn expand_b(&self, b: &[u32]) -> Vec<u32> {
        let mut full = vec![0u32; self.n_ops];
        for (p, &i) in self.ops.iter().enumerate() {
            full[i] = b[p];
        }
        full
    }

    /// Expand per-edge routing matrices to the full edge list and node
    /// count.  Unscoped edges and down-node rows route locally (the
    /// executor's least-occupied fallback then applies).
    pub fn expand_routes(&self, route: &[Vec<Vec<f64>>]) -> Vec<Vec<Vec<f64>>> {
        let mut by_edge: Vec<Option<&Vec<Vec<f64>>>> = vec![None; self.n_edges];
        for (p, &e) in self.edges.iter().enumerate() {
            if let Some(sub) = route.get(p) {
                by_edge[e] = Some(sub);
            }
        }
        (0..self.n_edges)
            .map(|e| {
                let mut m: Vec<Vec<f64>> = (0..self.n_nodes)
                    .map(|kk| {
                        let mut row = vec![0.0; self.n_nodes];
                        row[kk] = 1.0;
                        row
                    })
                    .collect();
                if let Some(sub) = by_edge[e] {
                    for (p, &from) in self.nodes.iter().enumerate() {
                        let mut row = vec![0.0; self.n_nodes];
                        for (q, &to) in self.nodes.iter().enumerate() {
                            row[to] = sub[p][q];
                        }
                        m[from] = row;
                    }
                }
                m
            })
            .collect()
    }
}

/// Build the round's MILP input from the shared context, restricted to
/// the surviving node/tenant set (the full problem absent dynamics).
/// Candidate rates enter only for operators mid-transition
/// (single-transition invariant); the current placement seeds the
/// movement-cost terms.  Returns the input plus the [`PlanScope`] that
/// maps the sub-plan back to full shape.
pub fn milp_input(ctx: &PolicyCtx<'_>) -> (MilpInput, PlanScope) {
    let (d_i, d_o_full) = ctx.spec.amplification();
    let n = ctx.spec.n_ops();
    let k = ctx.cluster.nodes.len();
    let ops_sel: Vec<usize> = (0..n).filter(|&i| ctx.op_active(i)).collect();
    let nodes_sel: Vec<usize> = (0..k).filter(|&kk| ctx.node_up[kk]).collect();
    let mut op_pos = vec![usize::MAX; n];
    for (p, &i) in ops_sel.iter().enumerate() {
        op_pos[i] = p;
    }
    let edges_sel: Vec<usize> = (0..ctx.spec.n_edges())
        .filter(|&e| {
            let (u, v) = ctx.spec.edges[e];
            op_pos[u] != usize::MAX && op_pos[v] != usize::MAX
        })
        .collect();
    let active_tenants: Vec<usize> =
        (0..ctx.tenancy.n_tenants()).filter(|&t| ctx.tenant_active[t]).collect();
    let multi = active_tenants.len() > 1;
    let tenants: Vec<MilpTenant> = if multi {
        active_tenants
            .iter()
            .map(|&t| MilpTenant {
                name: ctx.tenancy.ids[t].clone(),
                weight: ctx.tenancy.weights[t],
                d_o: ctx.tenancy.d_o[t],
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut tpos = vec![0usize; ctx.tenancy.n_tenants()];
    for (p, &t) in active_tenants.iter().enumerate() {
        tpos[t] = p;
    }
    let op_tenant: Vec<usize> = if multi {
        ops_sel.iter().map(|&i| tpos[ctx.tenancy.op_tenant[i]]).collect()
    } else {
        Vec::new()
    };
    // The classic scalar D_o: the sole active tenant's own amplification
    // when exactly one tenant remains, the merged value otherwise (it is
    // only consulted in the single-tenant formulation).
    let d_o = if active_tenants.len() == 1 {
        ctx.tenancy.d_o[active_tenants[0]]
    } else {
        d_o_full
    };
    let input = MilpInput {
        ops: ops_sel
            .iter()
            .map(|&i| {
                let o = &ctx.spec.operators[i];
                OpSched {
                    name: o.name.clone(),
                    ut_cur: ctx.rates[i].max(1e-6),
                    ut_cand: ctx.rolling[i].in_transition().then(|| ctx.rolling[i].ut_cand),
                    n_new: ctx.rolling[i].n_new,
                    n_old: ctx.rolling[i].n_old,
                    cpu: o.cpu,
                    mem_gb: o.mem_gb,
                    accels: o.accels,
                    out_mb: o.out_mb,
                    d_i: d_i[i],
                    h_start: o.start_s,
                    h_stop: o.stop_s,
                    h_cold: o.cold_s,
                    cur_x: nodes_sel.iter().map(|&kk| ctx.placement[i][kk]).collect(),
                }
            })
            .collect(),
        edges: edges_sel
            .iter()
            .map(|&e| {
                let (u, v) = ctx.spec.edges[e];
                (op_pos[u], op_pos[v])
            })
            .collect(),
        nodes: nodes_sel.iter().map(|&kk| ctx.cluster.nodes[kk].clone()).collect(),
        d_o,
        tenants,
        op_tenant,
        t_sched: ctx.cfg.t_sched_s,
        lambda1: ctx.cfg.lambda1,
        lambda2: ctx.cfg.lambda2,
        b_max: ctx.cfg.b_max as u32,
        placement_aware: ctx.variant.placement_aware,
        join_colocate: ctx.cfg.milp_join_colocation,
        all_at_once: !ctx.variant.rolling,
    };
    let scope = PlanScope {
        ops: ops_sel,
        nodes: nodes_sel,
        edges: edges_sel,
        n_ops: n,
        n_nodes: k,
        n_edges: ctx.spec.n_edges(),
    };
    (input, scope)
}
