//! The Trident coordinator: the closed control loop of Figure 1.
//!
//! Wires the pipeline executor (simulator), metrics collector, observation
//! layer, adaptation layer, and scheduling layer together — including
//! paths ⑧ (plan application) and ⑨ (sample invalidation on configuration
//! transitions).  The loop itself is policy-agnostic: every scheduler in
//! the evaluation (Trident's MILP and all baselines) implements the
//! [`SchedulingPolicy`] trait and is applied through the same
//! plan-application path, so comparisons differ only in policy.
//!
//! Module family (see `DESIGN.md`):
//! * [`policy`] — the [`SchedulingPolicy`] trait, [`PolicyCtx`] /
//!   [`Plan`], and the Static / SCOOT / Trident implementations
//!   (Ray Data, DS2, ContTune live in [`crate::baselines`]);
//! * [`ingest`] — per-window metrics ingestion, the Table-3
//!   `EstimatorBank` MAPE lattice, and BO probe evaluation;
//! * [`transition`] — initial deployment, placement application, rolling
//!   updates + sample invalidation (path ⑨), and the OOM safety fallback;
//! * [`report`] — [`RunReport`] assembly.
//!
//! One deliberate simulation shortcut (DESIGN.md): BO probe evaluations are
//! measured against the operator's ground-truth service model plus
//! measurement noise instead of occupying a live instance for the full
//! evaluation window.  The measured quantity is identical to what a probe
//! instance would report; a probe OOM still costs real downtime (one live
//! instance is cold-restarted) so Table 6's downtime is honest.

mod ingest;
pub mod policy;
pub mod report;
mod transition;

#[cfg(test)]
mod tests;

pub use policy::{Plan, Policy, PolicyCtx, SchedulingPolicy, TransitionCmd, Variant};
pub use report::{RunReport, TenantReport};

use std::collections::HashMap;

use crate::adaptation::OperatorAdaptation;
use crate::config::{ClusterSpec, PipelineSpec, Tenancy, TridentConfig};
use crate::observation::{CapacityEstimator, ObsConfig, UsefulTimeEstimator};
use crate::runtime::GpBackend;
use crate::scheduling::RollingState;
use crate::sim::{ItemAttrs, OpMetrics, PipelineSim};
use crate::workload::Trace;

use ingest::EstimatorBank;

/// The coordinator.
pub struct Coordinator {
    pub sim: PipelineSim,
    pub cfg: TridentConfig,
    pub variant: Variant,
    backend: GpBackend,
    /// Main estimator per op (the one the scheduler consumes).
    estimators: Vec<CapacityEstimator>,
    useful_time: Vec<UsefulTimeEstimator>,
    /// Table-3 lattice (only when `collect_mape`).
    banks: Vec<EstimatorBank>,
    pub collect_mape: bool,
    mape: HashMap<&'static str, (f64, u64)>,
    adaptation: Vec<Option<OperatorAdaptation>>,
    rolling: Vec<RollingState>,
    /// The active scheduler (trait object — replaces the old inline
    /// per-policy match arms and per-baseline fields).
    policy: Box<dyn SchedulingPolicy>,
    /// Whether the op has had its samples invalidated for the current
    /// transition already.
    invalidated: Vec<bool>,
    /// Deployed-config OOM safety fallback bookkeeping.
    recent_ooms: Vec<u32>,
    milp_ms: Vec<f64>,
    obs_ms: Vec<f64>,
    adapt_ms: Vec<f64>,
    transitions: u64,
    series: Vec<(f64, f64)>,
    cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    nominal: Vec<ItemAttrs>,
    last_metrics: Option<Vec<OpMetrics>>,
    last_throughput: f64,
    /// Per-op wall of the last committed transition (anti-thrash cooldown).
    last_transition_t: Vec<f64>,
}

/// Propagate a source item's mean attrs through the pipeline's child
/// scalings to get nominal per-op attrs (used for the Static plan).
///
/// Runs over the DAG in topological order: an operator inherits its
/// predecessor's scaled attrs; a join sees the merge of its branches
/// (token loads accumulate, spatial extents take the max — mirroring the
/// executor's `merge_group`).  For a chain this is the old sequential
/// propagation.
pub fn nominal_attrs(pipeline: &PipelineSpec, source: ItemAttrs) -> Vec<ItemAttrs> {
    nominal_attrs_rooted(pipeline, &[(0, source)])
}

/// Multi-root variant of [`nominal_attrs`] for merged tenancies: each
/// tenant's source operator gets its own nominal source attrs, and the
/// propagation stays within each tenant's (disjoint) DAG.
pub fn nominal_attrs_rooted(
    pipeline: &PipelineSpec,
    roots: &[(usize, ItemAttrs)],
) -> Vec<ItemAttrs> {
    let scale = |a: ItemAttrs, s: [f64; 4]| ItemAttrs {
        tokens_in: a.tokens_in * s[0],
        tokens_out: a.tokens_out * s[1],
        pixels_m: a.pixels_m * s[2],
        frames: a.frames * s[3],
    };
    let fallback = roots
        .first()
        .map(|&(_, a)| a)
        .unwrap_or(ItemAttrs { tokens_in: 512.0, tokens_out: 64.0, pixels_m: 1.0, frames: 1.0 });
    let mut out = vec![fallback; pipeline.n_ops()];
    for &v in &pipeline.topo_order() {
        let preds = pipeline.in_edges(v);
        match preds.len() {
            0 => {
                out[v] = roots
                    .iter()
                    .find(|&&(r, _)| r == v)
                    .map(|&(_, a)| a)
                    .unwrap_or(fallback)
            }
            1 => {
                let u = pipeline.edges[preds[0]].0;
                out[v] = scale(out[u], pipeline.operators[u].child_scale);
            }
            _ => {
                let mut merged: Option<ItemAttrs> = None;
                for &e in &preds {
                    let u = pipeline.edges[e].0;
                    let a = scale(out[u], pipeline.operators[u].child_scale);
                    merged = Some(match merged {
                        None => a,
                        Some(m) => m.merge(&a),
                    });
                }
                out[v] = merged.unwrap();
            }
        }
    }
    out
}

impl Coordinator {
    pub fn new(
        pipeline: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: ItemAttrs,
        seed: u64,
    ) -> Self {
        Self::new_tenancy(
            Tenancy::single(pipeline),
            cluster,
            vec![trace],
            cfg,
            variant,
            vec![source_attrs],
            seed,
        )
        .unwrap_or_else(|e| panic!("invalid pipeline spec: {e}"))
    }

    /// Multi-tenant constructor: N pipelines (`tenancy`) sharing `cluster`,
    /// one trace + nominal source attrs per tenant.  A single-tenant
    /// tenancy reproduces [`Coordinator::new`] event-for-event.
    pub fn new_tenancy(
        tenancy: Tenancy,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: Vec<ItemAttrs>,
        seed: u64,
    ) -> Result<Self, String> {
        let (pipeline, view) = tenancy.merged()?;
        if traces.len() != view.n_tenants() {
            return Err(format!(
                "{} traces for {} tenants",
                traces.len(),
                view.n_tenants()
            ));
        }
        if source_attrs.len() != view.n_tenants() {
            return Err(format!(
                "{} source-attr entries for {} tenants",
                source_attrs.len(),
                view.n_tenants()
            ));
        }
        let backend = if cfg.native_gp { GpBackend::Native } else { GpBackend::from_env() };
        let n = pipeline.n_ops();
        let roots: Vec<(usize, ItemAttrs)> =
            view.sources.iter().copied().zip(source_attrs).collect();
        let nominal = nominal_attrs_rooted(&pipeline, &roots);
        let estimators = pipeline
            .operators
            .iter()
            .map(|o| CapacityEstimator::new(ObsConfig::from_trident(&cfg), o.features))
            .collect();
        let useful_time = (0..n).map(|_| UsefulTimeEstimator::new()).collect();
        let banks = pipeline
            .operators
            .iter()
            .map(|o| EstimatorBank::new(&cfg, o.features))
            .collect();
        let adaptation = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                if o.tunable && variant.use_adaptation {
                    let mut ad = OperatorAdaptation::new(
                        i,
                        o.config_space.clone(),
                        &cfg,
                        cluster.nodes[0].accel_mem_mb,
                        seed ^ (i as u64) << 8,
                    );
                    ad.set_strategy(variant.strategy);
                    Some(ad)
                } else {
                    None
                }
            })
            .collect();
        let rolling = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let init = variant
                    .initial_configs
                    .as_ref()
                    .and_then(|v| v.get(i).cloned().flatten())
                    .unwrap_or_else(|| o.config_space.default_config());
                RollingState::new(init, 0)
            })
            .collect();
        let policy = variant.policy.build();
        let sim = PipelineSim::new_tenancy(pipeline, view, cluster, traces, seed);
        Ok(Coordinator {
            sim,
            cfg,
            variant,
            backend,
            estimators,
            useful_time,
            banks,
            collect_mape: false,
            mape: HashMap::new(),
            adaptation,
            rolling,
            policy,
            invalidated: vec![false; n],
            recent_ooms: vec![0; n],
            milp_ms: Vec::new(),
            obs_ms: Vec::new(),
            adapt_ms: Vec::new(),
            transitions: 0,
            series: Vec::new(),
            cluster_eval: Vec::new(),
            nominal,
            last_metrics: None,
            last_throughput: 0.0,
            last_transition_t: vec![f64::NEG_INFINITY; n],
        })
    }

    /// One scheduling round (Algorithm 2): estimate rates, forward
    /// adaptation recommendations into rolling state, ask the policy for a
    /// plan, and apply it through the shared path ⑧.
    fn schedule_round(&mut self, metrics: &[OpMetrics]) {
        let rates = self.current_rates(metrics);
        let adapt_on = self.forward_recommendations();
        let placement = self.sim.placement();
        // Note: includes draining instances (unlike `placement()`), matching
        // what the reactive baselines have always seen as "current p".
        let cur_p: Vec<u32> = (0..self.sim.spec.n_ops())
            .map(|i| self.sim.instances_of(i).len() as u32)
            .collect();
        let plan = {
            let ctx = PolicyCtx {
                spec: &self.sim.spec,
                cluster: &self.sim.cluster,
                cfg: &self.cfg,
                variant: &self.variant,
                metrics,
                rates: &rates,
                cur_p: &cur_p,
                placement: &placement,
                rolling: &self.rolling,
                tenancy: &self.sim.tenancy,
                last_throughput: self.last_throughput,
                now: self.sim.now(),
            };
            self.policy.plan(&ctx)
        };
        if let Some(ms) = plan.milp_ms {
            self.milp_ms.push(ms);
        }
        if let Some(x) = &plan.placement {
            self.apply_placement(x);
        }
        if let Some(routes) = plan.routes {
            // Routing fractions are keyed by pipeline edge id.
            for (edge, m) in routes.into_iter().enumerate() {
                self.sim.set_route(edge, Some(m));
            }
        }
        match plan.transitions {
            TransitionCmd::None => {}
            TransitionCmd::AllAtOnce => self.apply_all_at_once_transitions(adapt_on),
            TransitionCmd::Rolling(b) => {
                for i in 0..self.sim.spec.n_ops() {
                    let bi = b[i];
                    if bi > 0 {
                        self.start_transition(i, bi);
                    }
                    let p_now = self.sim.instances_of(i).len() as u32;
                    if bi > 0 {
                        self.rolling[i].apply_round(bi, p_now);
                    } else {
                        self.rolling[i].sync_count(p_now);
                    }
                }
            }
        }
        self.last_throughput = metrics
            .iter()
            .last()
            .map(|m| m.records_out as f64 / m.window_s)
            .unwrap_or(0.0);
    }

    /// The closed drive loop shared by [`run`](Coordinator::run) and
    /// [`run_to_completion`](Coordinator::run_to_completion): advance the
    /// simulator one metrics window at a time, ingest, and re-schedule
    /// every `t_sched_s`.
    fn drive(&mut self, max_s: f64, until_drained: bool) -> RunReport {
        if self.sim.instances.is_empty() {
            self.deploy_initial();
        }
        let mut t = self.sim.now();
        let end = t + max_s;
        let mut next_sched = t + self.cfg.t_sched_s;
        while t < end && !(until_drained && self.sim.drained()) {
            t = (t + self.cfg.metrics_interval_s).min(end);
            self.sim.run_until(t);
            let (metrics, outs) = self.sim.flush_metrics();
            // Aggregate windowed throughput: per-tenant outputs scaled to
            // input items each (a single-element sum for one tenant).
            let thr = outs
                .iter()
                .zip(&self.sim.tenancy.d_o)
                .map(|(&o, &d)| o as f64 / d)
                .sum::<f64>()
                / self.cfg.metrics_interval_s;
            self.series.push((t, thr));
            self.ingest_window(&metrics);
            self.last_metrics = Some(metrics);
            if t >= next_sched && !(until_drained && self.sim.drained()) {
                next_sched = t + self.cfg.t_sched_s;
                let m = self.last_metrics.take().unwrap();
                self.schedule_round(&m);
                self.last_metrics = Some(m);
            }
        }
        let duration = if until_drained { self.sim.now() } else { max_s };
        self.report(duration)
    }

    /// Drive the closed loop until the input trace is fully processed
    /// (the paper's offline paradigm: fixed dataset, fastest finish wins)
    /// or `max_s` elapses.  Throughput = items / completion time.
    pub fn run_to_completion(&mut self, max_s: f64) -> RunReport {
        self.drive(max_s, true)
    }

    /// Drive the closed loop for `duration_s` simulated seconds.
    pub fn run(&mut self, duration_s: f64) -> RunReport {
        self.drive(duration_s, false)
    }
}
