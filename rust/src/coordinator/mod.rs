//! The Trident coordinator: the closed control loop of Figure 1.
//!
//! Wires the pipeline executor (simulator), metrics collector, observation
//! layer, adaptation layer, and scheduling layer together — including
//! paths ⑧ (plan application) and ⑨ (sample invalidation on configuration
//! transitions).  The loop itself is policy-agnostic: every scheduler in
//! the evaluation (Trident's MILP and all baselines) implements the
//! [`SchedulingPolicy`] trait and is applied through the same
//! plan-application path, so comparisons differ only in policy.
//!
//! Module family (see `DESIGN.md`):
//! * [`policy`] — the [`SchedulingPolicy`] trait, [`PolicyCtx`] /
//!   [`Plan`], and the Static / SCOOT / Trident implementations
//!   (Ray Data, DS2, ContTune live in [`crate::baselines`]);
//! * [`ingest`] — per-window metrics ingestion, the Table-3
//!   `EstimatorBank` MAPE lattice, and BO probe evaluation;
//! * [`transition`] — initial deployment, placement application, rolling
//!   updates + sample invalidation (path ⑨), and the OOM safety fallback;
//! * [`report`] — [`RunReport`] assembly.
//!
//! One deliberate simulation shortcut (DESIGN.md): BO probe evaluations are
//! measured against the operator's ground-truth service model plus
//! measurement noise instead of occupying a live instance for the full
//! evaluation window.  The measured quantity is identical to what a probe
//! instance would report; a probe OOM still costs real downtime (one live
//! instance is cold-restarted) so Table 6's downtime is honest.

mod ingest;
pub mod policy;
pub mod report;
mod transition;

#[cfg(test)]
mod tests;

pub use policy::{Plan, Policy, PolicyCtx, SchedulingPolicy, TransitionCmd, Variant};
pub use report::{RunReport, TenantReport};

use std::collections::HashMap;

use crate::adaptation::OperatorAdaptation;
use crate::config::{ClusterSpec, Json, PipelineSpec, Tenancy, TridentConfig};
use crate::dynamics::{ClusterEvent, DynamicsSpec, EventReport, RecoveryPolicy, TimedEvent};
use crate::observation::{CapacityEstimator, ObsConfig, UsefulTimeEstimator};
use crate::runtime::GpBackend;
use crate::scheduling::RollingState;
use crate::sim::{ItemAttrs, OpMetrics, ShardedSim};
use crate::solver::MilpStats;
use crate::trace::{TraceFormat, TraceSink};
use crate::workload::Trace;

use ingest::EstimatorBank;

/// The coordinator.
pub struct Coordinator {
    /// The executor: K tenant-shards behind the serial API, bit-identical
    /// to the serial executor at any `cfg.sim_shards` (1 = serial path).
    pub sim: ShardedSim,
    pub cfg: TridentConfig,
    pub variant: Variant,
    backend: GpBackend,
    /// Main estimator per op (the one the scheduler consumes).
    estimators: Vec<CapacityEstimator>,
    useful_time: Vec<UsefulTimeEstimator>,
    /// Table-3 lattice (only when `collect_mape`).
    banks: Vec<EstimatorBank>,
    pub collect_mape: bool,
    mape: HashMap<&'static str, (f64, u64)>,
    adaptation: Vec<Option<OperatorAdaptation>>,
    rolling: Vec<RollingState>,
    /// The active scheduler (trait object — replaces the old inline
    /// per-policy match arms and per-baseline fields).
    policy: Box<dyn SchedulingPolicy>,
    /// Whether the op has had its samples invalidated for the current
    /// transition already.
    invalidated: Vec<bool>,
    /// Deployed-config OOM safety fallback bookkeeping.
    recent_ooms: Vec<u32>,
    milp_ms: Vec<f64>,
    obs_ms: Vec<f64>,
    adapt_ms: Vec<f64>,
    transitions: u64,
    series: Vec<(f64, f64)>,
    cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    nominal: Vec<ItemAttrs>,
    last_metrics: Option<Vec<OpMetrics>>,
    last_throughput: f64,
    /// Per-op wall of the last committed transition (anti-thrash cooldown).
    last_transition_t: Vec<f64>,
    /// Seed the coordinator was built with (dynamics timeline sampling).
    seed: u64,
    /// Cluster-dynamics spec (`None` = static cluster and tenancy — the
    /// classic pre-dynamics closed loop, bit-for-bit).
    dynamics: Option<DynamicsSpec>,
    /// The generated event timeline (built lazily on the first drive
    /// call, when the horizon is known) and the cursor into it.
    timeline: Vec<TimedEvent>,
    timeline_built: bool,
    next_event: usize,
    /// A topology/tenancy event awaits its event-driven re-plan: the
    /// next metrics window triggers an immediate scheduling round
    /// instead of waiting out the periodic `t_sched_s` timer.
    replan_pending: bool,
    /// Per-event recovery metrics (reported in `RunReport::events`) and
    /// the consecutive-recovered-window streak behind `recovered_s`.
    event_reports: Vec<EventReport>,
    recovery_streak: Vec<u32>,
    /// Flight recorder (`None` = tracing off, the zero-overhead state:
    /// the loop pays one `Option` check per site and allocates nothing).
    trace: Option<Box<TraceSink>>,
    /// Where to persist the trace when a drive finishes.
    trace_out: Option<(String, TraceFormat)>,
    /// Union of every committed plan's solver counters (RunReport's
    /// per-phase solver breakdown).
    milp_stats: MilpStats,
    /// Scheduling rounds that committed a plan (placement / routes /
    /// transitions) — a `Plan::keep` round is consulted, not committed.
    plans_committed: u64,
}

/// Propagate a source item's mean attrs through the pipeline's child
/// scalings to get nominal per-op attrs (used for the Static plan).
///
/// Runs over the DAG in topological order: an operator inherits its
/// predecessor's scaled attrs; a join sees the merge of its branches
/// (token loads accumulate, spatial extents take the max — mirroring the
/// executor's `merge_group`).  For a chain this is the old sequential
/// propagation.
pub fn nominal_attrs(pipeline: &PipelineSpec, source: ItemAttrs) -> Vec<ItemAttrs> {
    nominal_attrs_rooted(pipeline, &[(0, source)])
}

/// Multi-root variant of [`nominal_attrs`] for merged tenancies: each
/// tenant's source operator gets its own nominal source attrs, and the
/// propagation stays within each tenant's (disjoint) DAG.
pub fn nominal_attrs_rooted(
    pipeline: &PipelineSpec,
    roots: &[(usize, ItemAttrs)],
) -> Vec<ItemAttrs> {
    let scale = |a: ItemAttrs, s: [f64; 4]| ItemAttrs {
        tokens_in: a.tokens_in * s[0],
        tokens_out: a.tokens_out * s[1],
        pixels_m: a.pixels_m * s[2],
        frames: a.frames * s[3],
    };
    let fallback = roots
        .first()
        .map(|&(_, a)| a)
        .unwrap_or(ItemAttrs { tokens_in: 512.0, tokens_out: 64.0, pixels_m: 1.0, frames: 1.0 });
    let mut out = vec![fallback; pipeline.n_ops()];
    for &v in &pipeline.topo_order() {
        let preds = pipeline.in_edges(v);
        match preds.len() {
            0 => {
                out[v] = roots
                    .iter()
                    .find(|&&(r, _)| r == v)
                    .map(|&(_, a)| a)
                    .unwrap_or(fallback)
            }
            1 => {
                let u = pipeline.edges[preds[0]].0;
                out[v] = scale(out[u], pipeline.operators[u].child_scale);
            }
            _ => {
                let mut merged: Option<ItemAttrs> = None;
                for &e in &preds {
                    let u = pipeline.edges[e].0;
                    let a = scale(out[u], pipeline.operators[u].child_scale);
                    merged = Some(match merged {
                        None => a,
                        Some(m) => m.merge(&a),
                    });
                }
                out[v] = merged.unwrap();
            }
        }
    }
    out
}

impl Coordinator {
    pub fn new(
        pipeline: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: ItemAttrs,
        seed: u64,
    ) -> Self {
        Self::new_tenancy(
            Tenancy::single(pipeline),
            cluster,
            vec![trace],
            cfg,
            variant,
            vec![source_attrs],
            seed,
        )
        .unwrap_or_else(|e| panic!("invalid pipeline spec: {e}"))
    }

    /// Multi-tenant constructor: N pipelines (`tenancy`) sharing `cluster`,
    /// one trace + nominal source attrs per tenant.  A single-tenant
    /// tenancy reproduces [`Coordinator::new`] event-for-event.
    pub fn new_tenancy(
        tenancy: Tenancy,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: Vec<ItemAttrs>,
        seed: u64,
    ) -> Result<Self, String> {
        let (pipeline, view) = tenancy.merged()?;
        if traces.len() != view.n_tenants() {
            return Err(format!(
                "{} traces for {} tenants",
                traces.len(),
                view.n_tenants()
            ));
        }
        if source_attrs.len() != view.n_tenants() {
            return Err(format!(
                "{} source-attr entries for {} tenants",
                source_attrs.len(),
                view.n_tenants()
            ));
        }
        let backend = if cfg.native_gp { GpBackend::Native } else { GpBackend::from_env() };
        let n = pipeline.n_ops();
        let roots: Vec<(usize, ItemAttrs)> =
            view.sources.iter().copied().zip(source_attrs).collect();
        let nominal = nominal_attrs_rooted(&pipeline, &roots);
        let estimators = pipeline
            .operators
            .iter()
            .map(|o| CapacityEstimator::new(ObsConfig::from_trident(&cfg), o.features))
            .collect();
        let useful_time = (0..n).map(|_| UsefulTimeEstimator::new()).collect();
        let banks = pipeline
            .operators
            .iter()
            .map(|o| EstimatorBank::new(&cfg, o.features))
            .collect();
        let adaptation = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                if o.tunable && variant.use_adaptation {
                    let mut ad = OperatorAdaptation::new(
                        i,
                        o.config_space.clone(),
                        &cfg,
                        cluster.nodes[0].accel_mem_mb,
                        seed ^ (i as u64) << 8,
                    );
                    ad.set_strategy(variant.strategy);
                    Some(ad)
                } else {
                    None
                }
            })
            .collect();
        let rolling = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let init = variant
                    .initial_configs
                    .as_ref()
                    .and_then(|v| v.get(i).cloned().flatten())
                    .unwrap_or_else(|| o.config_space.default_config());
                RollingState::new(init, 0)
            })
            .collect();
        let policy = variant.policy.build();
        let mut sim =
            ShardedSim::new_tenancy(pipeline, view, cluster, traces, seed, cfg.sim_shards);
        sim.set_workers(cfg.sim_workers);
        sim.set_seed_event_stream(cfg.sim_seed_event_stream);
        Ok(Coordinator {
            sim,
            cfg,
            variant,
            backend,
            estimators,
            useful_time,
            banks,
            collect_mape: false,
            mape: HashMap::new(),
            adaptation,
            rolling,
            policy,
            invalidated: vec![false; n],
            recent_ooms: vec![0; n],
            milp_ms: Vec::new(),
            obs_ms: Vec::new(),
            adapt_ms: Vec::new(),
            transitions: 0,
            series: Vec::new(),
            cluster_eval: Vec::new(),
            nominal,
            last_metrics: None,
            last_throughput: 0.0,
            last_transition_t: vec![f64::NEG_INFINITY; n],
            seed,
            dynamics: None,
            timeline: Vec::new(),
            timeline_built: false,
            next_event: 0,
            replan_pending: false,
            event_reports: Vec::new(),
            recovery_streak: Vec::new(),
            trace: None,
            trace_out: None,
            milp_stats: MilpStats::default(),
            plans_committed: 0,
        })
    }

    /// Attach a cluster-dynamics spec before the run starts.  Validates
    /// it against the deployment, holds `node_join` spares offline, and
    /// puts arriving tenants to sleep until their arrival events fire.
    pub fn set_dynamics(&mut self, spec: DynamicsSpec) -> Result<(), String> {
        if self.sim.has_instances() {
            return Err("set_dynamics must be called before the run starts".into());
        }
        spec.validate(self.sim.cluster.nodes.len(), &self.sim.tenancy.ids)?;
        for node in spec.joining_nodes() {
            // No instances exist yet: failing the empty node just holds
            // it down until its node_join event.
            self.sim.fail_node(node, true);
        }
        for id in spec.arriving_tenants() {
            let t = self
                .sim
                .tenancy
                .ids
                .iter()
                .position(|i| *i == id)
                .expect("validated tenant id");
            self.sim.set_tenant_active(t, false);
        }
        self.dynamics = Some(spec);
        self.timeline_built = false;
        self.next_event = 0;
        Ok(())
    }

    /// Turn the flight recorder on (idempotent).  The contract that makes
    /// this safe to leave on in experiments: recording consumes no RNG
    /// draws, never re-orders executor events, and the parity suite pins
    /// bit-identical [`RunReport`]s with tracing on vs off.
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(Box::new(TraceSink::new()));
        }
        self.sim.set_trace_ooms(true);
    }

    /// Enable tracing and write the recording to `path` when the next
    /// drive finishes (JSONL or Chrome trace-event JSON).
    pub fn set_trace(&mut self, path: &str, format: TraceFormat) {
        self.enable_trace();
        self.trace_out = Some((path.to_string(), format));
    }

    /// Detach the recorded trace (e.g. to summarize in-process).
    pub fn take_trace(&mut self) -> Option<Box<TraceSink>> {
        self.trace.take()
    }

    /// Tenants the scheduler should still plan for: active ones, plus
    /// departed ones that have admitted items in flight (their operators
    /// are reclaimed only once they drain).  All-true absent dynamics.
    fn tenant_live(&self) -> Vec<bool> {
        (0..self.sim.tenancy.n_tenants())
            .map(|t| self.sim.tenants_active()[t] || !self.sim.tenant_drained(t))
            .collect()
    }

    /// Mean windowed throughput over the most recent metrics windows —
    /// the pre-event reference level for recovery tracking.
    fn recent_throughput(&self) -> f64 {
        let n = self.series.len().min(6);
        if n == 0 {
            return 0.0;
        }
        self.series[self.series.len() - n..].iter().map(|&(_, v)| v).sum::<f64>() / n as f64
    }

    /// Apply one timeline event to the executor and control state: kill /
    /// revive capacity, splice tenants, invalidate observation samples of
    /// the affected operators (the paper's sample-invalidation rule
    /// extended to topology changes), re-sync rolling books (failed
    /// instances are already-stopped — no cold-start charge for capacity
    /// that no longer exists), and arm the event-driven re-plan.
    fn apply_event(&mut self, te: &TimedEvent) {
        let requeue = self
            .dynamics
            .as_ref()
            .map(|d| d.recovery == RecoveryPolicy::Requeue)
            .unwrap_or(true);
        let mut lost = 0u64;
        let mut invalidated_ops: Vec<usize> = Vec::new();
        let label = match &te.event {
            ClusterEvent::NodeFail { node } => {
                // Includes Draining instances (the crash kills those too,
                // unlike placement()), so their ops are invalidated as
                // well.
                let affected = self.sim.ops_on_node(*node);
                lost = self.sim.fail_node(*node, requeue);
                for &i in &affected {
                    self.estimators[i].invalidate();
                    let live = self.sim.instances_of(i).len() as u32;
                    self.rolling[i].on_capacity_loss(live);
                }
                invalidated_ops = affected;
                format!("node_fail(node {node})")
            }
            ClusterEvent::NodeRecover { node } => {
                self.sim.set_node_up(*node);
                format!("node_recover(node {node})")
            }
            ClusterEvent::NodeJoin { node } => {
                self.sim.set_node_up(*node);
                format!("node_join(node {node})")
            }
            ClusterEvent::TenantArrive { tenant } => {
                if let Some(t) = self.sim.tenancy.ids.iter().position(|i| i == tenant) {
                    self.sim.set_tenant_active(t, true);
                }
                format!("tenant_arrive({tenant})")
            }
            ClusterEvent::TenantDepart { tenant } => {
                if let Some(t) = self.sim.tenancy.ids.iter().position(|i| i == tenant) {
                    self.sim.set_tenant_active(t, false);
                }
                format!("tenant_depart({tenant})")
            }
            ClusterEvent::BandwidthDegrade { node, factor } => {
                self.sim.set_bandwidth_factor(*node, *factor);
                // The node's egress feeds these ops' downstream windows;
                // their samples are stale now.
                for i in self.sim.ops_on_node(*node) {
                    self.estimators[i].invalidate();
                    invalidated_ops.push(i);
                }
                format!("bandwidth_degrade(node {node}, x{factor})")
            }
            ClusterEvent::BandwidthRestore { node } => {
                self.sim.set_bandwidth_factor(*node, 1.0);
                // Symmetric with the degrade arm: windows observed while
                // the link was squeezed are just as stale now.
                for i in self.sim.ops_on_node(*node) {
                    self.estimators[i].invalidate();
                    invalidated_ops.push(i);
                }
                format!("bandwidth_restore(node {node})")
            }
        };
        let baseline_thr = self.recent_throughput();
        if let Some(ts) = self.trace.as_mut() {
            ts.sim_event(
                te.at_s,
                "dynamics",
                vec![
                    ("label", Json::str(&label)),
                    ("lost", Json::num(lost as f64)),
                    ("baseline_thr", Json::num(baseline_thr)),
                ],
            );
            for &i in &invalidated_ops {
                ts.sim_event(
                    te.at_s,
                    "invalidation",
                    vec![
                        ("op", Json::str(&self.sim.spec.operators[i].name)),
                        ("reason", Json::str("topology")),
                    ],
                );
            }
        }
        self.event_reports.push(EventReport {
            at_s: te.at_s,
            label,
            baseline_thr,
            replan_s: None,
            recovered_s: None,
            lost_records: lost,
        });
        self.recovery_streak.push(0);
        self.replan_pending = true;
    }

    /// Per-window recovery tracking: an event counts as recovered once
    /// windowed throughput sustains >= 90% of its pre-event baseline for
    /// two consecutive windows (one noisy window must not declare
    /// victory).
    fn track_recovery(&mut self, t: f64, thr: f64) {
        let mut recovered: Vec<(String, f64)> = Vec::new();
        for (ev, streak) in self.event_reports.iter_mut().zip(&mut self.recovery_streak) {
            // No pre-event traffic ⇒ no baseline to recover to: leave
            // recovered_s undefined instead of declaring instant victory
            // against a zero threshold.
            if ev.recovered_s.is_some() || t <= ev.at_s || ev.baseline_thr <= 0.0 {
                continue;
            }
            if thr >= 0.9 * ev.baseline_thr {
                *streak += 1;
                if *streak >= 2 {
                    ev.recovered_s = Some(t - ev.at_s);
                    if self.trace.is_some() {
                        recovered.push((ev.label.clone(), t - ev.at_s));
                    }
                }
            } else {
                *streak = 0;
            }
        }
        if let Some(ts) = self.trace.as_mut() {
            for (label, latency) in recovered {
                ts.sim_event(
                    t,
                    "recover",
                    vec![("label", Json::str(&label)), ("latency_s", Json::num(latency))],
                );
            }
        }
    }

    /// Stamp time-to-replan on events whose re-plan just committed.
    fn mark_replanned(&mut self, t: f64) {
        let mut stamped: Vec<(String, f64)> = Vec::new();
        for ev in &mut self.event_reports {
            if ev.replan_s.is_none() {
                let latency = (t - ev.at_s).max(0.0);
                ev.replan_s = Some(latency);
                if self.trace.is_some() {
                    stamped.push((ev.label.clone(), latency));
                }
            }
        }
        if let Some(ts) = self.trace.as_mut() {
            for (label, latency) in stamped {
                ts.sim_event(
                    t,
                    "replan",
                    vec![("label", Json::str(&label)), ("latency_s", Json::num(latency))],
                );
            }
        }
    }

    /// Record one scheduling round's decision: a sim-lane `plan` record
    /// (diff size vs the pre-application placement, transition shape) and
    /// — when the policy ran the MILP — a wall-lane `solve` record with
    /// the full per-phase solver breakdown.
    fn emit_plan_records(&mut self, plan: &Plan, placement: &[Vec<u32>], acted: bool) {
        let now = self.sim.now();
        let placement_diff: u64 = plan
            .placement
            .as_ref()
            .map(|x| {
                x.iter()
                    .zip(placement)
                    .map(|(new_row, old_row)| {
                        new_row
                            .iter()
                            .zip(old_row)
                            .map(|(&n, &o)| (i64::from(n) - i64::from(o)).unsigned_abs())
                            .sum::<u64>()
                    })
                    .sum()
            })
            .unwrap_or(0);
        let (transition, b_sum) = match &plan.transitions {
            TransitionCmd::None => ("none", 0u64),
            TransitionCmd::AllAtOnce => ("all_at_once", 0),
            TransitionCmd::Rolling(b) => ("rolling", b.iter().map(|&x| u64::from(x)).sum()),
        };
        let Some(ts) = self.trace.as_mut() else { return };
        ts.sim_event(
            now,
            "plan",
            vec![
                ("acted", Json::Bool(acted)),
                ("placement_diff", Json::num(placement_diff as f64)),
                ("transition", Json::str(transition)),
                ("b_sum", Json::num(b_sum as f64)),
                ("routes", Json::Bool(plan.routes.is_some())),
            ],
        );
        if let (Some(ms), Some(st)) = (plan.milp_ms, plan.stats.as_ref()) {
            // Budget-bound solves leave machine-dependent counters, and the
            // gap can be non-finite when no incumbent exists — everything
            // here lives on the wall lane, sanitized for strict JSON.
            let gap = if st.gap.is_finite() { st.gap } else { -1.0 };
            ts.wall_event(
                now,
                "solve",
                vec![
                    ("milp_ms", Json::num(ms)),
                    ("nodes", Json::num(st.nodes as f64)),
                    ("lp_solves", Json::num(st.lp_solves as f64)),
                    ("gap", Json::num(gap)),
                    ("pivots", Json::num(st.pivots as f64)),
                    ("phase1_pivots", Json::num(st.phase1_pivots as f64)),
                    ("warm_solves", Json::num(st.warm_solves as f64)),
                    ("cold_solves", Json::num(st.cold_solves as f64)),
                    ("dense_fallbacks", Json::num(st.dense_fallbacks as f64)),
                    ("root_warm", Json::Bool(st.root_warm)),
                    ("warm_hit_rate", Json::num(st.warm_hit_rate())),
                    ("build_ms", Json::num(st.build_ms)),
                    ("root_lp_ms", Json::num(st.root_lp_ms)),
                    ("bnb_ms", Json::num(st.bnb_ms)),
                    ("pricing_ms", Json::num(st.pricing_ms)),
                    ("pricing_rounds", Json::num(st.pricing_rounds as f64)),
                    ("columns", Json::num(st.columns as f64)),
                ],
            );
        }
    }

    /// Per-window flight-recorder drain: simulator OOM kills (buffered
    /// during the window, merged K-invariantly), the window boundary, the
    /// per-op window summaries, and a cumulative wall-lane pool snapshot.
    fn emit_window_records(
        &mut self,
        t0: f64,
        t1: f64,
        thr: f64,
        metrics: &[OpMetrics],
        outs: &[u64],
    ) {
        let ooms = self.sim.take_trace_ooms();
        let index = self.series.len().saturating_sub(1);
        let pool = self.sim.pool_telemetry();
        let Some(ts) = self.trace.as_mut() else { return };
        for (t, op, gid) in ooms {
            ts.sim_event(
                t,
                "oom",
                vec![
                    ("op", Json::str(&self.sim.spec.operators[op].name)),
                    ("op_idx", Json::num(op as f64)),
                    ("inst", Json::num(gid as f64)),
                ],
            );
        }
        ts.sim_event(
            t1,
            "window",
            vec![
                ("index", Json::num(index as f64)),
                ("t0", Json::num(t0)),
                ("t1", Json::num(t1)),
                ("thr", Json::num(thr)),
                ("outs", Json::Arr(outs.iter().map(|&o| Json::num(o as f64)).collect())),
            ],
        );
        for m in metrics {
            if m.records_in == 0 && m.records_out == 0 && m.oom_events == 0 {
                continue; // idle op: keep the trace proportional to activity
            }
            ts.sim_event(
                t1,
                "op_window",
                vec![
                    ("op", Json::str(&self.sim.spec.operators[m.op].name)),
                    ("records_in", Json::num(m.records_in as f64)),
                    ("records_out", Json::num(m.records_out as f64)),
                    ("rate_per_inst", Json::num(m.rate_per_inst)),
                    ("utilization", Json::num(m.utilization)),
                    ("queue_begin", Json::num(m.queue_begin as f64)),
                    ("queue_end", Json::num(m.queue_end as f64)),
                    ("queue_avg", Json::num(m.queue_avg)),
                    ("peak_mem_mb", Json::num(m.peak_mem_mb)),
                    ("oom_events", Json::num(f64::from(m.oom_events))),
                    ("n_active", Json::num(m.n_active as f64)),
                ],
            );
        }
        if let Some(p) = pool {
            ts.wall_event(
                t1,
                "pool",
                vec![
                    ("workers", Json::num(p.workers as f64)),
                    ("steals", Json::num(p.steals as f64)),
                    ("epochs", Json::num(p.epochs as f64)),
                    ("wait_ms", Json::num(p.wait_ms)),
                    ("tasks", Json::Arr(p.tasks.iter().map(|&x| Json::num(x as f64)).collect())),
                ],
            );
        }
    }

    /// One scheduling round (Algorithm 2): estimate rates, forward
    /// adaptation recommendations into rolling state, ask the policy for a
    /// plan, and apply it through the shared path ⑧.  Returns whether the
    /// policy actually produced a plan (placement/routes/transitions) —
    /// a `Plan::keep` from Static is a round, not a re-plan.
    fn schedule_round(&mut self, metrics: &[OpMetrics]) -> bool {
        let rates = self.current_rates(metrics);
        let adapt_on = self.forward_recommendations();
        let placement = self.sim.placement();
        // A departed tenant stays schedulable until its admitted items
        // drain; only then are its operators reclaimed (excluded from the
        // plan, instances stopped).  Identity absent dynamics.
        let tenant_live = self.tenant_live();
        // Note: includes draining instances (unlike `placement()`), matching
        // what the reactive baselines have always seen as "current p".
        let cur_p: Vec<u32> = (0..self.sim.spec.n_ops())
            .map(|i| self.sim.instances_of(i).len() as u32)
            .collect();
        let plan = {
            let ctx = PolicyCtx {
                spec: &self.sim.spec,
                cluster: &self.sim.cluster,
                cfg: &self.cfg,
                variant: &self.variant,
                metrics,
                rates: &rates,
                cur_p: &cur_p,
                placement: &placement,
                rolling: &self.rolling,
                tenancy: &self.sim.tenancy,
                node_up: self.sim.nodes_up(),
                tenant_active: &tenant_live,
                last_throughput: self.last_throughput,
                now: self.sim.now(),
            };
            self.policy.plan(&ctx)
        };
        if let Some(ms) = plan.milp_ms {
            self.milp_ms.push(ms);
        }
        if let Some(st) = plan.stats.as_ref() {
            self.milp_stats.absorb(st);
        }
        let acted = plan.placement.is_some()
            || plan.routes.is_some()
            || plan.transitions != TransitionCmd::None;
        if acted {
            self.plans_committed += 1;
        }
        if self.trace.is_some() {
            self.emit_plan_records(&plan, &placement, acted);
        }
        if let Some(x) = &plan.placement {
            self.apply_placement(x);
        }
        if let Some(routes) = plan.routes {
            // Routing fractions are keyed by pipeline edge id.
            for (edge, m) in routes.into_iter().enumerate() {
                self.sim.set_route(edge, Some(m));
            }
        }
        match plan.transitions {
            TransitionCmd::None => {}
            TransitionCmd::AllAtOnce => self.apply_all_at_once_transitions(adapt_on),
            TransitionCmd::Rolling(b) => {
                for i in 0..self.sim.spec.n_ops() {
                    let bi = b[i];
                    if bi > 0 {
                        self.start_transition(i, bi);
                    }
                    let p_now = self.sim.instances_of(i).len() as u32;
                    if bi > 0 {
                        self.rolling[i].apply_round(bi, p_now);
                    } else {
                        self.rolling[i].sync_count(p_now);
                    }
                }
            }
        }
        self.last_throughput = metrics
            .iter()
            .last()
            .map(|m| m.records_out as f64 / m.window_s)
            .unwrap_or(0.0);
        acted
    }

    /// The closed drive loop shared by [`run`](Coordinator::run) and
    /// [`run_to_completion`](Coordinator::run_to_completion): advance the
    /// simulator one metrics window at a time, ingest, and re-schedule
    /// every `t_sched_s`.
    fn drive(&mut self, max_s: f64, until_drained: bool) -> RunReport {
        if !self.sim.has_instances() {
            self.deploy_initial();
        }
        let mut t = self.sim.now();
        let end = t + max_s;
        if !self.timeline_built {
            if let Some(spec) = &self.dynamics {
                self.timeline =
                    spec.timeline(self.sim.cluster.nodes.len(), end, self.seed ^ 0x7472_6964);
            }
            self.timeline_built = true;
        }
        if self.trace.as_ref().is_some_and(|ts| ts.is_empty()) {
            let fields = vec![
                ("pipeline", Json::str(&self.sim.spec.name)),
                ("policy", Json::str(self.variant.policy.name())),
                ("seed", Json::num(self.seed as f64)),
                ("shards", Json::num(self.sim.shard_count() as f64)),
                ("workers", Json::num(self.sim.workers_effective() as f64)),
                ("tenants", Json::num(self.sim.tenancy.n_tenants() as f64)),
            ];
            if let Some(ts) = self.trace.as_mut() {
                ts.header(fields);
            }
        }
        let mut next_sched = t + self.cfg.t_sched_s;
        while t < end
            && !(until_drained
                && self.sim.drained()
                && self.next_event >= self.timeline.len())
        {
            let wstart = t;
            t = (t + self.cfg.metrics_interval_s).min(end);
            // Inject timeline events at their exact sim timestamps inside
            // this window: advance the executor to the event time, apply,
            // continue.
            while self.next_event < self.timeline.len()
                && self.timeline[self.next_event].at_s <= t
            {
                let te = self.timeline[self.next_event].clone();
                self.next_event += 1;
                self.sim.run_until(te.at_s);
                self.apply_event(&te);
            }
            self.sim.run_until(t);
            let (metrics, outs) = self.sim.flush_metrics();
            // Aggregate windowed throughput: per-tenant outputs scaled to
            // input items each (a single-element sum for one tenant).
            let thr = outs
                .iter()
                .zip(&self.sim.tenancy.d_o)
                .map(|(&o, &d)| o as f64 / d)
                .sum::<f64>()
                / self.cfg.metrics_interval_s;
            self.series.push((t, thr));
            self.track_recovery(t, thr);
            self.ingest_window(&metrics);
            if self.trace.is_some() {
                self.emit_window_records(wstart, t, thr, &metrics, &outs);
            }
            self.last_metrics = Some(metrics);
            // Event-driven re-plan: a topology/tenancy event re-plans at
            // the very next metrics window (within one
            // `metrics_interval_s` of the event) instead of waiting out
            // the periodic timer.
            let due = t >= next_sched || self.replan_pending;
            if due && !(until_drained && self.sim.drained()) {
                next_sched = t + self.cfg.t_sched_s;
                let m = self.last_metrics.take().unwrap();
                let acted = self.schedule_round(&m);
                self.last_metrics = Some(m);
                if acted {
                    // `replan_s` means "a plan was committed", not "a
                    // round ran": Static's keep-everything rounds leave
                    // its events unstamped (reported as never re-planned).
                    self.mark_replanned(t);
                }
                self.replan_pending = false;
            }
        }
        let duration = if until_drained { self.sim.now() } else { max_s };
        let report = self.report(duration);
        if self.trace.is_some() {
            self.emit_run_summary(&report);
        }
        if let Some((path, fmt)) = self.trace_out.clone() {
            if let Some(ts) = self.trace.as_ref() {
                if let Err(e) = ts.write(&path, fmt) {
                    eprintln!("trace: failed to write {path}: {e}");
                }
            }
        }
        report
    }

    /// Final sim-lane record: the producing run's own `RunReport` totals,
    /// which `trace-summary --check` (and the analyzer's `check()`) diffs
    /// against the aggregates recomputed from the records themselves.
    fn emit_run_summary(&mut self, report: &RunReport) {
        let t_end = self.sim.now();
        let replans = report.events.iter().filter(|e| e.replan_s.is_some()).count();
        let recovers = report.events.iter().filter(|e| e.recovered_s.is_some()).count();
        let tenants: Vec<Json> = report
            .tenants
            .iter()
            .map(|tr| {
                Json::obj(vec![
                    ("id", Json::str(&tr.id)),
                    ("items", Json::num(tr.items_processed as f64)),
                    ("throughput", Json::num(tr.throughput)),
                ])
            })
            .collect();
        let windows = self.series.len();
        let plans_committed = self.plans_committed;
        let Some(ts) = self.trace.as_mut() else { return };
        ts.sim_event(
            t_end,
            "run_summary",
            vec![
                ("duration_s", Json::num(report.duration_s)),
                ("throughput", Json::num(report.throughput)),
                ("items", Json::num(report.items_processed as f64)),
                ("oom_events", Json::num(f64::from(report.oom_events))),
                ("oom_downtime_s", Json::num(report.oom_downtime_s)),
                ("config_transitions", Json::num(report.config_transitions as f64)),
                ("solves", Json::num(report.milp_ms.len() as f64)),
                ("plans_committed", Json::num(plans_committed as f64)),
                ("dynamics_events", Json::num(report.events.len() as f64)),
                ("replans", Json::num(replans as f64)),
                ("recovers", Json::num(recovers as f64)),
                ("lost_records", Json::num(report.lost_records as f64)),
                ("windows", Json::num(windows as f64)),
                ("tenants", Json::Arr(tenants)),
            ],
        );
    }

    /// Drive the closed loop until the input trace is fully processed
    /// (the paper's offline paradigm: fixed dataset, fastest finish wins)
    /// or `max_s` elapses.  Throughput = items / completion time.
    pub fn run_to_completion(&mut self, max_s: f64) -> RunReport {
        self.drive(max_s, true)
    }

    /// Drive the closed loop for `duration_s` simulated seconds.
    pub fn run(&mut self, duration_s: f64) -> RunReport {
        self.drive(duration_s, false)
    }
}
