//! The Trident coordinator: the closed control loop of Figure 1.
//!
//! Wires the pipeline executor (simulator), metrics collector, observation
//! layer, adaptation layer, and scheduling layer together — including
//! paths ⑧ (plan application) and ⑨ (sample invalidation on configuration
//! transitions) — and hosts every baseline scheduler behind the same
//! plan-application path so evaluation comparisons differ only in policy.
//!
//! One deliberate simulation shortcut (DESIGN.md): BO probe evaluations are
//! measured against the operator's ground-truth service model plus
//! measurement noise instead of occupying a live instance for the full
//! evaluation window.  The measured quantity is identical to what a probe
//! instance would report; a probe OOM still costs real downtime (one live
//! instance is cold-restarted) so Table 6's downtime is honest.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::adaptation::{OperatorAdaptation, Strategy};
use crate::baselines::{pack, ContTune, RayDataAutoscaler};
use crate::config::{ClusterSpec, PipelineSpec, TridentConfig};
use crate::observation::{CapacityEstimator, ObsConfig, UsefulTimeEstimator};
use crate::runtime::GpBackend;
use crate::scheduling::{self, MilpInput, OpSched, RollingState};
use crate::sim::{ItemAttrs, OpMetrics, PipelineSim};
use crate::workload::Trace;

/// Which scheduling policy drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Fixed manually-tuned allocation (one-shot nominal MILP).
    Static,
    /// Ray Data's reactive threshold autoscaler.
    RayData,
    /// DS2: useful-time rates + waterfall parallelism.
    Ds2,
    /// ContTune: DS2 + conservative parallelism BO.
    ContTune,
    /// SCOOT: offline per-op config tuning + Static allocation.
    Scoot,
    /// The full Trident MILP.
    Trident,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Static => "Static",
            Policy::RayData => "Ray Data",
            Policy::Ds2 => "DS2",
            Policy::ContTune => "ContTune",
            Policy::Scoot => "SCOOT",
            Policy::Trident => "Trident",
        }
    }
}

/// Full experiment variant: policy + layer toggles (RQ2 sharing, RQ5
/// ablations, Table 5/6 strategies).
#[derive(Debug, Clone)]
pub struct Variant {
    pub policy: Policy,
    /// RQ2: give baselines Trident's observation-layer estimates.
    pub shared_observation: bool,
    /// RQ2: give baselines Trident's adaptation recommendations
    /// (applied all-at-once).
    pub shared_adaptation: bool,
    /// RQ5 w/o Observation: Trident falls back to useful-time rates.
    pub use_observation: bool,
    /// RQ5 w/o Adaptation: disable clustering + tuning.
    pub use_adaptation: bool,
    /// RQ5 w/o Placement: network-agnostic MILP.
    pub placement_aware: bool,
    /// RQ5 w/o Rolling: all-at-once config switches.
    pub rolling: bool,
    /// Tuning strategy (Table 5/6).
    pub strategy: Strategy,
    /// Initial per-op configs (SCOOT's offline-tuned configs).
    pub initial_configs: Option<Vec<Option<Vec<f64>>>>,
}

impl Variant {
    pub fn trident() -> Self {
        Variant {
            policy: Policy::Trident,
            shared_observation: false,
            shared_adaptation: false,
            use_observation: true,
            use_adaptation: true,
            placement_aware: true,
            rolling: true,
            strategy: Strategy::ConstrainedBo,
            initial_configs: None,
        }
    }

    pub fn baseline(policy: Policy) -> Self {
        Variant { policy, use_adaptation: false, ..Variant::trident() }
    }

    /// RQ2: baseline with Trident's observation + adaptation layers.
    pub fn controlled(policy: Policy) -> Self {
        Variant {
            policy,
            shared_observation: true,
            shared_adaptation: true,
            use_adaptation: true,
            rolling: false,
            ..Variant::trident()
        }
    }
}

/// Run outcome for reports and benches.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub pipeline: String,
    pub variant: String,
    pub duration_s: f64,
    /// Average pipeline throughput, input records/s.
    pub throughput: f64,
    /// (time, windowed throughput) series.
    pub series: Vec<(f64, f64)>,
    pub oom_events: u32,
    pub oom_downtime_s: f64,
    pub config_transitions: u64,
    /// Wall-clock of each MILP solve, ms.
    pub milp_ms: Vec<f64>,
    /// Mean per-invocation overhead of obs / adaptation layers, ms.
    pub obs_overhead_ms: f64,
    pub adapt_overhead_ms: f64,
    /// MAPE per estimator variant (Table 3), percent.
    pub estimator_mape: HashMap<&'static str, f64>,
    /// Clustering snapshots: per tunable op, (assignments, truth) samples.
    pub cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    pub items_processed: u64,
}

/// Estimator lattice carried for Table 3 MAPE accounting.
struct EstimatorBank {
    true_rate: UsefulTimeEstimator,
    ema_only: CapacityEstimator,
    gp_raw: CapacityEstimator,
    gp_signal: CapacityEstimator,
    gp_full: CapacityEstimator,
}

impl EstimatorBank {
    fn new(cfg: &TridentConfig, ex: crate::config::FeatureExtractor) -> Self {
        let base = ObsConfig::from_trident(cfg);
        EstimatorBank {
            true_rate: UsefulTimeEstimator::new(),
            ema_only: CapacityEstimator::new(
                ObsConfig { use_gp: false, model_filter: false, signal_filter: false, ..base.clone() },
                ex,
            ),
            gp_raw: CapacityEstimator::new(
                ObsConfig { signal_filter: false, model_filter: false, ..base.clone() },
                ex,
            ),
            gp_signal: CapacityEstimator::new(ObsConfig { model_filter: false, ..base.clone() }, ex),
            gp_full: CapacityEstimator::new(base, ex),
        }
    }
}

/// The coordinator.
pub struct Coordinator {
    pub sim: PipelineSim,
    pub cfg: TridentConfig,
    pub variant: Variant,
    backend: GpBackend,
    /// Main estimator per op (the one the scheduler consumes).
    estimators: Vec<CapacityEstimator>,
    useful_time: Vec<UsefulTimeEstimator>,
    /// Table-3 lattice (only when `collect_mape`).
    banks: Vec<EstimatorBank>,
    pub collect_mape: bool,
    mape: HashMap<&'static str, (f64, u64)>,
    adaptation: Vec<Option<OperatorAdaptation>>,
    rolling: Vec<RollingState>,
    raydata: RayDataAutoscaler,
    conttune: ContTune,
    /// Whether the op has had its samples invalidated for the current
    /// transition already.
    invalidated: Vec<bool>,
    /// Deployed-config OOM safety fallback bookkeeping.
    recent_ooms: Vec<u32>,
    milp_ms: Vec<f64>,
    obs_ms: Vec<f64>,
    adapt_ms: Vec<f64>,
    transitions: u64,
    series: Vec<(f64, f64)>,
    cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    nominal: Vec<ItemAttrs>,
    last_metrics: Option<Vec<OpMetrics>>,
    last_throughput: f64,
    /// Per-op wall of the last committed transition (anti-thrash cooldown).
    last_transition_t: Vec<f64>,
}

/// Propagate a source item's mean attrs through the pipeline's child
/// scalings to get nominal per-op attrs (used for the Static plan).
pub fn nominal_attrs(pipeline: &PipelineSpec, source: ItemAttrs) -> Vec<ItemAttrs> {
    let mut cur = source;
    let mut out = Vec::with_capacity(pipeline.n_ops());
    for op in &pipeline.operators {
        out.push(cur);
        let s = op.child_scale;
        cur = ItemAttrs {
            tokens_in: cur.tokens_in * s[0],
            tokens_out: cur.tokens_out * s[1],
            pixels_m: cur.pixels_m * s[2],
            frames: cur.frames * s[3],
        };
    }
    out
}

impl Coordinator {
    pub fn new(
        pipeline: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: ItemAttrs,
        seed: u64,
    ) -> Self {
        let backend = if cfg.native_gp { GpBackend::Native } else { GpBackend::from_env() };
        let n = pipeline.n_ops();
        let nominal = nominal_attrs(&pipeline, source_attrs);
        let estimators = pipeline
            .operators
            .iter()
            .map(|o| CapacityEstimator::new(ObsConfig::from_trident(&cfg), o.features))
            .collect();
        let useful_time = (0..n).map(|_| UsefulTimeEstimator::new()).collect();
        let banks = pipeline
            .operators
            .iter()
            .map(|o| EstimatorBank::new(&cfg, o.features))
            .collect();
        let adaptation = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                if o.tunable && variant.use_adaptation {
                    let mut ad = OperatorAdaptation::new(
                        i,
                        o.config_space.clone(),
                        &cfg,
                        cluster.nodes[0].accel_mem_mb,
                        seed ^ (i as u64) << 8,
                    );
                    ad.set_strategy(variant.strategy);
                    Some(ad)
                } else {
                    None
                }
            })
            .collect();
        let rolling = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let init = variant
                    .initial_configs
                    .as_ref()
                    .and_then(|v| v.get(i).cloned().flatten())
                    .unwrap_or_else(|| o.config_space.default_config());
                RollingState::new(init, 0)
            })
            .collect();
        let sim = PipelineSim::new(pipeline, cluster, trace, seed);
        Coordinator {
            sim,
            cfg,
            variant,
            backend,
            estimators,
            useful_time,
            banks,
            collect_mape: false,
            mape: HashMap::new(),
            adaptation,
            rolling,
            raydata: RayDataAutoscaler::default(),
            conttune: ContTune::default(),
            invalidated: vec![false; n],
            recent_ooms: vec![0; n],
            milp_ms: Vec::new(),
            obs_ms: Vec::new(),
            adapt_ms: Vec::new(),
            transitions: 0,
            series: Vec::new(),
            cluster_eval: Vec::new(),
            nominal,
            last_metrics: None,
            last_throughput: 0.0,
            last_transition_t: vec![f64::NEG_INFINITY; n],
        }
    }

    /// Nominal per-instance rate for the Static plan ("manual tuning"):
    /// the default-config capacity at the first regime's expected load.
    fn nominal_rates(&self) -> Vec<f64> {
        self.sim
            .spec
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                crate::sim::service::true_unit_rate(
                    &o.service,
                    &self.rolling[i].current,
                    &self.nominal[i],
                )
            })
            .collect()
    }

    /// Initial deployment shared by every policy: one-shot MILP on nominal
    /// rates (the "manually tuned" allocation).
    pub fn deploy_initial(&mut self) {
        let rates = self.nominal_rates();
        let input = self.milp_input(&rates, &vec![None; rates.len()]);
        let plan = scheduling::solve(&input, Duration::from_millis(self.cfg.milp_time_budget_ms));
        let x = if plan.t_pred > 0.0 {
            plan.x
        } else {
            // Fallback: greedy pack of a waterfall plan.
            let p = crate::baselines::waterfall(&self.sim.spec, &self.sim.cluster, &rates, 1.1);
            pack(&self.sim.spec, &self.sim.cluster, &p)
        };
        self.apply_placement(&x);
        if self.variant.policy == Policy::Trident && self.variant.placement_aware {
            for (i, m) in plan.route.iter().enumerate() {
                self.sim.set_route(i, Some(m.clone()));
            }
        }
        for (i, rs) in self.rolling.iter_mut().enumerate() {
            rs.sync_count(x[i].iter().sum());
        }
    }

    fn milp_input(&self, ut: &[f64], cand: &[Option<(f64, ())>]) -> MilpInput {
        let (d_i, d_o) = self.sim.spec.amplification();
        let cur = self.sim.placement();
        MilpInput {
            ops: self
                .sim
                .spec
                .operators
                .iter()
                .enumerate()
                .map(|(i, o)| OpSched {
                    name: o.name.clone(),
                    ut_cur: ut[i].max(1e-6),
                    ut_cand: cand[i].map(|(u, _)| u).filter(|_| self.rolling[i].in_transition()),
                    n_new: self.rolling[i].n_new,
                    n_old: self.rolling[i].n_old,
                    cpu: o.cpu,
                    mem_gb: o.mem_gb,
                    accels: o.accels,
                    out_mb: o.out_mb,
                    d_i: d_i[i],
                    h_start: o.start_s,
                    h_stop: o.stop_s,
                    h_cold: o.cold_s,
                    cur_x: cur[i].clone(),
                })
                .collect(),
            nodes: self.sim.cluster.nodes.clone(),
            d_o,
            t_sched: self.cfg.t_sched_s,
            lambda1: self.cfg.lambda1,
            lambda2: self.cfg.lambda2,
            b_max: self.cfg.b_max as u32,
            placement_aware: self.variant.placement_aware,
            all_at_once: !self.variant.rolling,
        }
    }

    /// Apply a placement diff: start missing instances, drain surplus.
    fn apply_placement(&mut self, x: &[Vec<u32>]) {
        let k = self.sim.cluster.nodes.len();
        for op in 0..self.sim.spec.n_ops() {
            for node in 0..k {
                let have: Vec<usize> = self
                    .sim
                    .instances_of(op)
                    .into_iter()
                    .filter(|&i| self.sim.instances[i].node == node)
                    .collect();
                let want = x[op][node] as usize;
                if have.len() < want {
                    let theta = self.launch_config(op);
                    for _ in have.len()..want {
                        // Capacity races can reject; skip silently (the next
                        // round repairs).
                        let _ = self.sim.add_instance(op, node, theta.clone());
                    }
                } else if have.len() > want {
                    // Drain the newest instances, but never the candidate-
                    // config ones mid-rollout (no-rollback semantics).
                    let cand = self.rolling[op].candidate.clone();
                    let mut surplus: Vec<usize> = have.clone();
                    surplus.sort_by_key(|&i| {
                        let is_cand =
                            cand.as_deref() == Some(&self.sim.instances[i].theta[..]);
                        (is_cand as u8, std::cmp::Reverse(i))
                    });
                    // stop non-candidate, newest-first
                    for &i in surplus.iter().take(have.len() - want) {
                        self.sim.stop_instance(i);
                    }
                }
            }
        }
    }

    /// Config for newly launched instances of `op`: the rolling current
    /// config (new instances join the old pool; the MILP's b decides
    /// transitions).
    fn launch_config(&self, op: usize) -> Vec<f64> {
        self.rolling[op].current.clone()
    }

    /// One metrics window tick: ingest metrics into every layer.
    fn ingest_window(&mut self, metrics: &[OpMetrics]) {
        let t0 = Instant::now();
        for (i, m) in metrics.iter().enumerate() {
            self.useful_time[i].observe(m);
            if self.variant.use_observation {
                self.estimators[i].observe(m, &self.backend);
            }
            // Table 3 targets the asynchronous accelerator operators —
            // useful-time estimation is exact for synchronous CPU ops and
            // averaging them in would mask the effect the paper measures.
            let async_op = self.sim.spec.operators[i].kind
                == crate::config::OperatorKind::AccelAsync;
            if self.collect_mape && m.records_out > 0 && async_op {
                let bank = &mut self.banks[i];
                bank.true_rate.observe(m);
                bank.ema_only.observe(m, &self.backend);
                bank.gp_raw.observe(m, &self.backend);
                bank.gp_signal.observe(m, &self.backend);
                bank.gp_full.observe(m, &self.backend);
                // Score each estimator against the isolated-profiling
                // oracle at the op's current config + workload.
                let theta = &self.rolling[i].current;
                let truth = self.sim.true_unit_rate(i, theta);
                if truth > 1e-6 {
                    let score = |name: &'static str, est: f64, mape: &mut HashMap<_, (f64, u64)>| {
                        let e = ((est - truth) / truth).abs() * 100.0;
                        let ent = mape.entry(name).or_insert((0.0, 0));
                        ent.0 += e.min(300.0);
                        ent.1 += 1;
                    };
                    let (e1, _) = self.banks[i].ema_only.estimate(m, &self.backend);
                    let (e2, _) = self.banks[i].gp_raw.estimate(m, &self.backend);
                    let (e3, _) = self.banks[i].gp_signal.estimate(m, &self.backend);
                    let (e4, _) = self.banks[i].gp_full.estimate(m, &self.backend);
                    let tr = self.banks[i].true_rate.estimate();
                    score("true_rate", tr, &mut self.mape);
                    score("ema", e1, &mut self.mape);
                    score("gp_raw", e2, &mut self.mape);
                    score("gp_signal", e3, &mut self.mape);
                    score("gp_two_stage", e4, &mut self.mape);
                }
            }
        }
        self.obs_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        let t1 = Instant::now();
        for (i, ad) in self.adaptation.iter_mut().enumerate() {
            if let Some(ad) = ad {
                ad.ingest(&metrics[i]);
                // Probe evaluation (see module docs): synthesize one probe
                // measurement per window while a tuning job is active.
                if let Some(theta) = ad.probe_request(&self.backend) {
                    let (ut, mem, oom) = probe_measure(&self.sim, i, &theta);
                    ad.probe_result(ut, mem, oom);
                    if oom {
                        // The probe crash costs a real instance restart.
                        if let Some(&victim) = self.sim.instances_of(i).first() {
                            let cur = self.sim.instances[victim].theta.clone();
                            self.sim.restart_with_config(victim, cur);
                            self.sim.oom_events_total[i] += 1;
                            self.sim.oom_downtime_s[i] += self.sim.spec.operators[i].cold_s;
                        }
                    }
                }
                // Collect clustering evaluation samples.
                if self.cluster_eval.len() <= i {
                    self.cluster_eval.resize_with(i + 1, || (Vec::new(), Vec::new()));
                }
                for (f, truth) in &metrics[i].cluster_samples {
                    // Re-assign for evaluation only (cheap): nearest centroid.
                    let assigned = ad
                        .clustering
                        .clusters
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| {
                            let da: f64 = a.centroid.iter().zip(f).map(|(x, y)| (x - y) * (x - y)).sum();
                            let db: f64 = b.centroid.iter().zip(f).map(|(x, y)| (x - y) * (x - y)).sum();
                            da.partial_cmp(&db).unwrap()
                        })
                        .map(|(idx, _)| idx)
                        .unwrap_or(0);
                    self.cluster_eval[i].0.push(assigned);
                    self.cluster_eval[i].1.push(*truth);
                }
            }
        }
        self.adapt_ms.push(t1.elapsed().as_secs_f64() * 1e3);

        // Deployed-config OOM safety fallback: repeated OOMs on the live
        // config revert the operator to its default configuration.
        for (i, m) in metrics.iter().enumerate() {
            self.recent_ooms[i] = self.recent_ooms[i] / 2 + m.oom_events;
            if self.recent_ooms[i] >= 2 {
                let default = self.sim.spec.operators[i].config_space.default_config();
                if !default.is_empty() && self.rolling[i].current != default {
                    for inst in self.sim.instances_of(i) {
                        self.sim.restart_with_config(inst, default.clone());
                    }
                    self.rolling[i] = RollingState::new(default, self.sim.instances_of(i).len() as u32);
                    self.estimators[i].invalidate();
                    self.recent_ooms[i] = 0;
                }
            }
        }
    }

    /// Current capacity estimates for the scheduler (per-op records/s per
    /// instance), from whichever observation path the variant uses.
    fn current_rates(&self, metrics: &[OpMetrics]) -> Vec<f64> {
        let use_obs = match self.variant.policy {
            Policy::Trident => self.variant.use_observation,
            _ => self.variant.shared_observation,
        };
        (0..self.sim.spec.n_ops())
            .map(|i| {
                if use_obs {
                    let (e, _) = self.estimators[i].estimate(&metrics[i], &self.backend);
                    e
                } else {
                    self.useful_time[i].estimate().max(1e-6)
                }
            })
            .collect()
    }

    /// One scheduling round (Algorithm 2).
    fn schedule_round(&mut self, metrics: &[OpMetrics]) {
        let rates = self.current_rates(metrics);
        let n = self.sim.spec.n_ops();

        // Forward adaptation recommendations into rolling state.
        let adapt_on = self.variant.use_adaptation
            && (self.variant.policy == Policy::Trident || self.variant.shared_adaptation);
        if adapt_on {
            for i in 0..n {
                // Anti-thrash cooldown: when workload clusters alternate in
                // dominance (queues hold a regime mix), back-to-back
                // re-transitions would pay restart cost every round.  A new
                // transition may start at most once per cooldown window.
                let cooldown_ok = self.sim.now()
                    >= self.last_transition_t[i] + 3.0 * self.cfg.t_sched_s;
                if !cooldown_ok && !self.rolling[i].in_transition() {
                    continue;
                }
                if let Some(ad) = &self.adaptation[i] {
                    if let Some(rec) = ad.recommendation() {
                        let fresh = self.rolling[i].offer(rec.config, rec.ut_cand);
                        if fresh && std::env::var("TRIDENT_DEBUG").is_ok() {
                            eprintln!(
                                "[{:.0}s] op{} candidate accepted: ut_cand={:.2}",
                                self.sim.now(), i, rec.ut_cand
                            );
                        }
                    } else if std::env::var("TRIDENT_DEBUG").is_ok() {
                        eprintln!(
                            "[{:.0}s] op{}: no recommendation (tuning={}, clusters={})",
                            self.sim.now(), i, ad.is_tuning(), ad.clustering.n_clusters()
                        );
                    }
                }
            }
        }

        match self.variant.policy {
            Policy::Static | Policy::Scoot => { /* never re-plan */ }
            Policy::RayData => {
                let cur_p: Vec<u32> =
                    (0..n).map(|i| self.sim.instances_of(i).len() as u32).collect();
                let p = self.raydata.step(&self.sim.spec, metrics, &cur_p);
                let x = pack(&self.sim.spec, &self.sim.cluster, &p);
                self.apply_placement(&x);
                self.apply_all_at_once_transitions(adapt_on);
            }
            Policy::Ds2 => {
                let p = crate::baselines::waterfall(&self.sim.spec, &self.sim.cluster, &rates, 1.05);
                let x = pack(&self.sim.spec, &self.sim.cluster, &p);
                self.apply_placement(&x);
                self.apply_all_at_once_transitions(adapt_on);
            }
            Policy::ContTune => {
                let cur_p: Vec<u32> =
                    (0..n).map(|i| self.sim.instances_of(i).len() as u32).collect();
                let p = self.conttune.step(
                    &self.sim.spec,
                    &rates,
                    metrics,
                    &cur_p,
                    self.last_throughput,
                );
                let x = pack(&self.sim.spec, &self.sim.cluster, &p);
                self.apply_placement(&x);
                self.apply_all_at_once_transitions(adapt_on);
            }
            Policy::Trident => {
                let cand: Vec<Option<(f64, ())>> = (0..n)
                    .map(|i| {
                        self.rolling[i]
                            .in_transition()
                            .then(|| (self.rolling[i].ut_cand, ()))
                    })
                    .collect();
                let input = self.milp_input(&rates, &cand);
                let t0 = Instant::now();
                let plan =
                    scheduling::solve(&input, Duration::from_millis(self.cfg.milp_time_budget_ms));
                self.milp_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                if plan.t_pred <= 0.0 {
                    return; // keep the previous feasible plan (paper §7)
                }
                self.apply_placement(&plan.x);
                if self.variant.placement_aware {
                    for (i, m) in plan.route.iter().enumerate() {
                        self.sim.set_route(i, Some(m.clone()));
                    }
                }
                // Rolling transitions: restart b_i old-config instances.
                if std::env::var("TRIDENT_DEBUG").is_ok() {
                    eprintln!(
                        "[{:.0}s] plan: T={:.2} p={:?} b={:?}",
                        self.sim.now(), plan.t_pred, plan.p, plan.b
                    );
                    for (i, o) in input.ops.iter().enumerate() {
                        if o.ut_cand.is_some() || self.sim.spec.operators[i].tunable {
                            eprintln!(
                                "    op{i} {}: ut_cur={:.2} ut_cand={:?} n_old={} n_new={} util={:.2}",
                                o.name, o.ut_cur, o.ut_cand, o.n_old, o.n_new,
                                metrics[i].utilization
                            );
                        }
                    }
                }
                for i in 0..n {
                    let b = plan.b[i];
                    if b > 0 {
                        self.start_transition(i, b);
                    }
                    let p_now = self.sim.instances_of(i).len() as u32;
                    if b > 0 {
                        self.rolling[i].apply_round(b, p_now);
                    } else {
                        self.rolling[i].sync_count(p_now);
                    }
                }
            }
        }
        self.last_throughput = metrics
            .iter()
            .last()
            .map(|m| m.records_out as f64 / m.window_s)
            .unwrap_or(0.0);
    }

    /// Restart `b` old-config instances of op `i` with the candidate
    /// config, invalidating observation samples (path ⑨) once per
    /// transition.
    fn start_transition(&mut self, i: usize, b: u32) {
        let Some(cand) = self.rolling[i].candidate.clone() else { return };
        let old: Vec<usize> = self
            .sim
            .instances_of(i)
            .into_iter()
            .filter(|&id| self.sim.instances[id].theta == self.rolling[i].current)
            .take(b as usize)
            .collect();
        for id in &old {
            self.sim.restart_with_config(*id, cand.clone());
        }
        if !old.is_empty() && !self.invalidated[i] {
            self.estimators[i].invalidate();
            self.invalidated[i] = true;
            self.transitions += 1;
            self.last_transition_t[i] = self.sim.now();
        }
        if !self.rolling[i].in_transition() {
            self.invalidated[i] = false;
        }
    }

    /// All-at-once transition application for baselines (RQ2 protocol) and
    /// the w/o-rolling ablation.
    fn apply_all_at_once_transitions(&mut self, adapt_on: bool) {
        if !adapt_on {
            return;
        }
        for i in 0..self.sim.spec.n_ops() {
            if self.rolling[i].in_transition() {
                let cand = self.rolling[i].candidate.clone().unwrap();
                let insts = self.sim.instances_of(i);
                let n_inst = insts.len() as u32;
                for id in insts {
                    self.sim.restart_with_config(id, cand.clone());
                }
                self.rolling[i].apply_round(n_inst, n_inst);
                self.estimators[i].invalidate();
                self.transitions += 1;
                self.last_transition_t[i] = self.sim.now();
            }
        }
    }

    /// Drive the closed loop until the input trace is fully processed
    /// (the paper's offline paradigm: fixed dataset, fastest finish wins)
    /// or `max_s` elapses.  Throughput = items / completion time.
    pub fn run_to_completion(&mut self, max_s: f64) -> RunReport {
        if self.sim.instances.is_empty() {
            self.deploy_initial();
        }
        let mut t = self.sim.now();
        let end = t + max_s;
        let mut next_sched = t + self.cfg.t_sched_s;
        while t < end && !self.sim.drained() {
            t = (t + self.cfg.metrics_interval_s).min(end);
            self.sim.run_until(t);
            let (metrics, out) = self.sim.flush_metrics();
            let thr = out as f64 / self.sim.d_o / self.cfg.metrics_interval_s;
            self.series.push((t, thr));
            self.ingest_window(&metrics);
            self.last_metrics = Some(metrics);
            if t >= next_sched && !self.sim.drained() {
                next_sched = t + self.cfg.t_sched_s;
                let m = self.last_metrics.take().unwrap();
                self.schedule_round(&m);
                self.last_metrics = Some(m);
            }
        }
        self.report(self.sim.now())
    }

    /// Drive the closed loop for `duration_s` simulated seconds.
    pub fn run(&mut self, duration_s: f64) -> RunReport {
        if self.sim.instances.is_empty() {
            self.deploy_initial();
        }
        let mut t = self.sim.now();
        let end = t + duration_s;
        let mut next_sched = t + self.cfg.t_sched_s;
        while t < end {
            t = (t + self.cfg.metrics_interval_s).min(end);
            self.sim.run_until(t);
            let (metrics, out) = self.sim.flush_metrics();
            let thr = out as f64 / self.sim.d_o / self.cfg.metrics_interval_s;
            self.series.push((t, thr));
            self.ingest_window(&metrics);
            self.last_metrics = Some(metrics);
            if t >= next_sched {
                next_sched = t + self.cfg.t_sched_s;
                let m = self.last_metrics.take().unwrap();
                self.schedule_round(&m);
                self.last_metrics = Some(m);
            }
        }
        self.report(duration_s)
    }

    fn report(&self, duration_s: f64) -> RunReport {
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        RunReport {
            pipeline: self.sim.spec.name.clone(),
            variant: self.variant.policy.name().to_string(),
            duration_s,
            throughput: self.sim.avg_throughput(),
            series: self.series.clone(),
            oom_events: self.sim.oom_events_total.iter().sum(),
            oom_downtime_s: self.sim.oom_downtime_s.iter().sum(),
            config_transitions: self.transitions,
            milp_ms: self.milp_ms.clone(),
            obs_overhead_ms: mean(&self.obs_ms),
            adapt_overhead_ms: mean(&self.adapt_ms),
            estimator_mape: self
                .mape
                .iter()
                .map(|(&k, &(s, n))| (k, if n > 0 { s / n as f64 } else { 0.0 }))
                .collect(),
            cluster_eval: self.cluster_eval.clone(),
            items_processed: self.sim.out_records,
        }
    }
}

/// Synthesized probe measurement: what a dedicated probe instance would
/// report after a sustained evaluation window at config θ (ground-truth
/// service model + measurement noise; OOM when the noisy peak crosses the
/// device limit).
fn probe_measure(sim: &PipelineSim, op: usize, theta: &[f64]) -> (f64, f64, bool) {
    let attrs = sim.mean_attrs(op).unwrap_or(ItemAttrs {
        tokens_in: 512.0,
        tokens_out: 64.0,
        pixels_m: 1.0,
        frames: 1.0,
    });
    let o = &sim.spec.operators[op];
    // Deterministic per-(op, theta) noise so repeated probes agree.
    let mut h = 0u64;
    for &v in theta {
        h = h.wrapping_mul(31).wrapping_add(v.to_bits());
    }
    let mut rng = crate::rngx::Rng::new(h ^ (op as u64) << 32 ^ sim.now().to_bits());
    let ut = crate::sim::service::true_unit_rate(&o.service, theta, &attrs)
        * rng.lognormal(0.0, 0.05);
    // Peak-of-window telemetry (NVML-style max), not the mean: a sustained
    // evaluation sees the upper tail of the allocator noise, which is what
    // the memory surrogate must learn to stay OOM-safe after deployment.
    let peak_factor = (2.0 * 0.03f64).exp();
    let mem = crate::sim::service::expected_mem(&o.service, theta, &attrs)
        * rng.lognormal(0.02, 0.03)
        * peak_factor;
    let cap = sim.cluster.nodes[0].accel_mem_mb;
    (ut, mem, mem > cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::pdf;

    fn mini_cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0)
    }

    fn mk(variant: Variant, seed: u64) -> Coordinator {
        let mut cfg = TridentConfig::default();
        cfg.native_gp = true;
        cfg.milp_time_budget_ms = 1500;
        cfg.tune_trigger = 32;
        cfg.bo_budget = 10;
        cfg.bo_init = 4;
        let trace = Box::new(pdf::trace(100_000));
        let src = crate::sim::ItemAttrs {
            tokens_in: 36_000.0,
            tokens_out: 7_200.0,
            pixels_m: 12.0,
            frames: 12.0,
        };
        Coordinator::new(pdf::pipeline(), mini_cluster(), trace, cfg, variant, src, seed)
    }

    #[test]
    fn static_deploys_and_flows() {
        let mut c = mk(Variant::baseline(Policy::Static), 1);
        let r = c.run(400.0);
        assert!(r.throughput > 0.0, "static must process documents: {r:?}");
        assert!(r.items_processed > 0);
        // all accel ops placed
        for i in 0..c.sim.spec.n_ops() {
            if c.sim.spec.operators[i].accels > 0 {
                assert!(!c.sim.instances_of(i).is_empty(), "op {i} placed");
            }
        }
    }

    #[test]
    fn trident_beats_nothing_crashes_and_schedules() {
        let mut c = mk(Variant::trident(), 2);
        let r = c.run(400.0);
        assert!(r.throughput > 0.0);
        assert!(!r.milp_ms.is_empty(), "trident must re-solve the MILP");
    }

    #[test]
    fn raydata_reacts() {
        let mut c = mk(Variant::baseline(Policy::RayData), 3);
        let r = c.run(400.0);
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn ds2_runs() {
        let mut c = mk(Variant::baseline(Policy::Ds2), 4);
        let r = c.run(400.0);
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn nominal_attrs_propagate_scaling() {
        let pl = pdf::pipeline();
        let src = crate::sim::ItemAttrs {
            tokens_in: 36_000.0,
            tokens_out: 7_200.0,
            pixels_m: 12.0,
            frames: 12.0,
        };
        let nom = nominal_attrs(&pl, src);
        let ocr = pl.operators.iter().position(|o| o.name == "text_ocr").unwrap();
        // per-block tokens at the OCR stage = 36000 / 120 = 300
        assert!((nom[ocr].tokens_in - 300.0).abs() < 1.0, "{}", nom[ocr].tokens_in);
    }
}
