//! The Trident coordinator: the closed control loop of Figure 1.
//!
//! Wires the pipeline executor (simulator), metrics collector, observation
//! layer, adaptation layer, and scheduling layer together — including
//! paths ⑧ (plan application) and ⑨ (sample invalidation on configuration
//! transitions).  The loop itself is policy-agnostic: every scheduler in
//! the evaluation (Trident's MILP and all baselines) implements the
//! [`SchedulingPolicy`] trait and is applied through the same
//! plan-application path, so comparisons differ only in policy.
//!
//! Module family (see `DESIGN.md`):
//! * [`policy`] — the [`SchedulingPolicy`] trait, [`PolicyCtx`] /
//!   [`Plan`], and the Static / SCOOT / Trident implementations
//!   (Ray Data, DS2, ContTune live in [`crate::baselines`]);
//! * [`ingest`] — per-window metrics ingestion, the Table-3
//!   `EstimatorBank` MAPE lattice, and BO probe evaluation;
//! * [`transition`] — initial deployment, placement application, rolling
//!   updates + sample invalidation (path ⑨), and the OOM safety fallback;
//! * [`report`] — [`RunReport`] assembly.
//!
//! One deliberate simulation shortcut (DESIGN.md): BO probe evaluations are
//! measured against the operator's ground-truth service model plus
//! measurement noise instead of occupying a live instance for the full
//! evaluation window.  The measured quantity is identical to what a probe
//! instance would report; a probe OOM still costs real downtime (one live
//! instance is cold-restarted) so Table 6's downtime is honest.

mod ingest;
pub mod policy;
pub mod report;
mod transition;

#[cfg(test)]
mod tests;

pub use policy::{Plan, Policy, PolicyCtx, SchedulingPolicy, TransitionCmd, Variant};
pub use report::{RunReport, TenantReport};

use std::collections::HashMap;

use crate::adaptation::OperatorAdaptation;
use crate::config::{ClusterSpec, PipelineSpec, Tenancy, TridentConfig};
use crate::dynamics::{ClusterEvent, DynamicsSpec, EventReport, RecoveryPolicy, TimedEvent};
use crate::observation::{CapacityEstimator, ObsConfig, UsefulTimeEstimator};
use crate::runtime::GpBackend;
use crate::scheduling::RollingState;
use crate::sim::{ItemAttrs, OpMetrics, ShardedSim};
use crate::workload::Trace;

use ingest::EstimatorBank;

/// The coordinator.
pub struct Coordinator {
    /// The executor: K tenant-shards behind the serial API, bit-identical
    /// to the serial executor at any `cfg.sim_shards` (1 = serial path).
    pub sim: ShardedSim,
    pub cfg: TridentConfig,
    pub variant: Variant,
    backend: GpBackend,
    /// Main estimator per op (the one the scheduler consumes).
    estimators: Vec<CapacityEstimator>,
    useful_time: Vec<UsefulTimeEstimator>,
    /// Table-3 lattice (only when `collect_mape`).
    banks: Vec<EstimatorBank>,
    pub collect_mape: bool,
    mape: HashMap<&'static str, (f64, u64)>,
    adaptation: Vec<Option<OperatorAdaptation>>,
    rolling: Vec<RollingState>,
    /// The active scheduler (trait object — replaces the old inline
    /// per-policy match arms and per-baseline fields).
    policy: Box<dyn SchedulingPolicy>,
    /// Whether the op has had its samples invalidated for the current
    /// transition already.
    invalidated: Vec<bool>,
    /// Deployed-config OOM safety fallback bookkeeping.
    recent_ooms: Vec<u32>,
    milp_ms: Vec<f64>,
    obs_ms: Vec<f64>,
    adapt_ms: Vec<f64>,
    transitions: u64,
    series: Vec<(f64, f64)>,
    cluster_eval: Vec<(Vec<usize>, Vec<u8>)>,
    nominal: Vec<ItemAttrs>,
    last_metrics: Option<Vec<OpMetrics>>,
    last_throughput: f64,
    /// Per-op wall of the last committed transition (anti-thrash cooldown).
    last_transition_t: Vec<f64>,
    /// Seed the coordinator was built with (dynamics timeline sampling).
    seed: u64,
    /// Cluster-dynamics spec (`None` = static cluster and tenancy — the
    /// classic pre-dynamics closed loop, bit-for-bit).
    dynamics: Option<DynamicsSpec>,
    /// The generated event timeline (built lazily on the first drive
    /// call, when the horizon is known) and the cursor into it.
    timeline: Vec<TimedEvent>,
    timeline_built: bool,
    next_event: usize,
    /// A topology/tenancy event awaits its event-driven re-plan: the
    /// next metrics window triggers an immediate scheduling round
    /// instead of waiting out the periodic `t_sched_s` timer.
    replan_pending: bool,
    /// Per-event recovery metrics (reported in `RunReport::events`) and
    /// the consecutive-recovered-window streak behind `recovered_s`.
    event_reports: Vec<EventReport>,
    recovery_streak: Vec<u32>,
}

/// Propagate a source item's mean attrs through the pipeline's child
/// scalings to get nominal per-op attrs (used for the Static plan).
///
/// Runs over the DAG in topological order: an operator inherits its
/// predecessor's scaled attrs; a join sees the merge of its branches
/// (token loads accumulate, spatial extents take the max — mirroring the
/// executor's `merge_group`).  For a chain this is the old sequential
/// propagation.
pub fn nominal_attrs(pipeline: &PipelineSpec, source: ItemAttrs) -> Vec<ItemAttrs> {
    nominal_attrs_rooted(pipeline, &[(0, source)])
}

/// Multi-root variant of [`nominal_attrs`] for merged tenancies: each
/// tenant's source operator gets its own nominal source attrs, and the
/// propagation stays within each tenant's (disjoint) DAG.
pub fn nominal_attrs_rooted(
    pipeline: &PipelineSpec,
    roots: &[(usize, ItemAttrs)],
) -> Vec<ItemAttrs> {
    let scale = |a: ItemAttrs, s: [f64; 4]| ItemAttrs {
        tokens_in: a.tokens_in * s[0],
        tokens_out: a.tokens_out * s[1],
        pixels_m: a.pixels_m * s[2],
        frames: a.frames * s[3],
    };
    let fallback = roots
        .first()
        .map(|&(_, a)| a)
        .unwrap_or(ItemAttrs { tokens_in: 512.0, tokens_out: 64.0, pixels_m: 1.0, frames: 1.0 });
    let mut out = vec![fallback; pipeline.n_ops()];
    for &v in &pipeline.topo_order() {
        let preds = pipeline.in_edges(v);
        match preds.len() {
            0 => {
                out[v] = roots
                    .iter()
                    .find(|&&(r, _)| r == v)
                    .map(|&(_, a)| a)
                    .unwrap_or(fallback)
            }
            1 => {
                let u = pipeline.edges[preds[0]].0;
                out[v] = scale(out[u], pipeline.operators[u].child_scale);
            }
            _ => {
                let mut merged: Option<ItemAttrs> = None;
                for &e in &preds {
                    let u = pipeline.edges[e].0;
                    let a = scale(out[u], pipeline.operators[u].child_scale);
                    merged = Some(match merged {
                        None => a,
                        Some(m) => m.merge(&a),
                    });
                }
                out[v] = merged.unwrap();
            }
        }
    }
    out
}

impl Coordinator {
    pub fn new(
        pipeline: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: ItemAttrs,
        seed: u64,
    ) -> Self {
        Self::new_tenancy(
            Tenancy::single(pipeline),
            cluster,
            vec![trace],
            cfg,
            variant,
            vec![source_attrs],
            seed,
        )
        .unwrap_or_else(|e| panic!("invalid pipeline spec: {e}"))
    }

    /// Multi-tenant constructor: N pipelines (`tenancy`) sharing `cluster`,
    /// one trace + nominal source attrs per tenant.  A single-tenant
    /// tenancy reproduces [`Coordinator::new`] event-for-event.
    pub fn new_tenancy(
        tenancy: Tenancy,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        cfg: TridentConfig,
        variant: Variant,
        source_attrs: Vec<ItemAttrs>,
        seed: u64,
    ) -> Result<Self, String> {
        let (pipeline, view) = tenancy.merged()?;
        if traces.len() != view.n_tenants() {
            return Err(format!(
                "{} traces for {} tenants",
                traces.len(),
                view.n_tenants()
            ));
        }
        if source_attrs.len() != view.n_tenants() {
            return Err(format!(
                "{} source-attr entries for {} tenants",
                source_attrs.len(),
                view.n_tenants()
            ));
        }
        let backend = if cfg.native_gp { GpBackend::Native } else { GpBackend::from_env() };
        let n = pipeline.n_ops();
        let roots: Vec<(usize, ItemAttrs)> =
            view.sources.iter().copied().zip(source_attrs).collect();
        let nominal = nominal_attrs_rooted(&pipeline, &roots);
        let estimators = pipeline
            .operators
            .iter()
            .map(|o| CapacityEstimator::new(ObsConfig::from_trident(&cfg), o.features))
            .collect();
        let useful_time = (0..n).map(|_| UsefulTimeEstimator::new()).collect();
        let banks = pipeline
            .operators
            .iter()
            .map(|o| EstimatorBank::new(&cfg, o.features))
            .collect();
        let adaptation = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                if o.tunable && variant.use_adaptation {
                    let mut ad = OperatorAdaptation::new(
                        i,
                        o.config_space.clone(),
                        &cfg,
                        cluster.nodes[0].accel_mem_mb,
                        seed ^ (i as u64) << 8,
                    );
                    ad.set_strategy(variant.strategy);
                    Some(ad)
                } else {
                    None
                }
            })
            .collect();
        let rolling = pipeline
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let init = variant
                    .initial_configs
                    .as_ref()
                    .and_then(|v| v.get(i).cloned().flatten())
                    .unwrap_or_else(|| o.config_space.default_config());
                RollingState::new(init, 0)
            })
            .collect();
        let policy = variant.policy.build();
        let mut sim =
            ShardedSim::new_tenancy(pipeline, view, cluster, traces, seed, cfg.sim_shards);
        sim.set_workers(cfg.sim_workers);
        sim.set_seed_event_stream(cfg.sim_seed_event_stream);
        Ok(Coordinator {
            sim,
            cfg,
            variant,
            backend,
            estimators,
            useful_time,
            banks,
            collect_mape: false,
            mape: HashMap::new(),
            adaptation,
            rolling,
            policy,
            invalidated: vec![false; n],
            recent_ooms: vec![0; n],
            milp_ms: Vec::new(),
            obs_ms: Vec::new(),
            adapt_ms: Vec::new(),
            transitions: 0,
            series: Vec::new(),
            cluster_eval: Vec::new(),
            nominal,
            last_metrics: None,
            last_throughput: 0.0,
            last_transition_t: vec![f64::NEG_INFINITY; n],
            seed,
            dynamics: None,
            timeline: Vec::new(),
            timeline_built: false,
            next_event: 0,
            replan_pending: false,
            event_reports: Vec::new(),
            recovery_streak: Vec::new(),
        })
    }

    /// Attach a cluster-dynamics spec before the run starts.  Validates
    /// it against the deployment, holds `node_join` spares offline, and
    /// puts arriving tenants to sleep until their arrival events fire.
    pub fn set_dynamics(&mut self, spec: DynamicsSpec) -> Result<(), String> {
        if self.sim.has_instances() {
            return Err("set_dynamics must be called before the run starts".into());
        }
        spec.validate(self.sim.cluster.nodes.len(), &self.sim.tenancy.ids)?;
        for node in spec.joining_nodes() {
            // No instances exist yet: failing the empty node just holds
            // it down until its node_join event.
            self.sim.fail_node(node, true);
        }
        for id in spec.arriving_tenants() {
            let t = self
                .sim
                .tenancy
                .ids
                .iter()
                .position(|i| *i == id)
                .expect("validated tenant id");
            self.sim.set_tenant_active(t, false);
        }
        self.dynamics = Some(spec);
        self.timeline_built = false;
        self.next_event = 0;
        Ok(())
    }

    /// Tenants the scheduler should still plan for: active ones, plus
    /// departed ones that have admitted items in flight (their operators
    /// are reclaimed only once they drain).  All-true absent dynamics.
    fn tenant_live(&self) -> Vec<bool> {
        (0..self.sim.tenancy.n_tenants())
            .map(|t| self.sim.tenants_active()[t] || !self.sim.tenant_drained(t))
            .collect()
    }

    /// Mean windowed throughput over the most recent metrics windows —
    /// the pre-event reference level for recovery tracking.
    fn recent_throughput(&self) -> f64 {
        let n = self.series.len().min(6);
        if n == 0 {
            return 0.0;
        }
        self.series[self.series.len() - n..].iter().map(|&(_, v)| v).sum::<f64>() / n as f64
    }

    /// Apply one timeline event to the executor and control state: kill /
    /// revive capacity, splice tenants, invalidate observation samples of
    /// the affected operators (the paper's sample-invalidation rule
    /// extended to topology changes), re-sync rolling books (failed
    /// instances are already-stopped — no cold-start charge for capacity
    /// that no longer exists), and arm the event-driven re-plan.
    fn apply_event(&mut self, te: &TimedEvent) {
        let requeue = self
            .dynamics
            .as_ref()
            .map(|d| d.recovery == RecoveryPolicy::Requeue)
            .unwrap_or(true);
        let mut lost = 0u64;
        let label = match &te.event {
            ClusterEvent::NodeFail { node } => {
                // Includes Draining instances (the crash kills those too,
                // unlike placement()), so their ops are invalidated as
                // well.
                let affected = self.sim.ops_on_node(*node);
                lost = self.sim.fail_node(*node, requeue);
                for &i in &affected {
                    self.estimators[i].invalidate();
                    let live = self.sim.instances_of(i).len() as u32;
                    self.rolling[i].on_capacity_loss(live);
                }
                format!("node_fail(node {node})")
            }
            ClusterEvent::NodeRecover { node } => {
                self.sim.set_node_up(*node);
                format!("node_recover(node {node})")
            }
            ClusterEvent::NodeJoin { node } => {
                self.sim.set_node_up(*node);
                format!("node_join(node {node})")
            }
            ClusterEvent::TenantArrive { tenant } => {
                if let Some(t) = self.sim.tenancy.ids.iter().position(|i| i == tenant) {
                    self.sim.set_tenant_active(t, true);
                }
                format!("tenant_arrive({tenant})")
            }
            ClusterEvent::TenantDepart { tenant } => {
                if let Some(t) = self.sim.tenancy.ids.iter().position(|i| i == tenant) {
                    self.sim.set_tenant_active(t, false);
                }
                format!("tenant_depart({tenant})")
            }
            ClusterEvent::BandwidthDegrade { node, factor } => {
                self.sim.set_bandwidth_factor(*node, *factor);
                // The node's egress feeds these ops' downstream windows;
                // their samples are stale now.
                for i in self.sim.ops_on_node(*node) {
                    self.estimators[i].invalidate();
                }
                format!("bandwidth_degrade(node {node}, x{factor})")
            }
            ClusterEvent::BandwidthRestore { node } => {
                self.sim.set_bandwidth_factor(*node, 1.0);
                // Symmetric with the degrade arm: windows observed while
                // the link was squeezed are just as stale now.
                for i in self.sim.ops_on_node(*node) {
                    self.estimators[i].invalidate();
                }
                format!("bandwidth_restore(node {node})")
            }
        };
        self.event_reports.push(EventReport {
            at_s: te.at_s,
            label,
            baseline_thr: self.recent_throughput(),
            replan_s: None,
            recovered_s: None,
            lost_records: lost,
        });
        self.recovery_streak.push(0);
        self.replan_pending = true;
    }

    /// Per-window recovery tracking: an event counts as recovered once
    /// windowed throughput sustains >= 90% of its pre-event baseline for
    /// two consecutive windows (one noisy window must not declare
    /// victory).
    fn track_recovery(&mut self, t: f64, thr: f64) {
        for (ev, streak) in self.event_reports.iter_mut().zip(&mut self.recovery_streak) {
            // No pre-event traffic ⇒ no baseline to recover to: leave
            // recovered_s undefined instead of declaring instant victory
            // against a zero threshold.
            if ev.recovered_s.is_some() || t <= ev.at_s || ev.baseline_thr <= 0.0 {
                continue;
            }
            if thr >= 0.9 * ev.baseline_thr {
                *streak += 1;
                if *streak >= 2 {
                    ev.recovered_s = Some(t - ev.at_s);
                }
            } else {
                *streak = 0;
            }
        }
    }

    /// Stamp time-to-replan on events whose re-plan just committed.
    fn mark_replanned(&mut self, t: f64) {
        for ev in &mut self.event_reports {
            if ev.replan_s.is_none() {
                ev.replan_s = Some((t - ev.at_s).max(0.0));
            }
        }
    }

    /// One scheduling round (Algorithm 2): estimate rates, forward
    /// adaptation recommendations into rolling state, ask the policy for a
    /// plan, and apply it through the shared path ⑧.  Returns whether the
    /// policy actually produced a plan (placement/routes/transitions) —
    /// a `Plan::keep` from Static is a round, not a re-plan.
    fn schedule_round(&mut self, metrics: &[OpMetrics]) -> bool {
        let rates = self.current_rates(metrics);
        let adapt_on = self.forward_recommendations();
        let placement = self.sim.placement();
        // A departed tenant stays schedulable until its admitted items
        // drain; only then are its operators reclaimed (excluded from the
        // plan, instances stopped).  Identity absent dynamics.
        let tenant_live = self.tenant_live();
        // Note: includes draining instances (unlike `placement()`), matching
        // what the reactive baselines have always seen as "current p".
        let cur_p: Vec<u32> = (0..self.sim.spec.n_ops())
            .map(|i| self.sim.instances_of(i).len() as u32)
            .collect();
        let plan = {
            let ctx = PolicyCtx {
                spec: &self.sim.spec,
                cluster: &self.sim.cluster,
                cfg: &self.cfg,
                variant: &self.variant,
                metrics,
                rates: &rates,
                cur_p: &cur_p,
                placement: &placement,
                rolling: &self.rolling,
                tenancy: &self.sim.tenancy,
                node_up: self.sim.nodes_up(),
                tenant_active: &tenant_live,
                last_throughput: self.last_throughput,
                now: self.sim.now(),
            };
            self.policy.plan(&ctx)
        };
        if let Some(ms) = plan.milp_ms {
            self.milp_ms.push(ms);
        }
        let acted = plan.placement.is_some()
            || plan.routes.is_some()
            || plan.transitions != TransitionCmd::None;
        if let Some(x) = &plan.placement {
            self.apply_placement(x);
        }
        if let Some(routes) = plan.routes {
            // Routing fractions are keyed by pipeline edge id.
            for (edge, m) in routes.into_iter().enumerate() {
                self.sim.set_route(edge, Some(m));
            }
        }
        match plan.transitions {
            TransitionCmd::None => {}
            TransitionCmd::AllAtOnce => self.apply_all_at_once_transitions(adapt_on),
            TransitionCmd::Rolling(b) => {
                for i in 0..self.sim.spec.n_ops() {
                    let bi = b[i];
                    if bi > 0 {
                        self.start_transition(i, bi);
                    }
                    let p_now = self.sim.instances_of(i).len() as u32;
                    if bi > 0 {
                        self.rolling[i].apply_round(bi, p_now);
                    } else {
                        self.rolling[i].sync_count(p_now);
                    }
                }
            }
        }
        self.last_throughput = metrics
            .iter()
            .last()
            .map(|m| m.records_out as f64 / m.window_s)
            .unwrap_or(0.0);
        acted
    }

    /// The closed drive loop shared by [`run`](Coordinator::run) and
    /// [`run_to_completion`](Coordinator::run_to_completion): advance the
    /// simulator one metrics window at a time, ingest, and re-schedule
    /// every `t_sched_s`.
    fn drive(&mut self, max_s: f64, until_drained: bool) -> RunReport {
        if !self.sim.has_instances() {
            self.deploy_initial();
        }
        let mut t = self.sim.now();
        let end = t + max_s;
        if !self.timeline_built {
            if let Some(spec) = &self.dynamics {
                self.timeline =
                    spec.timeline(self.sim.cluster.nodes.len(), end, self.seed ^ 0x7472_6964);
            }
            self.timeline_built = true;
        }
        let mut next_sched = t + self.cfg.t_sched_s;
        while t < end
            && !(until_drained
                && self.sim.drained()
                && self.next_event >= self.timeline.len())
        {
            t = (t + self.cfg.metrics_interval_s).min(end);
            // Inject timeline events at their exact sim timestamps inside
            // this window: advance the executor to the event time, apply,
            // continue.
            while self.next_event < self.timeline.len()
                && self.timeline[self.next_event].at_s <= t
            {
                let te = self.timeline[self.next_event].clone();
                self.next_event += 1;
                self.sim.run_until(te.at_s);
                self.apply_event(&te);
            }
            self.sim.run_until(t);
            let (metrics, outs) = self.sim.flush_metrics();
            // Aggregate windowed throughput: per-tenant outputs scaled to
            // input items each (a single-element sum for one tenant).
            let thr = outs
                .iter()
                .zip(&self.sim.tenancy.d_o)
                .map(|(&o, &d)| o as f64 / d)
                .sum::<f64>()
                / self.cfg.metrics_interval_s;
            self.series.push((t, thr));
            self.track_recovery(t, thr);
            self.ingest_window(&metrics);
            self.last_metrics = Some(metrics);
            // Event-driven re-plan: a topology/tenancy event re-plans at
            // the very next metrics window (within one
            // `metrics_interval_s` of the event) instead of waiting out
            // the periodic timer.
            let due = t >= next_sched || self.replan_pending;
            if due && !(until_drained && self.sim.drained()) {
                next_sched = t + self.cfg.t_sched_s;
                let m = self.last_metrics.take().unwrap();
                let acted = self.schedule_round(&m);
                self.last_metrics = Some(m);
                if acted {
                    // `replan_s` means "a plan was committed", not "a
                    // round ran": Static's keep-everything rounds leave
                    // its events unstamped (reported as never re-planned).
                    self.mark_replanned(t);
                }
                self.replan_pending = false;
            }
        }
        let duration = if until_drained { self.sim.now() } else { max_s };
        self.report(duration)
    }

    /// Drive the closed loop until the input trace is fully processed
    /// (the paper's offline paradigm: fixed dataset, fastest finish wins)
    /// or `max_s` elapses.  Throughput = items / completion time.
    pub fn run_to_completion(&mut self, max_s: f64) -> RunReport {
        self.drive(max_s, true)
    }

    /// Drive the closed loop for `duration_s` simulated seconds.
    pub fn run(&mut self, duration_s: f64) -> RunReport {
        self.drive(duration_s, false)
    }
}
