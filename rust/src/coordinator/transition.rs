//! Transition-side of the closed loop: initial deployment, placement
//! application (instance start/drain), rolling configuration updates with
//! sample invalidation (path ⑨), the all-at-once transition path used by
//! baselines and the w/o-rolling ablation, and the deployed-config OOM
//! safety fallback.

use std::time::Duration;

use crate::baselines::pack;
use crate::config::Json;
use crate::scheduling::{self, RollingState};
use crate::sim::OpMetrics;

use super::policy::{self, Policy, PolicyCtx};
use super::Coordinator;

impl Coordinator {
    /// Nominal per-instance rate for the Static plan ("manual tuning"):
    /// the default-config capacity at the first regime's expected load.
    fn nominal_rates(&self) -> Vec<f64> {
        self.sim
            .spec
            .operators
            .iter()
            .enumerate()
            .map(|(i, o)| {
                crate::sim::service::true_unit_rate(
                    &o.service,
                    &self.rolling[i].current,
                    &self.nominal[i],
                )
            })
            .collect()
    }

    /// Initial deployment shared by every policy: one-shot MILP on nominal
    /// rates (the "manually tuned" allocation), restricted to the live
    /// node/tenant set (a `node_join` spare starts empty, an arriving
    /// tenant starts dormant).
    pub fn deploy_initial(&mut self) {
        let rates = self.nominal_rates();
        let placement = self.sim.placement();
        let cur_p: Vec<u32> = placement.iter().map(|row| row.iter().sum()).collect();
        let tenant_live = self.tenant_live();
        let (input, scope) = {
            let ctx = PolicyCtx {
                spec: &self.sim.spec,
                cluster: &self.sim.cluster,
                cfg: &self.cfg,
                variant: &self.variant,
                metrics: &[],
                rates: &rates,
                cur_p: &cur_p,
                placement: &placement,
                rolling: &self.rolling,
                tenancy: &self.sim.tenancy,
                node_up: self.sim.nodes_up(),
                tenant_active: &tenant_live,
                last_throughput: 0.0,
                now: self.sim.now(),
            };
            policy::milp_input(&ctx)
        };
        if input.ops.is_empty() || input.nodes.is_empty() {
            return; // nothing live to deploy yet
        }
        let plan = scheduling::solve(&input, Duration::from_millis(self.cfg.milp_time_budget_ms));
        let identity = scope.is_identity();
        let (x, route) = if plan.t_pred > 0.0 {
            if identity {
                (plan.x, plan.route)
            } else {
                (scope.expand_x(&plan.x), scope.expand_routes(&plan.route))
            }
        } else {
            // Fallback: greedy pack of a (tenant-aware) waterfall plan;
            // multi-tenant packs fairly so no tenant's op is zeroed out.
            // Inactive tenants get nothing and down nodes are masked out.
            let mut p = crate::baselines::waterfall_t(
                &self.sim.spec,
                &self.sim.tenancy,
                &self.sim.cluster,
                &rates,
                1.1,
            );
            for (i, pi) in p.iter_mut().enumerate() {
                if !tenant_live[self.sim.tenancy.op_tenant[i]] {
                    *pi = 0;
                }
            }
            let masked;
            let cluster = if identity {
                &self.sim.cluster
            } else {
                masked =
                    crate::baselines::masked_cluster(&self.sim.cluster, self.sim.nodes_up());
                &masked
            };
            let x = if self.sim.tenancy.n_tenants() > 1 {
                crate::baselines::pack_fair(&self.sim.spec, cluster, &p)
            } else {
                pack(&self.sim.spec, cluster, &p)
            };
            (x, Vec::new())
        };
        self.apply_placement(&x);
        if self.variant.policy == Policy::Trident && self.variant.placement_aware {
            // One routing matrix per pipeline edge (DAG-aware).
            for (edge, m) in route.iter().enumerate() {
                self.sim.set_route(edge, Some(m.clone()));
            }
        }
        for (i, rs) in self.rolling.iter_mut().enumerate() {
            rs.sync_count(x[i].iter().sum());
        }
    }

    /// Apply a placement diff: start missing instances, drain surplus.
    pub(super) fn apply_placement(&mut self, x: &[Vec<u32>]) {
        let k = self.sim.cluster.nodes.len();
        for op in 0..self.sim.spec.n_ops() {
            for node in 0..k {
                let have: Vec<usize> = self
                    .sim
                    .instances_of(op)
                    .into_iter()
                    .filter(|&i| self.sim.instance(i).node == node)
                    .collect();
                let want = x[op][node] as usize;
                if have.len() < want {
                    let theta = self.launch_config(op);
                    for _ in have.len()..want {
                        // Capacity races can reject; the next round repairs,
                        // but the flight recorder keeps the rejection.
                        if let Err(e) = self.sim.add_instance(op, node, theta.clone()) {
                            if let Some(ts) = self.trace.as_mut() {
                                let err = e.to_string();
                                ts.sim_event(
                                    self.sim.now(),
                                    "admission_error",
                                    vec![
                                        ("op", Json::str(&self.sim.spec.operators[op].name)),
                                        ("node", Json::num(node as f64)),
                                        ("error", Json::str(&err)),
                                    ],
                                );
                            }
                        }
                    }
                } else if have.len() > want {
                    // Drain the newest instances, but never the candidate-
                    // config ones mid-rollout (no-rollback semantics).
                    let cand = self.rolling[op].candidate.clone();
                    let mut surplus: Vec<usize> = have.clone();
                    surplus.sort_by_key(|&i| {
                        let is_cand =
                            cand.as_deref() == Some(&self.sim.instance(i).theta[..]);
                        (is_cand as u8, std::cmp::Reverse(i))
                    });
                    // stop non-candidate, newest-first
                    for &i in surplus.iter().take(have.len() - want) {
                        self.sim.stop_instance(i);
                    }
                }
            }
        }
    }

    /// Config for newly launched instances of `op`: the rolling current
    /// config (new instances join the old pool; the MILP's b decides
    /// transitions).
    fn launch_config(&self, op: usize) -> Vec<f64> {
        self.rolling[op].current.clone()
    }

    /// Forward adaptation recommendations into rolling state (Algorithm 2
    /// step 1).  Returns whether adaptation drives transitions this run —
    /// Trident with its own adaptation layer, or a baseline under the RQ2
    /// shared-adaptation protocol.
    pub(super) fn forward_recommendations(&mut self) -> bool {
        let adapt_on = self.variant.use_adaptation
            && (self.variant.policy == Policy::Trident || self.variant.shared_adaptation);
        if !adapt_on {
            return false;
        }
        for i in 0..self.sim.spec.n_ops() {
            // Anti-thrash cooldown: when workload clusters alternate in
            // dominance (queues hold a regime mix), back-to-back
            // re-transitions would pay restart cost every round.  A new
            // transition may start at most once per cooldown window.
            let cooldown_ok =
                self.sim.now() >= self.last_transition_t[i] + 3.0 * self.cfg.t_sched_s;
            if !cooldown_ok && !self.rolling[i].in_transition() {
                continue;
            }
            if let Some(ad) = &self.adaptation[i] {
                if let Some(rec) = ad.recommendation() {
                    let fresh = self.rolling[i].offer(rec.config, rec.ut_cand);
                    if fresh && std::env::var("TRIDENT_DEBUG").is_ok() {
                        eprintln!(
                            "[{:.0}s] op{} candidate accepted: ut_cand={:.2}",
                            self.sim.now(),
                            i,
                            rec.ut_cand
                        );
                    }
                } else if std::env::var("TRIDENT_DEBUG").is_ok() {
                    eprintln!(
                        "[{:.0}s] op{}: no recommendation (tuning={}, clusters={})",
                        self.sim.now(),
                        i,
                        ad.is_tuning(),
                        ad.clustering.n_clusters()
                    );
                }
            }
        }
        true
    }

    /// Restart `b` old-config instances of op `i` with the candidate
    /// config, invalidating observation samples (path ⑨) once per
    /// transition.
    pub(super) fn start_transition(&mut self, i: usize, b: u32) {
        let Some(cand) = self.rolling[i].candidate.clone() else { return };
        let old: Vec<usize> = self
            .sim
            .instances_of(i)
            .into_iter()
            .filter(|&id| self.sim.instance(id).theta == self.rolling[i].current)
            .take(b as usize)
            .collect();
        for id in &old {
            self.sim.restart_with_config(*id, cand.clone());
        }
        if !old.is_empty() {
            if let Some(ts) = self.trace.as_mut() {
                ts.sim_event(
                    self.sim.now(),
                    "rolling_wave",
                    vec![
                        ("op", Json::str(&self.sim.spec.operators[i].name)),
                        ("batch", Json::num(old.len() as f64)),
                        ("cold_s", Json::num(self.sim.spec.operators[i].cold_s)),
                    ],
                );
            }
        }
        if !old.is_empty() && !self.invalidated[i] {
            self.estimators[i].invalidate();
            self.invalidate_downstream_joins(i);
            self.invalidated[i] = true;
            self.transitions += 1;
            self.last_transition_t[i] = self.sim.now();
            if let Some(ts) = self.trace.as_mut() {
                ts.sim_event(
                    self.sim.now(),
                    "invalidation",
                    vec![
                        ("op", Json::str(&self.sim.spec.operators[i].name)),
                        ("reason", Json::str("transition")),
                    ],
                );
            }
        }
        if !self.rolling[i].in_transition() {
            self.invalidated[i] = false;
        }
    }

    /// Path ⑨, per-edge extension for DAGs: a transition at `i` also
    /// invalidates the samples of any join fed directly by one of `i`'s
    /// out-edges.  A join's window rates depend on how its branch arrivals
    /// interleave, and the transition just changed that interleaving; on a
    /// chain no operator is a join, so this is a no-op there.
    fn invalidate_downstream_joins(&mut self, i: usize) {
        let succs: Vec<usize> = self
            .sim
            .spec
            .out_edges(i)
            .into_iter()
            .map(|e| self.sim.spec.edges[e].1)
            .filter(|&v| self.sim.spec.is_join(v))
            .collect();
        for v in succs {
            self.estimators[v].invalidate();
        }
    }

    /// All-at-once transition application for baselines (RQ2 protocol) and
    /// the w/o-rolling ablation.
    pub(super) fn apply_all_at_once_transitions(&mut self, adapt_on: bool) {
        if !adapt_on {
            return;
        }
        for i in 0..self.sim.spec.n_ops() {
            if self.rolling[i].in_transition() {
                let cand = self.rolling[i].candidate.clone().unwrap();
                let insts = self.sim.instances_of(i);
                let n_inst = insts.len() as u32;
                for id in insts {
                    self.sim.restart_with_config(id, cand.clone());
                }
                self.rolling[i].apply_round(n_inst, n_inst);
                self.estimators[i].invalidate();
                self.invalidate_downstream_joins(i);
                self.transitions += 1;
                self.last_transition_t[i] = self.sim.now();
                if let Some(ts) = self.trace.as_mut() {
                    let now = self.sim.now();
                    ts.sim_event(
                        now,
                        "rolling_wave",
                        vec![
                            ("op", Json::str(&self.sim.spec.operators[i].name)),
                            ("batch", Json::num(f64::from(n_inst))),
                            ("cold_s", Json::num(self.sim.spec.operators[i].cold_s)),
                        ],
                    );
                    ts.sim_event(
                        now,
                        "invalidation",
                        vec![
                            ("op", Json::str(&self.sim.spec.operators[i].name)),
                            ("reason", Json::str("transition")),
                        ],
                    );
                }
            }
        }
    }

    /// Deployed-config OOM safety fallback: repeated OOMs on the live
    /// config revert the operator to its default configuration.
    pub(super) fn oom_safety_fallback(&mut self, metrics: &[OpMetrics]) {
        for (i, m) in metrics.iter().enumerate() {
            self.recent_ooms[i] = self.recent_ooms[i] / 2 + m.oom_events;
            if self.recent_ooms[i] >= 2 {
                let default = self.sim.spec.operators[i].config_space.default_config();
                if !default.is_empty() && self.rolling[i].current != default {
                    for inst in self.sim.instances_of(i) {
                        self.sim.restart_with_config(inst, default.clone());
                    }
                    self.rolling[i] =
                        RollingState::new(default, self.sim.instances_of(i).len() as u32);
                    self.estimators[i].invalidate();
                    self.recent_ooms[i] = 0;
                    if let Some(ts) = self.trace.as_mut() {
                        ts.sim_event(
                            self.sim.now(),
                            "invalidation",
                            vec![
                                ("op", Json::str(&self.sim.spec.operators[i].name)),
                                ("reason", Json::str("oom_fallback")),
                            ],
                        );
                    }
                }
            }
        }
    }
}
