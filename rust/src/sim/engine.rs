//! Discrete-event core: a monotonic f64 clock and a binary-heap event queue
//! with deterministic FIFO tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Instance identifier (index into `PipelineSim::instances`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct InstId(pub usize);

/// Typed simulator events.
#[derive(Debug, Clone)]
pub enum Ev {
    /// Tenant `t`'s source attempts to emit the next input item(s)
    /// (tenant 0 is the only tenant of a single-pipeline deployment).
    SourceEmit(u32),
    /// An instance finished its current batch.
    BatchDone(InstId),
    /// An instance finished starting / restarting.
    InstanceReady(InstId),
    /// A cross-node transfer arrived at its destination instance along the
    /// given pipeline edge (joins need the edge to slot the partial).
    TransferDone(InstId, usize, crate::sim::items::Item),
}

struct Entry {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time first, then FIFO by sequence number.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Event queue + clock.
pub struct Engine {
    now: f64,
    seq: u64,
    heap: BinaryHeap<Entry>,
    pub events_processed: u64,
}

impl Engine {
    pub fn new() -> Self {
        Engine { now: 0.0, seq: 0, heap: BinaryHeap::new(), events_processed: 0 }
    }

    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `ev` at absolute time `t` (clamped to now).
    pub fn at(&mut self, t: f64, ev: Ev) {
        let t = t.max(self.now);
        self.seq += 1;
        self.heap.push(Entry { t, seq: self.seq, ev });
    }

    /// Schedule `ev` after `dt` seconds.
    pub fn after(&mut self, dt: f64, ev: Ev) {
        debug_assert!(dt >= 0.0, "negative delay");
        self.at(self.now + dt, ev);
    }

    /// Pop the next event at or before `t_end`; advances the clock.
    pub fn next_before(&mut self, t_end: f64) -> Option<Ev> {
        if let Some(e) = self.heap.peek() {
            if e.t <= t_end {
                let e = self.heap.pop().unwrap();
                self.now = e.t;
                self.events_processed += 1;
                return Some(e.ev);
            }
        }
        self.now = self.now.max(t_end.min(self.heap.peek().map(|e| e.t).unwrap_or(t_end)));
        None
    }

    /// Advance the clock to `t` without processing (used when idle).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ordering_and_fifo_ties() {
        let mut e = Engine::new();
        e.at(2.0, Ev::SourceEmit(0));
        e.at(1.0, Ev::BatchDone(InstId(1)));
        e.at(1.0, Ev::BatchDone(InstId(2)));
        match e.next_before(10.0).unwrap() {
            Ev::BatchDone(InstId(1)) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(e.now(), 1.0);
        match e.next_before(10.0).unwrap() {
            Ev::BatchDone(InstId(2)) => {}
            other => panic!("{other:?}"),
        }
        match e.next_before(10.0).unwrap() {
            Ev::SourceEmit(0) => {}
            other => panic!("{other:?}"),
        }
        assert!(e.next_before(10.0).is_none());
    }

    #[test]
    fn respects_horizon() {
        let mut e = Engine::new();
        e.at(5.0, Ev::SourceEmit(0));
        assert!(e.next_before(4.0).is_none());
        assert_eq!(e.now(), 4.0);
        assert!(e.next_before(5.0).is_some());
        assert_eq!(e.now(), 5.0);
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut e = Engine::new();
        e.at(3.0, Ev::SourceEmit(0));
        e.next_before(10.0);
        e.at(1.0, Ev::SourceEmit(0)); // in the past -> fires at now
        assert!(e.next_before(10.0).is_some());
        assert_eq!(e.now(), 3.0);
    }
}
