//! Discrete-event core: a monotonic f64 clock and a slab-backed pairing
//! heap with deterministic FIFO tie-breaking.
//!
//! **Determinism contract** (unchanged from the original `BinaryHeap`
//! implementation): events are consumed in ascending `(time, seq)` order —
//! earlier time first, then FIFO by the sequence number allocated at
//! scheduling time.  Since every `(time, seq)` key is unique (`seq` comes
//! from one monotone counter), the pop order is a property of the keys
//! alone and is independent of the heap's internal shape.
//!
//! **Storage.**  Heap nodes live in a slab (`Vec<Node>` + intrusive free
//! list indexed by `u32`): no per-event allocation, no `Ord`-wrapper
//! boxing, and the event payload is a 16-byte POD id bundle ([`Ev`]) —
//! cross-node transfers reference their record by slot id into the
//! pipeline's transfer slab instead of embedding the ~64-byte `Item`.
//!
//! The pipeline keeps in-flight link transfers *outside* this heap (in
//! per-node FIFO queues) and merges the two stores by `(time, seq)` at pop
//! time; [`Engine::alloc_seq`] hands those entries sequence numbers from
//! the same counter so cross-store tie-breaks replay the one-store order,
//! and [`Engine::deliver_external`] advances the clock/event counters for
//! them exactly like a popped heap event.

/// Instance identifier: a dense u32 index into `PipelineSim::instances`
/// (instance counts never approach 2^32; ids are assigned densely).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct InstId(pub u32);

impl InstId {
    #[inline]
    pub fn of(i: usize) -> Self {
        debug_assert!(i < u32::MAX as usize, "instance id overflows u32");
        InstId(i as u32)
    }

    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Typed simulator events — plain ids only, no owned payloads, so every
/// variant is `Copy` and heap entries stay small.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// Tenant `t`'s source attempts to emit the next input item(s)
    /// (tenant 0 is the only tenant of a single-pipeline deployment).
    SourceEmit(u32),
    /// An instance finished its current batch.
    BatchDone(InstId),
    /// An instance finished starting / restarting.
    InstanceReady(InstId),
    /// A cross-node transfer arrived at its destination instance along the
    /// given pipeline edge (joins need the edge to slot the partial).
    /// The record itself sits in the pipeline's transfer slab at `slot`.
    TransferDone { dest: InstId, edge: u32, slot: u32 },
}

// The whole point of the POD refactor: an event is an id bundle, not a
// record carrier.  Keep it that way.
const _: () = assert!(std::mem::size_of::<Ev>() <= 16, "Ev must stay a POD id bundle");

/// Slab slot of a pairing-heap node.  `sibling` doubles as the free-list
/// link while the slot is unused.
struct Node {
    t: f64,
    seq: u64,
    ev: Ev,
    child: u32,
    sibling: u32,
}

const NIL: u32 = u32::MAX;

/// Event queue + clock.
pub struct Engine {
    now: f64,
    seq: u64,
    nodes: Vec<Node>,
    root: u32,
    /// Head of the intrusive free list through `Node::sibling`.
    free: u32,
    len: usize,
    peak: usize,
    /// Reused two-pass merge scratch (cleared per pop, never shrunk).
    scratch: Vec<u32>,
    pub events_processed: u64,
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            now: 0.0,
            seq: 0,
            nodes: Vec::new(),
            root: NIL,
            free: NIL,
            len: 0,
            peak: 0,
            scratch: Vec::new(),
            events_processed: 0,
        }
    }

    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Strict `(t, seq)` order between two live nodes.  Keys are unique,
    /// so this is a total order and the heap's pop sequence is fully
    /// determined by it.
    #[inline]
    fn before(&self, a: u32, b: u32) -> bool {
        let (na, nb) = (&self.nodes[a as usize], &self.nodes[b as usize]);
        na.t < nb.t || (na.t == nb.t && na.seq < nb.seq)
    }

    /// Meld two heap roots; the earlier `(t, seq)` key wins.
    #[inline]
    fn meld(&mut self, a: u32, b: u32) -> u32 {
        if a == NIL {
            return b;
        }
        if b == NIL {
            return a;
        }
        let (top, bot) = if self.before(b, a) { (b, a) } else { (a, b) };
        self.nodes[bot as usize].sibling = self.nodes[top as usize].child;
        self.nodes[top as usize].child = bot;
        top
    }

    fn alloc_node(&mut self, t: f64, seq: u64, ev: Ev) -> u32 {
        let idx = if self.free != NIL {
            let idx = self.free;
            self.free = self.nodes[idx as usize].sibling;
            self.nodes[idx as usize] = Node { t, seq, ev, child: NIL, sibling: NIL };
            idx
        } else {
            debug_assert!(self.nodes.len() < NIL as usize, "event slab overflows u32");
            self.nodes.push(Node { t, seq, ev, child: NIL, sibling: NIL });
            (self.nodes.len() - 1) as u32
        };
        self.len += 1;
        self.peak = self.peak.max(self.len);
        idx
    }

    /// Iterative two-pass pairing merge of a popped root's child list.
    fn merge_pairs(&mut self, first: u32) -> u32 {
        if first == NIL {
            return NIL;
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        let mut cur = first;
        while cur != NIL {
            let a = cur;
            let b = self.nodes[a as usize].sibling;
            if b == NIL {
                self.nodes[a as usize].sibling = NIL;
                scratch.push(a);
                break;
            }
            let next = self.nodes[b as usize].sibling;
            self.nodes[a as usize].sibling = NIL;
            self.nodes[b as usize].sibling = NIL;
            scratch.push(self.meld(a, b));
            cur = next;
        }
        let mut root = NIL;
        while let Some(h) = scratch.pop() {
            root = self.meld(root, h);
        }
        self.scratch = scratch;
        root
    }

    /// Pop the root (caller guarantees non-empty) and recycle its slot.
    fn pop_root(&mut self) -> (f64, Ev) {
        let r = self.root;
        let (t, ev, first_child) = {
            let n = &self.nodes[r as usize];
            (n.t, n.ev, n.child)
        };
        self.nodes[r as usize].sibling = self.free;
        self.free = r;
        self.len -= 1;
        self.root = self.merge_pairs(first_child);
        (t, ev)
    }

    /// Bump the sequence counter with an explicit overflow check.  At the
    /// 1M-records-in-flight bench scale a u64 counter cannot wrap in any
    /// physical run (2^64 events at 10^9 ev/s is ~585 years), but the
    /// counter is the determinism keystone — wrap-around would silently
    /// reorder ties — so exhaustion is a hard error, not UB-by-assumption.
    #[inline]
    fn bump_seq(&mut self) -> u64 {
        self.seq = self.seq.checked_add(1).expect("event sequence counter overflow");
        self.seq
    }

    /// Fast-forward the sequence counter to `v` (no-op if already past).
    /// Used by sharded runs to sub-allocate disjoint, globally consistent
    /// sequence ranges to per-shard engines from one logical counter, and
    /// by tests to exercise counter values past `u32::MAX` cheaply.
    pub fn advance_seq_to(&mut self, v: u64) {
        self.seq = self.seq.max(v);
    }

    /// Current value of the sequence counter (last allocated seq).
    #[inline]
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Schedule `ev` at absolute time `t` (clamped to now).
    pub fn at(&mut self, t: f64, ev: Ev) {
        let t = t.max(self.now);
        let seq = self.bump_seq();
        let n = self.alloc_node(t, seq, ev);
        self.root = self.meld(self.root, n);
    }

    /// Schedule `ev` after `dt` seconds.
    pub fn after(&mut self, dt: f64, ev: Ev) {
        debug_assert!(dt >= 0.0, "negative delay");
        self.at(self.now + dt, ev);
    }

    /// Allocate a sequence number for an event stored *outside* the heap
    /// (the pipeline's per-node link queues of in-flight transfers).
    /// Drawn from the same counter as [`Engine::at`], so `(time, seq)`
    /// stays a strict total order across both stores and equal-time
    /// tie-breaks are identical whichever store holds the entry.
    #[inline]
    pub fn alloc_seq(&mut self) -> u64 {
        self.bump_seq()
    }

    /// The earliest pending `(time, seq)` key in the heap, if any.
    #[inline]
    pub fn peek_key(&self) -> Option<(f64, u64)> {
        if self.root == NIL {
            return None;
        }
        let n = &self.nodes[self.root as usize];
        Some((n.t, n.seq))
    }

    /// Pop the next event at or before `t_end`; advances the clock.
    pub fn next_before(&mut self, t_end: f64) -> Option<Ev> {
        if let Some((t, _)) = self.peek_key() {
            if t <= t_end {
                let (t, ev) = self.pop_root();
                self.now = t;
                self.events_processed += 1;
                return Some(ev);
            }
        }
        self.now = self.now.max(t_end.min(self.peek_key().map(|k| k.0).unwrap_or(t_end)));
        None
    }

    /// Consume an externally stored event (a link-queue transfer) at `t`:
    /// advance the clock and count it exactly like a popped heap event,
    /// so both transfer modes report identical event totals.
    #[inline]
    pub fn deliver_external(&mut self, t: f64) {
        debug_assert!(t >= self.now, "external events are consumed in order");
        self.now = self.now.max(t);
        self.events_processed += 1;
    }

    /// Advance the clock to `t` without processing (used when idle).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    /// Pending heap entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// High-water mark of simultaneously pending heap entries.
    pub fn peak_entries(&self) -> usize {
        self.peak
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ordering_and_fifo_ties() {
        let mut e = Engine::new();
        e.at(2.0, Ev::SourceEmit(0));
        e.at(1.0, Ev::BatchDone(InstId(1)));
        e.at(1.0, Ev::BatchDone(InstId(2)));
        match e.next_before(10.0).unwrap() {
            Ev::BatchDone(InstId(1)) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(e.now(), 1.0);
        match e.next_before(10.0).unwrap() {
            Ev::BatchDone(InstId(2)) => {}
            other => panic!("{other:?}"),
        }
        match e.next_before(10.0).unwrap() {
            Ev::SourceEmit(0) => {}
            other => panic!("{other:?}"),
        }
        assert!(e.next_before(10.0).is_none());
    }

    #[test]
    fn respects_horizon() {
        let mut e = Engine::new();
        e.at(5.0, Ev::SourceEmit(0));
        assert!(e.next_before(4.0).is_none());
        assert_eq!(e.now(), 4.0);
        assert!(e.next_before(5.0).is_some());
        assert_eq!(e.now(), 5.0);
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut e = Engine::new();
        e.at(3.0, Ev::SourceEmit(0));
        e.next_before(10.0);
        e.at(1.0, Ev::SourceEmit(0)); // in the past -> fires at now
        assert!(e.next_before(10.0).is_some());
        assert_eq!(e.now(), 3.0);
    }

    /// Many events at one timestamp must drain in exact insertion order —
    /// the FIFO half of the determinism contract, now a property of the
    /// pairing heap instead of `BinaryHeap`'s comparator.
    #[test]
    fn equal_time_events_drain_in_insertion_order() {
        let mut e = Engine::new();
        for i in 0..64u32 {
            e.at(7.0, Ev::SourceEmit(i));
        }
        // Interleave an earlier event to exercise meld paths.
        e.at(6.5, Ev::BatchDone(InstId(99)));
        assert!(matches!(e.next_before(100.0), Some(Ev::BatchDone(InstId(99)))));
        for i in 0..64u32 {
            match e.next_before(100.0).unwrap() {
                Ev::SourceEmit(got) => assert_eq!(got, i, "FIFO violated at {i}"),
                other => panic!("{other:?}"),
            }
        }
        assert!(e.is_empty());
    }

    /// Randomized differential test against a sorted-model reference: the
    /// pairing heap must pop the exact `(t, seq)`-minimal entry under an
    /// adversarial mix of inserts, pops, and heavy timestamp ties.
    #[test]
    fn differential_vs_sorted_model() {
        let mut lcg: u64 = 0x243F_6A88_85A3_08D3;
        let mut next = move || {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            lcg >> 11
        };
        let mut e = Engine::new();
        // Model entries: (t, seq, payload).  seq mirrors the engine's
        // internal counter (we only ever schedule via `at`).
        let mut model: Vec<(f64, u64, u32)> = Vec::new();
        let mut seq = 0u64;
        let mut peak = 0usize;
        for _ in 0..4000 {
            let r = next();
            if r % 3 != 0 || model.is_empty() {
                // Quantized offsets force many exact timestamp ties.
                let t = e.now() + (next() % 8) as f64 * 0.25;
                let payload = (next() % 1_000_000) as u32;
                e.at(t, Ev::SourceEmit(payload));
                seq += 1;
                model.push((t, seq, payload));
                peak = peak.max(model.len());
            } else {
                let min = model
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                let (t, _, payload) = model.remove(min);
                match e.next_before(f64::INFINITY) {
                    Some(Ev::SourceEmit(got)) => {
                        assert_eq!(got, payload, "pop order diverged from model");
                        assert_eq!(e.now(), t, "clock diverged from model");
                    }
                    other => panic!("expected SourceEmit, got {other:?}"),
                }
            }
        }
        while !model.is_empty() {
            let min = model
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)))
                .map(|(i, _)| i)
                .unwrap();
            let (_, _, payload) = model.remove(min);
            match e.next_before(f64::INFINITY) {
                Some(Ev::SourceEmit(got)) => assert_eq!(got, payload),
                other => panic!("expected SourceEmit, got {other:?}"),
            }
        }
        assert!(e.next_before(f64::INFINITY).is_none());
        assert!(e.is_empty());
        assert!(e.peak_entries() >= peak, "peak high-water must cover the model's");
    }

    /// The sequence counter must keep ordering ties correctly past
    /// `u32::MAX` — the regime the 1M-records-in-flight bench rungs push
    /// toward.  `advance_seq_to` jumps the counter there cheaply instead
    /// of scheduling four billion events.
    #[test]
    fn seq_counter_survives_u32_overflow() {
        let mut e = Engine::new();
        e.advance_seq_to(u32::MAX as u64 - 1);
        assert_eq!(e.seq(), u32::MAX as u64 - 1);
        // These three same-time events straddle the u32 boundary: their
        // seqs are MAX-0, MAX, MAX+1.  A u32-truncating comparator would
        // wrap the third to 0 and pop it first.
        for i in 0..3u32 {
            e.at(4.0, Ev::SourceEmit(i));
        }
        assert!(e.seq() > u32::MAX as u64);
        for i in 0..3u32 {
            match e.next_before(10.0).unwrap() {
                Ev::SourceEmit(got) => assert_eq!(got, i, "FIFO violated across u32 boundary"),
                other => panic!("{other:?}"),
            }
        }
        // alloc_seq shares the guarded counter and keeps ascending.
        let s1 = e.alloc_seq();
        let s2 = e.alloc_seq();
        assert!(s1 > u32::MAX as u64 && s2 == s1 + 1);
        // advance_seq_to never moves backwards.
        e.advance_seq_to(5);
        assert_eq!(e.seq(), s2);
    }

    /// Sharded-merge determinism: split one randomized event stream across
    /// M per-shard engines (seqs sub-allocated from one logical counter via
    /// `advance_seq_to`), pop always from the shard whose `peek_key` is
    /// `(t, seq)`-minimal, and the merged order must equal a single serial
    /// engine fed the identical stream.
    #[test]
    fn shard_merged_pop_order_equals_serial() {
        let mut lcg: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut next = move || {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            lcg >> 11
        };
        for shards in [1usize, 2, 3, 4] {
            let mut serial = Engine::new();
            let mut sharded: Vec<Engine> = (0..shards).map(|_| Engine::new()).collect();
            for _ in 0..800 {
                // Quantized times force heavy cross-shard ties; payload
                // identifies the event for the order comparison.
                let t = (next() % 16) as f64 * 0.5;
                let payload = (next() % 1_000_000) as u32;
                let shard = (next() % shards as u64) as usize;
                serial.at(t, Ev::SourceEmit(payload));
                // Sub-allocate the owning shard's seq from the logical
                // global counter (the serial engine IS that counter here).
                sharded[shard].advance_seq_to(serial.seq() - 1);
                sharded[shard].at(t, Ev::SourceEmit(payload));
                assert_eq!(sharded[shard].seq(), serial.seq(), "seq sub-allocation drifted");
            }
            loop {
                // Deterministic merge: pop from the (t, seq)-minimal shard.
                let min = sharded
                    .iter()
                    .enumerate()
                    .filter_map(|(i, e)| e.peek_key().map(|k| (i, k)))
                    .min_by(|(_, a), (_, b)| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)))
                    .map(|(i, _)| i);
                let Some(i) = min else { break };
                let got = sharded[i].next_before(f64::INFINITY).unwrap();
                let want = serial.next_before(f64::INFINITY).unwrap();
                assert_eq!(got, want, "merged pop order diverged at K={shards}");
            }
            assert!(serial.next_before(f64::INFINITY).is_none(), "shard merge dropped events");
        }
    }

    /// `alloc_seq` draws from the same counter as `at`, so an externally
    /// stored entry scheduled between two heap inserts at the same time
    /// slots between them in the total order.
    #[test]
    fn alloc_seq_shares_the_counter() {
        let mut e = Engine::new();
        e.at(5.0, Ev::SourceEmit(1));
        let s = e.alloc_seq();
        e.at(5.0, Ev::SourceEmit(2));
        let (t1, q1) = e.peek_key().unwrap();
        assert_eq!(t1, 5.0);
        assert!(q1 < s, "first heap event precedes the external seq");
        assert!(matches!(e.next_before(10.0), Some(Ev::SourceEmit(1))));
        let (_, q2) = e.peek_key().unwrap();
        assert!(s < q2, "external seq precedes the later heap event");
        // Consuming the external entry counts like a heap pop.
        let before = e.events_processed;
        e.deliver_external(5.0);
        assert_eq!(e.events_processed, before + 1);
        assert_eq!(e.now(), 5.0);
    }
}
