//! Metrics collection: per-instance window accumulators and the
//! per-operator snapshots the observation/adaptation layers consume
//! (paper §3.1 "Metrics Collector").

use crate::sim::items::{Item, ItemAttrs};
use crate::rngx::Rng;

/// Per-instance accumulators over one metrics window.
#[derive(Debug, Clone, Default)]
pub struct InstWindow {
    pub records_done: u64,
    pub batches_done: u64,
    pub busy_s: f64,
    /// Downtime (starting / OOM restart) inside the window.
    pub down_s: f64,
    pub peak_mem_mb: f64,
    pub oom_events: u32,
    /// Queue length sampled at each batch start.
    pub q_sum: f64,
    pub q_n: u64,
}

impl InstWindow {
    pub fn reset(&mut self) {
        *self = InstWindow::default();
    }
}

/// Per-instance view exposed to schedulers/tuners (BO probes, DS2
/// useful-time rates).
#[derive(Debug, Clone)]
pub struct InstanceMetrics {
    pub inst: usize,
    pub node: usize,
    pub records: u64,
    pub busy_s: f64,
    /// Seconds the instance was up (existed minus downtime) this window.
    pub active_s: f64,
    pub peak_mem_mb: f64,
    pub oom_events: u32,
    pub queue_len: usize,
    /// Config generation marker (bumped on each reconfig restart).
    pub config_gen: u32,
}

/// Aggregated per-operator metrics for one window — the payload of
/// "path ②" in Figure 1.
#[derive(Debug, Clone)]
pub struct OpMetrics {
    pub op: usize,
    pub window_s: f64,
    pub records_in: u64,
    pub records_out: u64,
    /// Observed throughput per active instance, records/s.
    pub rate_per_inst: f64,
    /// Mean busy-time fraction across active instances (stage-1 filter
    /// signal τ_u).
    pub utilization: f64,
    /// Total queued records at window start / end (stage-1 queue-trend
    /// signal).
    pub queue_begin: usize,
    pub queue_end: usize,
    pub queue_avg: f64,
    /// Workload descriptor: mean/std of (tokens_in, tokens_out, pixels_m,
    /// frames) over records processed this window.
    pub feat_mean: [f64; 4],
    pub feat_std: [f64; 4],
    pub peak_mem_mb: f64,
    pub oom_events: u32,
    pub n_active: usize,
    /// Per-request cluster features sampled this window (reservoir ≤ 64),
    /// with ground-truth regime tags for evaluation only.
    pub cluster_samples: Vec<([f64; 2], u8)>,
    pub per_instance: Vec<InstanceMetrics>,
}

impl OpMetrics {
    /// Mean item attrs reconstructed from the window descriptor.
    pub fn mean_attrs(&self) -> ItemAttrs {
        ItemAttrs {
            tokens_in: self.feat_mean[0],
            tokens_out: self.feat_mean[1],
            pixels_m: self.feat_mean[2],
            frames: self.feat_mean[3],
        }
    }

    /// GP workload-descriptor vector (§4.2): operator-specific features,
    /// normalized to O(1) scale.
    pub fn gp_features(&self, ex: crate::config::FeatureExtractor) -> Vec<f64> {
        use crate::config::FeatureExtractor as FE;
        match ex {
            FE::LlmTokens => vec![
                self.feat_mean[0] / 1024.0,
                self.feat_std[0] / 1024.0,
                self.feat_mean[1] / 256.0,
                self.feat_std[1] / 256.0,
            ],
            FE::Vision => vec![
                self.feat_mean[2] / 2.0,
                self.feat_std[2] / 2.0,
                self.feat_mean[3] / 256.0,
                self.feat_std[3] / 256.0,
            ],
            FE::Cost => vec![
                (self.feat_mean[0] + self.feat_mean[1]) / 1024.0,
                self.feat_mean[2] / 2.0,
                self.feat_mean[3] / 256.0,
            ],
        }
    }
}

/// Per-operator accumulators shared across instances (feature stats +
/// cluster-sample reservoir).
#[derive(Debug, Clone)]
pub struct OpWindowAcc {
    pub records_in: u64,
    pub n: u64,
    pub sum: [f64; 4],
    pub sumsq: [f64; 4],
    pub reservoir: Vec<([f64; 2], u8)>,
    seen: u64,
}

impl OpWindowAcc {
    pub fn new() -> Self {
        OpWindowAcc { records_in: 0, n: 0, sum: [0.0; 4], sumsq: [0.0; 4], reservoir: Vec::new(), seen: 0 }
    }

    pub fn reset(&mut self) {
        *self = OpWindowAcc::new();
    }

    pub fn observe(&mut self, item: &Item, ex: crate::config::FeatureExtractor, rng: &mut Rng) {
        let a = &item.attrs;
        let f = [a.tokens_in, a.tokens_out, a.pixels_m, a.frames];
        self.n += 1;
        for i in 0..4 {
            self.sum[i] += f[i];
            self.sumsq[i] += f[i] * f[i];
        }
        // Reservoir sample of cluster features.
        const CAP: usize = 64;
        self.seen += 1;
        let cf = (a.cluster_features(ex), item.regime);
        if self.reservoir.len() < CAP {
            self.reservoir.push(cf);
        } else {
            let j = rng.below(self.seen as usize);
            if j < CAP {
                self.reservoir[j] = cf;
            }
        }
    }

    pub fn mean_std(&self) -> ([f64; 4], [f64; 4]) {
        if self.n == 0 {
            return ([0.0; 4], [0.0; 4]);
        }
        let n = self.n as f64;
        let mut mean = [0.0; 4];
        let mut std = [0.0; 4];
        for i in 0..4 {
            mean[i] = self.sum[i] / n;
            std[i] = (self.sumsq[i] / n - mean[i] * mean[i]).max(0.0).sqrt();
        }
        (mean, std)
    }
}

impl Default for OpWindowAcc {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FeatureExtractor;

    fn item(tin: f64) -> Item {
        Item {
            id: 0,
            attrs: ItemAttrs { tokens_in: tin, tokens_out: 10.0, pixels_m: 0.0, frames: 1.0 },
            size_mb: 0.1,
            regime: 0,
        }
    }

    #[test]
    fn mean_std_accumulate() {
        let mut acc = OpWindowAcc::new();
        let mut rng = Rng::new(0);
        for t in [100.0, 200.0, 300.0] {
            acc.observe(&item(t), FeatureExtractor::LlmTokens, &mut rng);
        }
        let (m, s) = acc.mean_std();
        assert!((m[0] - 200.0).abs() < 1e-9);
        assert!((s[0] - (20000.0f64 / 3.0 * 2.0).sqrt()).abs() < 1e-6 || s[0] > 0.0);
        assert_eq!(m[3], 1.0);
        assert_eq!(s[3], 0.0);
    }

    #[test]
    fn reservoir_bounded() {
        let mut acc = OpWindowAcc::new();
        let mut rng = Rng::new(1);
        for i in 0..1000 {
            acc.observe(&item(i as f64), FeatureExtractor::LlmTokens, &mut rng);
        }
        assert_eq!(acc.reservoir.len(), 64);
        assert_eq!(acc.n, 1000);
    }
}
