//! Tenant-sharded parallel executor: K [`PipelineSim`] shards advanced by
//! a persistent work-stealing pool ([`ShardPool`]) of W workers,
//! bit-identical to the serial executor at any (K, W).
//!
//! ## Why tenants are the shard boundary
//!
//! Node-sharding (the obvious cut) cannot be made bit-identical: nodes
//! share the global RNG draw order, synchronous occupancy reads, and
//! cross-node wake cascades, so any node partition changes float values,
//! not just event interleavings.  Tenant DAGs, by contrast, are disjoint
//! by construction (records never cross tenants), and PR 7 removed the
//! four remaining cross-tenant couplings from the serial executor itself:
//!
//! 1. **RNG** — one xoshiro stream per tenant (stream 0 is the legacy
//!    generator, so single-tenant runs are unchanged bit-for-bit);
//! 2. **lineage ids** — minted from per-tenant namespaced counters;
//! 3. **egress** — each node's link is split into fixed per-tenant WFQ
//!    sub-links (non-work-conserving: an idle tenant's share is not lent
//!    out — a deliberate semantic, documented in DESIGN.md);
//! 4. **CPU contention** — the per-node denominator is frozen at window
//!    entry from per-tenant bookings summed in ascending-tenant order,
//!    so every shard computes the identical float from the identical
//!    gather this facade installs via `set_frozen_cpu`.
//!
//! With those gone, no event handler reads another tenant's mutable state
//! within a window, so each shard — owning the full cluster spec but only
//! its tenants' sources and instances — replays exactly the serial
//! executor's event subsequence for those tenants: same `(time, seq)`-
//! relative order, same float values, same counters.  The shards' event
//! sets *partition* the serial executor's (the CI drift check asserts the
//! totals), and the per-window barrier in [`ShardedSim::run_until`] is the
//! degenerate conservative-PDES horizon: the window end, since no
//! cross-shard messages exist at all.
//!
//! Merging is therefore selection, not arithmetic: per-op metrics are the
//! owner shard's verbatim (instance ids remapped to the global space),
//! per-tenant counters are the owner's, and cross-tenant aggregates are
//! sums in fixed ascending order — the same operation sequence the serial
//! executor performs.
//!
//! ## Work stealing and the overlapped gather
//!
//! Shard-tick tasks are indices into a per-window epoch on a persistent
//! [`ShardPool`] of `workers_effective()` threads (default
//! `min(K, cores − 1)`, `--workers` / `sim_workers` to override), so
//! K ≫ cores runs no longer spawn K OS threads per window and stacks are
//! reused across the whole `drive()` loop.  Stealing order decides only
//! *which worker* advances a shard; shards share no mutable state within
//! a window, so it is unobservable to the sim — bit-identity cannot
//! depend on W.  As the last step of its own tick task each shard
//! publishes (a) a dense per-owned-tenant row of per-node CPU bookings
//! and (b) its pure [`PipelineSim::window_metrics`] snapshot, stamped
//! with the shard clock.  The next window's frozen-CPU gather and the
//! facade's `flush_metrics` merge then fold over those already-published
//! buffers (ascending-tenant / ascending-op order preserved, so the
//! float sequences are the serial executor's) instead of walking every
//! shard's live state on the caller's thread after the barrier.  Any
//! facade mutation between windows (dynamics, instance churn) clears the
//! stamps and the folds fall back to the direct PR 7-style pass — same
//! values either way, which is why the fast path cannot drift.

use crate::config::{ClusterSpec, PipelineSpec, TenancyView};
use crate::rngx::Rng;
use crate::sim::items::{Item, ItemAttrs};
use crate::sim::metrics::OpMetrics;
use crate::sim::pipeline::{Instance, PipelineSim, SimError};
use crate::sim::pool::{PoolTelemetry, ShardPool};
use crate::workload::Trace;
use std::sync::Arc;

/// Placeholder trace for tenants a shard does not own: never emits.
/// (Non-owned tenants are born `source_done`, so this is never polled;
/// it only fills the one-trace-per-tenant constructor contract.)
struct NullTrace;

impl Trace for NullTrace {
    fn next_item(&mut self, _rng: &mut Rng) -> Option<Item> {
        None
    }
    fn n_regimes(&self) -> usize {
        0
    }
}

/// Buffers a shard publishes as the last step of its own tick task, so
/// the serial inter-window work (frozen-CPU gather, metrics merge) is a
/// fold instead of a walk over live shard state.  Stamps are
/// `f64::to_bits` of the shard clock at publish time; any facade
/// mutation clears them (see `invalidate_published`), and a cleared or
/// mismatched stamp sends the consumer down the direct fallback path.
struct ShardPublish {
    /// The tenants this shard owns, ascending (`s, s+K, s+2K, …`).
    owned: Vec<usize>,
    /// Node count (row stride of `cpu_rows`).
    n_nodes: usize,
    /// Row-major per-owned-tenant CPU bookings: `owned[i]`'s per-node
    /// row at `i * n_nodes`.  Tenant `t`'s row index is `t / K`.
    cpu_rows: Vec<f64>,
    /// Shard clock (bits) when `cpu_rows` was filled; `None` = stale.
    cpu_at: Option<u64>,
    /// Pure [`PipelineSim::window_metrics`] snapshot, consumed at most
    /// once by the facade flush (`take`), never reused.
    metrics: Option<(Vec<OpMetrics>, Vec<u64>)>,
    /// Shard clock (bits) when `metrics` was computed; `None` = stale.
    metrics_at: Option<u64>,
}

/// One shard-tick task: advance the shard, then publish its CPU rows and
/// window-metrics snapshot.  Both the pool workers and the sequential /
/// W = 1 driver run exactly this function, so every (K, W) executes the
/// same per-shard code.
fn tick_shard(sh: &mut PipelineSim, pb: &mut ShardPublish, t_end: f64) {
    sh.run_until(t_end);
    let at = sh.now().to_bits();
    for (i, &t) in pb.owned.iter().enumerate() {
        sh.copy_cpu_booked(t, &mut pb.cpu_rows[i * pb.n_nodes..(i + 1) * pb.n_nodes]);
    }
    pb.cpu_at = Some(at);
    pb.metrics = Some(sh.window_metrics());
    pb.metrics_at = Some(at);
}

/// K-way tenant-sharded facade over [`PipelineSim`] with the serial
/// executor's exact API surface and bit-identical results at any (K, W)
/// (pinned by `tests/sim_perf_parity.rs`).  Tenant `t` is owned by shard
/// `t % K`; K is clamped to the tenant count, so K = 1 (or a single
/// tenant) runs the serial code on the caller's thread.  W workers
/// (clamped to [1, K]) advance the shards; W = 1 also stays on the
/// caller's thread.
pub struct ShardedSim {
    shards: Vec<PipelineSim>,
    /// Owner shard of each tenant (`t % K`).
    tenant_shard: Vec<usize>,
    /// Global instance id → (shard, local id).  Global ids are assigned
    /// in `add_instance` call order, exactly like the serial executor's.
    inst_map: Vec<(usize, usize)>,
    /// Per shard: local instance id → global id.
    local2global: Vec<Vec<usize>>,
    pub spec: PipelineSpec,
    pub cluster: ClusterSpec,
    pub tenancy: TenancyView,
    /// Advance shards on pool worker threads (`false` forces the
    /// sequential loop — the degenerate-path oracle for tests).
    threaded: bool,
    /// Per-shard published buffers (same index as `shards`).
    published: Vec<ShardPublish>,
    /// Lazily built when the threaded path first runs (and rebuilt if
    /// the effective worker count changes); reused across windows.
    pool: Option<ShardPool>,
    /// Configured worker count; 0 = auto (`cores − 1`).
    workers_cfg: usize,
}

impl ShardedSim {
    /// Single-tenant constructor (mirrors [`PipelineSim::new`]).  With
    /// one tenant K clamps to 1: the serial executor behind the facade.
    pub fn new(
        spec: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        seed: u64,
        shards: usize,
    ) -> Self {
        if let Err(e) = spec.validate() {
            panic!("invalid pipeline spec '{}': {e}", spec.name);
        }
        let view = TenancyView::single_for(&spec);
        Self::build(spec, view, cluster, vec![trace], seed, shards)
    }

    /// Multi-tenant constructor (mirrors [`PipelineSim::new_tenancy`]).
    pub fn new_tenancy(
        spec: PipelineSpec,
        view: TenancyView,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        seed: u64,
        shards: usize,
    ) -> Self {
        assert_eq!(traces.len(), view.n_tenants(), "one trace per tenant");
        Self::build(spec, view, cluster, traces, seed, shards)
    }

    fn build(
        spec: PipelineSpec,
        view: TenancyView,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        seed: u64,
        shards: usize,
    ) -> Self {
        let nt = view.n_tenants();
        let k = shards.max(1).min(nt.max(1));
        let tenant_shard: Vec<usize> = (0..nt).map(|t| t % k).collect();
        let mut slots: Vec<Option<Box<dyn Trace>>> = traces.into_iter().map(Some).collect();
        let mut pool = Vec::with_capacity(k);
        for s in 0..k {
            let tr: Vec<Box<dyn Trace>> = (0..nt)
                .map(|t| {
                    if tenant_shard[t] == s {
                        slots[t].take().expect("each trace is owned by exactly one shard")
                    } else {
                        Box::new(NullTrace) as Box<dyn Trace>
                    }
                })
                .collect();
            let owned: Vec<bool> = (0..nt).map(|t| tenant_shard[t] == s).collect();
            pool.push(PipelineSim::new_sharded(
                spec.clone(),
                view.clone(),
                cluster.clone(),
                tr,
                seed,
                &owned,
            ));
        }
        let n_nodes = cluster.nodes.len();
        let published = (0..k)
            .map(|s| {
                let owned: Vec<usize> = (0..nt).filter(|t| t % k == s).collect();
                ShardPublish {
                    cpu_rows: vec![0.0; owned.len() * n_nodes],
                    owned,
                    n_nodes,
                    cpu_at: None,
                    metrics: None,
                    metrics_at: None,
                }
            })
            .collect();
        ShardedSim {
            shards: pool,
            tenant_shard,
            inst_map: Vec::new(),
            local2global: vec![Vec::new(); k],
            spec,
            cluster,
            tenancy: view,
            threaded: true,
            published,
            pool: None,
            workers_cfg: 0,
        }
    }

    /// Number of shards actually running (after clamping to tenants).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Force the sequential shard loop (tests: pins that the threaded and
    /// sequential drivers are the same code path modulo the thread pool).
    pub fn set_threaded(&mut self, on: bool) {
        self.threaded = on;
    }

    /// Configure the worker-thread count; 0 (the default) means auto
    /// (`cores − 1`).  Clamped to [1, K] at use — see
    /// [`workers_effective`](Self::workers_effective).
    pub fn set_workers(&mut self, workers: usize) {
        self.workers_cfg = workers;
    }

    /// The worker count the pool actually runs: the configured count (or
    /// `available_parallelism − 1` when auto), clamped to [1, K] — more
    /// workers than shards would only park on the condvar.
    pub fn workers_effective(&self) -> usize {
        let want = if self.workers_cfg == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get().saturating_sub(1))
                .unwrap_or(1)
        } else {
            self.workers_cfg
        };
        want.clamp(1, self.shards.len().max(1))
    }

    /// Lifetime steal count of the current pool (telemetry; 0 when the
    /// sequential path has been running).
    pub fn pool_steals(&self) -> u64 {
        self.pool.as_ref().map(|p| p.steals()).unwrap_or(0)
    }

    /// Full pool telemetry snapshot (`None` while the sequential path has
    /// been running — K = 1, W = 1, or `set_threaded(false)`).
    pub fn pool_telemetry(&self) -> Option<PoolTelemetry> {
        self.pool.as_ref().map(|p| p.telemetry())
    }

    /// Toggle the flight-recorder OOM buffer in every shard.  Pure
    /// telemetry: buffers are push-only and consume no RNG, so the
    /// published gather/flush stamps stay valid.
    pub fn set_trace_ooms(&mut self, on: bool) {
        for sh in &mut self.shards {
            sh.set_trace_ooms(on);
        }
    }

    /// Drain every shard's OOM buffer into one K-invariant stream:
    /// local instance ids map to global, and the merge orders by
    /// `(time-bits, op, global id)` — times are non-negative, so the
    /// bit order is the numeric order, and an op's kills all live on its
    /// owner shard, so the result is identical at any (K, W).
    pub fn take_trace_ooms(&mut self) -> Vec<(f64, usize, usize)> {
        let mut all = Vec::new();
        for s in 0..self.shards.len() {
            for (t, op, local) in self.shards[s].take_trace_ooms() {
                all.push((t, op as usize, self.local2global[s][local as usize]));
            }
        }
        all.sort_by_key(|&(t, op, gid)| (t.to_bits(), op, gid));
        all
    }

    /// Drop every published buffer's validity stamp.  Called from every
    /// facade mutator: between-window mutations (dynamics events,
    /// instance churn, route changes) can change what a gather would
    /// read, so the next gather/flush must take the direct path.
    fn invalidate_published(&mut self) {
        for pb in &mut self.published {
            pb.cpu_at = None;
            pb.metrics = None;
            pb.metrics_at = None;
        }
    }

    #[inline]
    fn owner_of_op(&self, op: usize) -> usize {
        self.tenant_shard[self.tenancy.op_tenant[op]]
    }

    // ------------------------------------------------------------------
    // Instance lifecycle (global-id view over per-shard instance tables)
    // ------------------------------------------------------------------

    /// Launch an instance; same admission decisions and error strings as
    /// the serial executor (accelerator occupancy is gathered across
    /// shards, since every tenant's bookings count against the node).
    pub fn add_instance(
        &mut self,
        op: usize,
        node: usize,
        theta: Vec<f64>,
    ) -> Result<usize, SimError> {
        self.invalidate_published();
        let s = self.owner_of_op(op);
        if !self.shards[s].nodes_up()[node] {
            return Err(SimError::NodeDown { node });
        }
        let o = &self.spec.operators[op];
        if o.accels > 0 {
            let booked: u32 = self.shards.iter().map(|sh| sh.node_accel_booked(node)).sum();
            let cap = self.cluster.nodes[node].accels;
            if booked + o.accels > cap {
                return Err(SimError::OutOfAccelerators {
                    node,
                    op: o.name.clone(),
                    booked,
                    want: o.accels,
                    cap,
                });
            }
        }
        // The owner's local checks are implied by the global ones (its
        // bookings are a subset), so this cannot fail; propagate anyway.
        let local = self.shards[s].add_instance(op, node, theta)?;
        let gid = self.inst_map.len();
        self.inst_map.push((s, local));
        debug_assert_eq!(self.local2global[s].len(), local);
        self.local2global[s].push(gid);
        Ok(gid)
    }

    /// The instance behind a global id (read-only; mirrors the serial
    /// executor's `instances[id]` indexing).
    pub fn instance(&self, id: usize) -> &Instance {
        let (s, l) = self.inst_map[id];
        &self.shards[s].instances[l]
    }

    /// Whether any instance was ever launched (the serial executor's
    /// `instances.is_empty()` check).
    pub fn has_instances(&self) -> bool {
        !self.inst_map.is_empty()
    }

    pub fn stop_instance(&mut self, id: usize) {
        self.invalidate_published();
        let (s, l) = self.inst_map[id];
        self.shards[s].stop_instance(l);
    }

    pub fn restart_with_config(&mut self, id: usize, theta: Vec<f64>) {
        self.invalidate_published();
        let (s, l) = self.inst_map[id];
        self.shards[s].restart_with_config(l, theta);
    }

    /// Live instances of `op`, as global ids in launch order (identical
    /// to the serial executor's list: all of an op's instances live on
    /// its owner shard, where local launch order is global launch order).
    pub fn instances_of(&self, op: usize) -> Vec<usize> {
        let s = self.owner_of_op(op);
        self.shards[s]
            .instances_of(op)
            .into_iter()
            .map(|l| self.local2global[s][l])
            .collect()
    }

    /// Live (non-draining) instance count per (op, node); each op counts
    /// only on its owner shard, so the elementwise sum is exact.
    pub fn placement(&self) -> Vec<Vec<u32>> {
        let mut x = vec![vec![0u32; self.cluster.nodes.len()]; self.spec.n_ops()];
        for sh in &self.shards {
            for (op, row) in sh.placement().into_iter().enumerate() {
                for (node, v) in row.into_iter().enumerate() {
                    x[op][node] += v;
                }
            }
        }
        x
    }

    pub fn set_route(&mut self, edge: usize, fractions: Option<Vec<Vec<f64>>>) {
        self.invalidate_published();
        for sh in &mut self.shards {
            sh.set_route(edge, fractions.clone());
        }
    }

    pub fn n_routes_set(&self) -> usize {
        self.shards[0].n_routes_set()
    }

    // ------------------------------------------------------------------
    // Advancing time
    // ------------------------------------------------------------------

    /// The cross-shard CPU-contention snapshot for the next window: per
    /// node, per-tenant bookings summed in ascending-tenant order — the
    /// serial executor's exact float sequence.  Each tenant's term comes
    /// from its owner shard's published row when the stamp is fresh
    /// (published at the end of the shard's own tick task, in parallel)
    /// and from a direct live read otherwise — identical values, so the
    /// fold is bit-identical either way.
    fn gather_frozen(&self) -> Arc<[f64]> {
        let n_nodes = self.cluster.nodes.len();
        let nt = self.tenancy.n_tenants();
        let k = self.shards.len();
        let fresh: Vec<bool> = self
            .shards
            .iter()
            .zip(&self.published)
            .map(|(sh, pb)| pb.cpu_at == Some(sh.now().to_bits()))
            .collect();
        let mut frozen = vec![0.0; n_nodes];
        for (node, f) in frozen.iter_mut().enumerate() {
            let mut acc = 0.0;
            for t in 0..nt {
                let s = self.tenant_shard[t];
                acc += if fresh[s] {
                    // Owned tenants are `s, s+K, s+2K, …`, so row `t / K`.
                    self.published[s].cpu_rows[(t / k) * n_nodes + node]
                } else {
                    self.shards[s].node_cpu_booked(node, t)
                };
            }
            *f = acc;
        }
        frozen.into()
    }

    /// Advance every shard to `t_end` — shard-tick tasks on the
    /// persistent work-stealing pool for K > 1 and W > 1, or the
    /// sequential loop (both drivers run [`tick_shard`], so every (K, W)
    /// executes the same per-shard code).
    ///
    /// Before the window starts, the cross-shard CPU-contention snapshot
    /// from [`gather_frozen`](Self::gather_frozen) is installed in every
    /// shard (one `Arc` shared by all K — no per-shard copies).  That is
    /// the only cross-shard communication; the window end is the
    /// conservative horizon, degenerate because tenants exchange no
    /// messages.
    pub fn run_until(&mut self, t_end: f64) {
        let frozen = self.gather_frozen();
        for sh in &mut self.shards {
            sh.set_frozen_cpu(Arc::clone(&frozen));
        }
        let k = self.shards.len();
        let w = self.workers_effective();
        if k == 1 || !self.threaded || w <= 1 {
            for (sh, pb) in self.shards.iter_mut().zip(self.published.iter_mut()) {
                tick_shard(sh, pb, t_end);
            }
        } else {
            if self.pool.as_ref().map(|p| p.workers()) != Some(w) {
                self.pool = Some(ShardPool::new(w));
            }
            let pool = self.pool.as_ref().expect("pool built above");
            let mut tasks: Vec<(&mut PipelineSim, &mut ShardPublish)> =
                self.shards.iter_mut().zip(self.published.iter_mut()).collect();
            pool.run_mut(&mut tasks, |task, _| tick_shard(task.0, task.1, t_end));
        }
    }

    pub fn now(&self) -> f64 {
        self.shards[0].now()
    }

    // ------------------------------------------------------------------
    // Metrics & counters (owner-selection merge)
    // ------------------------------------------------------------------

    /// Flush every shard's metrics window and merge: per-op snapshots are
    /// the owner shard's verbatim (per-instance ids remapped to global),
    /// per-tenant window outputs are the owners' (others are zero).
    ///
    /// When a shard's published [`PipelineSim::window_metrics`] snapshot
    /// is still fresh (stamped at the end of its own tick task, nothing
    /// mutated since), the snapshot is consumed and only the cheap
    /// [`PipelineSim::close_window`] reset runs here; otherwise the full
    /// recompute-and-reset flush runs.  Identical values either way.
    pub fn flush_metrics(&mut self) -> (Vec<OpMetrics>, Vec<u64>) {
        let per_shard: Vec<(Vec<OpMetrics>, Vec<u64>)> = self
            .shards
            .iter_mut()
            .zip(self.published.iter_mut())
            .map(|(sh, pb)| {
                let fresh = pb.metrics_at == Some(sh.now().to_bits());
                pb.metrics_at = None;
                match pb.metrics.take() {
                    Some(snap) if fresh => {
                        sh.close_window();
                        snap
                    }
                    _ => sh.flush_metrics(),
                }
            })
            .collect();
        let mut outs = vec![0u64; self.tenancy.n_tenants()];
        for (_, w) in &per_shard {
            for (t, &v) in w.iter().enumerate() {
                outs[t] += v;
            }
        }
        let mut metrics = Vec::with_capacity(self.spec.n_ops());
        for op in 0..self.spec.n_ops() {
            let s = self.owner_of_op(op);
            let mut m = per_shard[s].0[op].clone();
            for pi in &mut m.per_instance {
                pi.inst = self.local2global[s][pi.inst];
            }
            metrics.push(m);
        }
        (metrics, outs)
    }

    pub fn avg_throughput(&self) -> f64 {
        if self.now() <= 0.0 {
            return 0.0;
        }
        (0..self.tenancy.n_tenants()).map(|t| self.tenant_throughput(t)).sum()
    }

    pub fn tenant_throughput(&self, t: usize) -> f64 {
        self.shards[self.tenant_shard[t]].tenant_throughput(t)
    }

    pub fn out_records(&self) -> u64 {
        self.shards.iter().map(|sh| sh.out_records).sum()
    }

    pub fn out_records_t(&self, t: usize) -> u64 {
        self.shards[self.tenant_shard[t]].out_records_t[t]
    }

    pub fn items_emitted(&self) -> u64 {
        self.shards.iter().map(|sh| sh.items_emitted).sum()
    }

    pub fn items_emitted_t(&self, t: usize) -> u64 {
        self.shards[self.tenant_shard[t]].items_emitted_t[t]
    }

    pub fn lost_items_t(&self, t: usize) -> u64 {
        self.shards[self.tenant_shard[t]].lost_items_t[t]
    }

    pub fn lost_records_total(&self) -> u64 {
        self.shards.iter().map(|sh| sh.lost_records_total()).sum()
    }

    /// Sum of per-op OOM events (ascending op, owner shard's counter —
    /// the serial executor's exact iteration).
    pub fn oom_events_total(&self) -> u32 {
        (0..self.spec.n_ops())
            .map(|op| self.shards[self.owner_of_op(op)].oom_events_total[op])
            .sum()
    }

    /// Sum of per-op OOM downtime (same ascending-op float sequence as
    /// the serial executor's `iter().sum()`).
    pub fn oom_downtime_s_total(&self) -> f64 {
        (0..self.spec.n_ops())
            .map(|op| self.shards[self.owner_of_op(op)].oom_downtime_s[op])
            .sum()
    }

    /// Charge a probe-OOM to `op`'s ledger (the coordinator's ingest path
    /// mutated the serial executor's counters directly).
    pub fn note_oom(&mut self, op: usize, downtime_s: f64) {
        self.invalidate_published();
        let s = self.owner_of_op(op);
        self.shards[s].oom_events_total[op] += 1;
        self.shards[s].oom_downtime_s[op] += downtime_s;
    }

    /// Total events processed across all shards.  The shards' event sets
    /// partition the serial executor's, so this equals the serial count
    /// exactly at any K — the CI drift check.
    pub fn events_processed(&self) -> u64 {
        self.shards.iter().map(|sh| sh.engine.events_processed).sum()
    }

    /// Lifetime records processed by `op` (owner shard's ledger).
    pub fn processed_total(&self, op: usize) -> u64 {
        self.shards[self.owner_of_op(op)].processed_total[op]
    }

    /// Lifetime records dispatched onto `edge` (its source op's owner).
    pub fn edge_emitted(&self, edge: usize) -> u64 {
        let s = self.owner_of_op(self.spec.edges[edge].0);
        self.shards[s].edge_emitted[edge]
    }

    /// Buffered join-state per node, MB, summed across shards (each
    /// shard's buffers hold only its own tenants' partial groups).
    pub fn join_state_mb(&self) -> Vec<f64> {
        let mut mb = vec![0.0; self.cluster.nodes.len()];
        for sh in &self.shards {
            for (node, v) in sh.join_state_mb().into_iter().enumerate() {
                mb[node] += v;
            }
        }
        mb
    }

    pub fn true_unit_rate(&self, op: usize, theta: &[f64]) -> f64 {
        self.shards[self.owner_of_op(op)].true_unit_rate(op, theta)
    }

    pub fn mean_attrs(&self, op: usize) -> Option<ItemAttrs> {
        self.shards[self.owner_of_op(op)].mean_attrs(op)
    }

    /// Sum of per-shard event-heap high-water marks (aggregate storage
    /// footprint; per-shard peaks need not be simultaneous).
    pub fn peak_heap_entries(&self) -> usize {
        self.shards.iter().map(|sh| sh.peak_heap_entries()).sum()
    }

    /// Sum of per-shard in-flight-transfer high-water marks (same
    /// aggregate-footprint caveat as [`peak_heap_entries`](Self::peak_heap_entries)).
    pub fn peak_in_flight_transfers(&self) -> usize {
        self.shards.iter().map(|sh| sh.peak_in_flight_transfers()).sum()
    }

    pub fn set_seed_event_stream(&mut self, on: bool) {
        self.invalidate_published();
        for sh in &mut self.shards {
            sh.set_seed_event_stream(on);
        }
    }

    // ------------------------------------------------------------------
    // Cluster dynamics (broadcast; shards keep consistent availability)
    // ------------------------------------------------------------------

    pub fn nodes_up(&self) -> &[bool] {
        self.shards[0].nodes_up()
    }

    pub fn tenants_active(&self) -> &[bool] {
        self.shards[0].tenants_active()
    }

    /// Crash a node in every shard (each kills its own instances there);
    /// returns the total records dropped, summed across shards.
    pub fn fail_node(&mut self, node: usize, requeue: bool) -> u64 {
        self.invalidate_published();
        self.shards.iter_mut().map(|sh| sh.fail_node(node, requeue)).sum()
    }

    pub fn set_node_up(&mut self, node: usize) {
        self.invalidate_published();
        for sh in &mut self.shards {
            sh.set_node_up(node);
        }
    }

    pub fn set_bandwidth_factor(&mut self, node: usize, factor: f64) {
        self.invalidate_published();
        for sh in &mut self.shards {
            sh.set_bandwidth_factor(node, factor);
        }
    }

    /// Splice a tenant in or out; broadcast so every shard's activity map
    /// stays consistent (only the owner re-arms a source — non-owners are
    /// born `source_done` and their guard makes this a no-op).
    pub fn set_tenant_active(&mut self, t: usize, active: bool) {
        self.invalidate_published();
        for sh in &mut self.shards {
            sh.set_tenant_active(t, active);
        }
    }

    /// Ops with any non-stopped instance on `node`, across all shards
    /// (ascending, like the serial scan; per-op instance sets are
    /// disjoint across shards so a plain merge is exact).
    pub fn ops_on_node(&self, node: usize) -> Vec<usize> {
        let mut seen = vec![false; self.spec.n_ops()];
        for sh in &self.shards {
            for op in sh.ops_on_node(node) {
                seen[op] = true;
            }
        }
        (0..self.spec.n_ops()).filter(|&i| seen[i]).collect()
    }

    pub fn drained(&self) -> bool {
        self.shards.iter().all(|sh| sh.drained())
    }

    pub fn tenant_drained(&self, t: usize) -> bool {
        self.shards[self.tenant_shard[t]].tenant_drained(t)
    }
}
