//! Persistent std-only work-stealing worker pool for the sharded sim
//! tick ([`crate::sim::ShardedSim`]).
//!
//! PR 7 spawned one `std::thread::scope` worker per shard per window,
//! which (a) cannot run K ≫ cores rungs without K OS threads fighting
//! the scheduler, and (b) pays thread spawn/join on every window of the
//! coordinator's drive loop.  This pool keeps W long-lived workers
//! alive across windows; each `run` epoch deals task indices round-robin
//! into per-worker deques, workers pop their own deque from the front
//! and steal from other deques' backs when they run dry.
//!
//! Stealing order is pure load balancing and can never leak into
//! results: a task here is "advance one shard's event loop", shards
//! share no mutable state during a window, and every index runs exactly
//! once per epoch — *which worker* runs it is unobservable to the sim.
//! The only protocol state is a mutex + two condvars; there are no
//! atomics-based fast paths to get subtly wrong, and at K shard-ticks
//! per window the lock traffic is noise next to the ticks themselves.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased pointer to the epoch's task closure.  Only dereferenced
/// by workers for task indices counted in `remaining`, and [`ShardPool::run`]
/// does not return until `remaining` hits zero — so the pointee (a
/// closure on `run`'s caller frame) strictly outlives every use.
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointer crosses threads inside the mutex; the pointee is
// `Sync` (bound on `run`) and kept alive by the epoch protocol above.
unsafe impl Send for Job {}

struct State {
    /// Current epoch's closure; `None` between epochs.
    job: Option<Job>,
    /// Per-worker task deques: owner pops the front, thieves pop the back.
    deques: Vec<VecDeque<usize>>,
    /// Tasks of the current epoch not yet *finished* (not merely popped).
    remaining: usize,
    /// Lifetime count of tasks served from another worker's deque.
    steals: u64,
    /// Lifetime tasks finished per worker (telemetry only).
    tasks: Vec<u64>,
    /// Lifetime `run` epochs posted (telemetry only).
    epochs: u64,
    /// Lifetime nanoseconds `run` spent blocked on epoch drains
    /// (wall-clock telemetry — never read on the deterministic sim lane).
    wait_ns: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Signals workers: a new epoch was posted (or shutdown).
    work: Condvar,
    /// Signals `run`: the epoch's last task finished.
    done: Condvar,
}

/// Snapshot of the pool's lifetime counters (flight recorder wall lane
/// and the RunReport pool section).  `wait_ms` is host wall clock;
/// `tasks`/`steals`/`epochs` depend on OS scheduling — none of it ever
/// feeds back into sim results.
#[derive(Debug, Clone, Default)]
pub struct PoolTelemetry {
    pub workers: usize,
    pub steals: u64,
    pub epochs: u64,
    pub wait_ms: f64,
    /// Lifetime tasks finished per worker.
    pub tasks: Vec<u64>,
}

/// Fixed-size persistent worker pool executing index-addressed task
/// batches (`f(0..n)`) with work stealing.  Dropping the pool shuts the
/// workers down and joins them.
pub struct ShardPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `workers` (min 1) long-lived worker threads.
    pub fn new(workers: usize) -> Self {
        let w = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                deques: vec![VecDeque::new(); w],
                remaining: 0,
                steals: 0,
                tasks: vec![0; w],
                epochs: 0,
                wait_ns: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..w)
            .map(|me| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("trident-shard-{me}"))
                    .spawn(move || Self::worker(sh, me))
                    .expect("spawn shard-pool worker")
            })
            .collect();
        ShardPool { shared, handles }
    }

    /// Worker thread count (fixed at construction).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Lifetime count of tasks served from another worker's deque
    /// (telemetry only — stealing order never affects results).
    pub fn steals(&self) -> u64 {
        self.shared.state.lock().expect("pool lock").steals
    }

    /// One-lock snapshot of every lifetime counter.
    pub fn telemetry(&self) -> PoolTelemetry {
        let st = self.shared.state.lock().expect("pool lock");
        PoolTelemetry {
            workers: st.deques.len(),
            steals: st.steals,
            epochs: st.epochs,
            wait_ms: st.wait_ns as f64 / 1e6,
            tasks: st.tasks.clone(),
        }
    }

    /// Next task for worker `me`: own deque front first, then other
    /// deques back-first (classic stealing order: thieves take the work
    /// the owner would reach last).
    fn take(deques: &mut [VecDeque<usize>], me: usize) -> Option<(usize, bool)> {
        if let Some(t) = deques[me].pop_front() {
            return Some((t, false));
        }
        let w = deques.len();
        for off in 1..w {
            if let Some(t) = deques[(me + off) % w].pop_back() {
                return Some((t, true));
            }
        }
        None
    }

    fn worker(shared: Arc<Shared>, me: usize) {
        let mut st = shared.state.lock().expect("pool lock");
        loop {
            if st.shutdown {
                return;
            }
            if st.job.is_some() {
                if let Some((task, stolen)) = Self::take(&mut st.deques, me) {
                    if stolen {
                        st.steals += 1;
                    }
                    let f = st.job.as_ref().expect("job present while tasks remain").0;
                    drop(st);
                    // SAFETY: `task` is counted in `remaining`, and `run`
                    // keeps the closure alive until `remaining` is zero.
                    unsafe { (*f)(task) };
                    st = shared.state.lock().expect("pool lock");
                    st.remaining -= 1;
                    st.tasks[me] += 1;
                    if st.remaining == 0 {
                        shared.done.notify_all();
                    }
                    continue;
                }
            }
            st = shared.work.wait(st).expect("pool lock");
        }
    }

    /// Run `f(i)` for every `i in 0..n`, each exactly once, across the
    /// pool; blocks until all have finished.  Panics in `f` poison the
    /// pool and propagate to the caller (matching the scoped-thread
    /// behavior this pool replaces).
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        let obj: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime erasure only — this function does not return
        // until every task has finished, so workers never dereference the
        // pointer after `f` (still on this frame) is dropped.
        let obj: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(obj)
        };
        let mut st = self.shared.state.lock().expect("pool lock");
        debug_assert!(st.job.is_none() && st.remaining == 0, "epochs never overlap");
        let w = st.deques.len();
        for (i, dq) in st.deques.iter_mut().enumerate() {
            dq.clear();
            let mut t = i;
            while t < n {
                dq.push_back(t);
                t += w;
            }
        }
        st.remaining = n;
        st.job = Some(Job(obj as *const _));
        st.epochs += 1;
        self.shared.work.notify_all();
        let t0 = std::time::Instant::now();
        while st.remaining > 0 {
            st = self.shared.done.wait(st).expect("pool lock");
        }
        st.wait_ns += t0.elapsed().as_nanos() as u64;
        st.job = None;
    }

    /// Run `f(&mut items[i], i)` for every element across the pool.  The
    /// `&mut` borrows are disjoint because `run` hands each index to
    /// exactly one worker per epoch.
    pub fn run_mut<T: Send, F: Fn(&mut T, usize) + Sync>(&self, items: &mut [T], f: F) {
        struct Base<T>(*mut T);
        // SAFETY: shared across workers, but each index is dereferenced
        // by exactly one worker per epoch (disjoint `&mut`); T: Send
        // lets that exclusive access hop threads.
        unsafe impl<T: Send> Sync for Base<T> {}
        let n = items.len();
        let base = Base(items.as_mut_ptr());
        self.run(n, move |i| {
            // SAFETY: i < n, and no other task aliases index i.
            let item = unsafe { &mut *base.0.add(i) };
            f(item, i);
        });
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        if let Ok(mut st) = self.shared.state.lock() {
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_every_index_exactly_once() {
        let pool = ShardPool::new(3);
        let mut hits = vec![0u32; 17];
        pool.run_mut(&mut hits, |h, i| *h += i as u32 + 1);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(*h, i as u32 + 1, "task {i} must run exactly once");
        }
    }

    #[test]
    fn pool_is_reusable_across_epochs() {
        let pool = ShardPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(8, |i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 50 * 36);
    }

    /// Task 0 blocks its worker until the other three tasks finish, so
    /// whichever worker holds it, the *other* worker must cross a deque
    /// boundary to drain the epoch — a steal is guaranteed, not timing-
    /// dependent.
    #[test]
    fn idle_workers_steal_from_a_busy_owner() {
        let pool = ShardPool::new(2);
        let done = AtomicUsize::new(0);
        pool.run(4, |i| {
            if i == 0 {
                while done.load(Ordering::SeqCst) < 3 {
                    std::thread::yield_now();
                }
            }
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 4);
        assert!(pool.steals() >= 1, "draining around the blocked task requires stealing");
    }

    #[test]
    fn telemetry_counts_epochs_and_tasks() {
        let pool = ShardPool::new(2);
        for _ in 0..10 {
            pool.run(8, |_| {});
        }
        let t = pool.telemetry();
        assert_eq!(t.workers, 2);
        assert_eq!(t.epochs, 10);
        assert_eq!(t.tasks.iter().sum::<u64>(), 80, "every finished task is attributed");
        assert_eq!(t.steals, pool.steals());
    }

    #[test]
    fn zero_tasks_is_a_no_op() {
        let pool = ShardPool::new(4);
        pool.run(0, |_| panic!("no tasks were posted"));
        assert_eq!(pool.steals(), 0);
    }

    #[test]
    fn single_worker_pool_drains_serially() {
        let pool = ShardPool::new(1);
        let mut v = vec![0usize; 5];
        pool.run_mut(&mut v, |slot, i| *slot = i * i);
        assert_eq!(v, vec![0, 1, 4, 9, 16]);
        assert_eq!(pool.steals(), 0, "one worker has nobody to steal from");
    }

    #[test]
    fn more_tasks_than_workers_all_complete() {
        let pool = ShardPool::new(2);
        let mut v = vec![0u8; 100];
        pool.run_mut(&mut v, |slot, _| *slot = 1);
        assert!(v.iter().all(|&b| b == 1), "oversubscribed epoch must drain fully");
    }
}
