//! Data items flowing through the pipeline.

use crate::config::FeatureExtractor;

/// Modality-agnostic per-item characteristics set by the workload generator
/// (and scaled when an operator splits an item into children).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ItemAttrs {
    /// Prefill token count at LLM-backed operators.
    pub tokens_in: f64,
    /// Decode token count at LLM-backed operators.
    pub tokens_out: f64,
    /// Megapixels per frame at vision operators.
    pub pixels_m: f64,
    /// Frame count at video operators (1 for stills/documents).
    pub frames: f64,
}

impl ItemAttrs {
    /// Join-merge of two branch records' attrs: token loads accumulate
    /// across branches, spatial extents take the maximum (both branches
    /// observed the same underlying asset).  The single definition point
    /// for join semantics — the executor's group merge and the
    /// coordinator's nominal-attrs propagation must agree.
    pub fn merge(&self, other: &ItemAttrs) -> ItemAttrs {
        ItemAttrs {
            tokens_in: self.tokens_in + other.tokens_in,
            tokens_out: self.tokens_out + other.tokens_out,
            pixels_m: self.pixels_m.max(other.pixels_m),
            frames: self.frames.max(other.frames),
        }
    }

    /// Generic scalar cost used by CPU-stage service models.
    pub fn cost(&self, w: &crate::config::CostW) -> f64 {
        (w.tokens_in * self.tokens_in
            + w.tokens_out * self.tokens_out
            + w.pixels_m * self.pixels_m
            + w.frames * self.frames
            + w.konst)
            .max(1e-9)
    }

    /// Regime/workload feature vector for the adaptation layer (§5.2
    /// uses low-dimensional per-request features).  Log-scaled: request
    /// sizes are lognormal, so log features make regimes compact,
    /// near-isotropic blobs (linear scaling fragments them into
    /// micro-clusters under the τ_d threshold rule).
    pub fn cluster_features(&self, ex: FeatureExtractor) -> [f64; 2] {
        let lg = |v: f64, base: f64| (v.max(1e-3) / base).log2() / 4.0;
        match ex {
            FeatureExtractor::LlmTokens => [lg(self.tokens_in, 64.0), lg(self.tokens_out, 16.0)],
            FeatureExtractor::Vision => [lg(self.pixels_m, 0.125), lg(self.frames, 16.0)],
            FeatureExtractor::Cost => [
                lg(self.tokens_in + self.tokens_out, 64.0),
                lg(self.pixels_m + self.frames, 1.0),
            ],
        }
    }
}

/// One record in flight.
///
/// Kept small and `Copy`: in-flight transfers park the payload in the
/// [`TransferNet`](crate::sim::net::TransferNet) slab and move only POD
/// slot ids through the event machinery, so `Item`'s footprint bounds
/// the slab's bytes-per-record.
#[derive(Debug, Clone, Copy)]
pub struct Item {
    /// Lineage id assigned by the simulator.  Fork edges replicate an item
    /// with its id intact, and single-output operators preserve it, so a
    /// join can align partial results from sibling branches.  Operators
    /// that split an item into several children give each child a fresh
    /// id (the children are new lineage roots).
    pub id: u64,
    pub attrs: ItemAttrs,
    /// Serialized size of this record, MB (drives network cost).
    pub size_mb: f64,
    /// Ground-truth workload regime tag (clustering accuracy only —
    /// invisible to the scheduler).
    pub regime: u8,
}

// Transfer-slab density guard: a record must stay within one cache line.
const _: () = assert!(std::mem::size_of::<Item>() <= 64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostW;

    #[test]
    fn cost_is_positive_and_linear() {
        let a = ItemAttrs { tokens_in: 100.0, tokens_out: 10.0, pixels_m: 2.0, frames: 1.0 };
        let w = CostW { tokens_in: 1.0, tokens_out: 2.0, pixels_m: 10.0, frames: 0.0, konst: 5.0 };
        assert_eq!(a.cost(&w), 100.0 + 20.0 + 20.0 + 5.0);
        let zero = ItemAttrs { tokens_in: 0.0, tokens_out: 0.0, pixels_m: 0.0, frames: 0.0 };
        assert!(zero.cost(&CostW::default()) > 0.0); // clamped
    }

    #[test]
    fn cluster_features_separate_regimes() {
        let short = ItemAttrs { tokens_in: 256.0, tokens_out: 64.0, pixels_m: 0.5, frames: 1.0 };
        let long = ItemAttrs { tokens_in: 4096.0, tokens_out: 512.0, pixels_m: 8.0, frames: 1.0 };
        let fs = short.cluster_features(FeatureExtractor::LlmTokens);
        let fl = long.cluster_features(FeatureExtractor::LlmTokens);
        let d2: f64 = fs.iter().zip(&fl).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(d2.sqrt() > 1.0, "regimes must be separable in feature space");
    }
}
