//! Discrete-event cluster/pipeline simulator — the execution substrate
//! standing in for the paper's 8-node Ascend NPU Ray cluster
//! (DESIGN.md §Hardware-Adaptation).

pub mod engine;
pub mod items;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod pool;
pub mod service;
pub mod shard;

pub use engine::{Engine, Ev, InstId};
pub use items::{Item, ItemAttrs};
pub use metrics::{InstanceMetrics, OpMetrics};
pub use pipeline::{InstState, PipelineSim, SimError};
pub use pool::{PoolTelemetry, ShardPool};
pub use shard::ShardedSim;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::workload::{ItemDist, UniformTrace};

    fn small_cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 64.0, 256.0, 4, 65536.0, 1250.0)
    }

    fn llm_dist() -> ItemDist {
        ItemDist {
            tokens_in: (512f64.ln(), 0.3),
            tokens_out: (64f64.ln(), 0.3),
            pixels_m: (0.0, 0.1),
            frames: (0.0, 0.0),
            size_mb: (0.1f64.ln(), 0.2),
        }
    }

    /// 2-op pipeline: CPU parse -> LLM infer.
    fn two_op_pipeline() -> crate::config::PipelineSpec {
        let mut ops = crate::workload::pdf::pipeline().operators;
        ops.truncate(2);
        // op0: fast cpu; op1: borrow an OCR op spec
        ops[1] = crate::workload::pdf::pipeline().operators[9].clone();
        crate::config::PipelineSpec::chain("mini", ops)
    }

    #[test]
    fn end_to_end_records_flow() {
        let spec = two_op_pipeline();
        let trace = UniformTrace { dist: llm_dist(), regime: 0 };
        let mut sim = PipelineSim::new(spec, small_cluster(), Box::new(trace), 1);
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 0, theta).unwrap();
        sim.run_until(120.0);
        let (ms, out) = sim.flush_metrics();
        let out: u64 = out.iter().sum();
        assert!(out > 50, "pipeline must produce output, got {out}");
        assert!(ms[0].records_out > 0 && ms[1].records_out > 0);
        assert!(ms[1].utilization > 0.3, "LLM op should be busy: {}", ms[1].utilization);
        assert!(ms[1].feat_mean[0] > 300.0, "workload descriptor populated");
    }

    #[test]
    fn accel_capacity_limits_scaling() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            2,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        for _ in 0..4 {
            sim.add_instance(1, 0, theta.clone()).unwrap();
        }
        // node 0 has 4 accelerators -> the fifth must fail
        assert!(sim.add_instance(1, 0, theta.clone()).is_err());
        assert!(sim.add_instance(1, 1, theta).is_ok());
    }

    #[test]
    fn more_instances_more_throughput() {
        let run = |n_llm: usize| {
            let spec = two_op_pipeline();
            let mut sim = PipelineSim::new(
                spec,
                small_cluster(),
                Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
                3,
            );
            let theta = sim.spec.operators[1].config_space.default_config();
            for _ in 0..2 {
                sim.add_instance(0, 0, vec![]).unwrap();
            }
            for i in 0..n_llm {
                sim.add_instance(1, i % 2, theta.clone()).unwrap();
            }
            sim.run_until(200.0);
            sim.out_records
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(
            t4 as f64 > 2.0 * t1 as f64,
            "4 LLM instances should far outpace 1: {t1} vs {t4}"
        );
    }

    #[test]
    fn oom_restarts_on_oversized_config() {
        let spec = two_op_pipeline();
        // Long inputs + max batch + big token budget -> guaranteed OOM.
        let dist = ItemDist {
            tokens_in: (6000f64.ln(), 0.2),
            tokens_out: (512f64.ln(), 0.2),
            pixels_m: (0.0, 0.1),
            frames: (0.0, 0.0),
            size_mb: (0.1f64.ln(), 0.2),
        };
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist, regime: 0 }),
            4,
        );
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 0, vec![128.0, 16384.0, 32.0, 0.0, 0.0, 0.0]).unwrap();
        sim.run_until(300.0);
        assert!(sim.oom_events_total[1] > 0, "oversized config must OOM");
        assert!(sim.oom_downtime_s[1] > 0.0);
        // and the pipeline still makes progress thanks to conservative
        // post-OOM batches:
        assert!(sim.out_records > 0);
    }

    #[test]
    fn draining_stop_preserves_work() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            5,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        let a = sim.add_instance(1, 0, theta.clone()).unwrap();
        let b = sim.add_instance(1, 1, theta).unwrap();
        sim.run_until(60.0);
        sim.stop_instance(b);
        sim.run_until(180.0);
        assert_eq!(sim.instances[b].state, InstState::Stopped);
        // work continues on the remaining instance
        let before = sim.out_records;
        sim.run_until(260.0);
        assert!(sim.out_records > before);
        assert_ne!(sim.instances[a].state, InstState::Stopped);
    }

    #[test]
    fn config_restart_bumps_generation_and_pauses() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            6,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        let id = sim.add_instance(1, 0, theta).unwrap();
        sim.run_until(60.0);
        assert_eq!(sim.instances[id].config_gen, 0);
        sim.restart_with_config(id, vec![32.0, 4096.0, 16.0, 0.0, 1.0, 1.0]);
        sim.run_until(120.0);
        assert_eq!(sim.instances[id].config_gen, 1);
        assert_eq!(sim.instances[id].theta[0], 32.0);
        assert_eq!(sim.instances[id].state, InstState::Running);
    }

    #[test]
    fn backpressure_bounds_queues() {
        // Slow downstream -> upstream queues must stay bounded by caps.
        let spec = two_op_pipeline();
        let cap0 = spec.operators[0].queue_cap;
        let cap1 = spec.operators[1].queue_cap;
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            7,
        );
        // tiny batch -> slow LLM
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 0, vec![1.0, 512.0, 16.0, 0.0, 0.0, 0.0]).unwrap();
        for _ in 0..6 {
            sim.run_until(sim.now() + 50.0);
            for inst in &sim.instances {
                let cap = if inst.op == 0 { cap0 } else { cap1 };
                assert!(
                    inst.queue.len() + inst.reserved <= cap + 1,
                    "queue overflow: op{} len {}",
                    inst.op,
                    inst.queue.len()
                );
            }
        }
    }

    #[test]
    fn cross_node_transfer_uses_link() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            8,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 1, theta).unwrap(); // downstream on the other node
        sim.run_until(100.0);
        let egress = sim.egress_window_mb();
        assert!(egress[0] > 0.0, "cross-node placement must generate egress");
        assert!(sim.out_records > 0);
    }

    /// Two producer instances sharing one node's egress link must
    /// serialize FIFO behind it (`NodeState::link_free`), so the pair
    /// moves no more bytes per steady-state window than the link rate
    /// admits — while the same pair split across two nodes (independent
    /// links) moves ~2x.  The window egress accounting must match the
    /// link's capacity once acceptance is arrival-clocked (each delivery
    /// frees one destination reservation).
    #[test]
    fn shared_egress_link_serializes_fifo() {
        let egress = 1.0; // MB/s — the link is the bottleneck by design
        let window = 200.0;
        let run = |split_producers: bool| {
            let spec = two_op_pipeline();
            let cluster = ClusterSpec::homogeneous(3, 64.0, 256.0, 4, 65536.0, egress);
            let mut sim = PipelineSim::new(
                spec,
                cluster,
                Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
                11,
            );
            let theta = sim.spec.operators[1].config_space.default_config();
            sim.add_instance(0, 0, vec![]).unwrap();
            sim.add_instance(0, if split_producers { 1 } else { 0 }, vec![]).unwrap();
            // Consumers only on node 2: every record crosses a link.
            sim.add_instance(1, 2, theta.clone()).unwrap();
            sim.add_instance(1, 2, theta).unwrap();
            // Warm up until destination reservations are full, then
            // measure one steady-state window.
            sim.run_until(100.0);
            sim.flush_metrics();
            let before = sim.out_records;
            sim.run_until(100.0 + window);
            (sim.out_records - before, sim.egress_window_mb())
        };
        let (out_shared, eg_shared) = run(false);
        let (out_split, eg_split) = run(true);
        assert!(out_shared > 0, "link-bound pipeline still flows");
        // FIFO sharing: one link cannot move the records of two.
        assert!(
            out_split as f64 >= 1.5 * out_shared as f64,
            "independent links must ~double link-bound throughput: {out_shared} vs {out_split}"
        );
        // Window accounting: in steady state the shared link accepts
        // exactly what it can carry — saturated but capacity-bounded.
        let carried = egress * window;
        assert!(
            eg_shared[0] <= 1.25 * carried,
            "egress accounting exceeds link capacity: {} MB in {window}s",
            eg_shared[0]
        );
        assert!(
            eg_shared[0] >= 0.7 * carried,
            "shared link should be saturated: {} MB in {window}s",
            eg_shared[0]
        );
        // Split case: both nodes' links carry traffic; node 1's link is
        // idle when both producers sit on node 0.
        assert!(eg_shared[1] == 0.0 && eg_split[0] > 0.0 && eg_split[1] > 0.0);
    }

    #[test]
    fn true_rate_oracle_close_to_saturated_observation() {
        // Saturated single-instance run: observed rate ~= oracle rate.
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            9,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        for _ in 0..3 {
            sim.add_instance(0, 0, vec![]).unwrap(); // ample upstream
        }
        sim.add_instance(1, 0, theta.clone()).unwrap();
        sim.run_until(60.0);
        sim.flush_metrics();
        sim.run_until(360.0);
        let (ms, _) = sim.flush_metrics();
        let observed = ms[1].rate_per_inst;
        let oracle = sim.true_unit_rate(1, &theta);
        let ratio = observed / oracle;
        assert!(
            (0.6..=1.4).contains(&ratio),
            "saturated observed {observed} vs oracle {oracle}"
        );
    }

    /// Minimal fork/join diamond driven at the simulator level: a fork
    /// replicates every item onto both branches, the join aligns partials
    /// by item id (merging token loads), bounded join state backpressures
    /// the fast branch instead of deadlocking, and everything drains.
    #[test]
    fn fork_join_diamond_conserves_items() {
        use crate::config::{
            ConfigSpace, CostW, FeatureExtractor, OperatorKind, OperatorSpec, PipelineSpec,
            ServiceModel,
        };
        use crate::workload::{Phase, PhasedTrace};

        let cpu = |name: &str, base_rate: f64, queue_cap: usize| OperatorSpec {
            name: name.into(),
            kind: OperatorKind::CpuSync,
            cpu: 1.0,
            mem_gb: 1.0,
            accels: 0,
            fanout: 1.0,
            out_mb: 0.2,
            start_s: 0.5,
            stop_s: 0.5,
            cold_s: 2.0,
            tunable: false,
            config_space: ConfigSpace::default(),
            service: ServiceModel::Cpu {
                base_rate,
                ref_cost: 1.0,
                cost: CostW { konst: 1.0, ..Default::default() },
            },
            features: FeatureExtractor::Cost,
            child_scale: [1.0; 4],
            queue_cap,
        };
        let spec = PipelineSpec {
            name: "diamond".into(),
            operators: vec![
                cpu("fork", 50.0, 64),
                cpu("fast", 40.0, 8),
                cpu("slow", 4.0, 8), // 10x slower: join groups pile up
                cpu("join", 50.0, 8),
            ],
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        };
        let n_items = 50u64;
        let trace = PhasedTrace::new(vec![Phase {
            regime: 0,
            count: n_items,
            sampler: llm_dist(),
        }]);
        let mut sim = PipelineSim::new(spec, small_cluster(), Box::new(trace), 13);
        for op in 0..4 {
            sim.add_instance(op, 0, vec![]).unwrap();
        }
        let mut join_seen_mb: f64 = 0.0;
        for _ in 0..40 {
            sim.run_until(sim.now() + 10.0);
            join_seen_mb = join_seen_mb.max(sim.join_state_mb()[0]);
            if sim.drained() {
                break;
            }
        }
        assert!(sim.drained(), "fork/join must not deadlock under backpressure");
        assert_eq!(sim.items_emitted, n_items);
        // Conservation: both branch edges carry every forked item, the
        // join consumes one merged record per pair, and its out-count
        // equals the fork's per-branch emission.
        assert_eq!(sim.edge_emitted[0], n_items, "fork replicates onto edge 0");
        assert_eq!(sim.edge_emitted[1], n_items, "fork replicates onto edge 1");
        assert_eq!(sim.edge_emitted[2], sim.edge_emitted[3], "branches conserve");
        assert_eq!(sim.processed_total[3], n_items, "join merges every pair");
        assert_eq!(sim.out_records, n_items, "items out of join == items into fork");
        // The slow branch made the join buffer partials (bounded, and
        // fully consumed by the end).
        assert!(join_seen_mb > 0.0, "join must have buffered partials");
        assert!(sim.join_state_mb()[0].abs() < 1e-9, "join memory fully released");
        // Merge semantics: the join saw summed branch token loads (~2x a
        // single branch's mean tokens_in of ~512).
        let j = sim.mean_attrs(3).unwrap();
        let b = sim.mean_attrs(1).unwrap();
        assert!(
            j.tokens_in > 1.6 * b.tokens_in,
            "merged records accumulate branch tokens: {} vs {}",
            j.tokens_in,
            b.tokens_in
        );
    }

    /// Satellite for the tentpole refactor: a join's parked-group path
    /// composed with a rolling update.  While one branch instance is
    /// mid-rolling-restart, the join's sole instance stops with partials
    /// buffered — the groups must be parked (not dropped), adopted by the
    /// replacement instance, and the DAG must still drain with exact
    /// conservation.
    #[test]
    fn parked_join_groups_survive_branch_rolling_update() {
        use crate::config::{
            ConfigSpace, CostW, FeatureExtractor, OperatorKind, OperatorSpec, PipelineSpec,
            ServiceModel,
        };
        use crate::workload::{Phase, PhasedTrace};

        let cpu = |name: &str, base_rate: f64, queue_cap: usize| OperatorSpec {
            name: name.into(),
            kind: OperatorKind::CpuSync,
            cpu: 1.0,
            mem_gb: 1.0,
            accels: 0,
            fanout: 1.0,
            out_mb: 0.2,
            start_s: 0.5,
            stop_s: 0.5,
            cold_s: 2.0,
            tunable: false,
            config_space: ConfigSpace::default(),
            service: ServiceModel::Cpu {
                base_rate,
                ref_cost: 1.0,
                cost: CostW { konst: 1.0, ..Default::default() },
            },
            features: FeatureExtractor::Cost,
            child_scale: [1.0; 4],
            queue_cap,
        };
        let spec = PipelineSpec {
            name: "diamond".into(),
            operators: vec![
                cpu("fork", 50.0, 64),
                cpu("fast", 40.0, 8),
                cpu("slow", 4.0, 8), // 10x slower: join groups pile up
                cpu("join", 50.0, 8),
            ],
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        };
        let n_items = 60u64;
        let trace = PhasedTrace::new(vec![Phase {
            regime: 0,
            count: n_items,
            sampler: llm_dist(),
        }]);
        let mut sim = PipelineSim::new(spec, small_cluster(), Box::new(trace), 17);
        for op in 0..4 {
            sim.add_instance(op, 0, vec![]).unwrap();
        }
        let fast_inst = 1usize;
        let join_inst = 3usize;
        // Run until the join holds incomplete groups at a moment where its
        // queue/batch are empty (so a stop cannot drop queued records).
        let mut t = 20.0;
        sim.run_until(t);
        while t < 300.0 {
            let j = &sim.instances[join_inst];
            if !j.join_buf.is_empty() && j.queue.is_empty() && j.batch.is_empty() {
                break;
            }
            t += 0.5;
            sim.run_until(t);
        }
        assert!(
            !sim.instances[join_inst].join_buf.is_empty(),
            "test setup: join must hold incomplete groups"
        );
        // One branch instance enters a rolling config restart mid-flight...
        sim.restart_with_config(fast_inst, vec![]);
        // ...and the join's only instance stops while buffering partials:
        // its groups are parked for the operator's next instance.
        sim.stop_instance(join_inst);
        sim.run_until(t + 5.0);
        // The replacement (on the other node) adopts the parked groups.
        sim.add_instance(3, 1, vec![]).unwrap();
        for _ in 0..100 {
            sim.run_until(sim.now() + 10.0);
            if sim.drained() {
                break;
            }
        }
        assert!(sim.drained(), "parked join groups must be adopted, not wedged");
        assert_eq!(sim.instances[fast_inst].config_gen, 1, "branch rolled its config");
        assert_eq!(sim.items_emitted, n_items);
        assert_eq!(sim.processed_total[3], n_items, "join merges every pair exactly once");
        assert_eq!(sim.out_records, n_items);
        for mb in sim.join_state_mb() {
            assert!(mb.abs() < 1e-9, "join memory fully released: {mb} MB");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let spec = two_op_pipeline();
            let mut sim = PipelineSim::new(
                spec,
                small_cluster(),
                Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
                42,
            );
            let theta = sim.spec.operators[1].config_space.default_config();
            sim.add_instance(0, 0, vec![]).unwrap();
            sim.add_instance(1, 0, theta).unwrap();
            sim.run_until(150.0);
            (sim.out_records, sim.items_emitted)
        };
        assert_eq!(run(), run());
    }
}
