//! Discrete-event cluster/pipeline simulator — the execution substrate
//! standing in for the paper's 8-node Ascend NPU Ray cluster
//! (DESIGN.md §Hardware-Adaptation).

pub mod engine;
pub mod items;
pub mod metrics;
pub mod pipeline;
pub mod service;

pub use engine::{Engine, Ev, InstId};
pub use items::{Item, ItemAttrs};
pub use metrics::{InstanceMetrics, OpMetrics};
pub use pipeline::{InstState, PipelineSim};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::workload::{ItemDist, UniformTrace};

    fn small_cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 64.0, 256.0, 4, 65536.0, 1250.0)
    }

    fn llm_dist() -> ItemDist {
        ItemDist {
            tokens_in: (512f64.ln(), 0.3),
            tokens_out: (64f64.ln(), 0.3),
            pixels_m: (0.0, 0.1),
            frames: (0.0, 0.0),
            size_mb: (0.1f64.ln(), 0.2),
        }
    }

    /// 2-op pipeline: CPU parse -> LLM infer.
    fn two_op_pipeline() -> crate::config::PipelineSpec {
        let mut p = crate::workload::pdf::pipeline();
        p.operators.truncate(2);
        // op0: fast cpu; op1: borrow an OCR op spec
        let ocr = crate::workload::pdf::pipeline().operators[9].clone();
        p.operators[1] = ocr;
        p.name = "mini".into();
        p
    }

    #[test]
    fn end_to_end_records_flow() {
        let spec = two_op_pipeline();
        let trace = UniformTrace { dist: llm_dist(), regime: 0 };
        let mut sim = PipelineSim::new(spec, small_cluster(), Box::new(trace), 1);
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 0, theta).unwrap();
        sim.run_until(120.0);
        let (ms, out) = sim.flush_metrics();
        assert!(out > 50, "pipeline must produce output, got {out}");
        assert!(ms[0].records_out > 0 && ms[1].records_out > 0);
        assert!(ms[1].utilization > 0.3, "LLM op should be busy: {}", ms[1].utilization);
        assert!(ms[1].feat_mean[0] > 300.0, "workload descriptor populated");
    }

    #[test]
    fn accel_capacity_limits_scaling() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            2,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        for _ in 0..4 {
            sim.add_instance(1, 0, theta.clone()).unwrap();
        }
        // node 0 has 4 accelerators -> the fifth must fail
        assert!(sim.add_instance(1, 0, theta.clone()).is_err());
        assert!(sim.add_instance(1, 1, theta).is_ok());
    }

    #[test]
    fn more_instances_more_throughput() {
        let run = |n_llm: usize| {
            let spec = two_op_pipeline();
            let mut sim = PipelineSim::new(
                spec,
                small_cluster(),
                Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
                3,
            );
            let theta = sim.spec.operators[1].config_space.default_config();
            for _ in 0..2 {
                sim.add_instance(0, 0, vec![]).unwrap();
            }
            for i in 0..n_llm {
                sim.add_instance(1, i % 2, theta.clone()).unwrap();
            }
            sim.run_until(200.0);
            sim.out_records
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(
            t4 as f64 > 2.0 * t1 as f64,
            "4 LLM instances should far outpace 1: {t1} vs {t4}"
        );
    }

    #[test]
    fn oom_restarts_on_oversized_config() {
        let spec = two_op_pipeline();
        // Long inputs + max batch + big token budget -> guaranteed OOM.
        let dist = ItemDist {
            tokens_in: (6000f64.ln(), 0.2),
            tokens_out: (512f64.ln(), 0.2),
            pixels_m: (0.0, 0.1),
            frames: (0.0, 0.0),
            size_mb: (0.1f64.ln(), 0.2),
        };
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist, regime: 0 }),
            4,
        );
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 0, vec![128.0, 16384.0, 32.0, 0.0, 0.0, 0.0]).unwrap();
        sim.run_until(300.0);
        assert!(sim.oom_events_total[1] > 0, "oversized config must OOM");
        assert!(sim.oom_downtime_s[1] > 0.0);
        // and the pipeline still makes progress thanks to conservative
        // post-OOM batches:
        assert!(sim.out_records > 0);
    }

    #[test]
    fn draining_stop_preserves_work() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            5,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        let a = sim.add_instance(1, 0, theta.clone()).unwrap();
        let b = sim.add_instance(1, 1, theta).unwrap();
        sim.run_until(60.0);
        sim.stop_instance(b);
        sim.run_until(180.0);
        assert_eq!(sim.instances[b].state, InstState::Stopped);
        // work continues on the remaining instance
        let before = sim.out_records;
        sim.run_until(260.0);
        assert!(sim.out_records > before);
        assert_ne!(sim.instances[a].state, InstState::Stopped);
    }

    #[test]
    fn config_restart_bumps_generation_and_pauses() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            6,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        let id = sim.add_instance(1, 0, theta).unwrap();
        sim.run_until(60.0);
        assert_eq!(sim.instances[id].config_gen, 0);
        sim.restart_with_config(id, vec![32.0, 4096.0, 16.0, 0.0, 1.0, 1.0]);
        sim.run_until(120.0);
        assert_eq!(sim.instances[id].config_gen, 1);
        assert_eq!(sim.instances[id].theta[0], 32.0);
        assert_eq!(sim.instances[id].state, InstState::Running);
    }

    #[test]
    fn backpressure_bounds_queues() {
        // Slow downstream -> upstream queues must stay bounded by caps.
        let spec = two_op_pipeline();
        let cap0 = spec.operators[0].queue_cap;
        let cap1 = spec.operators[1].queue_cap;
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            7,
        );
        // tiny batch -> slow LLM
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 0, vec![1.0, 512.0, 16.0, 0.0, 0.0, 0.0]).unwrap();
        for _ in 0..6 {
            sim.run_until(sim.now() + 50.0);
            for inst in &sim.instances {
                let cap = if inst.op == 0 { cap0 } else { cap1 };
                assert!(
                    inst.queue.len() + inst.reserved <= cap + 1,
                    "queue overflow: op{} len {}",
                    inst.op,
                    inst.queue.len()
                );
            }
        }
    }

    #[test]
    fn cross_node_transfer_uses_link() {
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            8,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        sim.add_instance(0, 0, vec![]).unwrap();
        sim.add_instance(1, 1, theta).unwrap(); // downstream on the other node
        sim.run_until(100.0);
        let egress = sim.egress_window_mb();
        assert!(egress[0] > 0.0, "cross-node placement must generate egress");
        assert!(sim.out_records > 0);
    }

    #[test]
    fn true_rate_oracle_close_to_saturated_observation() {
        // Saturated single-instance run: observed rate ~= oracle rate.
        let spec = two_op_pipeline();
        let mut sim = PipelineSim::new(
            spec,
            small_cluster(),
            Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
            9,
        );
        let theta = sim.spec.operators[1].config_space.default_config();
        for _ in 0..3 {
            sim.add_instance(0, 0, vec![]).unwrap(); // ample upstream
        }
        sim.add_instance(1, 0, theta.clone()).unwrap();
        sim.run_until(60.0);
        sim.flush_metrics();
        sim.run_until(360.0);
        let (ms, _) = sim.flush_metrics();
        let observed = ms[1].rate_per_inst;
        let oracle = sim.true_unit_rate(1, &theta);
        let ratio = observed / oracle;
        assert!(
            (0.6..=1.4).contains(&ratio),
            "saturated observed {observed} vs oracle {oracle}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let spec = two_op_pipeline();
            let mut sim = PipelineSim::new(
                spec,
                small_cluster(),
                Box::new(UniformTrace { dist: llm_dist(), regime: 0 }),
                42,
            );
            let theta = sim.spec.operators[1].config_space.default_config();
            sim.add_instance(0, 0, vec![]).unwrap();
            sim.add_instance(1, 0, theta).unwrap();
            sim.run_until(150.0);
            (sim.out_records, sim.items_emitted)
        };
        assert_eq!(run(), run());
    }
}
