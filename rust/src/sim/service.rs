//! Ground-truth service and memory models (sim-only — the scheduler sees
//! only metrics).
//!
//! These functions encode the behaviours the paper's arguments rest on:
//!
//! * **continuous batching**: accelerator throughput saturates with
//!   effective batch size, so records/busy-second under partial batches is
//!   far below capacity — the reason useful-time estimators (DS2) break;
//! * **input dependence**: token/pixel loads drive both service time and
//!   peak memory, so regime shifts move the throughput surface;
//! * **config dependence**: the vLLM-style knobs trade throughput against
//!   peak device memory, making configuration tuning a constrained
//!   optimization with workload-dependent optima.

use crate::config::{ConfigSpace, ServiceModel};
use crate::rngx::Rng;
use crate::sim::items::ItemAttrs;

/// Per-batch fixed overhead, seconds (kernel launch, scheduling).
const BATCH_SETUP_S: f64 = 0.05;

/// Mean attrs of a batch (used by both service time and the capacity
/// oracle).
#[derive(Debug, Clone, Copy)]
pub struct BatchStats {
    pub n: f64,
    pub mean_tokens_in: f64,
    pub mean_tokens_out: f64,
}

impl BatchStats {
    pub fn of(items: &[ItemAttrs]) -> BatchStats {
        let n = items.len().max(1) as f64;
        BatchStats {
            n: items.len() as f64,
            mean_tokens_in: items.iter().map(|a| a.tokens_in).sum::<f64>() / n,
            mean_tokens_out: items.iter().map(|a| a.tokens_out).sum::<f64>() / n,
        }
    }
}

/// Effective decode concurrency given config θ (llm_engine space):
/// continuous batching keeps up to `max_num_seqs` requests in flight; the
/// token budget `max_num_batched_tokens` caps the prefill *chunk* (and so
/// the activation spike), not the concurrency.
pub fn accel_eff_batch(theta: &[f64]) -> usize {
    theta.first().copied().unwrap_or(16.0).max(1.0) as usize
}

/// Multiplicative config gain on token throughput (workload-dependent, so
/// optima move with regimes).
fn config_gain(theta: &[f64], mean_tokens_in: f64, prefix_share: f64) -> f64 {
    let toks = theta.get(1).copied().unwrap_or(2048.0).max(256.0);
    let block = theta.get(2).copied().unwrap_or(16.0).max(1.0);
    let delay = theta.get(3).copied().unwrap_or(0.0);
    let chunked = theta.get(4).copied().unwrap_or(0.0);
    let prefix = theta.get(5).copied().unwrap_or(0.0);
    // Larger prefill chunks amortize scheduling overhead...
    let g_tokens = 1.0 + 0.08 * (toks / 2048.0).log2();
    let g_block = 1.0 + 0.06 * (block / 16.0).log2();
    let g_delay = 1.0 - 0.08 * delay;
    let g_chunked = 1.0 + 0.12 * chunked * (mean_tokens_in / 4096.0).clamp(0.0, 1.5) - 0.03 * chunked;
    let g_prefix = 1.0 + 0.25 * prefix * prefix_share - 0.02 * prefix;
    (g_tokens * g_block * g_delay * g_chunked * g_prefix).max(0.05)
}

/// Accelerator batch service time, seconds.
pub fn accel_batch_time(
    m: &ServiceModel,
    theta: &[f64],
    stats: BatchStats,
    rng: &mut Rng,
) -> f64 {
    let ServiceModel::Accel { peak_tok_rate, batch_half, decode_weight, prefix_share, .. } = m
    else {
        panic!("accel_batch_time on CPU model")
    };
    let sat = stats.n / (stats.n + batch_half);
    let rate = peak_tok_rate * sat * config_gain(theta, stats.mean_tokens_in, *prefix_share);
    let tokens = stats.n * (stats.mean_tokens_in + decode_weight * stats.mean_tokens_out);
    let jitter = rng.lognormal(0.0, 0.05);
    BATCH_SETUP_S + jitter * tokens / rate.max(1e-6)
}

/// Accelerator peak memory for a batch, MB (black-box constraint for BO).
/// `chunked_prefill` lowers the activation spike; `block_size` wastes KV
/// space (≈ block/2 tokens per sequence).
pub fn accel_batch_mem(m: &ServiceModel, theta: &[f64], stats: BatchStats, rng: &mut Rng) -> f64 {
    let ServiceModel::Accel { mem_base_mb, kv_mb_per_token, act_mb_per_token, mem_noise_sigma, .. } =
        m
    else {
        panic!("accel_batch_mem on CPU model")
    };
    let block = theta.get(2).copied().unwrap_or(16.0);
    let chunked = theta.get(4).copied().unwrap_or(0.0);
    let max_toks = theta.get(1).copied().unwrap_or(2048.0);
    // KV cache: every in-flight sequence holds its full context (+ block
    // rounding waste).
    let seq_tokens = stats.mean_tokens_in + stats.mean_tokens_out + block / 2.0;
    let kv = kv_mb_per_token * stats.n * seq_tokens;
    // Activation spike scales with the prefill chunk budget; chunked
    // prefill halves it.
    let act_tokens = max_toks.min(stats.n * stats.mean_tokens_in) * (1.0 - 0.5 * chunked);
    let act = act_mb_per_token * act_tokens;
    (mem_base_mb + kv + act) * rng.lognormal(0.0, *mem_noise_sigma)
}

/// Synchronous CPU per-record service time, seconds (with occasional
/// GC-pause outliers — the sporadic anomalies stage-2 filtering exists for).
pub fn cpu_record_time(m: &ServiceModel, attrs: &ItemAttrs, rng: &mut Rng) -> f64 {
    let ServiceModel::Cpu { base_rate, ref_cost, cost } = m else {
        panic!("cpu_record_time on accel model")
    };
    let t = (attrs.cost(cost) / ref_cost) / base_rate.max(1e-9);
    let jitter = rng.lognormal(0.0, 0.08);
    let gc = if rng.bool(0.004) { rng.uniform(0.3, 1.5) } else { 0.0 };
    t * jitter + gc
}

/// **Capacity oracle**: sustainable records/s of one instance under
/// saturated input with workload `attrs` and config θ.  This is the
/// "profile the operator in isolation at full load" ground truth used by
/// Table 3; it never feeds the scheduler.
pub fn true_unit_rate(m: &ServiceModel, theta: &[f64], mean_attrs: &ItemAttrs) -> f64 {
    match m {
        ServiceModel::Cpu { base_rate, ref_cost, cost } => {
            base_rate * ref_cost / mean_attrs.cost(cost)
        }
        ServiceModel::Accel { peak_tok_rate, batch_half, decode_weight, prefix_share, .. } => {
            let b = accel_eff_batch(theta) as f64;
            let sat = b / (b + batch_half);
            let rate =
                peak_tok_rate * sat * config_gain(theta, mean_attrs.tokens_in, *prefix_share);
            let tokens_per_rec = mean_attrs.tokens_in + decode_weight * mean_attrs.tokens_out;
            let t_batch = BATCH_SETUP_S + b * tokens_per_rec / rate.max(1e-6);
            b / t_batch
        }
    }
}

/// Expected peak memory (noise-free) — used by OOM-oracle comparisons.
pub fn expected_mem(m: &ServiceModel, theta: &[f64], mean_attrs: &ItemAttrs) -> f64 {
    match m {
        ServiceModel::Cpu { .. } => 0.0,
        ServiceModel::Accel { .. } => {
            let b = accel_eff_batch(theta);
            let stats = BatchStats {
                n: b as f64,
                mean_tokens_in: mean_attrs.tokens_in,
                mean_tokens_out: mean_attrs.tokens_out,
            };
            // Noise-free: reuse the formula with sigma 0 via a throwaway rng.
            let mut rng = Rng::new(0);
            let m0 = match m {
                ServiceModel::Accel {
                    peak_tok_rate,
                    batch_half,
                    decode_weight,
                    prefix_share,
                    mem_base_mb,
                    kv_mb_per_token,
                    act_mb_per_token,
                    ..
                } => ServiceModel::Accel {
                    peak_tok_rate: *peak_tok_rate,
                    batch_half: *batch_half,
                    decode_weight: *decode_weight,
                    prefix_share: *prefix_share,
                    mem_base_mb: *mem_base_mb,
                    kv_mb_per_token: *kv_mb_per_token,
                    act_mb_per_token: *act_mb_per_token,
                    mem_noise_sigma: 0.0,
                },
                _ => unreachable!(),
            };
            accel_batch_mem(&m0, theta, stats, &mut rng)
        }
    }
}

/// Default config for an operator (empty for non-tunable).
pub fn default_theta(space: &ConfigSpace) -> Vec<f64> {
    space.default_config()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostW;

    fn accel_model() -> ServiceModel {
        ServiceModel::Accel {
            peak_tok_rate: 8000.0,
            batch_half: 8.0,
            decode_weight: 4.0,
            prefix_share: 0.3,
            mem_base_mb: 16000.0,
            kv_mb_per_token: 0.04,
            act_mb_per_token: 1.5,
            mem_noise_sigma: 0.0,
        }
    }

    fn attrs(tin: f64, tout: f64) -> ItemAttrs {
        ItemAttrs { tokens_in: tin, tokens_out: tout, pixels_m: 0.0, frames: 1.0 }
    }

    #[test]
    fn eff_batch_is_decode_concurrency() {
        assert_eq!(accel_eff_batch(&[64.0, 2048.0]), 64);
        assert_eq!(accel_eff_batch(&[8.0, 65536.0]), 8);
        assert_eq!(accel_eff_batch(&[0.2, 512.0]), 1); // floor at 1
    }

    #[test]
    fn throughput_increases_with_batch_then_saturates() {
        let m = accel_model();
        let a = attrs(512.0, 64.0);
        let r8 = true_unit_rate(&m, &[8.0, 1e9, 16.0, 0.0, 0.0, 0.0], &a);
        let r32 = true_unit_rate(&m, &[32.0, 1e9, 16.0, 0.0, 0.0, 0.0], &a);
        let r128 = true_unit_rate(&m, &[128.0, 1e9, 16.0, 0.0, 0.0, 0.0], &a);
        assert!(r32 > r8 * 1.1, "{r8} {r32}");
        assert!(r128 > r32, "{r32} {r128}");
        assert!(r128 / r32 < r32 / r8, "saturating curve expected");
    }

    #[test]
    fn longer_inputs_mean_lower_record_rate_and_higher_mem() {
        let m = accel_model();
        let theta = [32.0, 8192.0, 16.0, 0.0, 0.0, 0.0];
        let short = attrs(256.0, 64.0);
        let long = attrs(4096.0, 256.0);
        assert!(true_unit_rate(&m, &theta, &short) > 2.0 * true_unit_rate(&m, &theta, &long));
        assert!(expected_mem(&m, &theta, &long) > expected_mem(&m, &theta, &short));
    }

    #[test]
    fn chunked_prefill_helps_long_inputs_only() {
        let m = accel_model();
        let base = [32.0, 8192.0, 16.0, 0.0, 0.0, 0.0];
        let chunked = [32.0, 8192.0, 16.0, 0.0, 1.0, 0.0];
        let long = attrs(4096.0, 256.0);
        let short = attrs(128.0, 64.0);
        assert!(true_unit_rate(&m, &chunked, &long) > true_unit_rate(&m, &base, &long));
        assert!(true_unit_rate(&m, &chunked, &short) < true_unit_rate(&m, &base, &short));
        // and lowers the activation spike:
        assert!(expected_mem(&m, &chunked, &long) < expected_mem(&m, &base, &long));
    }

    #[test]
    fn busy_time_underestimates_capacity_on_partial_batches() {
        // The DS2-breaking property: records/busy-second at batch 1 is far
        // below the saturated rate.
        let m = accel_model();
        let a = attrs(512.0, 64.0);
        let theta = [64.0, 1e9, 16.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(0);
        let t1 = accel_batch_time(&m, &theta, BatchStats { n: 1.0, mean_tokens_in: 512.0, mean_tokens_out: 64.0 }, &mut rng);
        let partial_rate = 1.0 / t1;
        let full_rate = true_unit_rate(&m, &theta, &a);
        assert!(full_rate > 5.0 * partial_rate, "full={full_rate} partial={partial_rate}");
    }

    #[test]
    fn cpu_time_scales_with_cost() {
        let m = ServiceModel::Cpu {
            base_rate: 10.0,
            ref_cost: 100.0,
            cost: CostW { tokens_in: 1.0, ..Default::default() },
        };
        let mut rng = Rng::new(1);
        let mut t_small = 0.0;
        let mut t_big = 0.0;
        for _ in 0..200 {
            t_small += cpu_record_time(&m, &attrs(100.0, 0.0), &mut rng);
            t_big += cpu_record_time(&m, &attrs(400.0, 0.0), &mut rng);
        }
        assert!(t_big > 3.0 * t_small && t_big < 5.0 * t_small, "{t_small} {t_big}");
    }

    #[test]
    fn oom_tradeoff_exists() {
        // There must exist a workload where the biggest batch OOMs a 64 GB
        // device but a moderate one fits — otherwise Table 5/6 is vacuous.
        let m = accel_model();
        let long = attrs(6000.0, 512.0);
        let big = expected_mem(&m, &[128.0, 16384.0, 32.0, 0.0, 0.0, 0.0], &long);
        let small = expected_mem(&m, &[8.0, 2048.0, 16.0, 0.0, 0.0, 0.0], &long);
        assert!(big > 65536.0, "big batch must exceed 64 GB, got {big}");
        assert!(small < 65536.0 - 2048.0, "small batch must fit, got {small}");
    }
}
